"""L1 masked mean-pool + L2-normalise kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import pooling, ref


def make(b, s, d, seed, mask_kind="random"):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(b, s, d).astype(np.float32))
    if mask_kind == "full":
        m = np.ones((b, s), np.float32)
    else:
        m = (rng.rand(b, s) > 0.4).astype(np.float32)
        m[:, 0] = 1.0
    return x, jnp.asarray(m)


@given(
    b=st.integers(1, 8),
    s=st.sampled_from([1, 8, 32, 80]),
    d=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 10_000),
    mask_kind=st.sampled_from(["full", "random"]),
)
def test_pool_hypothesis(b, s, d, seed, mask_kind):
    x, m = make(b, s, d, seed, mask_kind)
    out = pooling.masked_mean_pool(x, m)
    exp = ref.masked_mean_pool_ref(x, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_pool_output_is_unit_norm():
    x, m = make(4, 32, 64, 5)
    out = np.asarray(pooling.masked_mean_pool(x, m))
    np.testing.assert_allclose(np.linalg.norm(out, axis=-1), 1.0, atol=1e-5)


def test_pool_ignores_padded_positions():
    x, m = make(2, 16, 32, 6, "full")
    m2 = np.asarray(m).copy()
    m2[:, 8:] = 0.0
    x2 = np.asarray(x).copy()
    x2[:, 8:, :] = 1e6  # garbage in padding must not leak
    a = pooling.masked_mean_pool(jnp.asarray(x2), jnp.asarray(m2))
    b = pooling.masked_mean_pool(x[:, :8], m[:, :8])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_pool_all_masked_row_is_finite():
    x = jnp.asarray(np.random.RandomState(0).randn(1, 8, 16).astype(np.float32))
    m = jnp.zeros((1, 8), jnp.float32)
    out = np.asarray(pooling.masked_mean_pool(x, m))
    assert np.isfinite(out).all()
