"""wtar tensor-archive round-trip (python writer <-> python reader)."""

import numpy as np
import pytest

from compile import wtar


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.wtar")
    tensors = [
        ("a", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b.scalar", np.asarray([7], dtype=np.int32)),
        ("c/deep/name", np.random.RandomState(0).randn(2, 3, 4).astype(np.float32)),
    ]
    wtar.write(path, tensors)
    out = wtar.read(path)
    assert [n for n, _ in out] == [n for n, _ in tensors]
    for (_, exp), (_, got) in zip(tensors, out):
        np.testing.assert_array_equal(exp, got)
        assert exp.dtype == got.dtype


def test_empty_archive(tmp_path):
    path = str(tmp_path / "e.wtar")
    wtar.write(path, [])
    assert wtar.read(path) == []


def test_order_preserved(tmp_path):
    path = str(tmp_path / "o.wtar")
    names = [f"t{i}" for i in range(20)]
    wtar.write(path, [(n, np.full((2,), i, np.float32)) for i, n in enumerate(names)])
    assert [n for n, _ in wtar.read(path)] == names


def test_bad_magic_rejected(tmp_path):
    path = str(tmp_path / "bad.wtar")
    with open(path, "wb") as f:
        f.write(b"NOTWTAR\x00\x00\x00")
    with pytest.raises(AssertionError):
        wtar.read(path)
