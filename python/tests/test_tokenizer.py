"""Hash tokenizer: determinism, padding, truncation, rust parity anchors."""

import pytest

from compile import tokenizer as tok


def test_fnv1a64_known_vectors():
    # Published FNV-1a 64 test vectors.
    assert tok.fnv1a64(b"") == 0xCBF29CE484222325
    assert tok.fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    assert tok.fnv1a64(b"foobar") == 0x85944171F73967E8


def test_encode_pads_and_masks():
    ids, mask = tok.encode("one two", 1000, 8)
    assert len(ids) == len(mask) == 8
    assert ids[0] == tok.CLS_ID
    assert mask[:3] == [1.0, 1.0, 1.0]
    assert mask[3:] == [0.0] * 5
    assert ids[3:] == [tok.PAD_ID] * 5


def test_encode_truncates():
    text = " ".join(f"w{i}" for i in range(100))
    ids, mask = tok.encode(text, 1000, 16)
    assert len(ids) == 16
    assert all(m == 1.0 for m in mask)


def test_encode_case_insensitive():
    assert tok.encode("Hello WORLD", 500, 8) == tok.encode("hello world", 500, 8)


def test_encode_splits_punctuation():
    a, _ = tok.encode("hello, world!", 500, 8)
    b, _ = tok.encode("hello world", 500, 8)
    assert a == b


def test_ids_in_range():
    ids, _ = tok.encode("alpha beta gamma delta", 64, 8)
    for i in ids:
        assert 0 <= i < 64


def test_empty_text():
    ids, mask = tok.encode("", 100, 4)
    assert ids == [tok.CLS_ID, 0, 0, 0]
    assert mask == [1.0, 0.0, 0.0, 0.0]


def test_deterministic_across_calls():
    for _ in range(3):
        assert tok.encode("stable output", 8192, 12) == tok.encode("stable output", 8192, 12)
