"""L1 fused FFN kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import ffn, ref


def run(rows, d, f, seed=0, **kw):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, d).astype(np.float32))
    w1 = jnp.asarray((rng.randn(d, f) * 0.05).astype(np.float32))
    b1 = jnp.asarray(rng.randn(f).astype(np.float32) * 0.1)
    w2 = jnp.asarray((rng.randn(f, d) * 0.05).astype(np.float32))
    b2 = jnp.asarray(rng.randn(d).astype(np.float32) * 0.1)
    out = ffn.ffn(x, w1, b1, w2, b2, **kw)
    exp = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=3e-5, atol=3e-5)


@given(
    rows=st.integers(1, 48),
    d=st.sampled_from([16, 64, 256]),
    f=st.sampled_from([32, 128, 512]),
    seed=st.integers(0, 10_000),
)
def test_ffn_hypothesis(rows, d, f, seed):
    run(rows, d, f, seed)


@pytest.mark.parametrize("block_rows", [1, 8, 32, 64])
def test_ffn_block_rows(block_rows):
    run(32, 64, 128, block_rows=block_rows)


def test_ffn_3d_input():
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(2, 16, 32).astype(np.float32))
    w1 = jnp.asarray((rng.randn(32, 64) * 0.05).astype(np.float32))
    b1 = jnp.zeros(64); w2 = jnp.asarray((rng.randn(64, 32) * 0.05).astype(np.float32))
    b2 = jnp.zeros(32)
    out = ffn.ffn(x, w1, b1, w2, b2)
    exp = ref.ffn_ref(x, w1, b1, w2, b2)
    assert out.shape == (2, 16, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=3e-5, atol=3e-5)


def test_ffn_zero_input_gives_bias_path():
    d, f = 16, 32
    x = jnp.zeros((4, d), jnp.float32)
    w1 = jnp.ones((d, f), jnp.float32)
    b1 = jnp.zeros(f); w2 = jnp.zeros((f, d)); b2 = jnp.full((d,), 5.0, jnp.float32)
    out = np.asarray(ffn.ffn(x, w1, b1, w2, b2))
    np.testing.assert_allclose(out, 5.0, atol=1e-6)
