"""L1 fused residual+layernorm kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import layernorm, ref


def run(shape, d, seed=0, **kw):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(*shape, d).astype(np.float32))
    r = jnp.asarray(rng.randn(*shape, d).astype(np.float32))
    g = jnp.asarray(rng.randn(d).astype(np.float32))
    b = jnp.asarray(rng.randn(d).astype(np.float32))
    out = layernorm.residual_layernorm(x, r, g, b, **kw)
    exp = ref.residual_layernorm_ref(x, r, g, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


@given(
    rows=st.integers(1, 64),
    d=st.sampled_from([8, 32, 64, 256]),
    seed=st.integers(0, 10_000),
)
def test_ln_rows_hypothesis(rows, d, seed):
    run((rows,), d, seed)


@pytest.mark.parametrize("shape", [(1, 1), (2, 32), (4, 80), (1, 128), (3, 7)])
def test_ln_3d_shapes(shape):
    run(shape, 64)


@pytest.mark.parametrize("block_rows", [1, 4, 32, 128])
def test_ln_block_rows(block_rows):
    run((2, 32), 64, block_rows=block_rows)


def test_ln_zero_residual_is_plain_layernorm():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(8, 32).astype(np.float32))
    g = jnp.ones(32, jnp.float32)
    b = jnp.zeros(32, jnp.float32)
    out = np.asarray(layernorm.residual_layernorm(x, jnp.zeros_like(x), g, b))
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-6)
    np.testing.assert_allclose(out.std(-1), 1.0, atol=1e-3)


def test_ln_constant_row_stays_finite():
    # var == 0 row: eps must keep the output finite.
    x = jnp.full((4, 16), 3.0, jnp.float32)
    g = jnp.ones(16); b = jnp.zeros(16)
    out = np.asarray(layernorm.residual_layernorm(x, jnp.zeros_like(x), g, b))
    assert np.isfinite(out).all()
