"""int8 weight-quantized matmul kernel: quantization error bounds and
kernel-vs-oracle equivalence."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import quant


def make(rows, d_in, d_out, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(rows, d_in).astype(np.float32)
    w = (rng.randn(d_in, d_out) * 0.05).astype(np.float32)
    return x, w


@given(
    rows=st.integers(1, 32),
    d_in=st.sampled_from([8, 32, 64]),
    d_out=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 10_000),
)
def test_qmatmul_matches_ref_hypothesis(rows, d_in, d_out, seed):
    x, w = make(rows, d_in, d_out, seed)
    w_q, scale = quant.quantize_weights(w)
    out = quant.qmatmul(jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale))
    exp = quant.qmatmul_ref(jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_quantization_error_bounded():
    # W8 per-channel: dequantised weights within one quantization step.
    _, w = make(1, 64, 128, 3)
    w_q, scale = quant.quantize_weights(w)
    w_back = w_q.astype(np.float32) * scale[None, :]
    step = scale[None, :]  # one LSB per channel
    assert (np.abs(w - w_back) <= step / 2 + 1e-7).all()


def test_end_to_end_error_small_vs_fp32():
    x, w = make(16, 64, 64, 4)
    w_q, scale = quant.quantize_weights(w)
    exact = x @ w
    approx = np.asarray(
        quant.qmatmul(jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale))
    )
    rel = np.abs(approx - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.02, f"relative error {rel}"


def test_scale_positive_and_int8_range():
    _, w = make(1, 32, 16, 5)
    w_q, scale = quant.quantize_weights(w)
    assert (scale > 0).all()
    assert w_q.dtype == np.int8
    assert w_q.min() >= -127 and w_q.max() <= 127


def test_zero_channel_safe():
    w = np.zeros((8, 4), np.float32)
    w_q, scale = quant.quantize_weights(w)
    assert np.isfinite(scale).all()
    x = np.ones((2, 8), np.float32)
    out = np.asarray(quant.qmatmul(jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale)))
    np.testing.assert_allclose(out, 0.0, atol=1e-7)


@pytest.mark.parametrize("block_rows", [1, 8, 64])
def test_block_row_invariance(block_rows):
    x, w = make(16, 32, 32, 6)
    w_q, scale = quant.quantize_weights(w)
    out = quant.qmatmul(
        jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale), block_rows=block_rows
    )
    exp = quant.qmatmul_ref(jnp.asarray(x), jnp.asarray(w_q), jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)
