"""AOT export path: HLO text shape, manifest ABI, digest stability."""

import json
import os

import pytest

from compile import aot, model as M


def test_lower_bucket_produces_hlo_text():
    cfg = M.ModelConfig(name="t", vocab_size=64, d_model=16, n_layers=1,
                        n_heads=2, d_ff=32, max_seq=32)
    text = aot.lower_bucket(cfg, 1, 8)
    assert "HloModule" in text
    # One parameter per weight + ids + mask.
    n_params = len(M.param_specs(cfg)) + 2
    assert text.count("parameter(") >= n_params


def test_source_digest_stable():
    assert aot.source_digest() == aot.source_digest()
    assert len(aot.source_digest()) == 64


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_exported_files():
    base = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    for name, entry in manifest["models"].items():
        assert os.path.exists(os.path.join(base, entry["weights"]))
        assert entry["config"]["name"] == name
        specs = M.param_specs(M.CONFIGS[name])
        assert [p["name"] for p in entry["params"]] == [n for n, _ in specs]
        assert [tuple(p["shape"]) for p in entry["params"]] == [s for _, s in specs]
        for art in entry["artifacts"]:
            assert os.path.exists(os.path.join(base, art["file"]))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/golden.json")),
    reason="artifacts not built",
)
def test_golden_embeddings_unit_norm():
    import numpy as np
    base = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(base, "golden.json")) as f:
        golden = json.load(f)
    emb = np.asarray(golden["embeddings"], dtype=np.float32)
    assert emb.shape[0] == len(golden["texts"])
    np.testing.assert_allclose(np.linalg.norm(emb, axis=-1), 1.0, atol=1e-4)
