"""L2 model: pallas path vs pure-jnp reference path, shapes, invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tokenizer


def tiny_cfg(name="tiny"):
    return M.ModelConfig(name=name, vocab_size=128, d_model=32, n_layers=2,
                         n_heads=2, d_ff=64, max_seq=64)


def embed(cfg, b, s, seed=0, use_pallas=True):
    rng = np.random.RandomState(seed)
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed=seed).items()}
    ids = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), dtype=jnp.int32)
    mask = np.ones((b, s), np.float32)
    for i in range(b):
        mask[i, rng.randint(1, s + 1):] = 0.0
    return M.forward(cfg, params, ids, jnp.asarray(mask), use_pallas=use_pallas), ids, mask


@pytest.mark.parametrize("b,s", [(1, 8), (2, 16), (4, 32)])
def test_pallas_matches_reference(b, s):
    cfg = tiny_cfg()
    out_k, ids, mask = embed(cfg, b, s, seed=b * 100 + s)
    rng = np.random.RandomState(b * 100 + s)
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed=b * 100 + s).items()}
    out_r = M.forward(cfg, params, ids, jnp.asarray(mask), use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=5e-5, atol=5e-5)


def test_output_shape_and_norm():
    cfg = tiny_cfg()
    out, _, _ = embed(cfg, 3, 16)
    assert out.shape == (3, cfg.d_model)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=-1), 1.0, atol=1e-5)


def test_padding_invariance():
    # Embedding a query padded to a longer bucket must give the same vector.
    cfg = tiny_cfg()
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed=0).items()}
    rng = np.random.RandomState(0)
    real = 8
    ids_short = rng.randint(2, cfg.vocab_size, (1, real)).astype(np.int32)
    for s in (16, 32):
        ids = np.zeros((1, s), np.int32)
        ids[0, :real] = ids_short
        mask = np.zeros((1, s), np.float32)
        mask[0, :real] = 1.0
        out = M.forward(cfg, params, jnp.asarray(ids), jnp.asarray(mask))
        if s == 16:
            base = np.asarray(out)
        else:
            np.testing.assert_allclose(np.asarray(out), base, rtol=1e-4, atol=1e-4)


def test_batch_consistency():
    # A query embedded alone equals the same query inside a batch.
    cfg = tiny_cfg()
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed=1).items()}
    rng = np.random.RandomState(1)
    ids = rng.randint(2, cfg.vocab_size, (4, 16)).astype(np.int32)
    mask = np.ones((4, 16), np.float32)
    full = np.asarray(M.forward(cfg, params, jnp.asarray(ids), jnp.asarray(mask)))
    solo = np.asarray(M.forward(cfg, params, jnp.asarray(ids[:1]), jnp.asarray(mask[:1])))
    np.testing.assert_allclose(full[0], solo[0], rtol=1e-4, atol=1e-4)


def test_param_specs_deterministic_and_complete():
    cfg = M.CONFIGS["bge_micro"]
    a = M.param_specs(cfg)
    b = M.param_specs(cfg)
    assert a == b
    names = [n for n, _ in a]
    assert len(names) == len(set(names))
    assert len(a) == 4 + 16 * cfg.n_layers


def test_param_count_matches_design():
    cfg = M.CONFIGS["bge_micro"]
    assert 4e6 < cfg.param_count < 10e6  # "~5M params" per DESIGN.md
    cfgj = M.CONFIGS["jina_micro"]
    assert cfgj.param_count > cfg.param_count


def test_init_params_seeded_reproducible():
    cfg = tiny_cfg()
    p1 = M.init_params(cfg, seed=42)
    p2 = M.init_params(cfg, seed=42)
    p3 = M.init_params(cfg, seed=43)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])
    assert any(not np.array_equal(p1[k], p3[k]) for k in p1)


def test_tokenized_roundtrip_embeds():
    cfg = tiny_cfg()
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed=0).items()}
    ids, mask = tokenizer.encode("hello world from windve", cfg.vocab_size, 16)
    out = M.forward(cfg, params, jnp.asarray([ids], dtype=jnp.int32),
                    jnp.asarray([mask], dtype=jnp.float32))
    assert np.isfinite(np.asarray(out)).all()
