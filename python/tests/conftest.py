import os
import sys

# Tests may be launched from the repo root or from python/; make `compile`
# importable either way.
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

# Pallas interpret mode is numpy-speed: keep example counts modest and
# disable deadlines so CI boxes don't flake.
settings.register_profile("windve", max_examples=12, deadline=None)
settings.load_profile("windve")
