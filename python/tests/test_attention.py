"""L1 attention kernel vs pure-jnp oracle (hypothesis shape/mask sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import attention, ref


def make_inputs(b, h, s, d, seed, mask_kind="random"):
    rng = np.random.RandomState(seed)
    q, k, v = (jnp.asarray(rng.randn(b, h, s, d).astype(np.float32)) for _ in range(3))
    if mask_kind == "full":
        mask = np.ones((b, s), np.float32)
    elif mask_kind == "prefix":
        mask = np.zeros((b, s), np.float32)
        for i in range(b):
            mask[i, : rng.randint(1, s + 1)] = 1.0
    else:
        mask = (rng.rand(b, s) > 0.3).astype(np.float32)
        mask[:, 0] = 1.0  # at least one real token per row
    return q, k, v, jnp.asarray(mask)


def check(b, h, s, d, seed=0, mask_kind="random", **kw):
    q, k, v, mask = make_inputs(b, h, s, d, seed, mask_kind)
    out = attention.mha(q, k, v, mask, **kw)
    exp = ref.mha_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([8, 16, 32, 48, 80]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 10_000),
    mask_kind=st.sampled_from(["full", "prefix", "random"]),
)
def test_mha_matches_ref_hypothesis(b, h, s, d, seed, mask_kind):
    check(b, h, s, d, seed, mask_kind)


@pytest.mark.parametrize("s", [8, 16, 32, 80, 128])
def test_mha_seq_buckets(s):
    check(2, 4, s, 64)


@pytest.mark.parametrize("block_q,block_k", [(4, 4), (8, 16), (16, 8), (32, 32)])
def test_mha_block_shapes(block_q, block_k):
    check(2, 2, 32, 32, block_q=block_q, block_k=block_k)


def test_mha_single_real_token():
    # Only the CLS token real: attention must collapse to that key exactly.
    b, h, s, d = 1, 2, 16, 32
    rng = np.random.RandomState(7)
    q, k, v = (jnp.asarray(rng.randn(b, h, s, d).astype(np.float32)) for _ in range(3))
    mask = np.zeros((b, s), np.float32)
    mask[:, 0] = 1.0
    out = attention.mha(q, k, v, jnp.asarray(mask))
    np.testing.assert_allclose(
        np.asarray(out[0, :, 3]), np.asarray(v[0, :, 0]), rtol=1e-5, atol=1e-5
    )


def test_mha_bf16_tolerance():
    b, h, s, d = 2, 2, 32, 32
    rng = np.random.RandomState(3)
    q, k, v = (
        jnp.asarray(rng.randn(b, h, s, d).astype(np.float32)).astype(jnp.bfloat16)
        for _ in range(3)
    )
    mask = jnp.ones((b, s), jnp.float32)
    out = attention.mha(q, k, v, mask)
    exp = ref.mha_ref(q, k, v, mask)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(exp, dtype=np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_mha_is_deterministic():
    q, k, v, mask = make_inputs(2, 2, 32, 32, seed=11)
    a = np.asarray(attention.mha(q, k, v, mask))
    b2 = np.asarray(attention.mha(q, k, v, mask))
    np.testing.assert_array_equal(a, b2)


def test_pick_block_divides():
    for n in [1, 2, 7, 16, 75, 80, 128, 500]:
        for cap in [1, 8, 16, 32]:
            b = attention._pick_block(n, cap)
            assert 1 <= b <= cap or b == min(n, cap)
            assert n % b == 0
