"""L1: fused feed-forward (matmul + GELU + matmul) Pallas kernel.

The transformer FFN is the FLOP-heaviest part of encoder inference
(2*d*f mults per token per matmul). Fusing the two projections around the
GELU keeps the ``[rows, f]`` intermediate in VMEM instead of spilling it to
HBM. Rows are tiled; the weight panels are re-streamed per row-block,
which is the right trade for serving batches (rows ~ batch*seq is small
relative to d*f).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .attention import _pick_block


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [br, d]
    h = jnp.dot(x, w1_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32)
    h = h + b1_ref[...].astype(jnp.float32)
    h = jax.nn.gelu(h)
    y = jnp.dot(h, w2_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32)
    o_ref[...] = (y + b2_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def ffn(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    block_rows: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """``gelu(x @ w1 + b1) @ w2 + b2`` fused over row tiles.

    Args:
      x: ``[..., d]``; w1: ``[d, f]``; b1: ``[f]``; w2: ``[f, d]``; b2: ``[d]``.
    """
    shape = x.shape
    d = shape[-1]
    f = w1.shape[1]
    rows = 1
    for n in shape[:-1]:
        rows *= n
    xf = x.reshape(rows, d)
    br = _pick_block(rows, block_rows)
    out = pl.pallas_call(
        _ffn_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, w1, b1, w2, b2)
    return out.reshape(shape)
