"""L1: fused masked mean-pool + L2-normalise Pallas kernel.

bge-style sentence embeddings are the mask-weighted token mean, unit-L2
normalised (so retrieval can use a plain dot product). One grid cell per
batch row keeps the whole ``[seq, d]`` slab in VMEM for the reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, m_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)  # [s, d]
    m = m_ref[0].astype(jnp.float32)  # [s]
    denom = jnp.maximum(jnp.sum(m), 1.0)
    pooled = jnp.sum(x * m[:, None], axis=0) / denom  # [d]
    norm = jax.lax.rsqrt(jnp.sum(jnp.square(pooled)) + eps)
    o_ref[0] = (pooled * norm).astype(o_ref.dtype)


def masked_mean_pool(
    x: jax.Array,
    mask: jax.Array,
    *,
    eps: float = 1e-12,
    interpret: bool = True,
) -> jax.Array:
    """Masked mean over ``seq`` then L2-normalise.

    Args:
      x: ``[batch, seq, d]`` final hidden states.
      mask: ``[batch, seq]`` 1.0/0.0 validity mask.

    Returns:
      ``[batch, d]`` unit-norm embeddings.
    """
    b, s, d = x.shape
    return pl.pallas_call(
        functools.partial(_pool_kernel, eps=eps),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=interpret,
    )(x, mask)
