"""L1: flash-style fused multi-head self-attention as a Pallas kernel.

TPU adaptation of the paper's encoder hot spot (see DESIGN.md
§Hardware-Adaptation): K/V stream through VMEM-sized tiles selected by
``BlockSpec``; a running-max/rescale ("flash") accumulator bounds the VMEM
footprint at O(block_q * d_head) instead of materialising the full S x S
score matrix. Contractions are plain ``jnp.dot`` so the TPU backend maps
them onto the MXU. ``interpret=True`` is mandatory on this image: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (>= 1)."""
    b = min(n, cap)
    while n % b:
        b -= 1
    return b


def _mha_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, block_k: int, seq: int):
    """One (batch*head, q-block) grid cell of flash attention.

    Refs (leading singleton = the bh grid dim):
      q_ref: [1, block_q, d]   VMEM-resident query tile
      k_ref: [1, seq, d]       keys (streamed block_k at a time below)
      v_ref: [1, seq, d]       values
      m_ref: [1, seq]          1.0 = real token, 0.0 = padding
      o_ref: [1, block_q, d]
    """
    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    bq, d = q.shape
    scale = 1.0 / math.sqrt(d)

    m0 = jnp.full((bq,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(i * block_k, block_k), slice(None)))
        msk = pl.load(m_ref, (0, pl.dslice(i * block_k, block_k)))
        # [bq, bk] scores on the MXU; additive -1e9 on padded keys.
        s = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
        s = s * scale + (msk.astype(jnp.float32) - 1.0) * 1e9
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    _, l_f, acc = jax.lax.fori_loop(0, seq // block_k, body, (m0, l0, acc0))
    o_ref[0] = (acc / l_f[:, None]).astype(o_ref.dtype)


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array,
    *,
    block_q: int = 16,
    block_k: int = 16,
    interpret: bool = True,
) -> jax.Array:
    """Fused multi-head attention.

    Args:
      q, k, v: ``[batch, heads, seq, d_head]``.
      mask: ``[batch, seq]`` with 1.0 on real tokens, 0.0 on padding.

    Returns:
      ``[batch, heads, seq, d_head]`` attention output. Rows whose query
      token is padding attend uniformly over real tokens; callers mask them
      out at pooling time (identical to the pure-jnp oracle).
    """
    b, h, s, d = q.shape
    bq = _pick_block(s, block_q)
    bk = _pick_block(s, block_k)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    # Broadcast the per-batch mask across heads up front (cheap: [b*h, s]).
    mf = jnp.repeat(mask, h, axis=0)

    grid = (b * h, s // bq)
    out = pl.pallas_call(
        functools.partial(_mha_kernel, block_k=bk, seq=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, mf)
    return out.reshape(b, h, s, d)
