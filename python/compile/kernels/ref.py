"""Pure-jnp correctness oracles for every Pallas kernel and the full model.

These are the ground truth the pytest/hypothesis suite checks the kernels
against; they deliberately use the most direct (unfused, materialise-
everything) formulation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha_ref(q, k, v, mask):
    """Direct softmax attention. Shapes as kernels.attention.mha."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / math.sqrt(d)
    bias = (mask.astype(jnp.float32) - 1.0) * 1e9  # [b, s]
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def residual_layernorm_ref(x, residual, gamma, beta, eps=1e-6):
    h = x.astype(jnp.float32) + residual.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    y = (h - mu) / jnp.sqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)


def ffn_ref(x, w1, b1, w2, b2):
    h = jax.nn.gelu(x.astype(jnp.float32) @ w1 + b1)
    return (h @ w2 + b2).astype(x.dtype)


def masked_mean_pool_ref(x, mask, eps=1e-12):
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(x.astype(jnp.float32) * m[:, :, None], axis=1) / denom
    norm = jnp.sqrt(jnp.sum(jnp.square(pooled), axis=-1, keepdims=True) + eps)
    return (pooled / norm).astype(x.dtype)
