"""L1 extension: int8 weight-quantized matmul Pallas kernel.

The paper's whole argument is cost-per-query; weight-only int8 halves the
FFN's HBM traffic (the serving bottleneck at small batch) at negligible
quality cost. Weights are symmetric per-output-channel quantized offline;
the kernel dequantises tiles in VMEM and contracts in fp32 on the MXU —
the standard W8A32 serving recipe, adapted to BlockSpec tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .attention import _pick_block


def quantize_weights(w: np.ndarray):
    """Symmetric per-output-channel int8 quantization.

    Args:
      w: ``[d_in, d_out]`` float32 weights.

    Returns:
      (w_q ``[d_in, d_out]`` int8, scale ``[d_out]`` float32) with
      ``w ≈ w_q * scale``.
    """
    absmax = np.abs(w).max(axis=0)
    scale = (absmax / 127.0 + 1e-12).astype(np.float32)
    w_q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return w_q, scale


def _qmatmul_kernel(x_ref, wq_ref, scale_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [br, d_in]
    # Dequantise the weight tile in VMEM, contract on the MXU.
    w = wq_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def qmatmul(
    x: jax.Array,
    w_q: jax.Array,
    scale: jax.Array,
    *,
    block_rows: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """``x @ (w_q * scale)`` with int8 weights dequantised on the fly.

    Args:
      x: ``[..., d_in]`` activations.
      w_q: ``[d_in, d_out]`` int8.
      scale: ``[d_out]`` per-channel scales.
    """
    shape = x.shape
    d_in = shape[-1]
    d_out = w_q.shape[1]
    rows = 1
    for n in shape[:-1]:
        rows *= n
    xf = x.reshape(rows, d_in)
    br = _pick_block(rows, block_rows)
    out = pl.pallas_call(
        _qmatmul_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d_out), jnp.float32),
        interpret=interpret,
    )(xf, w_q, scale)
    return out.reshape(*shape[:-1], d_out)


def qmatmul_ref(x, w_q, scale):
    """Oracle: dequantise fully, then matmul."""
    w = w_q.astype(jnp.float32) * scale[None, :]
    return x.astype(jnp.float32) @ w
