"""L1: fused residual-add + LayerNorm Pallas kernel.

Post-LN transformer blocks compute ``LN(x + sublayer(x))``; fusing the
residual add into the normalisation avoids one full HBM round-trip of the
``[rows, d]`` activation. Rows are tiled so a block of activations plus the
``[d]`` scale/shift fits comfortably in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .attention import _pick_block


def _ln_kernel(x_ref, r_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def residual_layernorm(
    x: jax.Array,
    residual: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """``LN(x + residual) * gamma + beta`` over the last axis.

    Args:
      x, residual: ``[..., d]`` (flattened to rows internally).
      gamma, beta: ``[d]``.
    """
    shape = x.shape
    d = shape[-1]
    rows = 1
    for n in shape[:-1]:
        rows *= n
    xf = x.reshape(rows, d)
    rf = residual.reshape(rows, d)
    br = _pick_block(rows, block_rows)
    out = pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, rf, gamma, beta)
    return out.reshape(shape)
