"""Writer for the ``.wtar`` tensor archive consumed by the Rust runtime.

Layout (little-endian):
  magic   b"WTAR1\\0"
  u32     tensor count
  per tensor:
    u32   name length, then name bytes (utf-8)
    u8    dtype tag (0 = f32, 1 = i32)
    u8    rank
    u64*  dims
    raw   payload (row-major)

Mirror reader: ``rust/src/runtime/wtar.rs``.
"""

from __future__ import annotations

import struct
from typing import Iterable, Tuple

import numpy as np

MAGIC = b"WTAR1\x00"
DTYPE_TAGS = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write(path: str, tensors: Iterable[Tuple[str, np.ndarray]]) -> None:
    tensors = list(tensors)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            tag = DTYPE_TAGS[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", tag, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read(path: str):
    """Round-trip reader (used by the Python tests only)."""
    inv = {v: k for k, v in DTYPE_TAGS.items()}
    out = []
    with open(path, "rb") as f:
        assert f.read(6) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            tag, rank = struct.unpack("<BB", f.read(2))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(rank)]
            dt = inv[tag]
            n = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(n * dt.itemsize), dtype=dt).reshape(dims)
            out.append((name, arr))
    return out
