"""AOT export: lower the L2 model (with L1 Pallas kernels) to HLO text.

Python runs ONCE, here. Outputs per model:
  artifacts/<model>_b{B}_s{S}.hlo.txt   one static-shape executable per
                                        (batch, seq) bucket
  artifacts/<model>.wtar                weights archive (runtime params)
  artifacts/manifest.json               parameter ABI + bucket index
  artifacts/golden.json                 input/output pairs + tokenizer
                                        parity vectors for Rust tests

HLO *text* is the interchange format: jax >= 0.5 serialises HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import tokenizer, wtar

DEFAULT_BUCKETS = {
    # (batches, seqs) exported per model. 80 covers the paper's canonical
    # 75-token RAG segment length (padded to a multiple of 16).
    "bge_micro": ([1, 2, 4, 8, 16], [32, 80, 128]),
    "jina_micro": ([1, 2, 4, 8], [32, 80]),
}

GOLDEN_TEXTS = [
    "Retrieval augmented generation enhances large language models",
    "WindVE offloads peak concurrent queries from the NPU to idle host CPUs",
    "vector embedding maps text to high dimensional semantic vectors",
    "the queue manager rejects excess queries with a busy status",
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(cfg, batch: int, seq: int) -> str:
    """Lower embed(weights..., ids, mask) for one static bucket."""
    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in model_lib.param_specs(cfg)
    ]
    ids_spec = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    mask_spec = jax.ShapeDtypeStruct((batch, seq), jnp.float32)

    def fn(*args):
        params = model_lib.params_from_list(cfg, args[: len(specs)])
        ids, mask = args[len(specs)], args[len(specs) + 1]
        return (model_lib.forward(cfg, params, ids, mask, use_pallas=True),)

    lowered = jax.jit(fn).lower(*specs, ids_spec, mask_spec)
    return to_hlo_text(lowered)


def source_digest() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip cleanly."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in sorted(os.walk(base)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def export_model(cfg, out_dir: str, seed: int, batches, seqs, entry: dict) -> None:
    params = model_lib.init_params(cfg, seed=seed)
    flat = model_lib.params_to_list(cfg, params)
    wtar_path = os.path.join(out_dir, f"{cfg.name}.wtar")
    wtar.write(wtar_path, [(n, a) for (n, _), a in zip(model_lib.param_specs(cfg), flat)])

    entry["config"] = {
        "name": cfg.name, "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq, "pad_id": cfg.pad_id,
        "param_count": cfg.param_count,
    }
    entry["weights"] = os.path.basename(wtar_path)
    entry["params"] = [
        {"name": n, "shape": list(s), "dtype": "f32"}
        for n, s in model_lib.param_specs(cfg)
    ]
    entry["artifacts"] = []
    for b in batches:
        for s in seqs:
            t0 = time.time()
            text = lower_bucket(cfg, b, s)
            fname = f"{cfg.name}_b{b}_s{s}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry["artifacts"].append({"batch": b, "seq": s, "file": fname})
            print(f"  lowered {fname}  ({len(text)//1024} KiB, {time.time()-t0:.1f}s)",
                  flush=True)


def export_golden(out_dir: str, seed: int) -> None:
    """Golden embeddings + tokenizer parity vectors for the Rust tests."""
    cfg = model_lib.CONFIGS["bge_micro"]
    params = model_lib.init_params(cfg, seed=seed)
    seq = 32
    ids_rows, mask_rows = [], []
    for t in GOLDEN_TEXTS:
        ids, mask = tokenizer.encode(t, cfg.vocab_size, seq)
        ids_rows.append(ids)
        mask_rows.append(mask)
    ids = jnp.asarray(ids_rows, dtype=jnp.int32)
    mask = jnp.asarray(mask_rows, dtype=jnp.float32)
    emb = model_lib.forward(cfg, {k: jnp.asarray(v) for k, v in params.items()},
                            ids, mask, use_pallas=True)
    parity = {
        w: tokenizer.fnv1a64(w.encode("utf-8")) % (cfg.vocab_size - 2) + 2
        for w in ["retrieval", "windve", "npu", "queue", "a", "0", "embedding"]
    }
    golden = {
        "model": cfg.name,
        "seq": seq,
        "texts": GOLDEN_TEXTS,
        "token_ids": [list(map(int, r)) for r in ids_rows],
        "mask": [list(map(float, r)) for r in mask_rows],
        "embeddings": np.asarray(emb).tolist(),
        "tokenizer_parity": parity,
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print("  wrote golden.json", flush=True)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="bge_micro,jina_micro")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    digest = source_digest()
    stamp = os.path.join(args.out_dir, ".stamp")
    if not args.force and os.path.exists(stamp):
        with open(stamp) as f:
            if f.read().strip() == digest:
                print("artifacts up to date (source digest match); skipping")
                return 0

    manifest = {"version": 1, "seed": args.seed, "models": {}}
    for name in args.models.split(","):
        cfg = model_lib.CONFIGS[name]
        batches, seqs = DEFAULT_BUCKETS[name]
        print(f"exporting {name} ({cfg.param_count/1e6:.1f}M params)", flush=True)
        entry: dict = {}
        export_model(cfg, args.out_dir, args.seed, batches, seqs, entry)
        manifest["models"][name] = entry
    export_golden(args.out_dir, args.seed)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp, "w") as f:
        f.write(digest)
    print("manifest.json written; AOT export complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
