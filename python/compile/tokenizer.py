"""Deterministic hash tokenizer — Python half of the Rust/Python pair.

The paper (§5.1.3) notes that for embedding *serving* only query length
matters; token identity just has to be deterministic and identical on both
sides of the AOT boundary so golden outputs line up. FNV-1a 64 over the
lower-cased word maps into ``[2, vocab)``; id 0 is PAD, id 1 is CLS.

Must stay byte-for-byte in sync with ``rust/src/runtime/tokenizer.rs``
(parity vectors in artifacts/golden.json and both test suites).
"""

from __future__ import annotations

import re
from typing import List, Tuple

PAD_ID = 0
CLS_ID = 1

_WORD = re.compile(r"[A-Za-z0-9]+")

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK = (1 << 64) - 1


def fnv1a64(data: bytes) -> int:
    h = _FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * _FNV_PRIME) & _MASK
    return h


def encode(text: str, vocab_size: int, max_len: int) -> Tuple[List[int], List[float]]:
    """Tokenise ``text`` to (ids, mask), CLS-prefixed, padded to ``max_len``."""
    ids = [CLS_ID]
    for word in _WORD.findall(text.lower()):
        if len(ids) >= max_len:
            break
        ids.append(2 + fnv1a64(word.encode("utf-8")) % (vocab_size - 2))
    mask = [1.0] * len(ids)
    while len(ids) < max_len:
        ids.append(PAD_ID)
        mask.append(0.0)
    return ids[:max_len], mask[:max_len]
