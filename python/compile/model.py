"""L2: the embedding model — a BERT-style bi-encoder in JAX.

Mirrors the architecture family of the paper's models (bge-large-zh-v1.5,
jina-v2): token+position embeddings, post-LN transformer blocks, masked
mean-pooling with L2 normalisation. Weights are seeded-PRNG synthetic
(no network access on this image — see DESIGN.md §2); serving behaviour
depends on compute shape, and numerics are validated kernel-vs-oracle.

The forward pass calls the L1 Pallas kernels (``use_pallas=True``) or the
pure-jnp oracles (``use_pallas=False``) so the whole model can be
cross-checked end to end.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention, ffn as ffn_k, layernorm, pooling
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description; serialised into the manifest."""

    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    pad_id: int = 0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def param_count(self) -> int:
        return sum(int(np.prod(s)) for _, s in param_specs(self))


#: Scaled-down stand-ins for the paper's 326M bge / 570M jina models.
CONFIGS: Dict[str, ModelConfig] = {
    "bge_micro": ModelConfig(
        name="bge_micro", vocab_size=8192, d_model=256, n_layers=4,
        n_heads=4, d_ff=1024, max_seq=512,
    ),
    "jina_micro": ModelConfig(
        name="jina_micro", vocab_size=8192, d_model=384, n_layers=4,
        n_heads=6, d_ff=1536, max_seq=512,
    ),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) order — the AOT parameter ABI.

    The Rust runtime feeds weights positionally in exactly this order,
    followed by ``token_ids`` and ``mask`` (see runtime/manifest.rs).
    """
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.max_seq
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (v, d)),
        ("pos_emb", (s, d)),
        ("emb_ln_g", (d,)),
        ("emb_ln_b", (d,)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "wq", (d, d)), (p + "bq", (d,)),
            (p + "wk", (d, d)), (p + "bk", (d,)),
            (p + "wv", (d, d)), (p + "bv", (d,)),
            (p + "wo", (d, d)), (p + "bo", (d,)),
            (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
            (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
        ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Seeded synthetic weights: N(0, 0.02) matrices, identity layernorms."""
    rng = np.random.RandomState(seed)
    params: Dict[str, np.ndarray] = {}
    for name, shape in param_specs(cfg):
        if name.endswith(("_g",)):
            params[name] = np.ones(shape, dtype=np.float32)
        elif name.endswith(("_b", "bq", "bk", "bv", "bo", "b1", "b2")):
            params[name] = np.zeros(shape, dtype=np.float32)
        else:
            params[name] = (rng.randn(*shape) * 0.02).astype(np.float32)
    return params


def params_to_list(cfg: ModelConfig, params: Dict[str, np.ndarray]) -> List[np.ndarray]:
    return [params[name] for name, _ in param_specs(cfg)]


def params_from_list(cfg: ModelConfig, flat) -> Dict[str, jax.Array]:
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


def forward(
    cfg: ModelConfig,
    params: Dict[str, jax.Array],
    token_ids: jax.Array,
    mask: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Embed ``token_ids [B, S] int32`` with mask ``[B, S] f32`` → ``[B, d]``.

    Output rows are unit-L2-normalised sentence embeddings.
    """
    b, s = token_ids.shape
    h = cfg.n_heads
    dh = cfg.d_head

    def ln(x, res, g, bta):
        if use_pallas:
            return layernorm.residual_layernorm(x, res, g, bta, interpret=interpret)
        return ref.residual_layernorm_ref(x, res, g, bta)

    x = jnp.take(params["tok_emb"], token_ids, axis=0)
    x = x + params["pos_emb"][:s][None, :, :]
    x = ln(x, jnp.zeros_like(x), params["emb_ln_g"], params["emb_ln_b"])

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        # QKV projections stay in L2 jax — XLA fuses them; attention itself
        # is the L1 kernel.
        q = (x @ params[p + "wq"] + params[p + "bq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        k = (x @ params[p + "wk"] + params[p + "bk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        v = (x @ params[p + "wv"] + params[p + "bv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        if use_pallas:
            a = attention.mha(q, k, v, mask, interpret=interpret)
        else:
            a = ref.mha_ref(q, k, v, mask)
        a = a.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        a = a @ params[p + "wo"] + params[p + "bo"]
        x = ln(a, x, params[p + "ln1_g"], params[p + "ln1_b"])

        if use_pallas:
            f = ffn_k.ffn(
                x, params[p + "w1"], params[p + "b1"],
                params[p + "w2"], params[p + "b2"], interpret=interpret,
            )
        else:
            f = ref.ffn_ref(
                x, params[p + "w1"], params[p + "b1"],
                params[p + "w2"], params[p + "b2"],
            )
        x = ln(f, x, params[p + "ln2_g"], params[p + "ln2_b"])

    if use_pallas:
        return pooling.masked_mean_pool(x, mask, interpret=interpret)
    return ref.masked_mean_pool_ref(x, mask)
