//! criterion-lite measurement harness (criterion is unavailable offline).
//!
//! Used by `benches/*.rs` (`harness = false`). Reports ns/op mean, p50 and
//! p99 from timed batches, after warmup.

use std::time::Instant;

use crate::util::json::Json;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "{:<44} {:>12.1} ns/op   p50 {:>12.1}   p99 {:>12.1}   ({} iters)",
            self.name, self.mean_ns, self.p50_ns, self.p99_ns, self.iters
        );
    }
}

/// Measure `f`, auto-scaling iteration count to ~`target_ms` of runtime.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    bench_with(name, 300, &mut f)
}

pub fn bench_with<F: FnMut()>(name: &str, target_ms: u64, f: &mut F) -> Measurement {
    // Warmup + calibration: find iters/batch so one batch is ~1ms.
    let mut batch = 1u64;
    loop {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let el = t.elapsed().as_nanos() as u64;
        if el > 1_000_000 || batch >= 1 << 24 {
            break;
        }
        batch *= 2;
    }

    let t0 = Instant::now();
    let mut samples = Vec::new();
    let mut total_iters = 0u64;
    while t0.elapsed().as_millis() < target_ms as u128 || samples.len() < 10 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
        total_iters += batch;
        if samples.len() > 10_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((q * (samples.len() - 1) as f64) as usize).min(samples.len() - 1)];
    Measurement {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        p50_ns: p(0.5),
        p99_ns: p(0.99),
    }
}

/// Print a section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench records for CI artifacts (`BENCH_*.json`):
/// one JSON object per measurement, written as
/// `{"records": [{...}, ...]}` so downstream tooling can track the perf
/// trajectory across commits without scraping bench stdout.
#[derive(Default)]
pub struct JsonReport {
    records: Vec<Json>,
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Append one record (field order is preserved in the output).
    pub fn push(&mut self, fields: Vec<(&str, Json)>) {
        self.records.push(Json::obj(fields));
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Write the report to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let doc = Json::obj(vec![("records", Json::Arr(self.records.clone()))]);
        std::fs::write(path, doc.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench_with("noop-ish", 20, &mut || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.iters > 0);
        assert!(m.p50_ns <= m.p99_ns * 1.001);
    }

    #[test]
    fn json_report_roundtrips() {
        let mut rep = JsonReport::new();
        assert!(rep.is_empty());
        rep.push(vec![
            ("bench", Json::str("flat search_batch")),
            ("quant", Json::str("int8")),
            ("ns_per_query", Json::num(12.5)),
        ]);
        assert_eq!(rep.len(), 1);
        let path = std::env::temp_dir().join("windve_bench_report_test.json");
        let path = path.to_str().unwrap().to_string();
        rep.write(&path).unwrap();
        let parsed =
            crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let records = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].get("quant").unwrap().as_str(), Some("int8"));
        assert_eq!(records[0].get("ns_per_query").unwrap().as_f64(), Some(12.5));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_scales_to_slow_ops() {
        let m = bench_with("sleepy", 20, &mut || {
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(m.mean_ns > 100_000.0, "mean {}", m.mean_ns);
    }
}
