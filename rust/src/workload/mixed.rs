//! Mixed embed+retrieve arrival processes.
//!
//! The paper's traffic model (Figure 2) covers embedding queries only;
//! a RAG deployment interleaves them with batched retrieval scans that
//! contend for the same host CPUs. [`MixedArrivals`] generates the two
//! streams as one marked Poisson process — a single arrival stream in
//! which each event is independently a retrieval with probability
//! `retrieve_fraction` — so the relative phase of the two classes is
//! physically plausible and every run reproduces bit-for-bit from its
//! seed. Feed the streams to `sim::OpenLoopSim::run_mixed`, and the
//! observed fraction to `estimator::depth::fine_tune_depths_mixed`.

use super::diurnal::DiurnalCurve;
use crate::util::rng::Pcg;

/// Two time-sorted arrival streams drawn from one marked point process.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MixedArrivals {
    /// Embedding-query arrival times (seconds, ascending).
    pub embed: Vec<f64>,
    /// Retrieval-scan arrival times (seconds, ascending).
    pub retrieve: Vec<f64>,
}

impl MixedArrivals {
    /// Homogeneous Poisson stream at `rate` q/s over `[0, horizon)`,
    /// marked retrieval with probability `retrieve_fraction`.
    pub fn poisson(
        rate: f64,
        retrieve_fraction: f64,
        horizon: f64,
        seed: u64,
    ) -> MixedArrivals {
        assert!(rate > 0.0, "rate must be positive");
        Self::thinned(|_| rate, rate, retrieve_fraction, horizon, seed)
    }

    /// Non-homogeneous stream thinned from a diurnal curve starting at
    /// `start_hour`, over `horizon` seconds — the peak-offload scenario
    /// with retrieval contention (e.g. `start_hour = 20.5` replays the
    /// evening peak).
    pub fn from_curve(
        curve: &DiurnalCurve,
        retrieve_fraction: f64,
        start_hour: f64,
        horizon: f64,
        seed: u64,
    ) -> MixedArrivals {
        let peak = curve.peak_rate();
        if peak <= 0.0 {
            return MixedArrivals::default();
        }
        Self::thinned(
            |t| curve.rate(start_hour + t / 3600.0),
            peak,
            retrieve_fraction,
            horizon,
            seed,
        )
    }

    /// Poisson thinning of `rate(t)` against `peak_rate`, marking each
    /// surviving arrival. One rng drives inter-arrivals, thinning and
    /// marking in a fixed draw order, so streams are seed-deterministic.
    ///
    /// This is THE thinning generator — `sim::OpenLoopSim::poisson_arrivals`
    /// delegates here with fraction 0, which skips the marking draw, so
    /// its seeded streams are draw-for-draw what they were before the
    /// mixed variant existed.
    pub(crate) fn thinned(
        rate: impl Fn(f64) -> f64,
        peak_rate: f64,
        retrieve_fraction: f64,
        horizon: f64,
        seed: u64,
    ) -> MixedArrivals {
        assert!(
            (0.0..=1.0).contains(&retrieve_fraction),
            "retrieve_fraction must be in [0, 1], got {retrieve_fraction}"
        );
        let mut rng = Pcg::new(seed);
        let mut t = 0.0;
        let mut out = MixedArrivals::default();
        while t < horizon {
            t += rng.exp(peak_rate);
            if t >= horizon {
                break;
            }
            if rng.f64() < rate(t) / peak_rate {
                if retrieve_fraction > 0.0 && rng.chance(retrieve_fraction) {
                    out.retrieve.push(t);
                } else {
                    out.embed.push(t);
                }
            }
        }
        out
    }

    /// Overlay a Poisson scan burst on `[t0, t0 + width)` at `rate`
    /// scans/s — the NPU-offload scenario generator: a retrieval burst
    /// arriving in an embedding valley, exactly where the device leg
    /// should absorb it (ROADMAP "batched NPU retrieval offload"). The
    /// retrieve stream stays time-sorted; seed-deterministic like every
    /// generator here.
    pub fn with_scan_burst(mut self, t0: f64, width: f64, rate: f64, seed: u64) -> MixedArrivals {
        assert!(rate > 0.0 && width > 0.0, "burst needs positive rate and width");
        let mut rng = Pcg::new(seed);
        let mut t = t0;
        loop {
            t += rng.exp(rate);
            if t >= t0 + width {
                break;
            }
            self.retrieve.push(t);
        }
        self.retrieve.sort_by(f64::total_cmp);
        self
    }

    /// Total arrivals across both classes.
    pub fn len(&self) -> usize {
        self.embed.len() + self.retrieve.len()
    }

    pub fn is_empty(&self) -> bool {
        self.embed.is_empty() && self.retrieve.is_empty()
    }

    /// The realized retrieval share (the fraction axis to calibrate
    /// depths against).
    pub fn observed_fraction(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.retrieve.len() as f64 / self.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_sorted_and_deterministic() {
        let a = MixedArrivals::poisson(50.0, 0.25, 30.0, 9);
        let b = MixedArrivals::poisson(50.0, 0.25, 30.0, 9);
        assert_eq!(a, b);
        assert!(a.embed.windows(2).all(|w| w[0] <= w[1]));
        assert!(a.retrieve.windows(2).all(|w| w[0] <= w[1]));
        let c = MixedArrivals::poisson(50.0, 0.25, 30.0, 10);
        assert_ne!(a, c);
    }

    #[test]
    fn rate_and_fraction_roughly_match() {
        let m = MixedArrivals::poisson(40.0, 0.3, 100.0, 3);
        let rate = m.len() as f64 / 100.0;
        assert!((rate - 40.0).abs() < 4.0, "rate {rate}");
        assert!((m.observed_fraction() - 0.3).abs() < 0.05, "{}", m.observed_fraction());
    }

    #[test]
    fn fraction_edges_produce_single_class_streams() {
        let all_embed = MixedArrivals::poisson(20.0, 0.0, 20.0, 1);
        assert!(all_embed.retrieve.is_empty());
        assert!(!all_embed.embed.is_empty());
        let all_retrieve = MixedArrivals::poisson(20.0, 1.0, 20.0, 1);
        assert!(all_retrieve.embed.is_empty());
        assert!(!all_retrieve.retrieve.is_empty());
        assert_eq!(all_retrieve.observed_fraction(), 1.0);
    }

    #[test]
    fn curve_thinning_peaks_where_the_curve_does() {
        let curve = DiurnalCurve::typical(2.0, 10.0);
        // One hour at the evening peak vs one hour overnight.
        let peak = MixedArrivals::from_curve(&curve, 0.2, 20.5, 3600.0, 5);
        let night = MixedArrivals::from_curve(&curve, 0.2, 3.0, 3600.0, 5);
        assert!(
            peak.len() > 2 * night.len(),
            "peak {} vs night {}",
            peak.len(),
            night.len()
        );
    }

    #[test]
    fn empty_default_observed_fraction_is_zero() {
        assert_eq!(MixedArrivals::default().observed_fraction(), 0.0);
    }

    #[test]
    fn scan_burst_overlays_the_retrieve_stream_deterministically() {
        let base = MixedArrivals::poisson(30.0, 0.1, 20.0, 4);
        let before = base.retrieve.len();
        let m = base.with_scan_burst(5.0, 2.0, 25.0, 9);
        assert!(m.retrieve.len() > before);
        assert!(m.retrieve.windows(2).all(|w| w[0] <= w[1]));
        // Burst density roughly matches inside the window (25/s × 2 s).
        let in_window = m.retrieve.iter().filter(|t| (5.0..7.0).contains(*t)).count();
        assert!((30..=80).contains(&in_window), "burst count {in_window}");
        // No arrivals leak outside the window beyond the base stream's.
        let m2 = MixedArrivals::poisson(30.0, 0.1, 20.0, 4).with_scan_burst(5.0, 2.0, 25.0, 9);
        assert_eq!(m, m2);
    }

    #[test]
    #[should_panic(expected = "burst needs positive")]
    fn scan_burst_rejects_degenerate_window() {
        let _ = MixedArrivals::default().with_scan_burst(0.0, 0.0, 10.0, 1);
    }

    #[test]
    #[should_panic(expected = "retrieve_fraction")]
    fn out_of_range_fraction_panics() {
        let _ = MixedArrivals::poisson(10.0, -0.1, 1.0, 1);
    }
}
