//! Arrival-trace recording and replay.
//!
//! Serialises arrival timestamps + query lengths to a simple line format
//! (`<t_seconds> <tokens>`), so production traces (or synthetic ones from
//! the diurnal model) can be replayed bit-exactly through the open-loop
//! simulator or a live service.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// One trace record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record {
    pub t: f64,
    pub tokens: usize,
}

/// A recorded workload trace (sorted by time).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub records: Vec<Record>,
}

impl Trace {
    pub fn new(mut records: Vec<Record>) -> Trace {
        records.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        Trace { records }
    }

    /// Synthesize from arrival times with a fixed query length.
    pub fn from_arrivals(arrivals: &[f64], tokens: usize) -> Trace {
        Trace::new(arrivals.iter().map(|&t| Record { t, tokens }).collect())
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn duration(&self) -> f64 {
        self.records.last().map(|r| r.t).unwrap_or(0.0)
    }

    /// Mean arrival rate (q/s).
    pub fn rate(&self) -> f64 {
        if self.duration() <= 0.0 {
            0.0
        } else {
            self.len() as f64 / self.duration()
        }
    }

    pub fn arrival_times(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.t).collect()
    }

    /// Write as `t tokens` lines.
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        writeln!(w, "# windve trace v1: <t_seconds> <tokens>")?;
        for r in &self.records {
            writeln!(w, "{:.9} {}", r.t, r.tokens)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut records = Vec::new();
        for (ln, line) in std::io::BufReader::new(f).lines().enumerate() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let t: f64 = parts
                .next()
                .and_then(|s| s.parse().ok())
                .with_context(|| format!("bad time at line {}", ln + 1))?;
            let tokens: usize = parts
                .next()
                .and_then(|s| s.parse().ok())
                .with_context(|| format!("bad token count at line {}", ln + 1))?;
            records.push(Record { t, tokens });
        }
        Ok(Trace::new(records))
    }

    /// Scale arrival rate by `factor` (compress time for faster replay).
    pub fn speedup(&self, factor: f64) -> Trace {
        assert!(factor > 0.0);
        Trace::new(
            self.records
                .iter()
                .map(|r| Record { t: r.t / factor, tokens: r.tokens })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("windve_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_file() {
        let t = Trace::new(vec![
            Record { t: 0.5, tokens: 75 },
            Record { t: 0.1, tokens: 128 },
            Record { t: 2.25, tokens: 75 },
        ]);
        let path = tmp("t1.trace");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back, t);
        // sorted on construction
        assert!(back.records.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let path = tmp("t2.trace");
        std::fs::write(&path, "# header\n\n0.5 75\n# mid\n1.0 80\n").unwrap();
        let t = Trace::load(&path).unwrap();
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn malformed_line_is_error() {
        let path = tmp("t3.trace");
        std::fs::write(&path, "0.5 notanumber\n").unwrap();
        assert!(Trace::load(&path).is_err());
    }

    #[test]
    fn rate_and_speedup() {
        let t = Trace::from_arrivals(&[0.0, 1.0, 2.0, 3.0, 4.0], 75);
        assert!((t.rate() - 1.25).abs() < 1e-9); // 5 arrivals / 4s
        let fast = t.speedup(2.0);
        assert!((fast.duration() - 2.0).abs() < 1e-9);
        assert_eq!(fast.len(), t.len());
    }

    #[test]
    fn empty_trace_degenerate() {
        let t = Trace::default();
        assert_eq!(t.rate(), 0.0);
        assert_eq!(t.duration(), 0.0);
    }
}
