//! Workload generation: query text of controlled token length, and the
//! diurnal arrival-rate curve of the paper's Figure 2.

pub mod diurnal;
pub mod queries;
pub mod trace;

pub use diurnal::DiurnalCurve;
pub use queries::QueryGen;
