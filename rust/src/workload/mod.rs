//! Workload generation: query text of controlled token length, the
//! diurnal arrival-rate curve of the paper's Figure 2, and mixed
//! embed+retrieve arrival processes for admission scenarios.

pub mod diurnal;
pub mod mixed;
pub mod queries;
pub mod trace;

pub use diurnal::DiurnalCurve;
pub use mixed::MixedArrivals;
pub use queries::QueryGen;
