//! Diurnal traffic model (paper Figure 2: "query number changes in a day").
//!
//! Industrial embedding traffic has a strong day/night cycle with lunchtime
//! and evening peaks; deployment by *average* rate under-provisions the
//! peaks (the paper's motivation for maximum-concurrency provisioning).
//! This model is a sum of Gaussian bumps over a base rate, normalised so
//! `rate(t)` is queries/second.

/// Piecewise-smooth day curve.
#[derive(Debug, Clone)]
pub struct DiurnalCurve {
    /// Base (overnight) rate, q/s.
    pub base: f64,
    /// (center hour, width hours, extra q/s) bumps.
    pub peaks: Vec<(f64, f64, f64)>,
}

impl DiurnalCurve {
    /// A typical business-app day: morning ramp, lunch spike, evening peak
    /// (shape of the paper's Fig. 2 illustration).
    pub fn typical(base: f64, scale: f64) -> DiurnalCurve {
        DiurnalCurve {
            base,
            peaks: vec![
                (10.0, 1.8, 3.0 * scale), // morning work peak
                (13.0, 1.0, 2.0 * scale), // lunch spike
                (20.5, 2.2, 4.0 * scale), // evening peak (the day's max)
            ],
        }
    }

    /// Rate (queries/s) at hour-of-day `h ∈ [0, 24)`.
    pub fn rate(&self, h: f64) -> f64 {
        let h = h.rem_euclid(24.0);
        let mut r = self.base;
        for &(c, w, a) in &self.peaks {
            // wrap-around distance so 23:30 feels a 00:30 peak
            let d = (h - c).abs().min(24.0 - (h - c).abs());
            r += a * (-0.5 * (d / w).powi(2)).exp();
        }
        r
    }

    /// Peak rate over the day (sampled minutely — Eq. 6's N_peak).
    pub fn peak_rate(&self) -> f64 {
        (0..24 * 60)
            .map(|m| self.rate(m as f64 / 60.0))
            .fold(0.0f64, f64::max)
    }

    /// Mean rate over the day (Eq. 5's N).
    pub fn mean_rate(&self) -> f64 {
        let n = 24 * 60;
        (0..n).map(|m| self.rate(m as f64 / 60.0)).sum::<f64>() / n as f64
    }

    /// Sampled series for plotting (hour, rate) — `windve repro fig2`.
    pub fn series(&self, samples_per_hour: usize) -> Vec<(f64, f64)> {
        let n = 24 * samples_per_hour;
        (0..n)
            .map(|i| {
                let h = i as f64 / samples_per_hour as f64;
                (h, self.rate(h))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_positive_everywhere() {
        let c = DiurnalCurve::typical(2.0, 10.0);
        for m in 0..24 * 60 {
            assert!(c.rate(m as f64 / 60.0) > 0.0);
        }
    }

    #[test]
    fn peak_exceeds_mean_substantially() {
        // The premise of §3: bursts far above average exist.
        let c = DiurnalCurve::typical(2.0, 10.0);
        assert!(c.peak_rate() > 2.0 * c.mean_rate());
    }

    #[test]
    fn evening_peak_is_global_max() {
        let c = DiurnalCurve::typical(2.0, 10.0);
        let peak = c.peak_rate();
        assert!((c.rate(20.5) - peak).abs() / peak < 0.05);
    }

    #[test]
    fn wraps_midnight() {
        let c = DiurnalCurve::typical(2.0, 10.0);
        assert!((c.rate(0.0) - c.rate(24.0)).abs() < 1e-9);
        assert!((c.rate(-1.0) - c.rate(23.0)).abs() < 1e-9);
    }

    #[test]
    fn series_has_expected_len() {
        let c = DiurnalCurve::typical(1.0, 1.0);
        assert_eq!(c.series(4).len(), 96);
    }
}
