//! Query-text generator with controlled token length.
//!
//! Per the paper (§5.1.3) "the length rather than the content of input
//! queries matters for vector embedding service"; the default 75 tokens
//! mirrors the paper's canonical RAG text-segmentation setting.

use crate::util::rng::Pcg;

/// Generates deterministic pseudo-text queries of an exact token count.
#[derive(Debug)]
pub struct QueryGen {
    rng: Pcg,
    /// Tokens per query, *including* the CLS token the tokenizer adds.
    pub tokens: usize,
}

impl QueryGen {
    /// `tokens` counts the CLS token, matching the paper's "query length".
    pub fn new(tokens: usize, seed: u64) -> QueryGen {
        assert!(tokens >= 1);
        QueryGen { rng: Pcg::new(seed), tokens }
    }

    /// One query with exactly `self.tokens` tokens.
    pub fn query(&mut self) -> String {
        let words = self.tokens - 1; // CLS provides the first token
        (0..words)
            .map(|_| {
                let n = self.rng.usize(3, 9);
                (0..n)
                    .map(|_| (b'a' + self.rng.usize(0, 26) as u8) as char)
                    .collect::<String>()
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// A batch of `n` queries.
    pub fn batch(&mut self, n: usize) -> Vec<String> {
        (0..n).map(|_| self.query()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tokenizer;

    #[test]
    fn token_count_is_exact() {
        for &len in &[1usize, 2, 10, 75, 128, 500] {
            let mut g = QueryGen::new(len, 1);
            for _ in 0..5 {
                let q = g.query();
                assert_eq!(tokenizer::token_count(&q), len, "len {len} q {q:?}");
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = QueryGen::new(75, 9);
        let mut b = QueryGen::new(75, 9);
        assert_eq!(a.batch(5), b.batch(5));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = QueryGen::new(75, 1);
        let mut b = QueryGen::new(75, 2);
        assert_ne!(a.query(), b.query());
    }
}
