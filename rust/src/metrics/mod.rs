//! Serving metrics: counters, latency histograms, percentile reports,
//! request-scoped stage tracing.

pub mod histogram;
pub mod registry;
pub mod slo;
pub mod trace;

pub use histogram::Histogram;
pub use registry::{Counter, Registry};
pub use slo::SloMonitor;
pub use trace::{ClassLabel, CodecLabel, RouteLabel, SpanRecord, SpanRing, Stage, Tracer};
