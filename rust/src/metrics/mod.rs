//! Serving metrics: counters, latency histograms, percentile reports.

pub mod histogram;
pub mod registry;
pub mod slo;

pub use histogram::Histogram;
pub use registry::{Counter, Registry};
pub use slo::SloMonitor;
