//! Sliding-window SLO monitor.
//!
//! Tracks per-request SLO outcomes over the most recent `window` requests
//! and flags breach when attainment drops below target — the signal an
//! operator (or the online re-calibrator) acts on.

use std::collections::VecDeque;

use crate::util::sync::Mutex;

/// Windowed SLO attainment tracker.
pub struct SloMonitor {
    slo_nanos: u64,
    target: f64,
    window: usize,
    state: Mutex<State>,
}

struct State {
    outcomes: VecDeque<bool>, // true = met
    met: usize,
}

impl SloMonitor {
    /// `target` is the required attainment fraction (e.g. 0.999).
    pub fn new(slo: std::time::Duration, target: f64, window: usize) -> SloMonitor {
        assert!(window > 0 && (0.0..=1.0).contains(&target));
        SloMonitor {
            slo_nanos: slo.as_nanos() as u64,
            target,
            window,
            state: Mutex::new(State { outcomes: VecDeque::new(), met: 0 }),
        }
    }

    /// Record one request's e2e latency.
    pub fn record(&self, latency_nanos: u64) {
        let met = latency_nanos <= self.slo_nanos;
        let mut s = self.state.lock().unwrap();
        s.outcomes.push_back(met);
        if met {
            s.met += 1;
        }
        if s.outcomes.len() > self.window {
            if s.outcomes.pop_front() == Some(true) {
                s.met -= 1;
            }
        }
    }

    /// Attainment over the current window (1.0 when empty).
    pub fn attainment(&self) -> f64 {
        let s = self.state.lock().unwrap();
        if s.outcomes.is_empty() {
            1.0
        } else {
            s.met as f64 / s.outcomes.len() as f64
        }
    }

    /// True when the window is full and attainment is below target.
    pub fn breached(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.outcomes.len() >= self.window
            && (s.met as f64 / s.outcomes.len() as f64) < self.target
    }

    pub fn samples(&self) -> usize {
        self.state.lock().unwrap().outcomes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn monitor(target: f64, window: usize) -> SloMonitor {
        SloMonitor::new(Duration::from_millis(100), target, window)
    }

    #[test]
    fn empty_monitor_is_healthy() {
        let m = monitor(0.99, 10);
        assert_eq!(m.attainment(), 1.0);
        assert!(!m.breached());
    }

    #[test]
    fn attainment_tracks_outcomes() {
        let m = monitor(0.9, 10);
        for _ in 0..8 {
            m.record(50_000_000); // 50ms ok
        }
        for _ in 0..2 {
            m.record(200_000_000); // 200ms violation
        }
        assert!((m.attainment() - 0.8).abs() < 1e-9);
        assert!(m.breached());
    }

    #[test]
    fn no_breach_until_window_full() {
        let m = monitor(0.99, 10);
        for _ in 0..5 {
            m.record(500_000_000);
        }
        assert_eq!(m.attainment(), 0.0);
        assert!(!m.breached(), "insufficient samples must not page anyone");
    }

    #[test]
    fn window_slides() {
        let m = monitor(0.5, 4);
        for _ in 0..4 {
            m.record(500_000_000); // all bad
        }
        assert!(m.breached());
        for _ in 0..4 {
            m.record(1_000_000); // all good → violations age out
        }
        assert_eq!(m.attainment(), 1.0);
        assert!(!m.breached());
        assert_eq!(m.samples(), 4);
    }

    #[test]
    fn boundary_latency_counts_as_met() {
        let m = monitor(1.0, 2);
        m.record(100_000_000); // exactly the SLO
        m.record(100_000_001); // one nano over
        assert!((m.attainment() - 0.5).abs() < 1e-9);
    }
}
