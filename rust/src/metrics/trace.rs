//! Request-scoped tracing: per-stage spans in a lock-free ring buffer.
//!
//! Every request gets a trace ID minted when the server accepts it for
//! dispatch. As the request moves through the pipeline, each stage records
//! one [`SpanRecord`] — `queue_wait`, `batch_form`, `embed`, `scan`,
//! `merge`, `respond` — labeled by work class × route × codec. Spans land
//! in two places:
//!
//! 1. a fixed-capacity overwrite-oldest [`SpanRing`] (plus a smaller ring
//!    for spans over the slow threshold), served raw by `GET /v1/trace`;
//! 2. a pre-resolved per-(stage, class, route, codec) [`Histogram`] in the
//!    service [`Registry`], surfaced as p50/p95/p99 in `/v1/stats` and as
//!    Prometheus text on `/v1/metrics`.
//!
//! The recording path is allocation-free: a span is seven atomic stores
//! into a pre-allocated slot plus one histogram bucket increment. Ring
//! slots use a per-slot sequence (seqlock-style) so `snapshot()` never
//! blocks recorders and never returns a torn record — a record raced by
//! an overwriting writer fails revalidation and is skipped instead.
//!
//! The metric name schema (`trace.<stage>.<class>.<route>.<codec>`, with
//! `all` for dimensions a stage does not distinguish) is shared verbatim
//! by the DES (`sim/des.rs`), so simulated scenarios and live traces are
//! directly comparable. See `docs/OBSERVABILITY.md`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicU64, Ordering};

use super::histogram::Histogram;
use super::registry::Registry;

/// Capacity of the slow-span ring (spans whose duration met the slow
/// threshold); small because slow spans should be rare.
pub const SLOW_RING_CAPACITY: usize = 256;

/// Pipeline stage a span measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Enqueue at `submit` until a device worker drains the batch.
    QueueWait,
    /// Drain until the backend call begins (batch assembly overhead).
    BatchForm,
    /// The backend embed call, attributed to each query in the batch.
    Embed,
    /// One scan leg over a panel of query vectors (per route).
    Scan,
    /// Assembling per-query hit lists into the response ordering.
    Merge,
    /// Serializing + writing the HTTP response.
    Respond,
}

impl Stage {
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchForm => "batch_form",
            Stage::Embed => "embed",
            Stage::Scan => "scan",
            Stage::Merge => "merge",
            Stage::Respond => "respond",
        }
    }

    fn from_u8(v: u8) -> Option<Stage> {
        Some(match v {
            0 => Stage::QueueWait,
            1 => Stage::BatchForm,
            2 => Stage::Embed,
            3 => Stage::Scan,
            4 => Stage::Merge,
            5 => Stage::Respond,
            _ => return None,
        })
    }

    fn to_u8(self) -> u8 {
        match self {
            Stage::QueueWait => 0,
            Stage::BatchForm => 1,
            Stage::Embed => 2,
            Stage::Scan => 3,
            Stage::Merge => 4,
            Stage::Respond => 5,
        }
    }
}

/// Work-class label dimension (`all` where a stage spans classes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClassLabel {
    Embed,
    Retrieve,
    Ingest,
    All,
}

impl ClassLabel {
    pub fn as_str(self) -> &'static str {
        match self {
            ClassLabel::Embed => "embed",
            ClassLabel::Retrieve => "retrieve",
            ClassLabel::Ingest => "ingest",
            ClassLabel::All => "all",
        }
    }

    fn from_u8(v: u8) -> Option<ClassLabel> {
        Some(match v {
            0 => ClassLabel::Embed,
            1 => ClassLabel::Retrieve,
            2 => ClassLabel::Ingest,
            3 => ClassLabel::All,
            _ => return None,
        })
    }

    fn to_u8(self) -> u8 {
        match self {
            ClassLabel::Embed => 0,
            ClassLabel::Retrieve => 1,
            ClassLabel::Ingest => 2,
            ClassLabel::All => 3,
        }
    }
}

/// Route label dimension (`all` for stages with no device affinity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteLabel {
    Npu,
    Cpu,
    All,
}

impl RouteLabel {
    pub fn as_str(self) -> &'static str {
        match self {
            RouteLabel::Npu => "npu",
            RouteLabel::Cpu => "cpu",
            RouteLabel::All => "all",
        }
    }

    fn from_u8(v: u8) -> Option<RouteLabel> {
        Some(match v {
            0 => RouteLabel::Npu,
            1 => RouteLabel::Cpu,
            2 => RouteLabel::All,
            _ => return None,
        })
    }

    fn to_u8(self) -> u8 {
        match self {
            RouteLabel::Npu => 0,
            RouteLabel::Cpu => 1,
            RouteLabel::All => 2,
        }
    }
}

/// Codec label dimension (only the scan stage distinguishes codecs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecLabel {
    F32,
    F16,
    Int8,
    Pq4,
    Pq8,
    All,
}

impl CodecLabel {
    pub fn as_str(self) -> &'static str {
        match self {
            CodecLabel::F32 => "f32",
            CodecLabel::F16 => "f16",
            CodecLabel::Int8 => "int8",
            CodecLabel::Pq4 => "pq4",
            CodecLabel::Pq8 => "pq8",
            CodecLabel::All => "all",
        }
    }

    fn from_u8(v: u8) -> Option<CodecLabel> {
        Some(match v {
            0 => CodecLabel::F32,
            1 => CodecLabel::F16,
            2 => CodecLabel::Int8,
            3 => CodecLabel::Pq4,
            4 => CodecLabel::Pq8,
            5 => CodecLabel::All,
            _ => return None,
        })
    }

    fn to_u8(self) -> u8 {
        match self {
            CodecLabel::F32 => 0,
            CodecLabel::F16 => 1,
            CodecLabel::Int8 => 2,
            CodecLabel::Pq4 => 3,
            CodecLabel::Pq8 => 4,
            CodecLabel::All => 5,
        }
    }
}

/// One recorded stage span. `start_ns` is relative to the tracer's epoch
/// (process-local monotonic time, not wall clock).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub stage: Stage,
    pub class: ClassLabel,
    pub route: RouteLabel,
    pub codec: CodecLabel,
    pub start_ns: u64,
    pub dur_ns: u64,
}

impl SpanRecord {
    fn meta(&self) -> u64 {
        self.stage.to_u8() as u64
            | (self.class.to_u8() as u64) << 8
            | (self.route.to_u8() as u64) << 16
            | (self.codec.to_u8() as u64) << 24
    }

    fn unpack(trace_id: u64, meta: u64, start_ns: u64, dur_ns: u64) -> Option<SpanRecord> {
        Some(SpanRecord {
            trace_id,
            stage: Stage::from_u8(meta as u8)?,
            class: ClassLabel::from_u8((meta >> 8) as u8)?,
            route: RouteLabel::from_u8((meta >> 16) as u8)?,
            codec: CodecLabel::from_u8((meta >> 24) as u8)?,
            start_ns,
            dur_ns,
        })
    }
}

/// One ring slot. Every field is an atomic so a snapshot racing the
/// writer reads defined values; the `seq` word both serializes writers
/// (CAS claim in [`SpanRing::push`]) and lets readers detect and
/// discard records overwritten mid-read — never UB, never a tear.
struct Slot {
    /// Seqlock word: `2*pos + 1` while slot `pos`'s record is being
    /// written ("dirty"), `2*pos + 2` once published. Strictly increases
    /// per slot across wraps (pos, pos+cap, ...), so stale positions are
    /// unambiguous.
    seq: AtomicU64,
    trace_id: AtomicU64,
    meta: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            trace_id: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            start_ns: AtomicU64::new(0),
            dur_ns: AtomicU64::new(0),
        }
    }
}

/// Fixed-capacity lock-free span ring: `push` is wait-free (one
/// fetch_add + a CAS claim + five stores, no allocation), oldest
/// records are overwritten once the ring is full, and `snapshot`
/// returns only records it can prove untorn. When the ring wraps fast
/// enough that two in-flight writers collide on one slot, the claim
/// race loser's record is dropped rather than torn.
pub struct SpanRing {
    slots: Box<[Slot]>,
    head: AtomicU64,
}

impl SpanRing {
    /// Heap-constructed (no statics) so the same type works under loom,
    /// whose atomics have no `const fn new`.
    pub fn new(capacity: usize) -> SpanRing {
        let cap = capacity.max(1);
        SpanRing {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever pushed (monotone; exceeds `capacity` once the
    /// ring has wrapped).
    pub fn recorded(&self) -> u64 {
        // ordering: monotone statistic; no payload is published through it.
        self.head.load(Ordering::Relaxed)
    }

    /// Records lost to overwrite so far.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    pub fn push(&self, rec: SpanRecord) {
        let cap = self.slots.len() as u64;
        // ordering: allocates a unique position; slot contents are
        // published by the seqlock stores below, not by this counter.
        let pos = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(pos % cap) as usize];
        // Claim the slot via its seq word. A slot is writable only while
        // quiescent (even seq) and only by a strictly newer position —
        // two writers can reach the same slot when the ring wraps within
        // their concurrency window, and concurrent field stores from
        // both could tear in a way the reader's revalidation cannot
        // detect (each field has its own modification order). Losing the
        // claim drops *this* record — bounded loss under a load where
        // the ring is wrapping anyway — and never blocks.
        // ordering: Acquire on success pairs with the previous writer's
        // publishing Release so this writer's field stores cannot be
        // reordered into the prior record's critical section.
        let cur = slot.seq.load(Ordering::Relaxed);
        if cur >= 2 * pos + 1 // a newer writer claimed or published
            || cur % 2 == 1 // an older writer is mid-write
            || slot
                .seq
                .compare_exchange(cur, 2 * pos + 1, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            return;
        }
        slot.trace_id.store(rec.trace_id, Ordering::Release);
        slot.meta.store(rec.meta(), Ordering::Release);
        slot.start_ns.store(rec.start_ns, Ordering::Release);
        slot.dur_ns.store(rec.dur_ns, Ordering::Release);
        slot.seq.store(2 * pos + 2, Ordering::Release);
    }

    /// Copy out the currently-live window, oldest first. Concurrent
    /// pushes may cause individual records to be skipped (dirty or
    /// overwritten mid-read); what is returned is never torn.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        // ordering: head only chooses the scan window; staleness is
        // tolerated because each slot is validated by its own seq.
        let head = self.head.load(Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let mut out = Vec::with_capacity(head.min(cap) as usize);
        for pos in head.saturating_sub(cap)..head {
            let slot = &self.slots[(pos % cap) as usize];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 != 2 * pos + 2 {
                continue; // never written, dirty, or already overwritten
            }
            let trace_id = slot.trace_id.load(Ordering::Acquire);
            let meta = slot.meta.load(Ordering::Acquire);
            let start_ns = slot.start_ns.load(Ordering::Acquire);
            let dur_ns = slot.dur_ns.load(Ordering::Acquire);
            // ordering: revalidation. The Acquire field loads above pin
            // this load after them; if any field value came from a newer
            // writer, that writer's Release store carries its own dirty
            // seq (sequenced before the field store), so this reload
            // observes a seq != s1 and the record is discarded.
            let s2 = slot.seq.load(Ordering::Relaxed);
            if s2 != s1 {
                continue;
            }
            if let Some(rec) = SpanRecord::unpack(trace_id, meta, start_ns, dur_ns) {
                out.push(rec);
            }
        }
        out
    }
}

/// The `(name, stage, class, route, codec)` table of every per-stage
/// latency histogram the tracer aggregates into. Names follow
/// `trace.<stage>.<class>.<route>.<codec>` with `all` for dimensions the
/// stage does not distinguish; `sim/des.rs` emits the same names so DES
/// scenarios and live traces are schema-compatible.
pub const STAGE_METRICS: &[(&str, Stage, ClassLabel, RouteLabel, CodecLabel)] = &[
    ("trace.queue_wait.embed.npu.all", Stage::QueueWait, ClassLabel::Embed, RouteLabel::Npu, CodecLabel::All),
    ("trace.queue_wait.embed.cpu.all", Stage::QueueWait, ClassLabel::Embed, RouteLabel::Cpu, CodecLabel::All),
    ("trace.queue_wait.ingest.npu.all", Stage::QueueWait, ClassLabel::Ingest, RouteLabel::Npu, CodecLabel::All),
    ("trace.queue_wait.ingest.cpu.all", Stage::QueueWait, ClassLabel::Ingest, RouteLabel::Cpu, CodecLabel::All),
    ("trace.batch_form.embed.npu.all", Stage::BatchForm, ClassLabel::Embed, RouteLabel::Npu, CodecLabel::All),
    ("trace.batch_form.embed.cpu.all", Stage::BatchForm, ClassLabel::Embed, RouteLabel::Cpu, CodecLabel::All),
    ("trace.batch_form.ingest.npu.all", Stage::BatchForm, ClassLabel::Ingest, RouteLabel::Npu, CodecLabel::All),
    ("trace.batch_form.ingest.cpu.all", Stage::BatchForm, ClassLabel::Ingest, RouteLabel::Cpu, CodecLabel::All),
    ("trace.embed.embed.npu.all", Stage::Embed, ClassLabel::Embed, RouteLabel::Npu, CodecLabel::All),
    ("trace.embed.embed.cpu.all", Stage::Embed, ClassLabel::Embed, RouteLabel::Cpu, CodecLabel::All),
    ("trace.embed.ingest.npu.all", Stage::Embed, ClassLabel::Ingest, RouteLabel::Npu, CodecLabel::All),
    ("trace.embed.ingest.cpu.all", Stage::Embed, ClassLabel::Ingest, RouteLabel::Cpu, CodecLabel::All),
    ("trace.scan.retrieve.npu.f32", Stage::Scan, ClassLabel::Retrieve, RouteLabel::Npu, CodecLabel::F32),
    ("trace.scan.retrieve.cpu.f32", Stage::Scan, ClassLabel::Retrieve, RouteLabel::Cpu, CodecLabel::F32),
    ("trace.scan.retrieve.cpu.f16", Stage::Scan, ClassLabel::Retrieve, RouteLabel::Cpu, CodecLabel::F16),
    ("trace.scan.retrieve.cpu.int8", Stage::Scan, ClassLabel::Retrieve, RouteLabel::Cpu, CodecLabel::Int8),
    ("trace.scan.retrieve.cpu.pq4", Stage::Scan, ClassLabel::Retrieve, RouteLabel::Cpu, CodecLabel::Pq4),
    ("trace.scan.retrieve.cpu.pq8", Stage::Scan, ClassLabel::Retrieve, RouteLabel::Cpu, CodecLabel::Pq8),
    ("trace.merge.retrieve.npu.all", Stage::Merge, ClassLabel::Retrieve, RouteLabel::Npu, CodecLabel::All),
    ("trace.merge.retrieve.cpu.all", Stage::Merge, ClassLabel::Retrieve, RouteLabel::Cpu, CodecLabel::All),
    ("trace.respond.all.all.all", Stage::Respond, ClassLabel::All, RouteLabel::All, CodecLabel::All),
];

/// Project a span's labels onto the dimensions its stage aggregates
/// under (`all` for the rest) — the canonical form used in metric names.
pub fn canonical_labels(
    stage: Stage,
    class: ClassLabel,
    route: RouteLabel,
    codec: CodecLabel,
) -> (ClassLabel, RouteLabel, CodecLabel) {
    match stage {
        Stage::QueueWait | Stage::BatchForm | Stage::Embed => (class, route, CodecLabel::All),
        Stage::Scan => (ClassLabel::Retrieve, route, codec),
        Stage::Merge => (ClassLabel::Retrieve, route, CodecLabel::All),
        Stage::Respond => (ClassLabel::All, RouteLabel::All, CodecLabel::All),
    }
}

fn stage_index(
    stage: Stage,
    class: ClassLabel,
    route: RouteLabel,
    codec: CodecLabel,
) -> Option<usize> {
    let (c, r, q) = canonical_labels(stage, class, route, codec);
    STAGE_METRICS
        .iter()
        .position(|&(_, s, sc, sr, sq)| s == stage && sc == c && sr == r && sq == q)
}

/// Registry name for a stage histogram, `None` if the label combination
/// is not part of the schema. The DES uses this to emit live-compatible
/// metric names.
pub fn stage_metric_name(
    stage: Stage,
    class: ClassLabel,
    route: RouteLabel,
    codec: CodecLabel,
) -> Option<&'static str> {
    stage_index(stage, class, route, codec).map(|i| STAGE_METRICS[i].0)
}

/// Per-service tracer: mints trace IDs, records spans into the ring(s),
/// and aggregates durations into the pre-resolved stage histograms.
pub struct Tracer {
    epoch: Instant,
    next_id: AtomicU64,
    ring: SpanRing,
    slow: SpanRing,
    slow_threshold_ns: u64,
    /// Parallel to [`STAGE_METRICS`]; resolved once at construction so
    /// the span path never touches the registry's name map.
    hists: Vec<Arc<Histogram>>,
}

impl Tracer {
    pub fn new(metrics: &Registry, capacity: usize, slow_threshold: Duration) -> Tracer {
        Tracer {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            ring: SpanRing::new(capacity),
            slow: SpanRing::new(SLOW_RING_CAPACITY),
            slow_threshold_ns: slow_threshold.as_nanos() as u64,
            hists: STAGE_METRICS
                .iter()
                .map(|&(name, ..)| metrics.histogram(name))
                .collect(),
        }
    }

    /// Mint a fresh process-unique trace ID (non-zero).
    pub fn mint(&self) -> u64 {
        // ordering: unique-ID counter; nothing is published through it.
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Record one stage span. Allocation-free: histogram bucket add +
    /// ring slot stores.
    pub fn span(
        &self,
        trace_id: u64,
        stage: Stage,
        class: ClassLabel,
        route: RouteLabel,
        codec: CodecLabel,
        start: Instant,
        dur: Duration,
    ) {
        let rec = SpanRecord {
            trace_id,
            stage,
            class,
            route,
            codec,
            start_ns: start.saturating_duration_since(self.epoch).as_nanos() as u64,
            dur_ns: dur.as_nanos() as u64,
        };
        if let Some(i) = stage_index(stage, class, route, codec) {
            self.hists[i].record(rec.dur_ns);
        }
        self.ring.push(rec);
        if rec.dur_ns >= self.slow_threshold_ns {
            self.slow.push(rec);
        }
    }

    /// Recent spans, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.ring.snapshot()
    }

    /// Recent spans at or over the slow threshold, oldest first.
    pub fn slow_snapshot(&self) -> Vec<SpanRecord> {
        self.slow.snapshot()
    }

    pub fn slow_threshold_ns(&self) -> u64 {
        self.slow_threshold_ns
    }

    pub fn recorded(&self) -> u64 {
        self.ring.recorded()
    }

    pub fn dropped(&self) -> u64 {
        self.ring.dropped()
    }

    pub fn capacity(&self) -> usize {
        self.ring.capacity()
    }

    /// `(name, histogram)` pairs for every stage metric, table order.
    pub fn stage_histograms(&self) -> impl Iterator<Item = (&'static str, &Arc<Histogram>)> {
        STAGE_METRICS
            .iter()
            .map(|&(name, ..)| name)
            .zip(self.hists.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(trace_id: u64, dur_ns: u64) -> SpanRecord {
        SpanRecord {
            trace_id,
            stage: Stage::Embed,
            class: ClassLabel::Embed,
            route: RouteLabel::Npu,
            codec: CodecLabel::All,
            start_ns: trace_id * 10,
            dur_ns,
        }
    }

    #[test]
    fn ring_roundtrips_records_in_order() {
        let ring = SpanRing::new(8);
        for i in 0..5 {
            ring.push(rec(i, i * 100));
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 5);
        for (i, r) in snap.iter().enumerate() {
            assert_eq!(*r, rec(i as u64, i as u64 * 100));
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_stays_bounded() {
        let ring = SpanRing::new(4);
        for i in 0..100 {
            ring.push(rec(i, 1));
        }
        assert_eq!(ring.capacity(), 4);
        assert_eq!(ring.recorded(), 100);
        assert_eq!(ring.dropped(), 96);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4, "only the last `capacity` records survive");
        let ids: Vec<u64> = snap.iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![96, 97, 98, 99]);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let ring = SpanRing::new(0);
        ring.push(rec(7, 7));
        assert_eq!(ring.capacity(), 1);
        assert_eq!(ring.snapshot().len(), 1);
    }

    #[test]
    fn meta_pack_unpack_roundtrips_all_labels() {
        for &(_, stage, class, route, codec) in STAGE_METRICS {
            let r = SpanRecord {
                trace_id: 42,
                stage,
                class,
                route,
                codec,
                start_ns: 1,
                dur_ns: 2,
            };
            assert_eq!(SpanRecord::unpack(42, r.meta(), 1, 2), Some(r));
        }
    }

    #[test]
    fn stage_metric_names_unique_and_resolvable() {
        let mut names: Vec<&str> = STAGE_METRICS.iter().map(|&(n, ..)| n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), STAGE_METRICS.len(), "duplicate metric name");
        for &(name, stage, class, route, codec) in STAGE_METRICS {
            assert_eq!(stage_metric_name(stage, class, route, codec), Some(name));
        }
        // Labels a stage does not distinguish are projected, not dropped.
        assert_eq!(
            stage_metric_name(Stage::Respond, ClassLabel::Embed, RouteLabel::Npu, CodecLabel::F32),
            Some("trace.respond.all.all.all")
        );
        // Unknown scan codec combinations are simply unaggregated.
        assert_eq!(
            stage_metric_name(Stage::Scan, ClassLabel::Retrieve, RouteLabel::Npu, CodecLabel::Pq8),
            None
        );
    }

    #[test]
    fn tracer_ids_unique_across_threads() {
        let reg = Registry::new();
        let tr = Arc::new(Tracer::new(&reg, 16, Duration::from_millis(50)));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let tr = Arc::clone(&tr);
                std::thread::spawn(move || (0..1000).map(|_| tr.mint()).collect::<Vec<u64>>())
            })
            .collect();
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        let before = all.len();
        all.dedup();
        assert_eq!(all.len(), before, "trace IDs must be unique");
        assert!(!all.contains(&0), "0 is reserved for 'untraced'");
    }

    #[test]
    fn tracer_feeds_stage_histogram_and_slow_ring() {
        let reg = Registry::new();
        let tr = Tracer::new(&reg, 16, Duration::from_micros(10));
        let t0 = Instant::now();
        let id = tr.mint();
        tr.span(
            id,
            Stage::Scan,
            ClassLabel::Retrieve,
            RouteLabel::Cpu,
            CodecLabel::Pq8,
            t0,
            Duration::from_micros(5),
        );
        tr.span(
            id,
            Stage::Scan,
            ClassLabel::Retrieve,
            RouteLabel::Cpu,
            CodecLabel::Pq8,
            t0,
            Duration::from_micros(50),
        );
        assert_eq!(reg.histogram("trace.scan.retrieve.cpu.pq8").count(), 2);
        assert_eq!(tr.snapshot().len(), 2);
        let slow = tr.slow_snapshot();
        assert_eq!(slow.len(), 1, "only the 50us span crosses the threshold");
        assert_eq!(slow[0].dur_ns, 50_000);
    }

    #[test]
    fn concurrent_record_vs_snapshot_never_tears() {
        // Heavier-weight std counterpart of the loom model in
        // tests/loom/trace.rs: writers maintain dur == trace_id * 3 and
        // start == trace_id + 1; any torn read would break the invariant.
        let ring = Arc::new(SpanRing::new(8));
        let stop = Arc::new(AtomicU64::new(0));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let ring = Arc::clone(&ring);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut i = w as u64;
                    // ordering: test shutdown flag only.
                    while stop.load(Ordering::Relaxed) == 0 {
                        ring.push(SpanRecord {
                            trace_id: i,
                            stage: Stage::Embed,
                            class: ClassLabel::Embed,
                            route: RouteLabel::Npu,
                            codec: CodecLabel::All,
                            start_ns: i + 1,
                            dur_ns: i * 3,
                        });
                        i += 4;
                    }
                })
            })
            .collect();
        for _ in 0..2000 {
            for r in ring.snapshot() {
                assert_eq!(r.dur_ns, r.trace_id * 3, "torn record: {r:?}");
                assert_eq!(r.start_ns, r.trace_id + 1, "torn record: {r:?}");
            }
        }
        // ordering: test shutdown flag only.
        stop.store(1, Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
    }
}
