//! Named counters + histograms with a JSON snapshot (served at /metrics).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;

use super::histogram::Histogram;
use crate::util::json::Json;

/// Monotone counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Process-wide metrics registry. Cheap to clone (Arc inside).
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.inner.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.inner.histograms.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// JSON snapshot of everything (histograms as percentile summaries, ns).
    pub fn snapshot(&self) -> Json {
        let counters = self.inner.counters.lock().unwrap();
        let histograms = self.inner.histograms.lock().unwrap();
        let mut out: Vec<(String, Json)> = Vec::new();
        for (name, c) in counters.iter() {
            out.push((name.clone(), Json::Num(c.get() as f64)));
        }
        for (name, h) in histograms.iter() {
            out.push((
                name.clone(),
                Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("mean_ns", Json::num(h.mean())),
                    ("p50_ns", Json::num(h.p50() as f64)),
                    ("p95_ns", Json::num(h.p95() as f64)),
                    ("p99_ns", Json::num(h.p99() as f64)),
                    ("max_ns", Json::num(h.max() as f64)),
                ]),
            ));
        }
        Json::Obj(out)
    }

    /// All registered histograms by name (read-only view for callers that
    /// want to shape their own summaries, e.g. the `/v1/stats` stage block).
    pub fn histograms(&self) -> Vec<(String, Arc<Histogram>)> {
        let map = self.inner.histograms.lock().unwrap();
        map.iter().map(|(n, h)| (n.clone(), Arc::clone(h))).collect()
    }

    /// Render everything as Prometheus text exposition (format 0.0.4).
    ///
    /// Names gain a `windve_` prefix with non-alphanumerics folded to `_`.
    /// Plain histograms render as summaries (`quantile` 0.5/0.95/0.99 +
    /// `_sum`/`_count`). The `trace.<stage>.<class>.<route>.<codec>` stage
    /// histograms fold into a single labeled family,
    /// `windve_stage_duration_ns{stage=,class=,route=,codec=}`; empty
    /// stage series are omitted to keep scrapes small.
    pub fn prometheus(&self) -> String {
        let counters = self.inner.counters.lock().unwrap();
        let histograms = self.inner.histograms.lock().unwrap();
        let mut out = String::new();
        for (name, c) in counters.iter() {
            let pname = prom_name(name);
            out.push_str(&format!("# TYPE {pname} counter\n{pname} {}\n", c.get()));
        }
        for (name, h) in histograms.iter() {
            if stage_labels(name).is_some() {
                continue; // folded into the labeled family below
            }
            let pname = prom_name(name);
            out.push_str(&format!("# TYPE {pname} summary\n"));
            for (q, v) in [("0.5", h.p50()), ("0.95", h.p95()), ("0.99", h.p99())] {
                out.push_str(&format!("{pname}{{quantile=\"{q}\"}} {v}\n"));
            }
            out.push_str(&format!("{pname}_sum {}\n", h.sum()));
            out.push_str(&format!("{pname}_count {}\n", h.count()));
        }
        let mut wrote_type = false;
        for (name, h) in histograms.iter() {
            let labels = match stage_labels(name) {
                Some(l) if h.count() > 0 => l,
                _ => continue,
            };
            if !wrote_type {
                out.push_str("# TYPE windve_stage_duration_ns summary\n");
                wrote_type = true;
            }
            for (q, v) in [("0.5", h.p50()), ("0.95", h.p95()), ("0.99", h.p99())] {
                out.push_str(&format!(
                    "windve_stage_duration_ns{{{labels},quantile=\"{q}\"}} {v}\n"
                ));
            }
            out.push_str(&format!("windve_stage_duration_ns_sum{{{labels}}} {}\n", h.sum()));
            out.push_str(&format!(
                "windve_stage_duration_ns_count{{{labels}}} {}\n",
                h.count()
            ));
        }
        out
    }
}

/// `service.e2e_npu_ns` → `windve_service_e2e_npu_ns`.
fn prom_name(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 7);
    s.push_str("windve_");
    for ch in name.chars() {
        s.push(if ch.is_ascii_alphanumeric() { ch } else { '_' });
    }
    s
}

/// Label set for a `trace.<stage>.<class>.<route>.<codec>` metric name.
fn stage_labels(name: &str) -> Option<String> {
    let rest = name.strip_prefix("trace.")?;
    let mut parts = rest.split('.');
    let (stage, class, route, codec) =
        (parts.next()?, parts.next()?, parts.next()?, parts.next()?);
    if parts.next().is_some() {
        return None;
    }
    Some(format!(
        "stage=\"{stage}\",class=\"{class}\",route=\"{route}\",codec=\"{codec}\""
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_shared_by_name() {
        let r = Registry::new();
        r.counter("a").inc();
        r.counter("a").add(4);
        assert_eq!(r.counter("a").get(), 5);
        assert_eq!(r.counter("b").get(), 0);
    }

    #[test]
    fn histograms_shared_by_name() {
        let r = Registry::new();
        r.histogram("lat").record(100);
        assert_eq!(r.histogram("lat").count(), 1);
    }

    #[test]
    fn snapshot_contains_everything() {
        let r = Registry::new();
        r.counter("requests").add(3);
        r.histogram("lat").record(500);
        let snap = r.snapshot();
        assert_eq!(snap.get("requests").unwrap().as_u64(), Some(3));
        assert_eq!(snap.path("lat.count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn clone_shares_state() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }

    #[test]
    fn snapshot_histogram_has_p95() {
        let r = Registry::new();
        r.histogram("lat").record(500);
        assert!(r.snapshot().path("lat.p95_ns").is_some());
    }

    #[test]
    fn prometheus_renders_counters_and_summaries() {
        let r = Registry::new();
        r.counter("service.accepted").add(7);
        r.histogram("service.e2e_npu_ns").record(1000);
        let text = r.prometheus();
        assert!(text.contains("# TYPE windve_service_accepted counter\n"));
        assert!(text.contains("windve_service_accepted 7\n"));
        assert!(text.contains("# TYPE windve_service_e2e_npu_ns summary\n"));
        assert!(text.contains("windve_service_e2e_npu_ns{quantile=\"0.95\"}"));
        assert!(text.contains("windve_service_e2e_npu_ns_count 1\n"));
        assert!(text.contains("windve_service_e2e_npu_ns_sum 1000\n"));
    }

    #[test]
    fn prometheus_folds_stage_histograms_into_labeled_family() {
        let r = Registry::new();
        r.histogram("trace.scan.retrieve.cpu.pq8").record(2000);
        r.histogram("trace.embed.embed.npu.all"); // empty → omitted
        let text = r.prometheus();
        assert!(text.contains("# TYPE windve_stage_duration_ns summary\n"));
        assert!(text.contains(
            "windve_stage_duration_ns{stage=\"scan\",class=\"retrieve\",route=\"cpu\",codec=\"pq8\",quantile=\"0.5\"}"
        ));
        assert!(text.contains(
            "windve_stage_duration_ns_count{stage=\"scan\",class=\"retrieve\",route=\"cpu\",codec=\"pq8\"} 1\n"
        ));
        assert!(
            !text.contains("stage=\"embed\""),
            "empty stage series must be omitted"
        );
        // No raw trace.* summary leaks outside the family.
        assert!(!text.contains("windve_trace_"));
    }
}
