//! Named counters + histograms with a JSON snapshot (served at /metrics).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::histogram::Histogram;
use crate::util::json::Json;

/// Monotone counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Process-wide metrics registry. Cheap to clone (Arc inside).
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.inner.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.inner.histograms.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// JSON snapshot of everything (histograms as percentile summaries, ns).
    pub fn snapshot(&self) -> Json {
        let counters = self.inner.counters.lock().unwrap();
        let histograms = self.inner.histograms.lock().unwrap();
        let mut out: Vec<(String, Json)> = Vec::new();
        for (name, c) in counters.iter() {
            out.push((name.clone(), Json::Num(c.get() as f64)));
        }
        for (name, h) in histograms.iter() {
            out.push((
                name.clone(),
                Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("mean_ns", Json::num(h.mean())),
                    ("p50_ns", Json::num(h.p50() as f64)),
                    ("p99_ns", Json::num(h.p99() as f64)),
                    ("max_ns", Json::num(h.max() as f64)),
                ]),
            ));
        }
        Json::Obj(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_shared_by_name() {
        let r = Registry::new();
        r.counter("a").inc();
        r.counter("a").add(4);
        assert_eq!(r.counter("a").get(), 5);
        assert_eq!(r.counter("b").get(), 0);
    }

    #[test]
    fn histograms_shared_by_name() {
        let r = Registry::new();
        r.histogram("lat").record(100);
        assert_eq!(r.histogram("lat").count(), 1);
    }

    #[test]
    fn snapshot_contains_everything() {
        let r = Registry::new();
        r.counter("requests").add(3);
        r.histogram("lat").record(500);
        let snap = r.snapshot();
        assert_eq!(snap.get("requests").unwrap().as_u64(), Some(3));
        assert_eq!(snap.path("lat.count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn clone_shares_state() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }
}
