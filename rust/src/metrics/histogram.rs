//! Log-bucketed latency histogram (HDR-style, lock-free recording).
//!
//! Values (nanoseconds or any u64 unit) land in buckets of ~2.5% relative
//! width: 64 base-2 magnitudes x 32 linear sub-buckets. Quantile error is
//! bounded by bucket width, plenty for SLO accounting.

use crate::util::sync::atomic::{AtomicU64, Ordering};

const SUB_BITS: u32 = 5; // 32 sub-buckets per magnitude
const SUB: usize = 1 << SUB_BITS;
const MAGNITUDES: usize = 64;
const BUCKETS: usize = MAGNITUDES * SUB;

/// Concurrent histogram; `record` is wait-free (one atomic add).
pub struct Histogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn index(value: u64) -> usize {
        let v = value.max(1);
        let mag = 63 - v.leading_zeros() as usize;
        if mag < SUB_BITS as usize {
            // Small values: identity mapping within the first magnitudes.
            return v as usize;
        }
        let sub = ((v >> (mag as u32 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        mag * SUB + sub
    }

    /// Representative (upper-bound) value for a bucket index.
    fn bucket_high(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64 + 1;
        }
        let mag = idx / SUB;
        let sub = (idx % SUB) as u64;
        let base = 1u64 << mag;
        base + ((sub + 1) << (mag as u32 - SUB_BITS)) - 1
    }

    pub fn record(&self, value: u64) {
        self.counts[Self::index(value)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        // CAS loop instead of fetch_max so the shimmed type stays
        // loom-compatible (loom's AtomicU64 lacks fetch_max).
        let mut cur = self.max.load(Ordering::Relaxed);
        while value > cur {
            match self
                .max
                .compare_exchange_weak(cur, value, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values (Prometheus `_sum`).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Quantile in `[0, 1]`; returns 0 when empty. Within-bucket error only.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_high(i).min(self.max());
            }
        }
        self.max()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Reset all counts (not concurrent-safe with recorders; test/bench use).
    pub fn clear(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.total.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn single_value() {
        let h = Histogram::new();
        h.record(1000);
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), 1000);
        let p = h.quantile(0.5);
        assert!((950..=1050).contains(&p), "p50 {p}");
    }

    #[test]
    fn quantile_relative_error_bounded() {
        let h = Histogram::new();
        let mut rng = Pcg::new(5);
        let mut vals: Vec<u64> = (0..20_000).map(|_| rng.range(100, 10_000_000)).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for &q in &[0.5, 0.9, 0.99, 0.999] {
            let exact = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)];
            let est = h.quantile(q);
            let rel = (est as f64 - exact as f64).abs() / exact as f64;
            assert!(rel < 0.08, "q={q} exact={exact} est={est} rel={rel}");
        }
    }

    #[test]
    fn quantiles_monotone() {
        let h = Histogram::new();
        let mut rng = Pcg::new(6);
        for _ in 0..5000 {
            h.record(rng.range(1, 1_000_000));
        }
        let mut prev = 0;
        for i in 0..=100 {
            let q = h.quantile(i as f64 / 100.0);
            assert!(q >= prev, "quantiles must be monotone");
            prev = q;
        }
    }

    #[test]
    fn mean_and_max_exact() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert_eq!(h.mean(), 25.0);
        assert_eq!(h.max(), 40);
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 80_000);
    }

    #[test]
    fn clear_resets() {
        let h = Histogram::new();
        h.record(5);
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn small_values_exact() {
        let h = Histogram::new();
        for v in 1..=16u64 {
            h.record(v);
        }
        // identity-mapped region: p100 == 16
        assert_eq!(h.quantile(1.0), 16);
    }
}
