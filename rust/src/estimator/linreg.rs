//! Ordinary least squares for the paper's Eq. 12: `t = α·C + β`,
//! constrained to α, β ≥ 0 (the paper's stated constraint).

/// A fitted latency-vs-concurrency line.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub alpha: f64,
    pub beta: f64,
    /// Coefficient of determination on the fitting data.
    pub r2: f64,
}

impl LinearFit {
    /// OLS fit over (concurrency, latency) points with the α, β ≥ 0
    /// constraint applied by projection (clamp + refit of the free term).
    ///
    /// Panics if fewer than 2 points are supplied.
    pub fn fit(points: &[(f64, f64)]) -> LinearFit {
        assert!(points.len() >= 2, "need >= 2 profiling points");
        let n = points.len() as f64;
        let sx: f64 = points.iter().map(|p| p.0).sum();
        let sy: f64 = points.iter().map(|p| p.1).sum();
        let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        let (mut alpha, mut beta);
        if denom.abs() < 1e-12 {
            // All x identical: flat line through the mean.
            alpha = 0.0;
            beta = sy / n;
        } else {
            alpha = (n * sxy - sx * sy) / denom;
            beta = (sy - alpha * sx) / n;
        }
        // α, β ≥ 0 projection (paper constraint): clamp the violated
        // coefficient and refit the other unconstrained.
        if alpha < 0.0 {
            alpha = 0.0;
            beta = (sy / n).max(0.0);
        } else if beta < 0.0 {
            beta = 0.0;
            alpha = if sxx.abs() < 1e-12 { 0.0 } else { (sxy / sxx).max(0.0) };
        }

        let mean_y = sy / n;
        let ss_tot: f64 = points.iter().map(|p| (p.1 - mean_y).powi(2)).sum();
        let ss_res: f64 = points
            .iter()
            .map(|p| (p.1 - (alpha * p.0 + beta)).powi(2))
            .sum();
        let r2 = if ss_tot < 1e-12 { 1.0 } else { 1.0 - ss_res / ss_tot };
        LinearFit { alpha, beta, r2 }
    }

    /// Predicted latency at concurrency `c` (Eq. 12).
    pub fn predict(&self, c: f64) -> f64 {
        self.alpha * c + self.beta
    }

    /// Largest concurrency whose predicted latency meets `slo` — the
    /// paper's fast estimate of the queue depth (Eqs. 7-10 via Eq. 12).
    pub fn max_concurrency(&self, slo: f64) -> usize {
        if self.beta > slo {
            return 0; // even one query times out (Eq. 11)
        }
        if self.alpha <= 0.0 {
            return usize::MAX; // flat line under SLO: unbounded by model
        }
        ((slo - self.beta) / self.alpha).floor() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn exact_line_recovered() {
        let pts: Vec<(f64, f64)> = (1..=10).map(|c| (c as f64, 0.02 * c as f64 + 0.3)).collect();
        let f = LinearFit::fit(&pts);
        assert!((f.alpha - 0.02).abs() < 1e-9);
        assert!((f.beta - 0.3).abs() < 1e-9);
        assert!(f.r2 > 0.999999);
    }

    #[test]
    fn noisy_line_recovered_approximately() {
        let mut rng = Pcg::new(1);
        let pts: Vec<(f64, f64)> = (1..=40)
            .map(|c| {
                let t = 0.0166 * c as f64 + 0.27;
                (c as f64, t * (1.0 + 0.02 * rng.normal()))
            })
            .collect();
        let f = LinearFit::fit(&pts);
        assert!((f.alpha - 0.0166).abs() < 0.002, "alpha {}", f.alpha);
        assert!((f.beta - 0.27).abs() < 0.05, "beta {}", f.beta);
        assert!(f.r2 > 0.97);
    }

    #[test]
    fn max_concurrency_solves_slo() {
        let f = LinearFit { alpha: 0.0166, beta: 0.27, r2: 1.0 };
        // (1 - 0.27)/0.0166 = 43.98 → 43; (2 - 0.27)/0.0166 = 104.2 → 104
        assert_eq!(f.max_concurrency(1.0), 43);
        assert_eq!(f.max_concurrency(2.0), 104);
    }

    #[test]
    fn beta_above_slo_gives_zero() {
        let f = LinearFit { alpha: 0.1, beta: 1.5, r2: 1.0 };
        assert_eq!(f.max_concurrency(1.0), 0); // Eq. 11 territory
        assert!(f.max_concurrency(2.0) > 0);
    }

    #[test]
    fn negative_slope_clamped_to_zero() {
        let pts = vec![(1.0, 0.9), (2.0, 0.8), (3.0, 0.7)];
        let f = LinearFit::fit(&pts);
        assert_eq!(f.alpha, 0.0);
        assert!(f.beta >= 0.0);
    }

    #[test]
    fn negative_intercept_clamped_to_zero() {
        let pts = vec![(10.0, 0.05), (20.0, 0.2), (30.0, 0.35)];
        let f = LinearFit::fit(&pts);
        assert!(f.beta >= 0.0);
        assert!(f.alpha > 0.0);
    }

    #[test]
    fn identical_x_degenerates_to_mean() {
        let pts = vec![(5.0, 1.0), (5.0, 2.0), (5.0, 3.0)];
        let f = LinearFit::fit(&pts);
        assert_eq!(f.alpha, 0.0);
        assert!((f.beta - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "need >= 2")]
    fn single_point_panics() {
        LinearFit::fit(&[(1.0, 1.0)]);
    }
}
