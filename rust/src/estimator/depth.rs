//! The paper's fast queue-depth estimator (§4.2.2) and the collaborative
//! fine-tuning pass that refines it.
//!
//! Procedure (mirrors the paper): run a handful of profiling sessions at
//! low concurrencies, fit `t = α·C + β` (OLS; Theil-Sen fallback when the
//! fit is outlier-degraded), solve for the largest C with `αC + β ≤ SLO`,
//! then locally fine-tune by measuring around the estimate.

use super::linreg::LinearFit;
use super::robust::theil_sen;

/// Result of a depth estimation.
#[derive(Debug, Clone)]
pub struct DepthEstimate {
    pub fit: LinearFit,
    /// Depth from the linear model (the paper's "linear regression" row).
    pub predicted: usize,
    /// Probes spent (the efficiency claim vs stress testing).
    pub probes: usize,
    /// True if the robust fallback was engaged.
    pub robust: bool,
    /// Profiling points used.
    pub points: Vec<(f64, f64)>,
}

/// R² below which the OLS fit is considered outlier-degraded and the
/// Theil-Sen fallback engages (Kunpeng case, paper §5.3).
const R2_ROBUST_THRESHOLD: f64 = 0.90;

/// Estimate the queue depth from a small set of profiling sessions.
///
/// `probe_points` are the concurrency levels to measure (the paper uses a
/// "limited number of profiling sessions"; 5-8 points are plenty).
/// `measure(C)` returns observed latency in seconds.
pub fn estimate_depth(
    slo: f64,
    probe_points: &[usize],
    mut measure: impl FnMut(usize) -> f64,
) -> DepthEstimate {
    assert!(probe_points.len() >= 2, "need >= 2 probe points");
    let points: Vec<(f64, f64)> = probe_points
        .iter()
        .map(|&c| (c as f64, measure(c)))
        .collect();
    let ols = LinearFit::fit(&points);
    let (fit, robust) = if ols.r2 < R2_ROBUST_THRESHOLD {
        (theil_sen(&points), true)
    } else {
        (ols, false)
    };
    DepthEstimate {
        predicted: fit.max_concurrency(slo),
        probes: points.len(),
        fit,
        robust,
        points,
    }
}

/// Collaborative fine-tuning (paper §5.2: "the queue depth is fine-tuned
/// according to the estimated value with CPUs and NPUs/GPUs running
/// collaboratively"): hill-climb from the estimate, measuring the real
/// end-to-end latency at each candidate depth, and return the largest
/// depth meeting the SLO within `radius` of the estimate.
pub fn fine_tune_depths(
    slo: f64,
    estimate: usize,
    radius: usize,
    mut measure: impl FnMut(usize) -> f64,
) -> usize {
    if estimate == 0 {
        // The estimator may under-predict to zero on noisy devices; walk
        // up from 1 and keep the highest depth that still meets the SLO.
        let mut best = 0;
        for c in 1..=radius.max(1) {
            if crate::devices::profile::slo_met(measure(c), slo) {
                best = c;
            } else {
                break;
            }
        }
        return best;
    }
    let lo = estimate.saturating_sub(radius).max(1);
    let hi = estimate + radius;
    let mut best = 0;
    // Walk upward; latency is monotone in depth so stop at first failure
    // past the estimate (but always scan the low side in case the
    // estimate itself violates the SLO).
    for c in lo..=hi {
        if crate::devices::profile::slo_met(measure(c), slo) {
            best = c;
        } else if c >= estimate {
            break;
        }
    }
    if best == 0 {
        // The whole window overshot (noisy over-prediction): walk down
        // from the window floor to the highest depth that still passes.
        let mut c = lo.saturating_sub(1);
        while c >= 1 {
            if crate::devices::profile::slo_met(measure(c), slo) {
                return c;
            }
            c -= 1;
        }
    }
    best
}

/// Per-class CPU depths from mixed-load fine-tuning: `embed` is the
/// paper's C^max_CPU share left for embedding overflow queries, and
/// `retrieve` is the cost-unit cap for concurrent retrieval scans (fed to
/// `coordinator::QueueManager::with_retrieval_cap` /
/// `ServiceConfig::retrieval_depth`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassDepths {
    pub embed: usize,
    pub retrieve: usize,
}

impl ClassDepths {
    /// The shared CPU pool both classes draw from (Eq. 9's C^max_CPU).
    pub fn total(&self) -> usize {
        self.embed + self.retrieve
    }
}

/// Split a total CPU depth along the retrieval-fraction axis: retrieval
/// gets the rounded share of the pool, embedding the rest.
fn split_depth(total: usize, retrieve_fraction: f64) -> ClassDepths {
    let retrieve = ((total as f64) * retrieve_fraction).round() as usize;
    let retrieve = retrieve.min(total);
    ClassDepths { embed: total - retrieve, retrieve }
}

/// [`fine_tune_depths`] with a retrieval-fraction axis — the mixed
/// embed+retrieve extension of the paper's collaborative fine-tuning.
///
/// `retrieve_fraction ∈ [0, 1]` is the share of CPU work that is
/// retrieval scan cost under the expected mix (e.g. from a trace's
/// observed fraction — see `workload::mixed`). Each candidate *total*
/// CPU depth `C` is split per class by the fraction and `measure(embed,
/// retrieve)` observes the real mixed-load latency at that operating
/// point; the returned [`ClassDepths`] is the split of the largest total
/// still meeting the SLO. A fraction of 0 degenerates to the pure-embed
/// [`fine_tune_depths`] walk.
pub fn fine_tune_depths_mixed(
    slo: f64,
    estimate: usize,
    radius: usize,
    retrieve_fraction: f64,
    mut measure: impl FnMut(usize, usize) -> f64,
) -> ClassDepths {
    assert!(
        (0.0..=1.0).contains(&retrieve_fraction),
        "retrieve_fraction must be in [0, 1], got {retrieve_fraction}"
    );
    let best = fine_tune_depths(slo, estimate, radius, |c| {
        let d = split_depth(c, retrieve_fraction);
        measure(d.embed, d.retrieve)
    });
    split_depth(best, retrieve_fraction)
}

/// The NPU-retrieval-depth axis: the largest offloaded-scan cost cap
/// (cost units co-resident with embed traffic on the shared NPU pool)
/// whose measured latency still meets the SLO.
///
/// This is the inverse companion of [`fine_tune_depths_mixed`]: instead
/// of splitting the *CPU* budget between embed overflow and scans, it
/// asks how much scan work the *NPU* can absorb in its load valleys
/// before embedding latency at the expected operating point violates the
/// SLO. `measure(cap)` observes the real embed+scan latency with `cap`
/// scan cost units held on the device; the walk is monotone and bounded
/// by `npu_depth` (a scan cap can never exceed the pool it draws from).
/// Feed the result to `ServiceConfig::npu_retrieval_depth` /
/// `QueueManager::with_class_caps`.
pub fn fine_tune_npu_retrieval_cap(
    slo: f64,
    npu_depth: usize,
    mut measure: impl FnMut(usize) -> f64,
) -> usize {
    let mut best = 0;
    for cap in 1..=npu_depth {
        if crate::devices::profile::slo_met(measure(cap), slo) {
            best = cap;
        } else {
            // Latency is monotone in co-resident scan cost: stop at the
            // first violation.
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::profile::DeviceProfile;
    use crate::util::rng::Pcg;

    /// Default probe schedule used across the repo: geometric-ish ramp.
    pub fn probes_for(cap: usize) -> Vec<usize> {
        [1usize, 2, 4, 8, 16, 24, 32]
            .iter()
            .copied()
            .filter(|&c| c <= cap.max(2))
            .collect()
    }

    #[test]
    fn clean_device_estimate_close_to_truth() {
        let p = DeviceProfile::v100_bge();
        let est = estimate_depth(1.0, &probes_for(32), |c| p.service_time(c, 75));
        let truth = p.true_max_concurrency(1.0, 75);
        let err = (est.predicted as f64 - truth as f64).abs() / truth as f64;
        assert!(err < 0.10, "predicted {} vs true {truth}", est.predicted);
        assert!(!est.robust);
        assert!(est.probes <= 7);
    }

    #[test]
    fn estimator_uses_far_fewer_probes_than_stress() {
        let p = DeviceProfile::atlas_300i_duo_bge();
        let est = estimate_depth(2.0, &probes_for(32), |c| p.service_time(c, 75));
        let stress = crate::estimator::stress::stress_search(2.0, 8, 512, |c| {
            p.service_time(c, 75)
        });
        assert!(est.probes * 3 < stress.probes, "{} vs {}", est.probes, stress.probes);
    }

    #[test]
    fn outlier_device_engages_robust_fallback() {
        let p = DeviceProfile::kunpeng_920_bge();
        let mut rng = Pcg::new(11);
        // Probe with heavy synthetic outliers: every 3rd probe is 4x late.
        let mut i = 0;
        let est = estimate_depth(2.0, &[1, 2, 3, 4, 5, 6, 7, 8], |c| {
            i += 1;
            let t = p.service_time(c, 75);
            if i % 3 == 0 {
                t * 4.0
            } else {
                t * (1.0 + 0.02 * rng.normal())
            }
        });
        assert!(est.robust, "robust fallback should engage on outliers");
        let truth = p.true_max_concurrency(2.0, 75);
        // Robust estimate within a factor ~2 of truth despite 33% outliers.
        assert!(
            est.predicted >= truth / 2 && est.predicted <= truth * 2,
            "predicted {} vs true {truth}",
            est.predicted
        );
    }

    #[test]
    fn fine_tune_recovers_exact_depth() {
        let p = DeviceProfile::v100_bge();
        // Estimator predicts 43-ish from the linear fit; fine-tuning against
        // the true curve must land exactly on 44 (the paper's Table 3 row).
        let est = estimate_depth(1.0, &probes_for(32), |c| p.service_time(c, 75));
        let tuned = fine_tune_depths(1.0, est.predicted, 8, |c| p.service_time(c, 75));
        assert_eq!(tuned, 44);
    }

    #[test]
    fn fine_tune_handles_zero_estimate() {
        // Constant sub-SLO latency: the zero-estimate walk climbs to the
        // scan radius; constant over-SLO latency: stays at zero (Eq. 11).
        assert_eq!(fine_tune_depths(1.0, 0, 8, |_| 0.5), 8);
        assert_eq!(fine_tune_depths(1.0, 0, 8, |_| 1.5), 0);
        // Monotone curve: stops exactly at the SLO boundary.
        assert_eq!(fine_tune_depths(1.0, 0, 8, |c| 0.3 * c as f64), 3);
    }

    #[test]
    fn fine_tune_corrects_overestimate() {
        let p = DeviceProfile::v100_bge();
        // Hand the tuner a wildly high estimate; it must fall back to the
        // highest passing depth within the radius.
        let tuned = fine_tune_depths(1.0, 50, 8, |c| p.service_time(c, 75));
        assert_eq!(tuned, 44);
    }

    #[test]
    fn unusable_device_estimates_zero() {
        let est = estimate_depth(1.0, &[1, 2, 3], |_| 3.0);
        assert_eq!(est.predicted, 0);
    }

    /// Latency model for the mixed tests: embeds cost α each, retrieval
    /// cost units β each (scans are heavier), plus a base — monotone in
    /// both axes like the real mixed service.
    fn mixed_latency(embed: usize, retrieve: usize) -> f64 {
        0.1 + 0.02 * embed as f64 + 0.05 * retrieve as f64
    }

    #[test]
    fn mixed_zero_fraction_matches_pure_embed_tuning() {
        let p = DeviceProfile::v100_bge();
        let est = estimate_depth(1.0, &probes_for(32), |c| p.service_time(c, 75));
        let pure = fine_tune_depths(1.0, est.predicted, 8, |c| p.service_time(c, 75));
        let mixed =
            fine_tune_depths_mixed(1.0, est.predicted, 8, 0.0, |e, _r| p.service_time(e, 75));
        assert_eq!(mixed.embed, pure);
        assert_eq!(mixed.retrieve, 0);
        assert_eq!(mixed.total(), pure);
    }

    #[test]
    fn mixed_tuning_finds_largest_passing_split() {
        // SLO 1.0 against the planted model: at fraction 0.5 a total C
        // splits (C/2, C/2), latency 0.1 + 0.035·C ≤ 1.0 → C = 25 →
        // split (12, 13) or (13, 12) by rounding. Verify the exact walk.
        let d = fine_tune_depths_mixed(1.0, 20, 10, 0.5, mixed_latency);
        assert_eq!(d.total(), 25);
        assert!(mixed_latency(d.embed, d.retrieve) <= 1.0);
        let worse = split_depth(d.total() + 1, 0.5);
        assert!(mixed_latency(worse.embed, worse.retrieve) > 1.0);
    }

    #[test]
    fn mixed_fraction_shifts_budget_between_classes() {
        // Retrieval-heavier mixes must shrink the total (scans cost more
        // per unit in the planted model) and grow retrieval's share.
        let lo = fine_tune_depths_mixed(1.0, 25, 12, 0.2, mixed_latency);
        let hi = fine_tune_depths_mixed(1.0, 25, 12, 0.8, mixed_latency);
        assert!(lo.embed > lo.retrieve);
        assert!(hi.retrieve > hi.embed);
        assert!(hi.total() <= lo.total(), "{} vs {}", hi.total(), lo.total());
        // Both operating points meet the SLO.
        assert!(mixed_latency(lo.embed, lo.retrieve) <= 1.0);
        assert!(mixed_latency(hi.embed, hi.retrieve) <= 1.0);
    }

    #[test]
    fn mixed_full_fraction_budgets_scans_only() {
        let d = fine_tune_depths_mixed(1.0, 10, 8, 1.0, |_e, r| 0.05 * r as f64);
        // The walk is bounded by estimate + radius = 18, all of which
        // passes (0.05 · 18 = 0.9 ≤ 1.0) and goes to retrieval.
        assert_eq!(d.embed, 0);
        assert_eq!(d.retrieve, 18);
    }

    #[test]
    #[should_panic(expected = "retrieve_fraction")]
    fn mixed_rejects_out_of_range_fraction() {
        let _ = fine_tune_depths_mixed(1.0, 4, 2, 1.5, |_e, _r| 0.1);
    }

    #[test]
    fn npu_retrieval_cap_stops_at_slo_boundary() {
        // Planted model: base embed latency 0.4 s plus 0.1 s per
        // co-resident scan cost unit → SLO 1.0 admits exactly 6 units.
        let cap = fine_tune_npu_retrieval_cap(1.0, 44, |c| 0.4 + 0.1 * c as f64);
        assert_eq!(cap, 6);
        // Bounded by the pool even when everything passes.
        assert_eq!(fine_tune_npu_retrieval_cap(1.0, 4, |_| 0.2), 4);
        // A device with no SLO headroom gets no offload budget.
        assert_eq!(fine_tune_npu_retrieval_cap(1.0, 44, |_| 1.5), 0);
        // A zero pool means no leg at all.
        assert_eq!(fine_tune_npu_retrieval_cap(1.0, 0, |_| 0.1), 0);
    }

    #[test]
    fn npu_retrieval_cap_probes_stop_after_first_violation() {
        let mut probes = 0;
        let cap = fine_tune_npu_retrieval_cap(1.0, 44, |c| {
            probes += 1;
            0.5 + 0.2 * c as f64
        });
        assert_eq!(cap, 2); // 0.9 passes, 1.1 fails
        assert_eq!(probes, 3, "monotone walk must stop at the boundary");
    }
}
