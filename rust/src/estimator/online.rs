//! Online re-calibration — the operational extension of §4.2.2.
//!
//! The paper calibrates queue depths offline. In production, α drifts
//! (thermal throttling, co-located tenants, model updates); this module
//! keeps an EWMA of observed (concurrency, latency) samples, refits the
//! line periodically, and recommends a depth change when the drift
//! exceeds a hysteresis band. Pairs with [`crate::metrics::slo`] for the
//! breach signal.

use std::collections::VecDeque;

use super::linreg::LinearFit;
use super::robust::theil_sen;

/// Streaming recalibrator.
pub struct OnlineCalibrator {
    slo: f64,
    window: usize,
    /// Relative change in recommended depth needed to emit an update.
    hysteresis: f64,
    samples: VecDeque<(f64, f64)>,
    current_depth: usize,
}

impl OnlineCalibrator {
    pub fn new(slo: f64, window: usize, hysteresis: f64, initial_depth: usize) -> Self {
        assert!(window >= 8);
        OnlineCalibrator {
            slo,
            window,
            hysteresis,
            samples: VecDeque::new(),
            current_depth: initial_depth,
        }
    }

    /// Feed one observation: the batch size a device just processed and
    /// the latency it took.
    pub fn observe(&mut self, concurrency: usize, latency: f64) {
        if concurrency == 0 {
            return;
        }
        self.samples.push_back((concurrency as f64, latency));
        if self.samples.len() > self.window {
            self.samples.pop_front();
        }
    }

    pub fn current_depth(&self) -> usize {
        self.current_depth
    }

    pub fn ready(&self) -> bool {
        self.samples.len() >= self.window / 2
    }

    /// Refit and return a new recommended depth if it moved beyond the
    /// hysteresis band (robust fit — production samples have outliers).
    pub fn recommend(&mut self) -> Option<usize> {
        if !self.ready() {
            return None;
        }
        let pts: Vec<(f64, f64)> = self.samples.iter().copied().collect();
        // Need at least two distinct concurrency levels to fit a slope.
        let first = pts[0].0;
        if pts.iter().all(|p| (p.0 - first).abs() < 1e-9) {
            return None;
        }
        let fit = theil_sen(&pts);
        let depth = fit.max_concurrency(self.slo);
        if depth == usize::MAX {
            return None; // flat fit: no evidence of saturation yet
        }
        let cur = self.current_depth.max(1) as f64;
        if (depth as f64 - cur).abs() / cur > self.hysteresis {
            self.current_depth = depth;
            Some(depth)
        } else {
            None
        }
    }

    /// Current fit (for dashboards).
    pub fn fit(&self) -> Option<LinearFit> {
        if self.samples.len() < 2 {
            return None;
        }
        Some(theil_sen(&self.samples.iter().copied().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn feed(cal: &mut OnlineCalibrator, alpha: f64, beta: f64, n: usize, rng: &mut Pcg) {
        for _ in 0..n {
            let c = rng.usize(1, 48);
            let t = alpha * c as f64 + beta + 0.002 * rng.normal();
            cal.observe(c, t);
        }
    }

    #[test]
    fn stable_device_no_update() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 44);
        let mut rng = Pcg::new(1);
        feed(&mut cal, 0.0166, 0.27, 64, &mut rng);
        // Recommended ≈ 44 = current → inside hysteresis → None.
        assert_eq!(cal.recommend(), None);
        assert_eq!(cal.current_depth(), 44);
    }

    #[test]
    fn degraded_device_shrinks_depth() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 44);
        let mut rng = Pcg::new(2);
        // Device got 2x slower (α doubles): true capacity ≈ 21.
        feed(&mut cal, 0.0332, 0.27, 64, &mut rng);
        let rec = cal.recommend().expect("drift must trigger update");
        assert!((18..=25).contains(&rec), "rec {rec}");
        assert_eq!(cal.current_depth(), rec);
    }

    #[test]
    fn improved_device_grows_depth() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 20);
        let mut rng = Pcg::new(3);
        feed(&mut cal, 0.0166, 0.27, 64, &mut rng);
        let rec = cal.recommend().expect("improvement must trigger update");
        assert!(rec > 35, "rec {rec}");
    }

    #[test]
    fn outliers_do_not_trigger_false_updates() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.15, 44);
        let mut rng = Pcg::new(4);
        for _ in 0..64 {
            let c = rng.usize(1, 48);
            let mut t = 0.0166 * c as f64 + 0.27 + 0.002 * rng.normal();
            if rng.chance(0.15) {
                t *= 4.0; // transient hiccups
            }
            cal.observe(c, t);
        }
        assert_eq!(cal.recommend(), None, "robust fit should ride out outliers");
    }

    #[test]
    fn not_ready_without_samples() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 44);
        assert!(!cal.ready());
        assert_eq!(cal.recommend(), None);
        cal.observe(0, 1.0); // ignored
        assert_eq!(cal.fit(), None);
    }

    #[test]
    fn single_concurrency_level_cannot_fit() {
        let mut cal = OnlineCalibrator::new(1.0, 8, 0.1, 10);
        for _ in 0..8 {
            cal.observe(5, 0.5);
        }
        assert_eq!(cal.recommend(), None);
    }
}
