//! Online re-calibration — the operational extension of §4.2.2.
//!
//! The paper calibrates queue depths offline. In production, α drifts
//! (thermal throttling, co-located tenants, model updates); this module
//! keeps an EWMA of observed (concurrency, latency) samples, refits the
//! line periodically, and recommends a depth change when the drift
//! exceeds a hysteresis band. Pairs with [`crate::metrics::slo`] for the
//! breach signal.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::linreg::LinearFit;
use super::robust::theil_sen;
use crate::metrics::SloMonitor;

/// Streaming recalibrator.
pub struct OnlineCalibrator {
    slo: f64,
    window: usize,
    /// Relative change in recommended depth needed to emit an update.
    hysteresis: f64,
    samples: VecDeque<(f64, f64)>,
    current_depth: usize,
}

impl OnlineCalibrator {
    pub fn new(slo: f64, window: usize, hysteresis: f64, initial_depth: usize) -> Self {
        assert!(window >= 8);
        OnlineCalibrator {
            slo,
            window,
            hysteresis,
            samples: VecDeque::new(),
            current_depth: initial_depth,
        }
    }

    /// Feed one observation: the batch size a device just processed and
    /// the latency it took.
    pub fn observe(&mut self, concurrency: usize, latency: f64) {
        if concurrency == 0 {
            return;
        }
        self.samples.push_back((concurrency as f64, latency));
        if self.samples.len() > self.window {
            self.samples.pop_front();
        }
    }

    pub fn current_depth(&self) -> usize {
        self.current_depth
    }

    pub fn ready(&self) -> bool {
        self.samples.len() >= self.window / 2
    }

    /// Refit and return a new recommended depth if it moved beyond the
    /// hysteresis band (robust fit — production samples have outliers).
    pub fn recommend(&mut self) -> Option<usize> {
        if !self.ready() {
            return None;
        }
        let pts: Vec<(f64, f64)> = self.samples.iter().copied().collect();
        // Need at least two distinct concurrency levels to fit a slope.
        let first = pts[0].0;
        if pts.iter().all(|p| (p.0 - first).abs() < 1e-9) {
            return None;
        }
        let fit = theil_sen(&pts);
        let depth = fit.max_concurrency(self.slo);
        if depth == usize::MAX {
            return None; // flat fit: no evidence of saturation yet
        }
        let cur = self.current_depth.max(1) as f64;
        if (depth as f64 - cur).abs() / cur > self.hysteresis {
            self.current_depth = depth;
            Some(depth)
        } else {
            None
        }
    }

    /// Current fit (for dashboards).
    pub fn fit(&self) -> Option<LinearFit> {
        if self.samples.len() < 2 {
            return None;
        }
        Some(theil_sen(&self.samples.iter().copied().collect::<Vec<_>>()))
    }
}

/// Live SLO governor: couples the windowed [`SloMonitor`] breach signal
/// to [`OnlineCalibrator`] depth retuning, exactly the loop the paper's
/// Eq. 9–10 calibrate offline. The service feeds it every served
/// request's (device concurrency, e2e latency); a depth recommendation
/// is only emitted while the attainment window shows a breach, so a
/// healthy system never thrashes its configured depth.
pub struct SloGovernor {
    monitor: SloMonitor,
    cal: Mutex<OnlineCalibrator>,
    /// Latest recommended depth (0 = none yet). Advisory: surfaced in
    /// `/v1/stats` for the operator / an external controller.
    recommended: AtomicU64,
    retunes: AtomicU64,
    slo_nanos: u64,
}

impl SloGovernor {
    /// `target` is required attainment (e.g. 0.99); `window` is the
    /// attainment window in requests (clamped to the calibrator's
    /// minimum of 8); `initial_depth` anchors the hysteresis band.
    pub fn new(slo: Duration, target: f64, window: usize, initial_depth: usize) -> SloGovernor {
        let window = window.max(8);
        SloGovernor {
            monitor: SloMonitor::new(slo, target, window),
            cal: Mutex::new(OnlineCalibrator::new(
                slo.as_secs_f64(),
                window,
                0.1,
                initial_depth.max(1),
            )),
            recommended: AtomicU64::new(0),
            retunes: AtomicU64::new(0),
            slo_nanos: slo.as_nanos() as u64,
        }
    }

    /// Feed one served request: the device-side concurrency it observed
    /// and its end-to-end latency.
    pub fn observe(&self, concurrency: usize, latency: Duration) {
        self.monitor.record(latency.as_nanos() as u64);
        let mut cal = match self.cal.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        cal.observe(concurrency, latency.as_secs_f64());
        if self.monitor.breached() {
            if let Some(depth) = cal.recommend() {
                // ordering: advisory gauges read by /v1/stats; nothing is
                // published through them.
                self.recommended.store(depth as u64, Ordering::Relaxed);
                self.retunes.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn attainment(&self) -> f64 {
        self.monitor.attainment()
    }

    pub fn breached(&self) -> bool {
        self.monitor.breached()
    }

    pub fn samples(&self) -> usize {
        self.monitor.samples()
    }

    pub fn slo_nanos(&self) -> u64 {
        self.slo_nanos
    }

    /// Latest breach-triggered depth recommendation, if any.
    pub fn recommended_depth(&self) -> Option<usize> {
        // ordering: advisory gauge; see `observe`.
        match self.recommended.load(Ordering::Relaxed) {
            0 => None,
            d => Some(d as usize),
        }
    }

    /// How many times the breach signal has moved the recommendation.
    pub fn retunes(&self) -> u64 {
        // ordering: advisory gauge; see `observe`.
        self.retunes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn feed(cal: &mut OnlineCalibrator, alpha: f64, beta: f64, n: usize, rng: &mut Pcg) {
        for _ in 0..n {
            let c = rng.usize(1, 48);
            let t = alpha * c as f64 + beta + 0.002 * rng.normal();
            cal.observe(c, t);
        }
    }

    #[test]
    fn stable_device_no_update() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 44);
        let mut rng = Pcg::new(1);
        feed(&mut cal, 0.0166, 0.27, 64, &mut rng);
        // Recommended ≈ 44 = current → inside hysteresis → None.
        assert_eq!(cal.recommend(), None);
        assert_eq!(cal.current_depth(), 44);
    }

    #[test]
    fn degraded_device_shrinks_depth() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 44);
        let mut rng = Pcg::new(2);
        // Device got 2x slower (α doubles): true capacity ≈ 21.
        feed(&mut cal, 0.0332, 0.27, 64, &mut rng);
        let rec = cal.recommend().expect("drift must trigger update");
        assert!((18..=25).contains(&rec), "rec {rec}");
        assert_eq!(cal.current_depth(), rec);
    }

    #[test]
    fn improved_device_grows_depth() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 20);
        let mut rng = Pcg::new(3);
        feed(&mut cal, 0.0166, 0.27, 64, &mut rng);
        let rec = cal.recommend().expect("improvement must trigger update");
        assert!(rec > 35, "rec {rec}");
    }

    #[test]
    fn outliers_do_not_trigger_false_updates() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.15, 44);
        let mut rng = Pcg::new(4);
        for _ in 0..64 {
            let c = rng.usize(1, 48);
            let mut t = 0.0166 * c as f64 + 0.27 + 0.002 * rng.normal();
            if rng.chance(0.15) {
                t *= 4.0; // transient hiccups
            }
            cal.observe(c, t);
        }
        assert_eq!(cal.recommend(), None, "robust fit should ride out outliers");
    }

    #[test]
    fn not_ready_without_samples() {
        let mut cal = OnlineCalibrator::new(1.0, 64, 0.1, 44);
        assert!(!cal.ready());
        assert_eq!(cal.recommend(), None);
        cal.observe(0, 1.0); // ignored
        assert_eq!(cal.fit(), None);
    }

    #[test]
    fn single_concurrency_level_cannot_fit() {
        let mut cal = OnlineCalibrator::new(1.0, 8, 0.1, 10);
        for _ in 0..8 {
            cal.observe(5, 0.5);
        }
        assert_eq!(cal.recommend(), None);
    }

    #[test]
    fn governor_retunes_only_on_breach() {
        // Healthy system: every request meets a generous SLO. Even
        // though the calibrator's fit would recommend a much larger
        // depth, the breach gate must keep the recommendation quiet.
        let g = SloGovernor::new(Duration::from_secs(10), 0.9, 16, 44);
        let mut rng = Pcg::new(7);
        for _ in 0..64 {
            let c = rng.usize(1, 48);
            let t = 0.0166 * c as f64 + 0.27 + 0.002 * rng.normal();
            g.observe(c, Duration::from_secs_f64(t));
        }
        assert!(!g.breached());
        assert!((g.attainment() - 1.0).abs() < 1e-9);
        assert_eq!(g.recommended_depth(), None);
        assert_eq!(g.retunes(), 0);
    }

    #[test]
    fn governor_recommends_smaller_depth_under_breach() {
        // Device degraded 2x (α doubled): at depth 44 roughly half the
        // requests blow a 1s SLO, the window breaches, and the governor
        // must recommend the true sustainable depth ≈ 21.
        let g = SloGovernor::new(Duration::from_secs(1), 0.9, 16, 44);
        let mut rng = Pcg::new(8);
        for _ in 0..128 {
            let c = rng.usize(1, 48);
            let t = 0.0332 * c as f64 + 0.27 + 0.002 * rng.normal();
            g.observe(c, Duration::from_secs_f64(t));
        }
        assert!(g.breached(), "attainment {}", g.attainment());
        let rec = g.recommended_depth().expect("breach must drive a retune");
        assert!((15..=28).contains(&rec), "rec {rec}");
        assert!(g.retunes() >= 1);
        assert_eq!(g.samples(), 16);
    }
}
