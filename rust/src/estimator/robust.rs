//! Theil–Sen robust regression.
//!
//! The paper observes (§5.3) that the Kunpeng 920 "generates a larger
//! number of outliers when mapping the relationship between concurrency
//! and end-to-end latency", degrading the OLS depth prediction. Theil–Sen
//! (median of pairwise slopes) tolerates up to ~29% outliers; WindVE uses
//! it automatically when the OLS fit's R² is poor. This is the repo's
//! implementation of the paper's noted-but-unsolved accuracy gap.

use super::linreg::LinearFit;

/// Theil–Sen fit: slope = median of pairwise slopes, intercept = median
/// of `y - slope·x`. Same α, β ≥ 0 projection as the OLS fit.
pub fn theil_sen(points: &[(f64, f64)]) -> LinearFit {
    assert!(points.len() >= 2, "need >= 2 profiling points");
    let mut slopes = Vec::with_capacity(points.len() * (points.len() - 1) / 2);
    for i in 0..points.len() {
        for j in (i + 1)..points.len() {
            let dx = points[j].0 - points[i].0;
            if dx.abs() > 1e-12 {
                slopes.push((points[j].1 - points[i].1) / dx);
            }
        }
    }
    let alpha = if slopes.is_empty() { 0.0 } else { median(&mut slopes) }.max(0.0);
    let mut residuals: Vec<f64> = points.iter().map(|p| p.1 - alpha * p.0).collect();
    let beta = median(&mut residuals).max(0.0);

    let mean_y = points.iter().map(|p| p.1).sum::<f64>() / points.len() as f64;
    let ss_tot: f64 = points.iter().map(|p| (p.1 - mean_y).powi(2)).sum();
    let ss_res: f64 = points
        .iter()
        .map(|p| (p.1 - (alpha * p.0 + beta)).powi(2))
        .sum();
    let r2 = if ss_tot < 1e-12 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LinearFit { alpha, beta, r2 }
}

fn median(v: &mut [f64]) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn clean_line_recovered_exactly() {
        let pts: Vec<(f64, f64)> = (1..=10).map(|c| (c as f64, 0.05 * c as f64 + 0.2)).collect();
        let f = theil_sen(&pts);
        assert!((f.alpha - 0.05).abs() < 1e-9);
        assert!((f.beta - 0.2).abs() < 1e-9);
    }

    #[test]
    fn outliers_do_not_move_the_fit() {
        // 20% gross outliers (the Kunpeng case): OLS drifts, Theil-Sen holds.
        let mut rng = Pcg::new(2);
        let mut pts: Vec<(f64, f64)> = (1..=20)
            .map(|c| (c as f64, 0.0754 * c as f64 + 0.85 + 0.01 * rng.normal()))
            .collect();
        pts[3].1 *= 3.0;
        pts[9].1 *= 4.0;
        pts[15].1 *= 2.5;
        pts[18].1 *= 3.5;
        let ts = theil_sen(&pts);
        let ols = LinearFit::fit(&pts);
        let ts_err = (ts.alpha - 0.0754).abs() / 0.0754;
        let ols_err = (ols.alpha - 0.0754).abs() / 0.0754;
        assert!(ts_err < 0.15, "theil-sen alpha rel err {ts_err}");
        assert!(ts_err < ols_err, "robust ({ts_err}) must beat OLS ({ols_err})");
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn constraint_projection_applies() {
        let pts = vec![(1.0, 1.0), (2.0, 0.5), (3.0, 0.1)];
        let f = theil_sen(&pts);
        assert!(f.alpha >= 0.0 && f.beta >= 0.0);
    }
}
