//! Stress-test queue-depth search — the slow baseline the paper's linear
//! estimator replaces (§4.2.2, and the "stress test" column of Table 3).
//!
//! Walks concurrency upward in `step` increments until the SLO breaks,
//! then reports the last passing level. The paper notes both failure
//! modes this has: cost (one measurement per step) and quantisation (a
//! large step "risks overlooking the optimal maximum value" — visible in
//! Table 3 where step 8 under-finds Atlas@2s).

/// Outcome of a stress search.
#[derive(Debug, Clone, PartialEq)]
pub struct StressResult {
    /// Largest concurrency that met the SLO (0 = even C=1 failed, Eq. 11).
    pub max_concurrency: usize,
    /// Number of measurements taken (the cost the estimator saves).
    pub probes: usize,
    /// (concurrency, latency) trace for reporting.
    pub trace: Vec<(usize, f64)>,
}

/// Search with increment `step`, measuring via `measure(C) -> seconds`.
/// `cap` bounds the walk (guard against unbounded devices).
pub fn stress_search(
    slo: f64,
    step: usize,
    cap: usize,
    mut measure: impl FnMut(usize) -> f64,
) -> StressResult {
    assert!(step >= 1);
    let mut trace = Vec::new();
    // C=1 first: the paper's Eq. 11 check (can this device serve at all?).
    let t1 = measure(1);
    trace.push((1, t1));
    if !crate::devices::profile::slo_met(t1, slo) {
        return StressResult { max_concurrency: 0, probes: trace.len(), trace };
    }
    let mut last_ok = 1;
    let mut c = step.max(2);
    while c <= cap {
        let t = measure(c);
        trace.push((c, t));
        if !crate::devices::profile::slo_met(t, slo) {
            return StressResult { max_concurrency: last_ok, probes: trace.len(), trace };
        }
        last_ok = c;
        c += step;
    }
    StressResult { max_concurrency: last_ok, probes: trace.len(), trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::profile::DeviceProfile;

    #[test]
    fn finds_exact_boundary_with_step_1() {
        let p = DeviceProfile::v100_bge();
        let r = stress_search(1.0, 1, 512, |c| p.service_time(c, 75));
        assert_eq!(r.max_concurrency, 44); // fine-tuned anchor
    }

    #[test]
    fn step_8_quantises_below_true_max() {
        let p = DeviceProfile::v100_bge();
        let r = stress_search(1.0, 8, 512, |c| p.service_time(c, 75));
        // true max 44 → step-8 walk passes 40, fails 48 (paper Table 3
        // reports 40 for exactly this reason).
        assert_eq!(r.max_concurrency, 40);
    }

    #[test]
    fn device_too_slow_reports_zero() {
        let r = stress_search(1.0, 8, 512, |_| 1.5);
        assert_eq!(r.max_concurrency, 0);
        assert_eq!(r.probes, 1); // gave up after the C=1 probe
    }

    #[test]
    fn cap_bounds_the_walk() {
        let r = stress_search(10.0, 8, 64, |_| 0.1);
        assert_eq!(r.max_concurrency, 64); // walk 8,16,...,64 all pass, stop at cap
    }

    #[test]
    fn probe_count_grows_linearly_with_capacity() {
        let p = DeviceProfile::atlas_300i_duo_bge();
        let r = stress_search(2.0, 8, 512, |c| p.service_time(c, 75));
        // Atlas true 172 @ 2 s → ~23 probes; the estimator needs ~6.
        assert!(r.probes > 20, "probes {}", r.probes);
        assert!((160..=176).contains(&r.max_concurrency), "{}", r.max_concurrency);
    }

    #[test]
    fn trace_is_monotone_in_concurrency() {
        let p = DeviceProfile::xeon_e5_2690_bge();
        let r = stress_search(1.0, 2, 64, |c| p.service_time(c, 75));
        for w in r.trace.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
    }
}
