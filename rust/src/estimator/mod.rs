//! Queue-depth estimation (paper §4.2.2): the linear-regression fast
//! estimator, the robust (Theil-Sen) variant for outlier-heavy devices,
//! the stress-test baseline it replaces, and the SLO → depth solver.

pub mod depth;
pub mod linreg;
pub mod online;
pub mod robust;
pub mod stress;

pub use depth::{
    estimate_depth, fine_tune_depths, fine_tune_depths_mixed, fine_tune_npu_retrieval_cap,
    ClassDepths, DepthEstimate,
};
pub use linreg::LinearFit;
pub use online::{OnlineCalibrator, SloGovernor};
pub use stress::{stress_search, StressResult};
