//! Real-hardware calibration: the paper's §4.2.2 procedure run against
//! the actual PJRT engine on this host (not the simulated profiles).
//!
//! Measures batch embedding latency at a ramp of batch sizes, fits
//! `t = α·C + β`, and solves the queue depth for a given SLO — exactly
//! what an operator deploying WindVE on new hardware would run
//! (`windve calibrate`). Also produces the host's own Figure-4-style fit.

use std::path::Path;

use anyhow::Result;

use crate::estimator::LinearFit;
use crate::runtime::EmbeddingEngine;
use crate::workload::queries::QueryGen;

#[derive(Debug, Clone)]
pub struct HostCalibration {
    pub model: String,
    pub points: Vec<(f64, f64)>,
    pub fit: LinearFit,
    pub depth_at_slo: usize,
    pub slo: f64,
}

/// Measure the real engine at batch sizes up to its largest bucket.
pub fn calibrate_host(
    artifacts: &Path,
    model: &str,
    qlen: usize,
    slo: f64,
    repeats: usize,
) -> Result<HostCalibration> {
    let mut engine = EmbeddingEngine::load(artifacts, model)?;
    engine.warmup()?;
    let mut gen = QueryGen::new(qlen, 0xCA11B);
    let max_b = engine.max_batch();
    let mut points = Vec::new();
    let batches: Vec<usize> = [1usize, 2, 4, 8, 16, 32]
        .into_iter()
        .filter(|&b| b <= max_b)
        .collect();
    for &b in &batches {
        let texts = gen.batch(b);
        // warm this bucket
        let _ = engine.embed(&texts)?;
        let mut total = 0.0;
        for _ in 0..repeats.max(1) {
            let t0 = std::time::Instant::now();
            let _ = engine.embed(&texts)?;
            total += t0.elapsed().as_secs_f64();
        }
        points.push((b as f64, total / repeats.max(1) as f64));
    }
    let fit = LinearFit::fit(&points);
    Ok(HostCalibration {
        model: model.to_string(),
        depth_at_slo: fit.max_concurrency(slo),
        points,
        fit,
        slo,
    })
}

pub fn print(c: &HostCalibration) {
    println!("\n=== Host calibration ({}; real PJRT engine) ===", c.model);
    for (b, t) in &c.points {
        println!("  batch {:>3.0}: {:>8.2} ms", b, t * 1e3);
    }
    println!(
        "fit: t = {:.5}·C + {:.5}  (R² {:.3}) → depth {} at SLO {}s",
        c.fit.alpha, c.fit.beta, c.fit.r2, c.depth_at_slo, c.slo
    );
}
