//! Figure 5: scalability with input query length (V100 + Xeon).
//!
//! "original" = NPU-only concurrency; "additional" = CPU offload capacity.
//! Paper phenomena: longer queries degrade both; at 500 tokens the CPU's
//! additional concurrency hits 0 under the 1 s SLO but stays ≈2 under 2 s.

use super::DevicePair;
use crate::sim::cluster::ClosedLoopSim;

#[derive(Debug, Clone)]
pub struct Point {
    pub qlen: usize,
    pub slo: f64,
    pub original: usize,
    pub additional: usize,
}

pub const QLENS: [usize; 6] = [75, 150, 250, 350, 450, 500];

pub fn run(seed: u64) -> Vec<Point> {
    let pair = DevicePair::v100_xeon_bge();
    let mut out = Vec::new();
    for &slo in &[1.0, 2.0] {
        for &qlen in &QLENS {
            // Ground-truth capacities at this length (fine-tuning would
            // find these; noise-free for the figure's smooth series).
            let original = pair.npu.true_max_concurrency(slo, qlen);
            let additional = pair.cpu.true_max_concurrency(slo, qlen);
            // Validate jointly through the queue manager.
            if original + additional > 0 {
                let mut joint = ClosedLoopSim::new(
                    pair.npu.clone(),
                    Some(pair.cpu.clone()),
                    original.max(1),
                    additional,
                    qlen,
                    seed,
                );
                joint.noisy = false;
                debug_assert!(joint.round(original + additional).meets_slo(slo) || original == 0);
            }
            out.push(Point { qlen, slo, original, additional });
        }
    }
    out
}

pub fn print(points: &[Point]) {
    println!("\n=== Figure 5 — concurrency vs query length (V100 + Xeon) ===");
    for &slo in &[1.0, 2.0] {
        println!("SLO {slo}s:");
        println!("  {:<8} {:>10} {:>12} {:>8}", "tokens", "original", "additional", "impr%");
        for p in points.iter().filter(|p| p.slo == slo) {
            println!(
                "  {:<8} {:>10} {:>12} {:>7.1}%",
                p.qlen,
                p.original,
                p.additional,
                if p.original > 0 {
                    100.0 * p.additional as f64 / p.original as f64
                } else {
                    0.0
                }
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longer_queries_degrade_both_series() {
        let pts = run(3);
        for &slo in &[1.0, 2.0] {
            let series: Vec<&Point> = pts.iter().filter(|p| p.slo == slo).collect();
            for w in series.windows(2) {
                assert!(w[1].original <= w[0].original, "original must fall with length");
                assert!(w[1].additional <= w[0].additional, "additional must fall with length");
            }
        }
    }

    #[test]
    fn cpu_additional_dies_at_500_tokens_1s_but_not_2s() {
        let pts = run(3);
        let at = |slo: f64, qlen: usize| {
            pts.iter().find(|p| p.slo == slo && p.qlen == qlen).unwrap()
        };
        assert_eq!(at(1.0, 500).additional, 0, "paper: additional→0 @500tok/1s");
        let a2 = at(2.0, 500).additional;
        assert!((1..=4).contains(&a2), "paper: ≈2 additional @500tok/2s, got {a2}");
        assert!(at(2.0, 500).original > 0);
    }

    #[test]
    fn baseline_75_tokens_matches_table1() {
        let pts = run(3);
        let p = pts.iter().find(|p| p.slo == 1.0 && p.qlen == 75).unwrap();
        assert_eq!(p.original, 44);
        assert_eq!(p.additional, 8);
    }
}
