//! Paper-reproduction harness: one module per table/figure in the
//! evaluation section (§5), each regenerating the paper's rows/series
//! through the production coordinator + estimator code over calibrated
//! device profiles (DESIGN.md §2 explains the hardware substitution).
//!
//! Every module exposes `run(...) -> rows` (consumed by the benches and
//! the `windve repro ...` CLI) and a `print` that formats paper-vs-
//! measured side by side.

pub mod calibrate;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;
pub mod table2;
pub mod table3;

use crate::devices::profile::DeviceProfile;
use crate::estimator::{estimate_depth, fine_tune_depths};
use crate::sim::cluster::ClosedLoopSim;

/// An (accelerator, host-CPU) pairing under test.
#[derive(Debug, Clone)]
pub struct DevicePair {
    pub npu: DeviceProfile,
    pub cpu: DeviceProfile,
}

impl DevicePair {
    pub fn v100_xeon_bge() -> DevicePair {
        DevicePair { npu: DeviceProfile::v100_bge(), cpu: DeviceProfile::xeon_e5_2690_bge() }
    }

    pub fn atlas_kunpeng_bge() -> DevicePair {
        DevicePair {
            npu: DeviceProfile::atlas_300i_duo_bge(),
            cpu: DeviceProfile::kunpeng_920_bge(),
        }
    }

    pub fn v100_xeon_jina() -> DevicePair {
        DevicePair { npu: DeviceProfile::v100_jina(), cpu: DeviceProfile::xeon_e5_2690_jina() }
    }

    pub fn atlas_kunpeng_jina() -> DevicePair {
        DevicePair {
            npu: DeviceProfile::atlas_300i_duo_jina(),
            cpu: DeviceProfile::kunpeng_920_jina(),
        }
    }
}

/// The paper's §5.2 calibration pipeline for one device: probe a few
/// concurrencies on the standalone device (closed loop, noisy), fit the
/// line, then fine-tune around the prediction.
///
/// Returns (linear-regression prediction, fine-tuned depth, probes used).
pub fn calibrate_device(
    profile: &DeviceProfile,
    slo: f64,
    qlen: usize,
    seed: u64,
) -> (usize, usize, usize) {
    let mut sim = ClosedLoopSim::new(profile.clone(), None, usize::MAX >> 1, 0, qlen, seed);
    // Probe schedule: small ramp, averaged over a few rounds per point to
    // tame outliers ("a limited number of profiling sessions", §4.2.2).
    let probes: Vec<usize> = [1usize, 2, 4, 8, 12, 16, 24, 32]
        .into_iter()
        .filter(|&c| c <= 32)
        .collect();
    let est = estimate_depth(slo, &probes, |c| sim.measure_latency(c, 3));
    let mut tune_sim =
        ClosedLoopSim::new(profile.clone(), None, usize::MAX >> 1, 0, qlen, seed ^ 0xABCD);
    tune_sim.noisy = false; // fine-tuning validates against sustained SLO
    let tuned = fine_tune_depths(slo, est.predicted, 8, |c| tune_sim.measure_latency(c, 1));
    (est.predicted, tuned, est.probes)
}

/// Fine-tuned WindVE configuration for a pair: per-device depths from
/// [`calibrate_device`], validated collaboratively (both devices loaded).
pub fn calibrate_pair(pair: &DevicePair, slo: f64, qlen: usize, seed: u64) -> (usize, usize) {
    let (_, npu_depth, _) = calibrate_device(&pair.npu, slo, qlen, seed);
    let (_, cpu_depth, _) = calibrate_device(&pair.cpu, slo, qlen, seed ^ 0x55);
    // Collaborative validation: joint capacity must equal the sum; if the
    // joint run violates the SLO (it cannot, devices are independent, but
    // guard anyway), shrink the CPU depth.
    let mut cpu_depth = cpu_depth;
    loop {
        let mut joint = ClosedLoopSim::new(
            pair.npu.clone(),
            Some(pair.cpu.clone()),
            npu_depth,
            cpu_depth,
            qlen,
            seed ^ 0x99,
        );
        joint.noisy = false;
        if cpu_depth == 0 || joint.round(npu_depth + cpu_depth).meets_slo(slo) {
            break;
        }
        cpu_depth -= 1;
    }
    (npu_depth, cpu_depth)
}

/// Percent improvement `extra/base`.
pub fn pct(base: usize, extra: usize) -> f64 {
    if base == 0 {
        0.0
    } else {
        100.0 * extra as f64 / base as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrate_v100_lands_near_paper() {
        let (lr, tuned, probes) = calibrate_device(&DeviceProfile::v100_bge(), 1.0, 75, 42);
        // Paper Table 3 @1s: LR 40, stress 40, fine-tuned 44.
        assert!((38..=48).contains(&lr), "LR {lr}");
        assert_eq!(tuned, 44);
        assert!(probes <= 8);
    }

    #[test]
    fn calibrate_pair_sums_to_table1() {
        let (n, c) = calibrate_pair(&DevicePair::v100_xeon_bge(), 1.0, 75, 7);
        assert_eq!(n, 44);
        assert_eq!(c, 8); // Table 1: 44 + 8
    }

    #[test]
    fn pct_helper() {
        assert!((pct(44, 8) - 18.18).abs() < 0.1);
        assert_eq!(pct(0, 5), 0.0);
    }
}
