//! Table 3: queue-depth prediction — linear regression vs stress test
//! (step 8) vs collaborative fine-tuning, for all four devices × two SLOs.

use super::calibrate_device;
use crate::devices::profile::DeviceProfile;
use crate::estimator::stress::stress_search;
use crate::sim::cluster::ClosedLoopSim;

#[derive(Debug, Clone)]
pub struct Row {
    pub device: String,
    pub slo: f64,
    pub linear_regression: usize,
    pub stress_test: usize,
    pub fine_tuned: usize,
    pub lr_probes: usize,
    pub stress_probes: usize,
    /// Paper's (LR, stress, fine-tuned) triple.
    pub paper: (usize, usize, usize),
}

/// Paper Table 3 values keyed by (device, slo).
fn paper_cell(device: &str, slo: f64) -> (usize, usize, usize) {
    match (device, slo as u64) {
        ("tesla_v100", 1) => (40, 40, 44),
        ("tesla_v100", 2) => (96, 88, 96),
        ("xeon_e5_2690", 1) => (8, 6, 8),
        ("xeon_e5_2690", 2) => (20, 18, 22),
        ("atlas_300i_duo", 1) => (84, 80, 84),
        ("atlas_300i_duo", 2) => (195, 176, 172),
        ("kunpeng_920", 1) => (2, 2, 2),
        ("kunpeng_920", 2) => (15, 12, 8),
        _ => (0, 0, 0),
    }
}

pub fn run(seed: u64) -> Vec<Row> {
    let devices = [
        DeviceProfile::v100_bge(),
        DeviceProfile::xeon_e5_2690_bge(),
        DeviceProfile::atlas_300i_duo_bge(),
        DeviceProfile::kunpeng_920_bge(),
    ];
    let mut rows = Vec::new();
    for (di, dev) in devices.iter().enumerate() {
        for &slo in &[1.0, 2.0] {
            let (lr, tuned, lr_probes) = calibrate_device(dev, slo, 75, seed + di as u64 * 31);
            // Stress test with the paper's increment step of 8, measuring
            // noisy closed-loop rounds like the real procedure would.
            let mut sim =
                ClosedLoopSim::new(dev.clone(), None, usize::MAX >> 1, 0, 75, seed ^ 0xF00D + di as u64);
            let stress = stress_search(slo, 8, 512, |c| sim.measure_latency(c, 3));
            rows.push(Row {
                device: dev.name.clone(),
                slo,
                linear_regression: lr,
                stress_test: stress.max_concurrency,
                fine_tuned: tuned,
                lr_probes,
                stress_probes: stress.probes,
                paper: paper_cell(&dev.name, slo),
            });
        }
    }
    rows
}

pub fn print(rows: &[Row]) {
    println!("\n=== Table 3 — queue depth: linear regression vs stress test vs fine-tuned ===");
    println!(
        "{:<16} {:>4} | {:>6} {:>7} {:>6} | {:>6} {:>7} {:>6} | {:>9} {:>9}",
        "device", "SLO", "LR", "stress", "tuned", "pLR", "pstress", "ptuned", "LRprobes", "STprobes"
    );
    for r in rows {
        println!(
            "{:<16} {:>3}s | {:>6} {:>7} {:>6} | {:>6} {:>7} {:>6} | {:>9} {:>9}",
            r.device, r.slo, r.linear_regression, r.stress_test, r.fine_tuned,
            r.paper.0, r.paper.1, r.paper.2, r.lr_probes, r.stress_probes,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_tracks_truth_and_beats_stress_on_probe_count() {
        for r in run(11) {
            let truth = DeviceProfile::by_name(
                r.device.strip_suffix("_jina").unwrap_or(&r.device),
            )
            .unwrap()
            .true_max_concurrency(r.slo, 75);
            // LR within 25% of truth for the clean devices. Kunpeng is the
            // paper's own counter-example (§5.3: outliers degrade its LR
            // prediction — their Table 3 shows LR 15 vs fine-tuned 8), so
            // it only gets a factor-2.5 sanity bound.
            if r.device.starts_with("kunpeng") {
                assert!(
                    r.linear_regression as f64 <= truth as f64 * 2.5 + 2.0
                        && r.linear_regression as f64 >= truth as f64 / 2.5 - 2.0,
                    "{} @{}s LR {} wildly off truth {truth}",
                    r.device, r.slo, r.linear_regression
                );
            } else if truth >= 4 {
                let err = (r.linear_regression as f64 - truth as f64).abs() / truth as f64;
                assert!(err < 0.25, "{} @{}s LR {} vs truth {truth}", r.device, r.slo, r.linear_regression);
            } else {
                assert!(r.linear_regression.abs_diff(truth) <= 2);
            }
            // Stress quantises to multiples of 8 (plus the C=1 floor).
            assert!(r.stress_test == 1 || r.stress_test % 8 == 0 || r.stress_test == 0);
            // Probe economy: LR needs far fewer measurements for big devices.
            if truth > 90 {
                assert!(r.lr_probes < r.stress_probes);
            }
        }
    }

    #[test]
    fn fine_tuned_matches_anchor_depths() {
        for r in run(11) {
            let truth = DeviceProfile::by_name(&r.device)
                .unwrap()
                .true_max_concurrency(r.slo, 75);
            assert_eq!(r.fine_tuned, truth, "{} @{}s", r.device, r.slo);
        }
    }
}
