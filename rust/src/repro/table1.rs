//! Table 1: WindVE vs FlagEmbedding (non-offloading) max concurrency on
//! the bge model, SLO ∈ {1 s, 2 s}, on (V100 + Xeon) and (Atlas + Kunpeng).

use super::{calibrate_pair, pct, DevicePair};
use crate::sim::cluster::ClosedLoopSim;

/// One column of the table.
#[derive(Debug, Clone)]
pub struct Row {
    pub npu_name: String,
    pub cpu_name: String,
    pub slo: f64,
    /// Non-offloading baseline (FlagEmbedding): NPU-only max concurrency.
    pub baseline: usize,
    /// WindVE: baseline + CPU additional.
    pub additional: usize,
    pub improvement_pct: f64,
    /// Paper's reported values for the same cell.
    pub paper_baseline: usize,
    pub paper_additional: usize,
}

/// The paper's reported cells, for side-by-side printing.
const PAPER: [(usize, usize); 4] = [(44, 8), (96, 22), (84, 1), (172, 8)];

/// Regenerate the table. `seed` drives all measurement noise.
pub fn run(seed: u64) -> Vec<Row> {
    run_pairs(
        &[DevicePair::v100_xeon_bge(), DevicePair::atlas_kunpeng_bge()],
        &PAPER,
        seed,
    )
}

pub(super) fn run_pairs(
    pairs: &[DevicePair],
    paper: &[(usize, usize)],
    seed: u64,
) -> Vec<Row> {
    let mut rows = Vec::new();
    for (pi, pair) in pairs.iter().enumerate() {
        for (si, &slo) in [1.0f64, 2.0].iter().enumerate() {
            let (npu_depth, cpu_depth) = calibrate_pair(pair, slo, 75, seed + pi as u64 * 17);
            // Validate the joint capacity through the queue manager.
            let mut joint = ClosedLoopSim::new(
                pair.npu.clone(),
                Some(pair.cpu.clone()),
                npu_depth,
                cpu_depth,
                75,
                seed,
            );
            joint.noisy = false;
            let windve = joint.max_concurrency(slo, npu_depth.max(1), npu_depth + cpu_depth + 4, 1);
            let additional = windve.saturating_sub(npu_depth);
            let (pb, pa) = paper[pi * 2 + si];
            rows.push(Row {
                npu_name: pair.npu.name.clone(),
                cpu_name: pair.cpu.name.clone(),
                slo,
                baseline: npu_depth,
                additional,
                improvement_pct: pct(npu_depth, additional),
                paper_baseline: pb,
                paper_additional: pa,
            });
        }
    }
    rows
}

pub fn print(rows: &[Row], title: &str, baseline_name: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<18} {:<16} {:>5} | {:>14} {:>14} {:>8} | {:>14} {:>8}",
        "NPU/GPU", "CPU", "SLO", format!("{baseline_name} C"), "WindVE C", "impr%",
        "paper C", "paper%"
    );
    for r in rows {
        println!(
            "{:<18} {:<16} {:>4}s | {:>14} {:>10}+{:<3} {:>7.1}% | {:>10}+{:<3} {:>7.1}%",
            r.npu_name,
            r.cpu_name,
            r.slo,
            r.baseline,
            r.baseline,
            r.additional,
            r.improvement_pct,
            r.paper_baseline,
            r.paper_additional,
            pct(r.paper_baseline, r.paper_additional),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let rows = run(42);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            // Baseline within 10% of paper's fine-tuned depth.
            let base_err =
                (r.baseline as f64 - r.paper_baseline as f64).abs() / r.paper_baseline as f64;
            assert!(base_err <= 0.10, "{}@{}s baseline {} vs paper {}",
                r.npu_name, r.slo, r.baseline, r.paper_baseline);
            // Offloading always helps (additional ≥ paper - small slack).
            assert!(
                r.additional + 2 >= r.paper_additional.min(2),
                "additional {} suspiciously low",
                r.additional
            );
        }
        // Phenomenon 1 (paper §5.2): 2 s improvement > 1 s improvement.
        assert!(rows[1].improvement_pct > rows[0].improvement_pct);
        // Phenomenon 2: V100+Xeon gains more than Atlas+Kunpeng.
        assert!(rows[0].improvement_pct > rows[2].improvement_pct);
        assert!(rows[1].improvement_pct > rows[3].improvement_pct);
    }

    #[test]
    fn headline_numbers_close_to_paper() {
        let rows = run(42);
        // V100+Xeon @2s: paper 22.3-22.9%; require >15% and <30%.
        let r = &rows[1];
        assert!(
            r.improvement_pct > 15.0 && r.improvement_pct < 30.0,
            "improvement {}%",
            r.improvement_pct
        );
    }
}
