//! Figure 2 (motivation): diurnal query-rate curve, plus the §3 cost
//! consequence — average-provisioned capacity misses the evening peak.

use crate::workload::diurnal::DiurnalCurve;

pub struct Fig2 {
    pub series: Vec<(f64, f64)>,
    pub mean: f64,
    pub peak: f64,
}

pub fn run() -> Fig2 {
    let curve = DiurnalCurve::typical(2.0, 10.0);
    Fig2 {
        series: curve.series(2),
        mean: curve.mean_rate(),
        peak: curve.peak_rate(),
    }
}

pub fn print(f: &Fig2) {
    println!("\n=== Figure 2 — query rate over a day ===");
    let max = f.peak;
    for (h, r) in &f.series {
        if (h * 2.0) as u64 % 2 == 0 {
            let bars = ((r / max) * 56.0) as usize;
            println!("  {:>5.1}h {:<56} {:.1} q/s", h, "#".repeat(bars), r);
        }
    }
    println!(
        "mean {:.1} q/s, peak {:.1} q/s → peak/mean = {:.2}x (why §3 provisions for peaks)",
        f.mean,
        f.peak,
        f.peak / f.mean
    );
}

#[cfg(test)]
mod tests {
    #[test]
    fn peak_dominates_mean() {
        let f = super::run();
        assert!(f.peak / f.mean > 2.0);
        assert_eq!(f.series.len(), 48);
    }
}
