//! Table 2: WindVE vs plain PyTorch serving on the jina model (same grid
//! as Table 1; jina's faster inference yields larger gains).

use super::{table1, DevicePair};

pub use super::table1::Row;

/// The paper's reported cells (baseline, additional).
const PAPER: [(usize, usize); 4] = [(48, 11), (112, 30), (128, 6), (256, 20)];

pub fn run(seed: u64) -> Vec<Row> {
    table1::run_pairs(
        &[DevicePair::v100_xeon_jina(), DevicePair::atlas_kunpeng_jina()],
        &PAPER,
        seed,
    )
}

pub fn print(rows: &[Row]) {
    table1::print(rows, "Table 2 — jina model, WindVE vs PyTorch", "PyTorch");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repro::pct;

    #[test]
    fn jina_gains_exceed_bge_gains() {
        // Paper phenomenon 3 (§5.2): faster models gain more from
        // offloading on the same hardware pair.
        let jina = run(7);
        let bge = crate::repro::table1::run(7);
        for (j, b) in jina.iter().zip(&bge) {
            assert!(
                j.improvement_pct + 1.0 > b.improvement_pct,
                "jina {}% vs bge {}% ({} @{}s)",
                j.improvement_pct, b.improvement_pct, j.npu_name, j.slo
            );
        }
    }

    #[test]
    fn values_track_paper() {
        let rows = run(7);
        for r in &rows {
            let err =
                (r.baseline as f64 - r.paper_baseline as f64).abs() / r.paper_baseline as f64;
            assert!(err <= 0.10, "{} baseline {} vs paper {}", r.npu_name, r.baseline, r.paper_baseline);
        }
        // Headline: V100+Xeon @2s ≈ 26.7%.
        let head = &rows[1];
        let paper_pct = pct(head.paper_baseline, head.paper_additional);
        assert!((head.improvement_pct - paper_pct).abs() < 8.0);
    }
}
