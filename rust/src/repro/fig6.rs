//! Figure 6: scalability with CPU core count (V100 + Xeon).
//!
//! Paper: below 44 usable cores the CPU brings no benefit at the 1 s SLO;
//! the floor drops to 36 cores at 2 s. Of 128 physical cores only 96 are
//! usable (the first NUMA node hosts the service framework, §5.4).

use crate::devices::profile::DeviceProfile;

#[derive(Debug, Clone)]
pub struct Point {
    pub cores: usize,
    pub slo: f64,
    pub additional: usize,
}

pub const CORES: [usize; 9] = [96, 88, 80, 64, 56, 48, 44, 36, 24];

pub fn run(_seed: u64) -> Vec<Point> {
    let cpu = DeviceProfile::xeon_e5_2690_bge();
    let mut out = Vec::new();
    for &slo in &[1.0, 2.0] {
        for &cores in &CORES {
            let scaled = cpu.with_cores(cores);
            out.push(Point {
                cores,
                slo,
                additional: scaled.true_max_concurrency(slo, 75),
            });
        }
    }
    out
}

pub fn print(points: &[Point]) {
    println!("\n=== Figure 6 — CPU additional concurrency vs core count (Xeon E5-2690) ===");
    for &slo in &[1.0, 2.0] {
        println!("SLO {slo}s:");
        for p in points.iter().filter(|p| p.slo == slo) {
            let bars = "#".repeat(p.additional.min(60));
            println!("  cores={:>3} {:<24} {}", p.cores, bars, p.additional);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fewer_cores_never_help() {
        let pts = run(0);
        for &slo in &[1.0, 2.0] {
            let series: Vec<&Point> = pts.iter().filter(|p| p.slo == slo).collect();
            for w in series.windows(2) {
                assert!(w[1].additional <= w[0].additional);
            }
        }
    }

    #[test]
    fn benefit_floor_at_44_cores_1s() {
        let pts = run(0);
        let at = |slo: f64, cores: usize| {
            pts.iter().find(|p| p.slo == slo && p.cores == cores).unwrap().additional
        };
        // Paper: "using less than 44 CPU cores does not bring any benefit"
        // at the 1 s limit...
        assert!(at(1.0, 44) >= 1, "44 cores should still help at 1s");
        assert_eq!(at(1.0, 36), 0, "36 cores must not help at 1s");
        // ... and the boundary drops to 36 cores at 2 s.
        assert!(at(2.0, 36) >= 1, "36 cores should still help at 2s");
        assert_eq!(at(2.0, 24), 0, "24 cores must not help at 2s");
    }

    #[test]
    fn full_cores_match_table1_additional() {
        let pts = run(0);
        let p = pts.iter().find(|p| p.slo == 1.0 && p.cores == 96).unwrap();
        assert_eq!(p.additional, 8);
    }
}
