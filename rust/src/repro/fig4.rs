//! Figure 4: latency-vs-concurrency scatter + linear fits for the four
//! devices. The paper reports β = 0.27 (V100), 0.32 (Xeon), 0.24 (Atlas),
//! 0.85 (Kunpeng) and α ratios 0.21 / 0.12.

use crate::devices::profile::DeviceProfile;
use crate::estimator::robust::theil_sen;
use crate::sim::cluster::ClosedLoopSim;

#[derive(Debug, Clone)]
pub struct Fit {
    pub device: String,
    pub alpha: f64,
    pub beta: f64,
    pub r2: f64,
    pub paper_beta: f64,
    pub points: Vec<(f64, f64)>,
}

pub fn run(seed: u64) -> Vec<Fit> {
    let devices = [
        (DeviceProfile::v100_bge(), 0.27),
        (DeviceProfile::xeon_e5_2690_bge(), 0.32),
        (DeviceProfile::atlas_300i_duo_bge(), 0.24),
        (DeviceProfile::kunpeng_920_bge(), 0.85),
    ];
    devices
        .iter()
        .enumerate()
        .map(|(i, (dev, paper_beta))| {
            let mut sim =
                ClosedLoopSim::new(dev.clone(), None, usize::MAX >> 1, 0, 75, seed + i as u64);
            // Fit within the device's SLO-1s operating region (C ≤ knee) —
            // Eq. 12 models exactly this regime. Small devices (Kunpeng:
            // knee = 2) get repeated measurements per level instead of a
            // wider sweep so the fit still has >= 8 points.
            let cmax = dev.knee.max(2);
            let step = (cmax / 16).max(1);
            let mut points: Vec<(f64, f64)> = Vec::new();
            let repeats = (32 / (cmax / step).max(1)).max(1);
            for c in (1..=cmax).step_by(step) {
                for _ in 0..repeats {
                    points.push((c as f64, sim.measure_latency(c, 1)));
                }
            }
            // Theil-Sen: the Kunpeng samples carry the paper's §5.3
            // outliers, which would drag an OLS slope on so few levels.
            let fit = theil_sen(&points);
            Fit {
                device: dev.name.clone(),
                alpha: fit.alpha,
                beta: fit.beta,
                r2: fit.r2,
                paper_beta: *paper_beta,
                points,
            }
        })
        .collect()
}

pub fn print(fits: &[Fit]) {
    println!("\n=== Figure 4 — latency vs concurrency fits (t = α·C + β) ===");
    println!(
        "{:<16} {:>9} {:>9} {:>7} | {:>10}",
        "device", "α (s/q)", "β (s)", "R²", "paper β"
    );
    for f in fits {
        println!(
            "{:<16} {:>9.4} {:>9.3} {:>7.3} | {:>10.2}",
            f.device, f.alpha, f.beta, f.r2, f.paper_beta
        );
    }
    let a_ratio_1 = fits[0].alpha / fits[1].alpha;
    let a_ratio_2 = fits[2].alpha / fits[3].alpha;
    println!("α_NPU/α_CPU: V100/Xeon = {a_ratio_1:.2} (paper 0.21), Atlas/Kunpeng = {a_ratio_2:.2} (paper 0.12)");
    // ascii scatter of the first device
    if let Some(f) = fits.first() {
        println!("\n{} latency curve:", f.device);
        let tmax = f.points.iter().map(|p| p.1).fold(0.0f64, f64::max);
        for (c, t) in &f.points {
            let bars = ((t / tmax) * 48.0) as usize;
            println!("  C={c:>4.0} {:<48} {t:.3}s", "#".repeat(bars));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn betas_track_paper_fig4() {
        let fits = run(5);
        for f in &fits {
            assert!(
                (f.beta - f.paper_beta).abs() < 0.15,
                "{}: β {} vs paper {}",
                f.device, f.beta, f.paper_beta
            );
        }
        // β_CPU > β_NPU within each pairing.
        assert!(fits[1].beta > fits[0].beta);
        assert!(fits[3].beta > fits[2].beta);
    }

    #[test]
    fn alpha_ratios_track_paper() {
        let fits = run(5);
        let r1 = fits[0].alpha / fits[1].alpha;
        let r2 = fits[2].alpha / fits[3].alpha;
        assert!((r1 - 0.21).abs() < 0.06, "V100/Xeon α ratio {r1}");
        assert!((r2 - 0.12).abs() < 0.06, "Atlas/Kunpeng α ratio {r2}");
    }

    #[test]
    fn fits_are_high_quality_except_outlier_devices() {
        let fits = run(5);
        assert!(fits[0].r2 > 0.95); // V100 clean
        assert!(fits[1].r2 > 0.9); // Xeon clean-ish
    }
}
