//! Deterministic hash tokenizer — Rust half of the Python/Rust pair.
//!
//! Must stay byte-for-byte in sync with `python/compile/tokenizer.py`:
//! lowercase, split on `[A-Za-z0-9]+`, FNV-1a 64 of the word mapped into
//! `[2, vocab)`; id 0 = PAD, id 1 = CLS. Parity is enforced against the
//! vectors exported in `artifacts/golden.json`.

pub const PAD_ID: i32 = 0;
pub const CLS_ID: i32 = 1;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// FNV-1a 64-bit hash.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Map one lower-case word to its token id.
pub fn word_id(word: &str, vocab_size: u32) -> i32 {
    (2 + fnv1a64(word.as_bytes()) % (vocab_size as u64 - 2)) as i32
}

/// Tokenised query: CLS-prefixed ids plus 1.0/0.0 validity mask.
#[derive(Debug, Clone, PartialEq)]
pub struct Encoded {
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    /// Number of real (non-padding) tokens including CLS.
    pub len: usize,
}

/// Tokenise `text` padded/truncated to `max_len`.
///
/// Allocation-free inner loop (perf pass §Perf: the per-word `String` of
/// the first version dominated the front-end cost): words are hashed
/// byte-by-byte as they stream past, never materialised.
pub fn encode(text: &str, vocab_size: u32, max_len: usize) -> Encoded {
    if max_len == 0 {
        return Encoded { ids: Vec::new(), mask: Vec::new(), len: 0 };
    }
    let mut ids = vec![PAD_ID; max_len];
    let mut mask = vec![0.0f32; max_len];
    ids[0] = CLS_ID;
    mask[0] = 1.0;
    let mut n = 1usize;
    let mut h = FNV_OFFSET;
    let mut in_word = false;
    for &b in text.as_bytes() {
        if b.is_ascii_alphanumeric() {
            h ^= b.to_ascii_lowercase() as u64;
            h = h.wrapping_mul(FNV_PRIME);
            in_word = true;
        } else if in_word {
            if n >= max_len {
                return Encoded { ids, mask, len: max_len };
            }
            ids[n] = (2 + h % (vocab_size as u64 - 2)) as i32;
            mask[n] = 1.0;
            n += 1;
            h = FNV_OFFSET;
            in_word = false;
        }
    }
    if in_word && n < max_len {
        ids[n] = (2 + h % (vocab_size as u64 - 2)) as i32;
        mask[n] = 1.0;
        n += 1;
    }
    Encoded { ids, mask, len: n }
}

/// Number of tokens (incl. CLS) `text` produces before padding.
/// Allocation-free single pass.
pub fn token_count(text: &str) -> usize {
    let mut count = 1usize; // CLS
    let mut in_word = false;
    for &b in text.as_bytes() {
        if b.is_ascii_alphanumeric() {
            in_word = true;
        } else if in_word {
            count += 1;
            in_word = false;
        }
    }
    count + usize::from(in_word)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Published FNV-1a 64 vectors (also asserted on the python side).
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_F739_67E8);
    }

    #[test]
    fn encode_pads_and_masks() {
        let e = encode("one two", 1000, 8);
        assert_eq!(e.ids.len(), 8);
        assert_eq!(e.ids[0], CLS_ID);
        assert_eq!(e.len, 3);
        assert_eq!(&e.mask[..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&e.mask[3..], &[0.0; 5]);
        assert!(e.ids[3..].iter().all(|&i| i == PAD_ID));
    }

    #[test]
    fn encode_truncates_long_text() {
        let text = (0..100).map(|i| format!("w{i}")).collect::<Vec<_>>().join(" ");
        let e = encode(&text, 1000, 16);
        assert_eq!(e.ids.len(), 16);
        assert_eq!(e.len, 16);
        assert!(e.mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn case_and_punctuation_insensitive() {
        assert_eq!(encode("Hello, WORLD!", 500, 8), encode("hello world", 500, 8));
    }

    #[test]
    fn unicode_separators_ignored() {
        // non-ascii chars act as separators, like the python \w-ish regex
        assert_eq!(encode("héllo", 500, 8).len, 3); // "h" + "llo"
    }

    #[test]
    fn empty_text_is_cls_only() {
        let e = encode("", 100, 4);
        assert_eq!(e.ids, vec![CLS_ID, 0, 0, 0]);
        assert_eq!(e.len, 1);
    }

    #[test]
    fn ids_in_vocab_range() {
        let e = encode("alpha beta gamma delta epsilon", 64, 8);
        assert!(e.ids.iter().all(|&i| (0..64).contains(&i)));
    }

    #[test]
    fn token_count_matches_encode() {
        let text = "a b c d";
        assert_eq!(token_count(text), 5);
        assert_eq!(encode(text, 100, 32).len, 5);
    }
}
