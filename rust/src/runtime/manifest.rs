//! Artifact manifest: the parameter ABI and (batch, seq) bucket index
//! written by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One static-shape executable bucket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    pub batch: usize,
    pub seq: usize,
    pub file: String,
}

/// Declared shape of one weight parameter (AOT positional ABI).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Model architecture constants mirrored from python `ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: u32,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub param_count: u64,
}

/// Everything the runtime needs to serve one model.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub weights_file: String,
    pub params: Vec<ParamSpec>,
    pub buckets: Vec<Bucket>,
}

impl ModelEntry {
    /// Smallest bucket that fits (batch, seq); `None` if nothing fits.
    pub fn select_bucket(&self, batch: usize, seq: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.batch >= batch && b.seq >= seq)
            .min_by_key(|b| (b.batch * b.seq, b.batch))
    }

    /// Largest exported batch size (the batcher's cap).
    pub fn max_batch(&self) -> usize {
        self.buckets.iter().map(|b| b.batch).max().unwrap_or(0)
    }

    pub fn max_bucket_seq(&self) -> usize {
        self.buckets.iter().map(|b| b.seq).max().unwrap_or(0)
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let root = json::parse(&text).context("parse manifest.json")?;
        Self::from_json(dir, &root)
    }

    pub fn from_json(dir: &Path, root: &Json) -> Result<Manifest> {
        let version = root
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let seed = root.get("seed").and_then(Json::as_u64).unwrap_or(0);
        let models_obj = root
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| anyhow!("manifest missing models"))?;

        let mut models = Vec::new();
        for (name, entry) in models_obj {
            let cfg = entry
                .get("config")
                .ok_or_else(|| anyhow!("model {name} missing config"))?;
            let get = |k: &str| -> Result<usize> {
                cfg.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("model {name} config missing {k}"))
            };
            let config = ModelConfig {
                name: name.clone(),
                vocab_size: get("vocab_size")? as u32,
                d_model: get("d_model")?,
                n_layers: get("n_layers")?,
                n_heads: get("n_heads")?,
                d_ff: get("d_ff")?,
                max_seq: get("max_seq")?,
                param_count: get("param_count")? as u64,
            };
            let params = entry
                .get("params")
                .and_then(|p| p.as_arr())
                .ok_or_else(|| anyhow!("model {name} missing params"))?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape: p
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .ok_or_else(|| anyhow!("param missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let buckets = entry
                .get("artifacts")
                .and_then(|a| a.as_arr())
                .ok_or_else(|| anyhow!("model {name} missing artifacts"))?
                .iter()
                .map(|a| {
                    Ok(Bucket {
                        batch: a
                            .get("batch")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("artifact missing batch"))?,
                        seq: a
                            .get("seq")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("artifact missing seq"))?,
                        file: a
                            .get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact missing file"))?
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let weights_file = entry
                .get("weights")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("model {name} missing weights"))?
                .to_string();
            models.push(ModelEntry { config, weights_file, params, buckets });
        }
        Ok(Manifest { dir: dir.to_path_buf(), seed, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.config.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "model {name} not in manifest (have: {})",
                    self.models
                        .iter()
                        .map(|m| m.config.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Json {
        json::parse(
            r#"{
          "version": 1, "seed": 7,
          "models": {
            "m": {
              "config": {"name":"m","vocab_size":128,"d_model":16,"n_layers":1,
                         "n_heads":2,"d_ff":32,"max_seq":64,"pad_id":0,"param_count":1000},
              "weights": "m.wtar",
              "params": [{"name":"tok_emb","shape":[128,16],"dtype":"f32"}],
              "artifacts": [
                {"batch":1,"seq":32,"file":"m_b1_s32.hlo.txt"},
                {"batch":4,"seq":32,"file":"m_b4_s32.hlo.txt"},
                {"batch":4,"seq":80,"file":"m_b4_s80.hlo.txt"},
                {"batch":8,"seq":80,"file":"m_b8_s80.hlo.txt"}
              ]
            }
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_models_params_buckets() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample_manifest()).unwrap();
        assert_eq!(m.seed, 7);
        let entry = m.model("m").unwrap();
        assert_eq!(entry.config.vocab_size, 128);
        assert_eq!(entry.params[0].shape, vec![128, 16]);
        assert_eq!(entry.buckets.len(), 4);
        assert_eq!(entry.max_batch(), 8);
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample_manifest()).unwrap();
        let e = m.model("m").unwrap();
        assert_eq!(e.select_bucket(1, 20).unwrap().file, "m_b1_s32.hlo.txt");
        assert_eq!(e.select_bucket(2, 32).unwrap().file, "m_b4_s32.hlo.txt");
        assert_eq!(e.select_bucket(3, 50).unwrap().file, "m_b4_s80.hlo.txt");
        assert_eq!(e.select_bucket(8, 80).unwrap().file, "m_b8_s80.hlo.txt");
        assert!(e.select_bucket(9, 32).is_none());
        assert!(e.select_bucket(1, 128).is_none());
    }

    #[test]
    fn unknown_model_error_lists_available() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample_manifest()).unwrap();
        let err = m.model("nope").unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("m"));
    }

    #[test]
    fn wrong_version_rejected() {
        let j = json::parse(r#"{"version": 2, "models": {}}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }
}
