//! Reader for the `.wtar` tensor archive written by `python/compile/wtar.py`.
//!
//! Layout (little-endian): `WTAR1\0` magic, u32 count, then per tensor:
//! u32 name-len + utf-8 name, u8 dtype tag (0=f32, 1=i32), u8 rank,
//! rank x u64 dims, row-major payload.

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 6] = b"WTAR1\x00";

/// Element type of an archived tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One named tensor. Payload is kept as f32 or i32 words.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub f32_data: Vec<f32>,
    pub i32_data: Vec<i32>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Read a whole archive (order preserved).
pub fn read(path: &Path) -> Result<Vec<Tensor>> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 6];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC {
        bail!("{}: bad wtar magic {:?}", path.display(), magic);
    }
    let count = read_u32(&mut r)? as usize;
    if count > 1_000_000 {
        bail!("implausible tensor count {count}");
    }

    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("implausible name length {name_len}");
        }
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("tensor name utf-8")?;

        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let dtype = match hdr[0] {
            0 => DType::F32,
            1 => DType::I32,
            t => bail!("unknown dtype tag {t} for tensor {name}"),
        };
        let rank = hdr[1] as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(read_u64(&mut r)? as usize);
        }
        let n: usize = dims.iter().product();
        if n > 512 * 1024 * 1024 {
            bail!("implausible tensor size {n} for {name}");
        }
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)
            .with_context(|| format!("payload of {name}"))?;
        let mut t = Tensor {
            name,
            dtype,
            dims,
            f32_data: Vec::new(),
            i32_data: Vec::new(),
        };
        match dtype {
            DType::F32 => {
                t.f32_data = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            }
            DType::I32 => {
                t.i32_data = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            }
        }
        out.push(t);
    }
    Ok(out)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_archive(path: &Path, tensors: &[(&str, &[usize], &[f32])]) {
        let mut f = File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(tensors.len() as u32).to_le_bytes()).unwrap();
        for (name, dims, data) in tensors {
            f.write_all(&(name.len() as u32).to_le_bytes()).unwrap();
            f.write_all(name.as_bytes()).unwrap();
            f.write_all(&[0u8, dims.len() as u8]).unwrap();
            for d in *dims {
                f.write_all(&(*d as u64).to_le_bytes()).unwrap();
            }
            for v in *data {
                f.write_all(&v.to_le_bytes()).unwrap();
            }
        }
    }

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("windve_wtar_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.wtar");
        write_archive(
            &path,
            &[("a", &[2, 3], &[1., 2., 3., 4., 5., 6.]), ("b", &[1], &[9.])],
        );
        let ts = read(&path).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "a");
        assert_eq!(ts[0].dims, vec![2, 3]);
        assert_eq!(ts[0].f32_data, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(ts[1].name, "b");
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("windve_wtar_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.wtar");
        std::fs::write(&path, b"GARBAGE___").unwrap();
        assert!(read(&path).is_err());
    }

    #[test]
    fn truncated_payload_rejected() {
        let dir = std::env::temp_dir().join("windve_wtar_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.wtar");
        write_archive(&path, &[("a", &[4], &[1., 2., 3., 4.])]);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 4]).unwrap();
        assert!(read(&path).is_err());
    }

    #[test]
    fn missing_file_is_contextual_error() {
        let err = read(Path::new("/nonexistent/x.wtar")).unwrap_err();
        assert!(format!("{err:#}").contains("x.wtar"));
    }
}
