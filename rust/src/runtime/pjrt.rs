//! Thin wrapper over the `xla` crate's PJRT client, gated behind the
//! `pjrt-xla` cargo feature.
//!
//! Pattern (from the load_hlo example): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`. HLO *text* is the interchange format —
//! xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit ids).
//!
//! `PjRtClient` holds raw pointers and is not `Send`; worker instances
//! construct their own [`Context`] on their own thread (one "device
//! context" per worker, matching the paper's one-model-copy-per-instance).
//!
//! # Feature gating
//!
//! The `xla` crate (an FFI binding to a multi-GB xla_extension build) is
//! not vendorable into offline build environments, so the real client
//! only compiles under `--features pjrt-xla` (supply the crate via a
//! `[patch]`/path dependency — see `Cargo.toml`). Default builds get a
//! **host stub** with the identical API surface: uploads keep a host-side
//! copy (so arena-resident code paths type-check and tests can assert
//! shapes), while compile/execute return descriptive errors. Everything
//! above this module (engine, scan offload, service) treats "PJRT
//! unavailable" as an ordinary backend failure and falls back to
//! deterministic host paths, so tests and the DES never need built
//! artifacts.

use anyhow::Result;

/// Pull the first output of the first device from PJRT's per-device
/// output nesting, validating shape instead of indexing `outs[0][0]`
/// unchecked — an executable with no outputs (or a backend returning an
/// empty device list) must surface as `Err`, not panic the worker
/// thread that drove the batch.
// Stub builds exercise this only from tests (the real caller is the
// feature-gated `Executable::run`).
#[cfg_attr(not(feature = "pjrt-xla"), allow(dead_code))]
fn first_device_output<T>(outs: Vec<Vec<T>>, what: &str) -> Result<T> {
    let mut device0 = match outs.into_iter().next() {
        Some(d) => d,
        None => anyhow::bail!("{what}: execute returned no per-device outputs"),
    };
    if device0.is_empty() {
        anyhow::bail!("{what}: executable produced no outputs on device 0");
    }
    Ok(device0.swap_remove(0))
}

#[cfg(feature = "pjrt-xla")]
mod imp {
    use std::path::Path;

    use anyhow::{Context as _, Result};

    use super::first_device_output;

    /// One PJRT client plus helpers. Not `Send` — build per worker thread.
    pub struct Context {
        client: xla::PjRtClient,
    }

    /// A compiled executable bound to the context's device.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    /// A device-resident input buffer (weights stay uploaded across calls).
    pub struct DeviceBuffer {
        pub(crate) buf: xla::PjRtBuffer,
    }

    impl Context {
        /// CPU PJRT client (the only backend available on this image).
        pub fn cpu() -> Result<Context> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Context { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load HLO text and compile it for this device.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parse HLO text {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
            Ok(Executable { exe })
        }

        /// Upload an f32 tensor to the device.
        pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuffer> {
            let buf = self
                .client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("upload f32 {dims:?}: {e}"))?;
            Ok(DeviceBuffer { buf })
        }

        /// Upload an i32 tensor to the device.
        pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuffer> {
            let buf = self
                .client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("upload i32 {dims:?}: {e}"))?;
            Ok(DeviceBuffer { buf })
        }
    }

    impl Executable {
        /// Execute with device-resident inputs; returns the flattened f32
        /// payload of the first tuple element (AOT lowers with
        /// `return_tuple=True`, so outputs arrive as a 1-tuple).
        pub fn run(&self, args: &[&DeviceBuffer]) -> Result<Vec<f32>> {
            let bufs: Vec<&xla::PjRtBuffer> = args.iter().map(|b| &b.buf).collect();
            let outs = self
                .exe
                .execute_b(&bufs)
                .map_err(|e| anyhow::anyhow!("pjrt execute: {e}"))?;
            let out = first_device_output(outs, "pjrt execute")?;
            let lit = out
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch output: {e}"))?;
            let first = lit
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("untuple output: {e}"))?;
            first
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("output to f32 vec: {e}"))
        }
    }
}

#[cfg(not(feature = "pjrt-xla"))]
mod imp {
    use std::path::Path;

    use anyhow::Result;

    const UNAVAILABLE: &str =
        "PJRT backend unavailable: built without the `pjrt-xla` feature";

    /// Host-stub context: uploads are host copies, compile is an error.
    pub struct Context {
        _priv: (),
    }

    /// Uninstantiable in stub builds ([`Context::load_hlo_text`] always
    /// errors), but keeps every call site type-checking.
    pub struct Executable {
        _priv: (),
    }

    /// Host-side stand-in for a device buffer: the data and dims as
    /// uploaded, so arena-resident code paths (and their tests) can
    /// assert shapes without a device.
    pub struct DeviceBuffer {
        pub(crate) f32_data: Vec<f32>,
        pub(crate) dims: Vec<usize>,
    }

    impl DeviceBuffer {
        /// Element count the buffer was uploaded with.
        pub fn element_count(&self) -> usize {
            self.dims.iter().product()
        }

        /// Host copy of the uploaded payload (stub builds only — lets
        /// arena-resident tests assert what crossed the "boundary").
        pub fn host_f32(&self) -> &[f32] {
            &self.f32_data
        }
    }

    impl Context {
        pub fn cpu() -> Result<Context> {
            Ok(Context { _priv: () })
        }

        pub fn platform(&self) -> String {
            "host-stub (pjrt-xla feature disabled)".to_string()
        }

        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            anyhow::bail!("{UNAVAILABLE}: cannot compile {}", path.display())
        }

        /// "Upload" an f32 tensor: validates the shape like the real
        /// client and keeps a host copy.
        pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuffer> {
            let want: usize = dims.iter().product();
            anyhow::ensure!(
                want == data.len(),
                "upload f32 {dims:?}: dims require {want} elements, got {}",
                data.len()
            );
            Ok(DeviceBuffer { f32_data: data.to_vec(), dims: dims.to_vec() })
        }

        /// "Upload" an i32 tensor (host copy, converted for storage).
        pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuffer> {
            let want: usize = dims.iter().product();
            anyhow::ensure!(
                want == data.len(),
                "upload i32 {dims:?}: dims require {want} elements, got {}",
                data.len()
            );
            Ok(DeviceBuffer {
                f32_data: data.iter().map(|&x| x as f32).collect(),
                dims: dims.to_vec(),
            })
        }
    }

    impl Executable {
        pub fn run(&self, _args: &[&DeviceBuffer]) -> Result<Vec<f32>> {
            // Unreachable in practice — no constructor succeeds in stub
            // builds — but kept honest rather than panicking.
            anyhow::bail!("{UNAVAILABLE}: no executable can exist")
        }
    }
}

pub use imp::{Context, DeviceBuffer, Executable};

#[cfg(test)]
mod tests {
    use super::*;

    // Full context tests live in rust/tests/runtime_artifacts.rs (they need
    // built artifacts); here only client creation, which needs no files.
    #[test]
    fn cpu_client_comes_up() {
        let ctx = Context::cpu().unwrap();
        assert!(!ctx.platform().is_empty());
    }

    /// Satellite regression: an executable with no outputs must produce a
    /// descriptive error, not an index panic on `outs[0][0]`.
    #[test]
    fn empty_execute_outputs_error_instead_of_panic() {
        let no_devices: Vec<Vec<u8>> = vec![];
        let err = first_device_output(no_devices, "pjrt execute").unwrap_err();
        assert!(
            err.to_string().contains("no per-device outputs"),
            "unexpected error text: {err}"
        );
        let no_outputs: Vec<Vec<u8>> = vec![vec![]];
        let err = first_device_output(no_outputs, "pjrt execute").unwrap_err();
        assert!(
            err.to_string().contains("no outputs on device 0"),
            "unexpected error text: {err}"
        );
        assert!(err.to_string().contains("pjrt execute"), "{err}");
    }

    #[test]
    fn present_output_is_extracted() {
        let outs = vec![vec![41u32, 7], vec![99]];
        assert_eq!(first_device_output(outs, "t").unwrap(), 41);
    }

    #[cfg(not(feature = "pjrt-xla"))]
    #[test]
    fn stub_upload_validates_dims_and_keeps_host_copy() {
        let ctx = Context::cpu().unwrap();
        let buf = ctx.upload_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(buf.element_count(), 6);
        assert_eq!(buf.host_f32()[4], 5.0);
        assert!(ctx.upload_f32(&[1.0, 2.0], &[2, 3]).is_err());
        assert!(ctx.upload_i32(&[1, 2, 3], &[4]).is_err());
    }

    #[cfg(not(feature = "pjrt-xla"))]
    #[test]
    fn stub_compile_reports_missing_feature() {
        let ctx = Context::cpu().unwrap();
        let err = ctx
            .load_hlo_text(std::path::Path::new("nope.hlo"))
            .unwrap_err();
        assert!(err.to_string().contains("pjrt-xla"), "{err}");
    }
}
