//! Thin wrapper over the `xla` crate's PJRT client.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`. HLO *text* is the interchange format —
//! xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit ids).
//!
//! `PjRtClient` holds raw pointers and is not `Send`; worker instances
//! construct their own [`Context`] on their own thread (one "device
//! context" per worker, matching the paper's one-model-copy-per-instance).

use std::path::Path;

use anyhow::{Context as _, Result};

/// One PJRT client plus helpers. Not `Send` — build per worker thread.
pub struct Context {
    client: xla::PjRtClient,
}

/// A compiled executable bound to the context's device.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

/// A device-resident input buffer (weights stay uploaded across calls).
pub struct DeviceBuffer {
    pub(crate) buf: xla::PjRtBuffer,
}

impl Context {
    /// CPU PJRT client (the only backend available on this image).
    pub fn cpu() -> Result<Context> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Context { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO text and compile it for this device.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Upload an f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuffer> {
        let buf = self
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {dims:?}: {e}"))?;
        Ok(DeviceBuffer { buf })
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuffer> {
        let buf = self
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {dims:?}: {e}"))?;
        Ok(DeviceBuffer { buf })
    }
}

impl Executable {
    /// Execute with device-resident inputs; returns the flattened f32
    /// payload of the first tuple element (AOT lowers with
    /// `return_tuple=True`, so outputs arrive as a 1-tuple).
    pub fn run(&self, args: &[&DeviceBuffer]) -> Result<Vec<f32>> {
        let bufs: Vec<&xla::PjRtBuffer> = args.iter().map(|b| &b.buf).collect();
        let outs = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow::anyhow!("pjrt execute: {e}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch output: {e}"))?;
        let first = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple output: {e}"))?;
        first
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("output to f32 vec: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full context tests live in rust/tests/runtime_artifacts.rs (they need
    // built artifacts); here only client creation, which needs no files.
    #[test]
    fn cpu_client_comes_up() {
        let ctx = Context::cpu().unwrap();
        assert!(!ctx.platform().is_empty());
    }
}
