//! The embedding engine: tokenize → pad to bucket → PJRT execute → vectors.
//!
//! One engine = one model copy on one device context (the paper's "each
//! instance employs its own model copy", §4.1). Weights are uploaded to
//! device buffers once at load time and stay resident; per request only
//! the `[batch, seq]` ids/mask tensors cross the host/device boundary.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::manifest::{Manifest, ModelEntry};
use super::pjrt::{Context, DeviceBuffer, Executable};
use super::{tokenizer, wtar};

/// Embedding engine for a single model. Not `Send`: construct on the
/// worker thread that will own it.
pub struct EmbeddingEngine {
    ctx: Context,
    entry: ModelEntry,
    dir: PathBuf,
    weights: Vec<DeviceBuffer>,
    executables: HashMap<(usize, usize), Executable>,
    /// Wall time spent in `load` (model + weights), exposed for t_model
    /// accounting in the latency decomposition (paper Eq. 13).
    pub load_time: std::time::Duration,
}

impl EmbeddingEngine {
    /// Load manifest + weights for `model`, compiling bucket executables
    /// lazily on first use (call [`EmbeddingEngine::warmup`] to preload).
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<EmbeddingEngine> {
        let t0 = Instant::now();
        let manifest = Manifest::load(artifacts_dir)?;
        let entry = manifest.model(model)?.clone();
        let ctx = Context::cpu()?;

        // Upload weights once, in ABI order, validating shapes against the
        // manifest so a stale .wtar fails loudly here rather than in XLA.
        let tensors = wtar::read(&artifacts_dir.join(&entry.weights_file))?;
        if tensors.len() != entry.params.len() {
            bail!(
                "weights archive has {} tensors, manifest declares {}",
                tensors.len(),
                entry.params.len()
            );
        }
        let mut weights = Vec::with_capacity(tensors.len());
        for (t, spec) in tensors.iter().zip(&entry.params) {
            if t.name != spec.name || t.dims != spec.shape {
                bail!(
                    "weight mismatch: archive {}{:?} vs manifest {}{:?}",
                    t.name, t.dims, spec.name, spec.shape
                );
            }
            weights.push(ctx.upload_f32(&t.f32_data, &t.dims)?);
        }

        Ok(EmbeddingEngine {
            ctx,
            entry,
            dir: artifacts_dir.to_path_buf(),
            weights,
            executables: HashMap::new(),
            load_time: t0.elapsed(),
        })
    }

    pub fn model_name(&self) -> &str {
        &self.entry.config.name
    }

    pub fn d_model(&self) -> usize {
        self.entry.config.d_model
    }

    pub fn max_batch(&self) -> usize {
        self.entry.max_batch()
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// Compile every bucket up front (serving deployments do this so the
    /// first request doesn't pay compile latency).
    pub fn warmup(&mut self) -> Result<()> {
        let buckets = self.entry.buckets.clone();
        for b in buckets {
            self.executable(b.batch, b.seq)?;
        }
        Ok(())
    }

    fn executable(&mut self, batch: usize, seq: usize) -> Result<&Executable> {
        if !self.executables.contains_key(&(batch, seq)) {
            let bucket = self
                .entry
                .buckets
                .iter()
                .find(|b| b.batch == batch && b.seq == seq)
                .ok_or_else(|| anyhow!("no artifact for bucket b{batch}_s{seq}"))?;
            let exe = self.ctx.load_hlo_text(&self.dir.join(&bucket.file))?;
            self.executables.insert((batch, seq), exe);
        }
        Ok(&self.executables[&(batch, seq)])
    }

    /// Embed up to `max_batch()` texts; returns one unit-norm `d_model`
    /// vector per text. Chunks internally if the batch exceeds the largest
    /// exported bucket. Generic over the text storage (`String`,
    /// `Arc<str>`, `&str`) so the serving path's shared `Arc<str>`
    /// payloads reach tokenization without a copy.
    pub fn embed<S: AsRef<str>>(&mut self, texts: &[S]) -> Result<Vec<Vec<f32>>> {
        if texts.is_empty() {
            return Ok(Vec::new());
        }
        let max_b = self.entry.max_batch();
        let mut out = Vec::with_capacity(texts.len());
        for chunk in texts.chunks(max_b.max(1)) {
            out.extend(self.embed_chunk(chunk)?);
        }
        Ok(out)
    }

    fn embed_chunk<S: AsRef<str>>(&mut self, texts: &[S]) -> Result<Vec<Vec<f32>>> {
        let vocab = self.entry.config.vocab_size;
        let need_seq = texts
            .iter()
            .map(|t| tokenizer::token_count(t.as_ref()))
            .max()
            .unwrap_or(1)
            .min(self.entry.max_bucket_seq());
        let bucket = self
            .entry
            .select_bucket(texts.len(), need_seq)
            .ok_or_else(|| {
                anyhow!(
                    "no bucket fits batch={} seq={} (max exported: b{} s{})",
                    texts.len(), need_seq,
                    self.entry.max_batch(), self.entry.max_bucket_seq()
                )
            })?
            .clone();

        // Tokenize into one contiguous [bucket.batch, bucket.seq] pair of
        // tensors; phantom padding rows are fully masked (the kernels keep
        // them finite and we drop them below).
        let (bb, ss) = (bucket.batch, bucket.seq);
        let mut ids = vec![tokenizer::PAD_ID; bb * ss];
        let mut mask = vec![0.0f32; bb * ss];
        for (i, text) in texts.iter().enumerate() {
            let e = tokenizer::encode(text.as_ref(), vocab, ss);
            ids[i * ss..(i + 1) * ss].copy_from_slice(&e.ids);
            mask[i * ss..(i + 1) * ss].copy_from_slice(&e.mask);
        }

        let ids_buf = self.ctx.upload_i32(&ids, &[bb, ss])?;
        let mask_buf = self.ctx.upload_f32(&mask, &[bb, ss])?;
        // Keep exe lookup after uploads (borrow of self ends before args).
        let d = self.entry.config.d_model;
        let n_weights = self.weights.len();
        let exe = {
            // split borrows: executables map vs weights
            if !self.executables.contains_key(&(bb, ss)) {
                let file = bucket.file.clone();
                let exe = self.ctx.load_hlo_text(&self.dir.join(&file))?;
                self.executables.insert((bb, ss), exe);
            }
            &self.executables[&(bb, ss)]
        };
        let mut args: Vec<&DeviceBuffer> = Vec::with_capacity(n_weights + 2);
        args.extend(self.weights.iter());
        args.push(&ids_buf);
        args.push(&mask_buf);
        let flat = exe.run(&args)?;
        if flat.len() != bb * d {
            bail!("unexpected output size {} (want {})", flat.len(), bb * d);
        }
        Ok(texts
            .iter()
            .enumerate()
            .map(|(i, _)| flat[i * d..(i + 1) * d].to_vec())
            .collect())
    }
}

/// Cosine similarity between two embeddings (they are unit-norm, so this
/// is just the dot product; exposed for the retrieval examples).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_of_identical_unit_vectors_is_one() {
        let v = vec![0.6f32, 0.8];
        assert!((cosine(&v, &v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_orthogonal_is_zero() {
        assert_eq!(cosine(&[1.0, 0.0], &[0.0, 1.0]), 0.0);
    }
    // Engine tests that require built artifacts live in
    // rust/tests/runtime_artifacts.rs.
}
