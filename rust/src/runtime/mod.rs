//! L3 runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the PJRT CPU client. No Python on the request path.

pub mod engine;
pub mod manifest;
pub mod npu_scan;
pub mod pjrt;
pub mod tokenizer;
pub mod wtar;

pub use engine::EmbeddingEngine;
pub use manifest::{Bucket, Manifest, ModelEntry};
pub use npu_scan::NpuScanner;
