//! Device-side batched top-k scan — the NPU retrieval offload leg.
//!
//! Mirrors `vecstore::Index::search_batch` on the accelerator path: the
//! corpus arena is uploaded once (resident across scans, like model
//! weights in [`super::engine`]), and each offloaded panel ships only the
//! `[nq, dim]` query tensor to the device, which answers with the
//! `[nq, n]` score matrix of `Q · Rᵀ`; top-k selection runs host-side
//! over the returned scores with the same deterministic tie-breaking as
//! the CPU scan.
//!
//! Two execution paths behind one handle:
//!
//! * **Device** — a [`ScanBackend`] (e.g. [`PjrtScanBackend`]: PJRT
//!   matmul over a [`Context::upload_f32`]-resident arena) owned by a
//!   dedicated worker thread ([`spawn_scan_worker`]) because PJRT handles
//!   are not `Send`. Device errors degrade to the host fallback and are
//!   counted, never surfaced as scan failures.
//! * **Host fallback** — the same role [`crate::devices::executor::SyntheticBackend`]
//!   plays for embedding: a deterministic stand-in so tests and the DES
//!   never need built artifacts. It scans the mirrored arena with the
//!   dispatched f32 panel kernels and global-row-sequence top-k, so its
//!   results are **bit-identical** to `FlatIndex::search` over the same
//!   rows — the acceptance bar for routing a scan to either processor.
//!
//! Freshness: the mirror records the corpus version it was exported at
//! ([`crate::devices::executor::RetrievalExecutor::export_corpus`]); the
//! service only offloads while the versions still match, so an offloaded
//! scan is always equivalent to a CPU scan that acquired the index lock
//! at mirror time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::pjrt::{Context, DeviceBuffer, Executable};
use crate::vecstore::{kernels, Hit, TopK};

/// Row tile per host-fallback kernel call (matches `vecstore::flat`).
const SCAN_BLOCK_ROWS: usize = 64;

/// A device executor for one resident corpus: panel in, scores out.
pub trait ScanBackend {
    /// Score `nq` row-major `dim`-vectors against the resident corpus;
    /// returns the row-major `[nq, n]` score matrix.
    fn scores(&mut self, queries: &[f32], nq: usize) -> Result<Vec<f32>>;
    /// Human-readable description (for logs).
    fn describe(&self) -> String;
}

/// Factory building the scan backend *on the worker thread* (PJRT
/// handles are not `Send`, same pattern as embedding workers).
pub type ScanBackendFactory = Box<dyn FnOnce() -> Result<Box<dyn ScanBackend>> + Send>;

/// PJRT-backed [`ScanBackend`]: compiles a `scores = Q · Rᵀ` HLO artifact
/// and keeps the corpus arena device-resident via [`Context::upload_f32`].
/// Construction fails cleanly when PJRT is unavailable (no `pjrt-xla`
/// feature, missing artifact), leaving callers on the host fallback.
pub struct PjrtScanBackend {
    ctx: Context,
    exe: Executable,
    corpus: DeviceBuffer,
    n: usize,
    dim: usize,
}

impl PjrtScanBackend {
    /// Compile `hlo_path` on a fresh CPU PJRT context and upload the
    /// `[n, dim]` corpus once; per call only the query panel crosses the
    /// host/device boundary.
    pub fn load(hlo_path: &std::path::Path, rows: &[f32], n: usize, dim: usize) -> Result<Self> {
        anyhow::ensure!(rows.len() == n * dim, "corpus shape: {} != {n}x{dim}", rows.len());
        let ctx = Context::cpu()?;
        let exe = ctx.load_hlo_text(hlo_path)?;
        let corpus = ctx.upload_f32(rows, &[n, dim])?;
        Ok(PjrtScanBackend { ctx, exe, corpus, n, dim })
    }
}

impl ScanBackend for PjrtScanBackend {
    fn scores(&mut self, queries: &[f32], nq: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(queries.len() == nq * self.dim, "query panel shape mismatch");
        let q = self.ctx.upload_f32(queries, &[nq, self.dim])?;
        let flat = self.exe.run(&[&self.corpus, &q])?;
        anyhow::ensure!(
            flat.len() == nq * self.n,
            "scan output {} != {nq}x{}",
            flat.len(),
            self.n
        );
        Ok(flat)
    }

    fn describe(&self) -> String {
        format!("pjrt-scan[{}x{}]", self.n, self.dim)
    }
}

struct ScanJob {
    queries: Vec<f32>,
    nq: usize,
    reply: mpsc::Sender<Result<Vec<f32>, String>>,
}

/// Handle to a scan worker thread owning a [`ScanBackend`]. Cloneless by
/// design: one handle per mirrored arena, shared behind the scanner.
pub struct DeviceScanHandle {
    tx: Mutex<mpsc::Sender<ScanJob>>,
}

impl DeviceScanHandle {
    fn scores(&self, queries: Vec<f32>, nq: usize) -> Result<Vec<f32>, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .map_err(|_| "scan worker handle poisoned".to_string())?
            .send(ScanJob { queries, nq, reply })
            .map_err(|_| "scan worker exited".to_string())?;
        rx.recv().map_err(|_| "scan worker dropped reply".to_string())?
    }
}

/// Spawn the device scan worker; the backend is built on the new thread.
/// A failed factory fails each job with its error (callers fall back to
/// the host scan), mirroring embedding-worker init failure containment.
pub fn spawn_scan_worker(factory: ScanBackendFactory) -> (DeviceScanHandle, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<ScanJob>();
    let join = std::thread::Builder::new()
        .name("npu-scan".into())
        .spawn(move || {
            let mut backend = match factory() {
                Ok(b) => b,
                Err(e) => {
                    log::warn!("npu-scan: backend init failed: {e:#}");
                    while let Ok(job) = rx.recv() {
                        let _ = job.reply.send(Err(format!("scan backend init failed: {e:#}")));
                    }
                    return;
                }
            };
            log::info!("npu-scan: serving with {}", backend.describe());
            while let Ok(job) = rx.recv() {
                let out = backend
                    .scores(&job.queries, job.nq)
                    .map_err(|e| format!("device scan failed: {e:#}"));
                let _ = job.reply.send(out);
            }
        })
        .expect("spawn npu-scan thread");
    (DeviceScanHandle { tx: Mutex::new(tx) }, join)
}

/// The NPU retrieval scanner: a mirrored corpus arena plus the device
/// and host execution paths (see module docs).
pub struct NpuScanner {
    dim: usize,
    ids: Vec<u64>,
    rows: Vec<f32>, // row-major [n, dim]; also the host-fallback arena
    corpus_version: u64,
    device: Option<DeviceScanHandle>,
    device_failures: AtomicU64,
}

impl NpuScanner {
    /// Build from a corpus snapshot (e.g.
    /// `RetrievalExecutor::export_corpus`). Host-fallback only; attach a
    /// device path with [`NpuScanner::with_device`].
    pub fn from_snapshot(
        dim: usize,
        ids: Vec<u64>,
        rows: Vec<f32>,
        corpus_version: u64,
    ) -> Result<NpuScanner> {
        anyhow::ensure!(dim > 0, "dim must be positive");
        anyhow::ensure!(
            rows.len() == ids.len() * dim,
            "arena shape: {} floats != {} ids x {dim}",
            rows.len(),
            ids.len()
        );
        Ok(NpuScanner {
            dim,
            ids,
            rows,
            corpus_version,
            device: None,
            device_failures: AtomicU64::new(0),
        })
    }

    /// Attach a device scan worker (the arena it holds resident must be
    /// the same snapshot this scanner mirrors).
    pub fn with_device(mut self, handle: DeviceScanHandle) -> NpuScanner {
        self.device = Some(handle);
        self
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// The executor corpus version this arena was exported at.
    pub fn corpus_version(&self) -> u64 {
        self.corpus_version
    }

    /// Bytes one offloaded scan streams from the mirrored arena (always
    /// f32 — the mirror is exact by construction).
    pub fn scan_bytes(&self) -> usize {
        self.rows.len() * std::mem::size_of::<f32>()
    }

    /// Admission slot cost on the NPU leg, in the same embed-query cost
    /// units as the CPU leg.
    pub fn scan_cost(&self, unit_bytes: usize) -> usize {
        crate::coordinator::queue_manager::retrieval_slot_cost(self.scan_bytes(), unit_bytes)
    }

    /// Device-path errors absorbed by the host fallback so far.
    pub fn device_failures(&self) -> u64 {
        self.device_failures.load(Ordering::Relaxed)
    }

    /// Batched top-k over the mirrored arena. Results are bit-identical
    /// to `FlatIndex::search` over the same rows on the host path; the
    /// device path agrees up to the device matmul's FP accumulation
    /// order (scores are re-ranked host-side with the same tie-breaks).
    pub fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        for q in queries {
            assert_eq!(q.len(), self.dim, "dimension mismatch");
        }
        let nq = queries.len();
        let n = self.ids.len();
        if nq == 0 {
            return Vec::new();
        }
        if n == 0 {
            return vec![Vec::new(); nq];
        }
        let mut qbuf = Vec::with_capacity(nq * self.dim);
        for q in queries {
            qbuf.extend_from_slice(q);
        }
        if let Some(dev) = &self.device {
            match dev.scores(qbuf.clone(), nq) {
                Ok(scores) if scores.len() == nq * n => {
                    return self.topk_from_dense_scores(&scores, nq, k);
                }
                Ok(scores) => {
                    self.device_failures.fetch_add(1, Ordering::Relaxed);
                    log::warn!(
                        "npu-scan: device returned {} scores, want {} — host fallback",
                        scores.len(),
                        nq * n
                    );
                }
                Err(e) => {
                    self.device_failures.fetch_add(1, Ordering::Relaxed);
                    log::warn!("npu-scan: {e} — host fallback");
                }
            }
        }
        self.host_search(&qbuf, nq, k)
    }

    /// Host fallback: the FlatIndex scan shape — blocked panel kernel,
    /// global row index as the tie-break sequence — over the mirror.
    fn host_search(&self, qbuf: &[f32], nq: usize, k: usize) -> Vec<Vec<Hit>> {
        let n = self.ids.len();
        let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
        let mut scores = vec![0.0f32; nq * SCAN_BLOCK_ROWS];
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + SCAN_BLOCK_ROWS).min(n);
            let nr = r1 - r0;
            let rows = &self.rows[r0 * self.dim..r1 * self.dim];
            kernels::panel_scores_into(qbuf, nq, rows, nr, self.dim, &mut scores[..nq * nr]);
            for (qi, tk) in tks.iter_mut().enumerate() {
                for r in 0..nr {
                    tk.push_with_seq(self.ids[r0 + r], scores[qi * nr + r], (r0 + r) as u64);
                }
            }
            r0 = r1;
        }
        tks.into_iter().map(TopK::into_vec).collect()
    }

    /// Top-k from a device-returned `[nq, n]` score matrix, with the same
    /// global-row-sequence tie-breaking as the host scan.
    fn topk_from_dense_scores(&self, scores: &[f32], nq: usize, k: usize) -> Vec<Vec<Hit>> {
        let n = self.ids.len();
        (0..nq)
            .map(|qi| {
                let mut tk = TopK::new(k);
                for r in 0..n {
                    tk.push_with_seq(self.ids[r], scores[qi * n + r], r as u64);
                }
                tk.into_vec()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;
    use crate::vecstore::{FlatIndex, Index};

    fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= norm);
        v
    }

    fn corpus(dim: usize, n: usize, seed: u64) -> (FlatIndex, Vec<u64>, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        let mut flat = FlatIndex::new(dim);
        let mut ids = Vec::new();
        let mut rows = Vec::new();
        for i in 0..n {
            let v = unit(&mut rng, dim);
            flat.add(i as u64, &v);
            ids.push(i as u64);
            rows.extend_from_slice(&v);
        }
        (flat, ids, rows)
    }

    /// The acceptance bar: host-fallback offload results are bit-identical
    /// to the CPU flat scan over the same rows — ids, order, and score
    /// bits — including across the 64-row block boundary.
    #[test]
    fn host_fallback_is_bit_identical_to_flat_search() {
        let dim = 48; // not a multiple of the SIMD lane width
        let (flat, ids, rows) = corpus(dim, 200, 7);
        let sc = NpuScanner::from_snapshot(dim, ids, rows, 0).unwrap();
        let mut rng = Pcg::new(8);
        let queries: Vec<Vec<f32>> = (0..5).map(|_| unit(&mut rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let got = sc.search_batch(&qrefs, 7);
        for (q, hits) in qrefs.iter().zip(&got) {
            let want = flat.search(q, 7);
            assert_eq!(hits, &want);
            for (a, b) in hits.iter().zip(&want) {
                assert_eq!(a.score.to_bits(), b.score.to_bits());
            }
        }
        assert_eq!(sc.device_failures(), 0);
    }

    #[test]
    fn snapshot_shape_is_validated() {
        assert!(NpuScanner::from_snapshot(4, vec![1, 2], vec![0.0; 7], 0).is_err());
        assert!(NpuScanner::from_snapshot(0, vec![], vec![], 0).is_err());
        let sc = NpuScanner::from_snapshot(4, vec![1, 2], vec![0.0; 8], 3).unwrap();
        assert_eq!(sc.len(), 2);
        assert_eq!(sc.corpus_version(), 3);
        assert_eq!(sc.scan_bytes(), 8 * 4);
        assert_eq!(sc.scan_cost(16), 2);
        assert_eq!(sc.scan_cost(usize::MAX), 1);
    }

    #[test]
    fn empty_inputs_are_handled() {
        let sc = NpuScanner::from_snapshot(4, vec![], vec![], 0).unwrap();
        let q = [0.0f32; 4];
        assert_eq!(sc.search_batch(&[&q], 3), vec![Vec::new()]);
        let (_, ids, rows) = corpus(4, 3, 1);
        let sc = NpuScanner::from_snapshot(4, ids, rows, 0).unwrap();
        assert!(sc.search_batch(&[], 3).is_empty());
    }

    /// A well-behaved device backend (host math shipped through the
    /// worker-thread plumbing) must produce the same hits as the host
    /// fallback.
    struct DenseBackend {
        rows: Vec<f32>,
        n: usize,
        dim: usize,
    }
    impl ScanBackend for DenseBackend {
        fn scores(&mut self, queries: &[f32], nq: usize) -> Result<Vec<f32>> {
            // [nq, n] dense scores via the same dispatched kernels.
            let mut out = vec![0.0f32; nq * self.n];
            kernels::panel_scores_into(queries, nq, &self.rows, self.n, self.dim, &mut out);
            Ok(out)
        }
        fn describe(&self) -> String {
            "dense-test".into()
        }
    }

    #[test]
    fn device_path_matches_host_fallback() {
        let dim = 16;
        let (flat, ids, rows) = corpus(dim, 120, 11);
        let (handle, _join) = spawn_scan_worker({
            let rows = rows.clone();
            Box::new(move || {
                Ok(Box::new(DenseBackend { n: rows.len() / dim, rows, dim })
                    as Box<dyn ScanBackend>)
            })
        });
        let sc = NpuScanner::from_snapshot(dim, ids, rows, 0)
            .unwrap()
            .with_device(handle);
        let mut rng = Pcg::new(12);
        let queries: Vec<Vec<f32>> = (0..4).map(|_| unit(&mut rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let got = sc.search_batch(&qrefs, 6);
        assert_eq!(sc.device_failures(), 0);
        for (q, hits) in qrefs.iter().zip(&got) {
            assert_eq!(hits, &flat.search(q, 6));
        }
    }

    /// Device failures (init or per-scan) degrade to the host fallback —
    /// counted, never a lost scan.
    #[test]
    fn device_failure_falls_back_to_host() {
        struct FailingBackend;
        impl ScanBackend for FailingBackend {
            fn scores(&mut self, _q: &[f32], _nq: usize) -> Result<Vec<f32>> {
                anyhow::bail!("injected device fault")
            }
            fn describe(&self) -> String {
                "failing-test".into()
            }
        }
        let dim = 8;
        let (flat, ids, rows) = corpus(dim, 40, 21);
        // Per-scan failure.
        let (handle, _j1) =
            spawn_scan_worker(Box::new(|| Ok(Box::new(FailingBackend) as Box<dyn ScanBackend>)));
        let sc = NpuScanner::from_snapshot(dim, ids.clone(), rows.clone(), 0)
            .unwrap()
            .with_device(handle);
        let mut rng = Pcg::new(22);
        let q = unit(&mut rng, dim);
        let hits = sc.search_batch(&[&q[..]], 5);
        assert_eq!(hits[0], flat.search(&q, 5));
        assert_eq!(sc.device_failures(), 1);
        // Init failure (e.g. PJRT unavailable): same containment.
        let (handle, _j2) = spawn_scan_worker(Box::new(|| anyhow::bail!("no artifacts")));
        let sc = NpuScanner::from_snapshot(dim, ids, rows, 0).unwrap().with_device(handle);
        let hits = sc.search_batch(&[&q[..]], 5);
        assert_eq!(hits[0], flat.search(&q, 5));
        assert_eq!(sc.device_failures(), 1);
    }

    /// Without the `pjrt-xla` feature the PJRT scan backend must fail
    /// construction with a descriptive error, not panic — this is the
    /// path that leaves default builds on the host fallback.
    #[cfg(not(feature = "pjrt-xla"))]
    #[test]
    fn pjrt_scan_backend_unavailable_without_feature() {
        let rows = vec![0.0f32; 8];
        let err = PjrtScanBackend::load(std::path::Path::new("scan.hlo"), &rows, 2, 4)
            .err()
            .expect("stub build cannot compile HLO");
        assert!(err.to_string().contains("pjrt-xla"), "{err}");
        // Shape validation still fires first on malformed input.
        let err = PjrtScanBackend::load(std::path::Path::new("scan.hlo"), &rows, 3, 4)
            .err()
            .unwrap();
        assert!(err.to_string().contains("corpus shape"), "{err}");
    }
}
