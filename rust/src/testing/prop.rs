//! quickcheck-lite: seeded random-input property testing.
//!
//! Each case gets a fresh [`Gen`] derived from a base seed; on failure the
//! harness retries with progressively simpler size hints (a lightweight
//! stand-in for shrinking) and panics with the exact seed so the failure
//! is reproducible with `WINDVE_PROP_SEED=<seed>`.
//!
//! ```
//! use windve::testing::prop::{property, Gen};
//! property("reverse twice is identity", 100, |g: &mut Gen| {
//!     let v: Vec<u32> = g.vec(0..g.size(), |g| g.u32(0, 1000));
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     if w == v { Ok(()) } else { Err(format!("{v:?}")) }
//! });
//! ```

use crate::util::rng::Pcg;

/// Random input generator with a size hint (grows over the run).
pub struct Gen {
    rng: Pcg,
    size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: Pcg::new(seed), size }
    }

    /// Current size hint (use to scale collection lengths).
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        if lo >= hi {
            return lo;
        }
        self.rng.range(lo, hi)
    }

    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.u64(lo as u64, hi as u64) as u32
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.pick(items)
    }

    /// Vec with length in `len` (e.g. `0..g.size()`), elements from `f`.
    pub fn vec<T>(
        &mut self,
        len: std::ops::Range<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(len.start, len.end.max(len.start + 1));
        (0..n).map(|_| f(self)).collect()
    }

    /// ASCII word (for query text).
    pub fn word(&mut self) -> String {
        let n = self.usize(1, 10);
        (0..n)
            .map(|_| (b'a' + self.u32(0, 26) as u8) as char)
            .collect()
    }

    pub fn sentence(&mut self, max_words: usize) -> String {
        let n = self.usize(1, max_words.max(2));
        (0..n).map(|_| self.word()).collect::<Vec<_>>().join(" ")
    }

    pub fn rng(&mut self) -> &mut Pcg {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. `prop` returns `Err(description)` on
/// failure. Panics with the reproducing seed.
pub fn property<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("WINDVE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000u64);
    for case in 0..cases as u64 {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        // size ramps 4 → 4+cases so early cases are small "shrunk" inputs
        let size = 4 + (case as usize * 60 / cases.max(1)).min(60);
        let mut gen = Gen::new(seed, size);
        if let Err(msg) = prop(&mut gen) {
            // Retry at minimal size with the same seed — if it still fails,
            // report the small counterexample; otherwise the original.
            let mut small = Gen::new(seed, 4);
            let small_msg = prop(&mut small).err();
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, size {size}):\n  {}\nreproduce with WINDVE_PROP_SEED={base_seed}",
                small_msg.unwrap_or(msg)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("addition commutes", 50, |g| {
            let a = g.u64(0, 1000);
            let b = g.u64(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        property("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(1, 10);
        let mut b = Gen::new(1, 10);
        for _ in 0..20 {
            assert_eq!(a.u64(0, 1_000_000), b.u64(0, 1_000_000));
        }
    }

    #[test]
    fn vec_length_in_range() {
        let mut g = Gen::new(3, 10);
        for _ in 0..100 {
            let v = g.vec(2..8, |g| g.bool());
            assert!((2..8).contains(&v.len()));
        }
    }

    #[test]
    fn words_are_nonempty_ascii() {
        let mut g = Gen::new(4, 10);
        for _ in 0..50 {
            let w = g.word();
            assert!(!w.is_empty() && w.bytes().all(|b| b.is_ascii_lowercase()));
        }
    }
}
