//! In-repo property-testing framework (proptest is unavailable offline).

pub mod prop;
