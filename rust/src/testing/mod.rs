//! In-repo property-testing framework (proptest is unavailable offline)
//! and shared test fixtures.

pub mod prop;

/// Deterministic text → unit-vector embedding (FNV-1a seed + LCG walk,
/// L2-normalised). The single definition of the contract retrieval
/// tests rely on: a backend built on this function and a corpus indexed
/// with it agree exactly, so nearest-neighbour assertions are exact.
/// Used by the service's test backend and the e2e saturation harness.
pub fn pseudo_embedding(text: &str, d: usize) -> Vec<f32> {
    let mut state = 0xcbf29ce484222325u64;
    for b in text.bytes() {
        state = (state ^ b as u64).wrapping_mul(0x100000001b3);
    }
    let mut v: Vec<f32> = (0..d)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect();
    let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= norm);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pseudo_embedding_is_unit_norm_and_deterministic() {
        let a = pseudo_embedding("same text", 32);
        assert_eq!(a, pseudo_embedding("same text", 32));
        assert_ne!(a, pseudo_embedding("other text", 32));
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4);
    }
}
