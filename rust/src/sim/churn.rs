//! Durability churn scenario — the corpus-lifecycle acceptance harness.
//!
//! Where [`super::des`] simulates *devices* under arrival streams, this
//! module drives the **real durable store** (`durability::DurableStore`
//! over a [`FaultFs`](crate::durability::FaultFs)) through simulated
//! days of mixed upsert/delete/query traffic in virtual time, with
//! mid-storm crashes and full recovery, and checks the two lifecycle
//! invariants end to end:
//!
//! * **zero acked-write loss** — after every crash+replay (and at the
//!   end of the run) the recovered corpus is compared bit-for-bit
//!   against a shadow executor that received exactly the acked
//!   mutations: no live document missing, no deleted document
//!   resurrected, no vector divergent.
//! * **zero oversubscription** — upserts are admitted through the
//!   production [`QueueManager`] under `WorkClass::Ingest` (BUSY =
//!   backpressure retry, as the pipeline does against the upload
//!   socket), queries under `WorkClass::Retrieve`; the combined CPU
//!   occupancy is probed at every event instant and must never exceed
//!   the calibrated depth.
//!
//! The run is fully deterministic per seed: arrival times, op kinds,
//! document ids and revisions, crash instants and recovery outcomes all
//! reproduce bit-for-bit, so the in-module tests can assert exact
//! conservation without ever sleeping.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::queue_manager::{ClassCaps, QueueManager, Route, WorkClass};
use crate::devices::executor::RetrievalExecutor;
use crate::durability::{DurabilityOptions, DurableStore, FaultFs, FaultPlan, Fs};
use crate::testing::pseudo_embedding;
use crate::util::rng::Pcg;
use crate::vecstore::FlatIndex;

/// Aggregate results of a [`ChurnSim::run`].
#[derive(Debug, Clone)]
pub struct ChurnStats {
    /// Ops generated, by kind (arrivals, before admission/retries).
    pub upserts_arrived: u64,
    pub deletes_arrived: u64,
    pub queries_arrived: u64,
    /// Mutations durably committed (WAL-logged + index-applied + acked).
    pub upserts_acked: u64,
    pub deletes_acked: u64,
    pub queries_served: u64,
    /// Queries declined by retrieval admission (never retried).
    pub queries_rejected: u64,
    /// BUSY responses the ingest class absorbed by retrying later — the
    /// virtual-time mirror of the pipeline's exponential backoff.
    pub backpressure_retries: u64,
    /// Mid-storm crashes injected (each followed by a full recovery).
    pub crashes: u64,
    /// WAL records re-applied across all recoveries.
    pub replayed: u64,
    pub snapshots: u64,
    pub compactions: u64,
    /// Final WAL watermark (== acked mutations, seqs are never reused).
    pub committed_seq: u64,
    /// Live documents at the end of the run.
    pub live_docs: u64,
    /// Acked documents missing after a recovery. Must be 0.
    pub lost_acked: u64,
    /// Deleted documents that reappeared after a recovery. Must be 0.
    pub resurrected: u64,
    /// Recovered vectors that differ bitwise from the acked ones. Must
    /// be 0.
    pub divergent: u64,
    /// Peak combined CPU-pool occupancy (ingest + retrieve cost units).
    pub peak_cpu_occupancy: usize,
    /// Event instants where occupancy exceeded the calibrated depth.
    /// Must be 0: admission is the only gate.
    pub oversub_events: u64,
    /// Virtual time the run actually took (retries can push past the
    /// nominal horizon), in days.
    pub makespan_days: f64,
}

impl ChurnStats {
    /// The lifecycle acceptance predicate: nothing acked was lost,
    /// nothing deleted came back, nothing drifted, and admission never
    /// let the pool oversubscribe.
    pub fn clean(&self) -> bool {
        self.lost_acked == 0
            && self.resurrected == 0
            && self.divergent == 0
            && self.oversub_events == 0
    }
}

/// Configuration for one churn run. All times are virtual seconds; one
/// "day" is 86 400 of them.
#[derive(Debug, Clone)]
pub struct ChurnSim {
    pub dim: usize,
    /// Nominal horizon in days.
    pub days: f64,
    /// Ops drawn per day, uniformly over the day.
    pub ops_per_day: u32,
    /// Document ids are drawn from `0..id_space` — small spaces force
    /// overwrites (upsert of a live id) and resurrection-by-upsert of
    /// previously deleted ids, the interesting lifecycle transitions.
    pub id_space: u64,
    /// Fraction of ops that delete a (currently live) document.
    pub delete_fraction: f64,
    /// Fraction of the remainder that are top-k queries.
    pub query_fraction: f64,
    /// Virtual seconds one admitted upsert holds its ingest slot.
    pub embed_service: f64,
    /// Virtual seconds one admitted query holds its retrieval slot.
    pub scan_service: f64,
    pub cpu_depth: usize,
    pub ingest_cap: usize,
    pub retrieve_cap: usize,
    /// Crash instants, in days from the start (e.g. `[0.7, 1.5]`).
    /// Each is a power-cut between two ops followed by restart +
    /// recovery + bit-exact verification against the shadow.
    pub crash_days: Vec<f64>,
    /// Periodic checkpoint interval in days (0 disables; compaction can
    /// still checkpoint on its own).
    pub snapshot_every_days: f64,
    pub seed: u64,
    pub opts: DurabilityOptions,
}

impl Default for ChurnSim {
    fn default() -> ChurnSim {
        ChurnSim {
            dim: 16,
            days: 2.0,
            ops_per_day: 300,
            id_space: 120,
            delete_fraction: 0.2,
            query_fraction: 0.3,
            embed_service: 120.0,
            scan_service: 60.0,
            cpu_depth: 8,
            ingest_cap: 4,
            retrieve_cap: 4,
            crash_days: vec![0.7, 1.5],
            snapshot_every_days: 0.5,
            seed: 1,
            opts: DurabilityOptions::default(),
        }
    }
}

const DAY: f64 = 86_400.0;

// Event kinds, in tie-break order at equal instants.
const EV_UPSERT: u8 = 0;
const EV_DELETE: u8 = 1;
const EV_QUERY: u8 = 2;
const EV_REL_INGEST: u8 = 3;
const EV_REL_RETR: u8 = 4;
const EV_CRASH: u8 = 5;
const EV_SNAPSHOT: u8 = 6;

/// Heap entry: ordered by (time, seq) so equal-instant events pop in
/// schedule order. `a` carries the retry attempt (arrivals) or the
/// admission epoch (releases — a release from before a crash must not
/// free a slot in the post-crash manager).
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Ev {
    t_ns: u64,
    seq: u64,
    kind: u8,
    a: u64,
}

fn ns(t: f64) -> u64 {
    (t * 1e9) as u64
}

impl ChurnSim {
    fn recover(
        &self,
        fs: &Arc<FaultFs>,
    ) -> Result<(Arc<DurableStore>, Arc<RetrievalExecutor>, u64)> {
        let dim = self.dim;
        let dynfs: Arc<dyn Fs> = fs.clone();
        let (store, exec, report) = DurableStore::recover(
            dynfs,
            Path::new("/churn"),
            self.opts.clone(),
            || Box::new(FlatIndex::new(dim)),
            |text| Ok(pseudo_embedding(text, dim)),
        )
        .context("churn: recovery failed")?;
        Ok((store, exec, report.replayed))
    }

    fn new_qm(&self) -> QueueManager {
        QueueManager::with_caps(
            1, // NPU pool unused: the churn exercises the CPU lifecycle
            self.cpu_depth,
            true,
            ClassCaps {
                retrieve: self.retrieve_cap,
                npu_retrieve: 0,
                ingest: self.ingest_cap,
                npu_ingest: 0,
            },
        )
    }

    /// Compare the recovered corpus against the shadow of acked
    /// mutations: `(lost, resurrected, divergent)`.
    fn diff(exec: &RetrievalExecutor, shadow: &RetrievalExecutor, dim: usize) -> (u64, u64, u64) {
        let (got_ids, got_rows, _) = exec.export_corpus().expect("flat index exports");
        let (want_ids, want_rows, _) = shadow.export_corpus().expect("flat index exports");
        let got: HashMap<u64, &[f32]> = got_ids
            .iter()
            .enumerate()
            .map(|(i, id)| (*id, &got_rows[i * dim..(i + 1) * dim]))
            .collect();
        let want: HashMap<u64, &[f32]> = want_ids
            .iter()
            .enumerate()
            .map(|(i, id)| (*id, &want_rows[i * dim..(i + 1) * dim]))
            .collect();
        let lost = want.keys().filter(|id| !got.contains_key(id)).count() as u64;
        let resurrected = got.keys().filter(|id| !want.contains_key(id)).count() as u64;
        let divergent = want
            .iter()
            .filter(|(id, w)| {
                got.get(id).is_some_and(|g| {
                    g.iter().map(|x| x.to_bits()).ne(w.iter().map(|x| x.to_bits()))
                })
            })
            .count() as u64;
        (lost, resurrected, divergent)
    }

    /// Run the scenario to completion (every generated mutation is
    /// eventually acked — backpressured upserts retry until a slot
    /// frees; queries are fire-and-forget and may be rejected).
    pub fn run(&self) -> Result<ChurnStats> {
        let fs = Arc::new(FaultFs::new());
        let (mut store, mut exec, _) = self.recover(&fs)?;
        // The shadow receives exactly the acked mutations, no
        // durability: the ground truth every recovery must reproduce.
        let shadow = RetrievalExecutor::flat(self.dim);
        let mut qm = self.new_qm();
        let mut epoch: u64 = 0;
        let mut rng = Pcg::new(self.seed);

        let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
        let mut seq: u64 = 0;
        let push = |heap: &mut BinaryHeap<Reverse<Ev>>, t: f64, kind: u8, a: u64, seq: &mut u64| {
            heap.push(Reverse(Ev { t_ns: ns(t), seq: *seq, kind, a }));
            *seq += 1;
        };

        let mut st = ChurnStats {
            upserts_arrived: 0,
            deletes_arrived: 0,
            queries_arrived: 0,
            upserts_acked: 0,
            deletes_acked: 0,
            queries_served: 0,
            queries_rejected: 0,
            backpressure_retries: 0,
            crashes: 0,
            replayed: 0,
            snapshots: 0,
            compactions: 0,
            committed_seq: 0,
            live_docs: 0,
            lost_acked: 0,
            resurrected: 0,
            divergent: 0,
            peak_cpu_occupancy: 0,
            oversub_events: 0,
            makespan_days: 0.0,
        };

        // Generate the schedule up front, so pop-time RNG draws (doc
        // ids, revisions) never perturb arrival instants.
        let total_ops = (self.days * self.ops_per_day as f64).round() as u64;
        for _ in 0..total_ops {
            let t = rng.f64() * self.days * DAY;
            let kind = if rng.chance(self.delete_fraction) {
                st.deletes_arrived += 1;
                EV_DELETE
            } else if rng.chance(self.query_fraction) {
                st.queries_arrived += 1;
                EV_QUERY
            } else {
                st.upserts_arrived += 1;
                EV_UPSERT
            };
            push(&mut heap, t, kind, 0, &mut seq);
        }
        for &d in &self.crash_days {
            push(&mut heap, d * DAY, EV_CRASH, 0, &mut seq);
        }
        if self.snapshot_every_days > 0.0 {
            let mut t = self.snapshot_every_days * DAY;
            while t < self.days * DAY {
                push(&mut heap, t, EV_SNAPSHOT, 0, &mut seq);
                t += self.snapshot_every_days * DAY;
            }
        }

        let mut rev: u64 = 0;
        // Cost units in flight per class — the probe's view of what the
        // manager has admitted.
        let mut ingest_inflight: usize = 0;
        let mut retr_inflight: usize = 0;

        while let Some(Reverse(ev)) = heap.pop() {
            let now = ev.t_ns as f64 / 1e9;
            st.makespan_days = now / DAY;
            match ev.kind {
                EV_UPSERT => {
                    if qm.dispatch_class(WorkClass::Ingest, 1) == Route::Cpu {
                        ingest_inflight += 1;
                        let id = rng.range(0, self.id_space);
                        rev += 1;
                        let text = format!("doc {id} rev {rev}");
                        let v = pseudo_embedding(&text, self.dim);
                        let vs = v.clone();
                        store
                            .log_upserts(&[(id, text.as_str())], || {
                                exec.upsert_batch(&[(id, vs)]);
                            })
                            .context("churn: upsert refused")?;
                        shadow.upsert_batch(&[(id, v)]);
                        st.upserts_acked += 1;
                        store.maybe_compact(&exec).context("churn: compaction")?;
                        push(&mut heap, now + self.embed_service, EV_REL_INGEST, epoch, &mut seq);
                    } else {
                        // The pipeline's backoff, in virtual time:
                        // re-offer the document later, never drop it.
                        st.backpressure_retries += 1;
                        let delay = self.embed_service * 0.25 * (ev.a + 1) as f64;
                        push(&mut heap, now + delay, EV_UPSERT, ev.a + 1, &mut seq);
                    }
                }
                EV_DELETE => {
                    // Delete a currently-live document (deterministic
                    // pick over the sorted live-id set); churn with an
                    // empty corpus degrades to a no-op arrival.
                    let (ids, _, _) = shadow.export_corpus().expect("flat index exports");
                    if !ids.is_empty() {
                        let mut sorted = ids;
                        sorted.sort_unstable();
                        let id = sorted[rng.usize(0, sorted.len())];
                        store
                            .log_delete(id, || {
                                exec.remove(id);
                            })
                            .context("churn: delete refused")?;
                        shadow.remove(id);
                        st.deletes_acked += 1;
                        store.maybe_compact(&exec).context("churn: compaction")?;
                    }
                }
                EV_QUERY => {
                    if qm.dispatch_class(WorkClass::Retrieve, 1) == Route::Cpu {
                        retr_inflight += 1;
                        let probe = format!("probe {}", rng.range(0, self.id_space));
                        let _hits = exec.search(&pseudo_embedding(&probe, self.dim), 8);
                        st.queries_served += 1;
                        push(&mut heap, now + self.scan_service, EV_REL_RETR, epoch, &mut seq);
                    } else {
                        st.queries_rejected += 1;
                    }
                }
                EV_REL_INGEST => {
                    if ev.a == epoch {
                        ingest_inflight -= 1;
                        qm.release_class(WorkClass::Ingest, Route::Cpu, 1);
                    }
                }
                EV_REL_RETR => {
                    if ev.a == epoch {
                        retr_inflight -= 1;
                        qm.release_class(WorkClass::Retrieve, Route::Cpu, 1);
                    }
                }
                EV_CRASH => {
                    // Power cut between two ops: unsynced bytes die,
                    // in-flight slot holds die with the process. Bank
                    // the dying store's counters first — a fresh store
                    // starts its own from zero.
                    let ds = store.stats();
                    st.snapshots += ds.snapshots_written;
                    st.compactions += ds.compactions;
                    fs.crash_now();
                    fs.restart(FaultPlan::default());
                    let (s2, e2, replayed) = self.recover(&fs)?;
                    store = s2;
                    exec = e2;
                    st.crashes += 1;
                    st.replayed += replayed;
                    epoch += 1;
                    qm = self.new_qm();
                    ingest_inflight = 0;
                    retr_inflight = 0;
                    let (lost, res, div) = Self::diff(&exec, &shadow, self.dim);
                    st.lost_acked += lost;
                    st.resurrected += res;
                    st.divergent += div;
                }
                EV_SNAPSHOT => {
                    store.snapshot(&exec).context("churn: periodic checkpoint")?;
                }
                _ => unreachable!(),
            }
            let occ = ingest_inflight + retr_inflight;
            st.peak_cpu_occupancy = st.peak_cpu_occupancy.max(occ);
            if occ > self.cpu_depth {
                st.oversub_events += 1;
            }
        }

        // Final reconciliation: the surviving store must still match the
        // acked shadow exactly.
        let (lost, res, div) = Self::diff(&exec, &shadow, self.dim);
        st.lost_acked += lost;
        st.resurrected += res;
        st.divergent += div;
        let ds = store.stats();
        st.snapshots += ds.snapshots_written;
        st.compactions += ds.compactions;
        st.committed_seq = ds.committed_seq;
        st.live_docs = exec.len() as u64;
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn days_of_churn_with_midstorm_crashes_lose_nothing() {
        let sim = ChurnSim::default();
        let st = sim.run().unwrap();
        // The storm actually exercised every lifecycle op.
        assert!(st.upserts_acked > 50, "upserts {}", st.upserts_acked);
        assert!(st.deletes_acked > 10, "deletes {}", st.deletes_acked);
        assert!(st.queries_served > 10, "queries {}", st.queries_served);
        assert_eq!(st.upserts_acked, st.upserts_arrived, "no upsert is ever dropped");
        assert_eq!(st.crashes, 2);
        assert!(st.replayed > 0, "crashes must land mid-WAL, not on a checkpoint");
        assert!(st.snapshots > 0);
        // The acceptance predicate: zero acked-write loss, zero
        // resurrection, zero divergence, zero oversubscription.
        assert!(
            st.clean(),
            "lost {} resurrected {} divergent {} oversub {}",
            st.lost_acked,
            st.resurrected,
            st.divergent,
            st.oversub_events
        );
        assert!(st.peak_cpu_occupancy <= sim.cpu_depth);
        // Every acked mutation holds a unique WAL seq.
        assert_eq!(st.committed_seq, st.upserts_acked + st.deletes_acked);
        // Live docs can never exceed the id space (upserts replace).
        assert!(st.live_docs <= sim.id_space);
    }

    #[test]
    fn churn_is_deterministic_per_seed() {
        let sim = ChurnSim { days: 1.0, crash_days: vec![0.5], ..ChurnSim::default() };
        let a = sim.run().unwrap();
        let b = sim.run().unwrap();
        assert_eq!(a.upserts_acked, b.upserts_acked);
        assert_eq!(a.deletes_acked, b.deletes_acked);
        assert_eq!(a.queries_served, b.queries_served);
        assert_eq!(a.queries_rejected, b.queries_rejected);
        assert_eq!(a.backpressure_retries, b.backpressure_retries);
        assert_eq!(a.replayed, b.replayed);
        assert_eq!(a.committed_seq, b.committed_seq);
        assert_eq!(a.live_docs, b.live_docs);
        assert_eq!(a.peak_cpu_occupancy, b.peak_cpu_occupancy);
        assert_eq!(a.makespan_days.to_bits(), b.makespan_days.to_bits());
    }

    #[test]
    fn tight_ingest_cap_backpressures_instead_of_oversubscribing() {
        // 200 upserts × 1000 s of slot time on a cap-1 class over a
        // 86 400 s day: cumulative demand (200 000 s) exceeds the
        // horizon, so collisions — and therefore retries — are
        // guaranteed; admission must convert ALL of the over-demand
        // into delayed completion, none into oversubscription or loss.
        let sim = ChurnSim {
            days: 1.0,
            ops_per_day: 200,
            delete_fraction: 0.0,
            query_fraction: 0.0,
            embed_service: 1000.0,
            ingest_cap: 1,
            crash_days: vec![],
            snapshot_every_days: 0.0,
            ..ChurnSim::default()
        };
        let st = sim.run().unwrap();
        assert_eq!(st.upserts_arrived, 200);
        assert_eq!(st.upserts_acked, 200, "every backpressured upsert eventually lands");
        assert!(st.backpressure_retries > 0, "an over-capacity storm must backpressure");
        assert_eq!(st.peak_cpu_occupancy, 1, "cap 1 admits exactly one at a time");
        assert_eq!(st.oversub_events, 0);
        assert!(st.makespan_days > 1.0, "retries push completion past the nominal horizon");
        assert!(st.clean());
    }

    #[test]
    fn delete_heavy_churn_compacts_and_survives_crashes() {
        let sim = ChurnSim {
            days: 1.0,
            ops_per_day: 400,
            id_space: 40,
            delete_fraction: 0.45,
            query_fraction: 0.1,
            crash_days: vec![0.33, 0.66],
            snapshot_every_days: 0.0, // compaction is the only checkpointer
            ..ChurnSim::default()
        };
        let st = sim.run().unwrap();
        assert!(st.deletes_acked > 50, "deletes {}", st.deletes_acked);
        assert!(st.compactions > 0, "tombstone density must trip compaction");
        assert!(st.snapshots >= st.compactions, "every compaction checkpoints");
        assert_eq!(st.crashes, 2);
        assert!(st.clean(), "lost {} res {} div {}", st.lost_acked, st.resurrected, st.divergent);
    }
}
