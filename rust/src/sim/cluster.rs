//! Closed-loop cluster simulation — the paper's measurement methodology.
//!
//! §5.1.3: "Input queries are sent concurrently and organized in batches.
//! A new batch of queries will be sent only after the responses of
//! previous batches have been received." Under that protocol, C
//! concurrent clients form device batches of exactly the queue-manager
//! admission split, and a device at concurrency C_d exhibits
//! `t = α·C_d + β` — Eq. 12's setting.
//!
//! The simulation routes every query through the **production**
//! [`QueueManager`] (Algorithm 1), then advances virtual time by the
//! profiles' service times. Nothing sleeps; stress tests over hundreds of
//! concurrency levels finish in microseconds.

use crate::coordinator::queue_manager::{QueueManager, Route};
use crate::devices::profile::DeviceProfile;
use crate::util::rng::Pcg;

/// One batch-synchronous round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundResult {
    pub npu_batch: usize,
    pub cpu_batch: usize,
    pub busy: usize,
    /// Batch latency per device (s); every query in a batch shares it.
    pub npu_latency: f64,
    pub cpu_latency: f64,
}

impl RoundResult {
    /// Worst per-query e2e latency of the round.
    pub fn max_latency(&self) -> f64 {
        self.npu_latency.max(self.cpu_latency)
    }

    /// SLO check for the round: every admitted query within `slo`, no
    /// rejects (a rejected query is an SLO violation for capacity search).
    pub fn meets_slo(&self, slo: f64) -> bool {
        self.busy == 0 && crate::devices::profile::slo_met(self.max_latency(), slo)
    }
}

/// Closed-loop simulator over one NPU instance and (optionally) one CPU
/// instance, fronted by the real queue manager.
pub struct ClosedLoopSim {
    pub npu: DeviceProfile,
    pub cpu: Option<DeviceProfile>,
    pub npu_depth: usize,
    pub cpu_depth: usize,
    /// Query length in tokens (paper default 75).
    pub qlen: usize,
    /// Deterministic measurement noise stream.
    pub rng: Pcg,
    /// When false, latencies are noise-free (used for ground-truth runs).
    pub noisy: bool,
}

impl ClosedLoopSim {
    pub fn new(
        npu: DeviceProfile,
        cpu: Option<DeviceProfile>,
        npu_depth: usize,
        cpu_depth: usize,
        qlen: usize,
        seed: u64,
    ) -> ClosedLoopSim {
        ClosedLoopSim { npu, cpu, npu_depth, cpu_depth, qlen, rng: Pcg::new(seed), noisy: true }
    }

    /// Run one round with `clients` concurrent queries.
    pub fn round(&mut self, clients: usize) -> RoundResult {
        // Fresh occupancy each round: the closed loop fully drains between
        // rounds (clients wait for all responses before resending).
        let hetero = self.cpu.is_some();
        let qm = QueueManager::new(self.npu_depth, if hetero { self.cpu_depth } else { 0 }, hetero);
        let mut npu_batch = 0usize;
        let mut cpu_batch = 0usize;
        let mut busy = 0usize;
        for _ in 0..clients {
            match qm.dispatch() {
                Route::Npu => npu_batch += 1,
                Route::Cpu => cpu_batch += 1,
                Route::Busy => busy += 1,
            }
        }
        let npu_latency = self.service(true, npu_batch);
        let cpu_latency = self.service(false, cpu_batch);
        RoundResult { npu_batch, cpu_batch, busy, npu_latency, cpu_latency }
    }

    fn service(&mut self, npu: bool, batch: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let profile = if npu { &self.npu } else { self.cpu.as_ref().unwrap() };
        if self.noisy {
            profile.noisy_service_time(batch, self.qlen, &mut self.rng)
        } else {
            profile.service_time(batch, self.qlen)
        }
    }

    /// Measure mean round latency at `clients` over `rounds` rounds —
    /// the "profiling session" primitive both estimators consume.
    pub fn measure_latency(&mut self, clients: usize, rounds: usize) -> f64 {
        let total: f64 = (0..rounds).map(|_| self.round(clients).max_latency()).sum();
        total / rounds.max(1) as f64
    }

    /// Largest client count whose rounds all meet `slo` (fine-tuning /
    /// ground-truth search). Scans `lo..=hi`.
    pub fn max_concurrency(&mut self, slo: f64, lo: usize, hi: usize, rounds: usize) -> usize {
        let mut best = 0;
        for c in lo..=hi {
            let ok = (0..rounds).all(|_| {
                let r = self.round(c);
                r.meets_slo(slo)
            });
            if ok {
                best = c;
            } else if best > 0 {
                break; // monotone beyond the first success
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(mut p: DeviceProfile) -> DeviceProfile {
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        p
    }

    fn bge_pair() -> (DeviceProfile, DeviceProfile) {
        (quiet(DeviceProfile::v100_bge()), quiet(DeviceProfile::xeon_e5_2690_bge()))
    }

    #[test]
    fn npu_fills_before_cpu() {
        let (npu, cpu) = bge_pair();
        let mut sim = ClosedLoopSim::new(npu, Some(cpu), 44, 8, 75, 1);
        let r = sim.round(50);
        assert_eq!(r.npu_batch, 44);
        assert_eq!(r.cpu_batch, 6);
        assert_eq!(r.busy, 0);
    }

    #[test]
    fn overflow_past_both_depths_is_busy() {
        let (npu, cpu) = bge_pair();
        let mut sim = ClosedLoopSim::new(npu, Some(cpu), 44, 8, 75, 1);
        let r = sim.round(60);
        assert_eq!(r.busy, 60 - 52);
        assert!(!r.meets_slo(1.0));
    }

    #[test]
    fn paper_table1_v100_xeon_1s() {
        // WindVE @ 1 s on V100+Xeon: 44 + 8 = 52 concurrent (Table 1).
        let (npu, cpu) = bge_pair();
        let mut sim = ClosedLoopSim::new(npu, Some(cpu), 44, 8, 75, 2);
        sim.noisy = false;
        assert!(sim.round(52).meets_slo(1.0));
        // The non-offloading baseline caps at 44.
        let npu2 = quiet(DeviceProfile::v100_bge());
        let mut solo = ClosedLoopSim::new(npu2, None, 44, 0, 75, 2);
        solo.noisy = false;
        assert!(solo.round(44).meets_slo(1.0));
        assert!(!solo.round(45).meets_slo(1.0)); // busy reject
    }

    #[test]
    fn max_concurrency_finds_joint_capacity() {
        let (npu, cpu) = bge_pair();
        let mut sim = ClosedLoopSim::new(npu, Some(cpu), 44, 8, 75, 3);
        sim.noisy = false;
        assert_eq!(sim.max_concurrency(1.0, 1, 80, 1), 52);
    }

    #[test]
    fn latency_grows_with_clients() {
        let (npu, _) = bge_pair();
        let mut sim = ClosedLoopSim::new(npu, None, 512, 0, 75, 4);
        sim.noisy = false;
        let t10 = sim.measure_latency(10, 1);
        let t40 = sim.measure_latency(40, 1);
        assert!(t40 > t10);
    }

    #[test]
    fn deterministic_per_seed() {
        let (npu, cpu) = bge_pair();
        let mut a = ClosedLoopSim::new(npu.clone(), Some(cpu.clone()), 44, 8, 75, 7);
        let mut b = ClosedLoopSim::new(npu, Some(cpu), 44, 8, 75, 7);
        for c in [10, 30, 50] {
            assert_eq!(a.round(c), b.round(c));
        }
    }
}
