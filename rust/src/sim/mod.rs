//! Simulation layer.
//!
//! Our testbed has none of the paper's accelerators (V100, Atlas 300I
//! DUO), so the paper-scale experiments run the **real coordinator code**
//! (queue manager, estimator, fine-tuning) against calibrated device
//! profiles in virtual time:
//!
//! * [`cluster`] — the paper's measurement methodology (§5.1.3):
//!   batch-synchronous closed-loop clients; used to regenerate every
//!   table and figure.
//! * [`des`] — open-loop discrete-event simulation for arrival-driven
//!   workloads (the Fig. 2 diurnal demo, admission-control studies).
//! * [`churn`] — the corpus-lifecycle acceptance harness: days of
//!   virtual-time upsert/delete/query churn against the real durable
//!   store with mid-storm crashes, verifying zero acked-write loss and
//!   zero oversubscription.

pub mod churn;
pub mod cluster;
pub mod des;

pub use churn::{ChurnSim, ChurnStats};
pub use cluster::{ClosedLoopSim, RoundResult};
pub use des::{IngestLoad, MixedStats, OpenLoopSim, RetrievalLoad, SimStats};
