//! Open-loop discrete-event simulation.
//!
//! Arrival-driven companion to [`super::cluster`]: queries arrive on a
//! timestamp stream (e.g. Poisson thinning of the Fig. 2 diurnal curve),
//! are admitted by the production [`QueueManager`], wait in their device
//! queue, and are served batch-at-a-time. Virtual time, no sleeping.
//!
//! Used by the motivation experiments: what happens to SLO attainment and
//! reject rate when evening-peak traffic hits an average-provisioned
//! NPU — and how much of it the CPU queue absorbs.

//! The mixed embed+retrieve extension ([`OpenLoopSim::run_mixed`])
//! replays the paper's peak-offload scenario *with retrieval
//! contention*: batched top-k scans arrive on their own stream, hold
//! cost-weighted CPU slots through the production
//! [`QueueManager::dispatch_class`] admission (or bypass it — the
//! pre-admission baseline), and the sim records the peak combined CPU
//! occupancy so oversubscription is measurable either way.
//!
//! The ingest-load axis ([`OpenLoopSim::run_mixed_ingest`]) adds the
//! third class: a bulk-upload storm of `WorkClass::Ingest` embeds with
//! strict per-pool caps and the NPU valley-soak policy, proving that
//! simultaneous bulk indexing + query serving stays inside the
//! calibrated depths (the streaming-ingest acceptance scenario).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::queue_manager::{ClassCaps, QueueManager, Route, WorkClass};
use crate::devices::profile::DeviceProfile;
use crate::metrics::trace::{stage_metric_name, ClassLabel, CodecLabel, RouteLabel, Stage};
use crate::metrics::{Histogram, Registry};
use crate::util::rng::Pcg;

/// Aggregate results of an open-loop run.
pub struct SimStats {
    pub arrived: u64,
    pub served_npu: u64,
    pub served_cpu: u64,
    pub rejected: u64,
    /// e2e latency (wait + service) in microseconds of virtual time.
    pub latency_us: Histogram,
    pub slo_violations: u64,
    pub makespan: f64,
}

impl SimStats {
    pub fn served(&self) -> u64 {
        self.served_npu + self.served_cpu
    }

    pub fn reject_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.rejected as f64 / self.arrived as f64
        }
    }

    pub fn slo_attainment(&self) -> f64 {
        let s = self.served();
        if s == 0 {
            1.0
        } else {
            1.0 - self.slo_violations as f64 / s as f64
        }
    }
}

/// Retrieval side of a mixed embed+retrieve open-loop scenario.
#[derive(Debug, Clone)]
pub struct RetrievalLoad {
    /// CPU cost units one batched scan holds while it runs (rows ×
    /// bytes_per_row normalized by the embed cost unit — see
    /// `coordinator::queue_manager::retrieval_slot_cost`).
    pub cost: usize,
    /// Virtual service time of one scan, seconds.
    pub service_time: f64,
    /// Retrieval's cap within the CPU pool (cost units, ≤ cpu_depth).
    pub cap: usize,
    /// When false, scans bypass admission — the pre-admission baseline —
    /// and the run records the oversubscription accounting would have
    /// prevented.
    pub admission: bool,
    /// Offloaded scans' cap within the NPU pool (cost units, ≤
    /// npu_depth; 0 disables the NPU retrieval leg). Only meaningful
    /// under admission — the leg is admission-aware by construction.
    pub npu_cap: usize,
    /// Offload policy mirror of `ServiceConfig::npu_offload_low_water`:
    /// a scan routes to the NPU leg only while embed-side NPU occupancy
    /// is ≤ this fraction of `npu_depth`.
    pub npu_low_water: f64,
}

impl Default for RetrievalLoad {
    fn default() -> Self {
        RetrievalLoad {
            cost: 1,
            service_time: 0.0,
            cap: 0,
            admission: true,
            npu_cap: 0,
            npu_low_water: 0.5,
        }
    }
}

/// Ingest side of a mixed scenario — the bulk-upload storm axis.
/// Ingest is always admission-metered (`WorkClass::Ingest` has no
/// unaccounted baseline: the class exists *because* of accounting), so
/// rejections here model the backpressure waits the real pipeline
/// absorbs by retrying against the upload socket.
#[derive(Debug, Clone)]
pub struct IngestLoad {
    /// CPU/NPU cost units one ingest embed holds while it runs.
    pub cost: usize,
    /// Virtual service time of one ingest embed, seconds.
    pub service_time: f64,
    /// Ingest's strict cap within the CPU pool (≤ cpu_depth; 0 = leg off).
    pub cap: usize,
    /// Ingest's strict cap within the NPU pool (≤ npu_depth; 0 = leg off).
    pub npu_cap: usize,
    /// Valley gate mirror of `ServiceConfig::ingest_low_water`: the NPU
    /// leg is tried only while embed-side NPU occupancy is ≤ this
    /// fraction of `npu_depth`.
    pub low_water: f64,
}

impl Default for IngestLoad {
    fn default() -> Self {
        IngestLoad { cost: 1, service_time: 0.0, cap: 0, npu_cap: 0, low_water: 0.25 }
    }
}

/// Results of [`OpenLoopSim::run_mixed`].
pub struct MixedStats {
    /// The embedding side, same accounting as [`OpenLoopSim::run`].
    pub embed: SimStats,
    pub retrieve_arrived: u64,
    pub retrieve_served: u64,
    /// Scans absorbed by the NPU offload leg (⊆ `retrieve_served`).
    pub retrieve_served_npu: u64,
    /// Scans declined by admission (always 0 in baseline mode).
    pub retrieve_rejected: u64,
    pub ingest_arrived: u64,
    pub ingest_served: u64,
    /// Ingest embeds absorbed by the NPU valley leg (⊆ `ingest_served`).
    pub ingest_served_npu: u64,
    /// Ingest units declined at admission — the backpressure events the
    /// real pipeline turns into socket stalls.
    pub ingest_rejected: u64,
    /// Peak combined ingest occupancy (both pools) — must never exceed
    /// the configured ingest caps.
    pub peak_ingest_cost: usize,
    /// Peak of embed CPU slots + retrieval slot-cost over the run — the
    /// acceptance metric: ≤ `cpu_depth` under admission.
    pub peak_cpu_cost: usize,
    /// Peak of embed NPU slots + offloaded scan cost — ≤ `npu_depth`
    /// under admission (the leg only exists under admission).
    pub peak_npu_cost: usize,
    /// Peak *total* admitted concurrency (both pools, both classes) —
    /// the concurrency-gain metric: NPU offload raises it at equal
    /// oversubscription.
    pub peak_admitted_cost: usize,
    /// Event instants at which either pool's combined occupancy exceeded
    /// its calibrated depth.
    pub oversub_events: u64,
    /// The calibrated CPU pool the run was bounded by (0 if no CPU).
    pub cpu_depth: usize,
    /// The calibrated NPU pool the run was bounded by.
    pub npu_depth: usize,
    /// Per-stage latency histograms under the **live metric schema**
    /// (`trace.<stage>.<class>.<route>.<codec>`, see
    /// [`crate::metrics::trace::STAGE_METRICS`]): queue_wait and embed
    /// per batch leg, scan per retrieval leg, ingest embeds under the
    /// ingest class. Virtual nanoseconds, so DES scenarios compare
    /// directly against `/v1/stats` stage quantiles.
    pub stage_metrics: Registry,
}

impl MixedStats {
    pub fn retrieve_reject_rate(&self) -> f64 {
        if self.retrieve_arrived == 0 {
            0.0
        } else {
            self.retrieve_rejected as f64 / self.retrieve_arrived as f64
        }
    }
}

/// Open-loop simulator: one NPU instance + optional CPU instance.
pub struct OpenLoopSim {
    pub npu: DeviceProfile,
    pub cpu: Option<DeviceProfile>,
    pub npu_depth: usize,
    pub cpu_depth: usize,
    pub qlen: usize,
    pub slo: f64,
    pub seed: u64,
}

impl OpenLoopSim {
    /// Run over explicit arrival timestamps (seconds, ascending).
    ///
    /// This is exactly [`OpenLoopSim::run_mixed`] with an empty retrieval
    /// stream (one event engine, no drift between the pure and mixed
    /// sims); the load parameters are irrelevant without scan arrivals.
    pub fn run(&self, arrivals: &[f64]) -> SimStats {
        let no_scans = RetrievalLoad { cost: 0, ..RetrievalLoad::default() };
        self.run_mixed(&no_scans, arrivals, &[]).embed
    }

    /// Mixed embed+retrieve open-loop run over two arrival streams
    /// (seconds, ascending). Embedding queries follow the same Algorithm-1
    /// path as [`OpenLoopSim::run`]; each retrieval arrival is one batched
    /// scan that holds `load.cost` CPU cost units for `load.service_time`
    /// virtual seconds.
    ///
    /// With `load.admission` the scan is admitted through
    /// `dispatch_class(Retrieve, cost)` against the shared CPU pool
    /// (embed slots + scan cost ≤ `cpu_depth`, scans additionally capped
    /// at `load.cap`), so embeds and scans exert real backpressure on
    /// each other. Without it, scans bypass accounting — the
    /// pre-admission baseline — and the run records how far the combined
    /// occupancy oversubscribes the calibrated depth.
    ///
    /// Fully deterministic per seed: identical inputs reproduce every
    /// counter and latency sample bit-for-bit.
    pub fn run_mixed(
        &self,
        load: &RetrievalLoad,
        embed_arrivals: &[f64],
        retrieve_arrivals: &[f64],
    ) -> MixedStats {
        self.run_mixed_ingest(load, &IngestLoad::default(), embed_arrivals, retrieve_arrivals, &[])
    }

    /// [`OpenLoopSim::run_mixed`] plus the **ingest-load axis**: a third
    /// arrival stream of bulk-upload embeds admitted under
    /// `WorkClass::Ingest` (strict per-pool caps, NPU valley policy
    /// mirroring `WindVE::submit_ingest`). The acceptance probe extends
    /// to all three classes: peak combined Embed+Retrieve+Ingest cost
    /// per pool must stay at or under the calibrated depth — queries
    /// keep their depth under a bulk-upload storm.
    pub fn run_mixed_ingest(
        &self,
        load: &RetrievalLoad,
        ingest: &IngestLoad,
        embed_arrivals: &[f64],
        retrieve_arrivals: &[f64],
        ingest_arrivals: &[f64],
    ) -> MixedStats {
        let hetero = self.cpu.is_some();
        let cpu_pool = if hetero { self.cpu_depth } else { 0 };
        let qm = QueueManager::with_caps(
            self.npu_depth,
            cpu_pool,
            hetero,
            ClassCaps {
                retrieve: load.cap,
                npu_retrieve: load.npu_cap,
                ingest: ingest.cap,
                npu_ingest: ingest.npu_cap,
            },
        );
        let mut rng = Pcg::new(self.seed);

        // Event heap keyed by (time, seq, tag) — seq breaks ties
        // deterministically. Tags: 0 embed arrival, 1 NPU done, 2 CPU
        // done, 3 retrieve arrival, 4 CPU scan done, 5 NPU (offloaded)
        // scan done, 6 ingest arrival, 7 CPU ingest done, 8 NPU ingest
        // done.
        let mut heap: BinaryHeap<Reverse<(u64, u64, u8)>> = BinaryHeap::new();
        let to_key = |t: f64| (t * 1e9) as u64;
        let mut seq = 0u64;
        let push = |heap: &mut BinaryHeap<Reverse<(u64, u64, u8)>>,
                    t: f64,
                    tag: u8,
                    seq: &mut u64| {
            heap.push(Reverse((to_key(t), *seq, tag)));
            *seq += 1;
        };
        for &t in embed_arrivals {
            push(&mut heap, t, 0, &mut seq);
        }
        for &t in retrieve_arrivals {
            push(&mut heap, t, 3, &mut seq);
        }
        for &t in ingest_arrivals {
            push(&mut heap, t, 6, &mut seq);
        }

        let mut npu_q: VecDeque<f64> = VecDeque::new(); // enqueue times
        let mut cpu_q: VecDeque<f64> = VecDeque::new();
        let mut npu_busy = false;
        let mut cpu_busy = false;
        let mut npu_inflight: Vec<f64> = Vec::new();
        let mut cpu_inflight: Vec<f64> = Vec::new();
        // Batch dispatch instants: splits each query's e2e latency into
        // queue_wait (enqueue → dispatch) and embed (dispatch → done)
        // for the stage histograms.
        let mut npu_started = 0.0f64;
        let mut cpu_started = 0.0f64;
        // Scan cost units in flight — equals the manager's retrieval
        // occupancy under admission, and the shadow the accounting
        // *would* have tracked in baseline mode.
        let mut retr_inflight: usize = 0;
        // Offloaded scan cost in flight on the NPU leg (admission only).
        let mut retr_npu_inflight: usize = 0;
        // Ingest cost units in flight per pool.
        let mut ingest_inflight: usize = 0;
        let mut ingest_npu_inflight: usize = 0;

        // Mirror the service's admission clamp (coordinator/service.rs):
        // a scan whose cost exceeds the whole retrieval budget holds the
        // full budget (scans serialize) instead of being permanently
        // unschedulable. Baseline mode keeps the raw cost — the real,
        // unaccounted footprint the accounting would have metered.
        let scan_cost = if load.admission {
            load.cost.clamp(1, qm.retrieve_cap().max(1))
        } else {
            load.cost.max(1)
        };
        // Same clamp on the NPU leg's budget.
        let npu_scan_cost = load.cost.clamp(1, qm.npu_retrieve_cap().max(1));
        // Ingest mirrors the same clamp against its own caps.
        let ingest_cost = ingest.cost.clamp(1, qm.ingest_cap().max(1));
        let npu_ingest_cost = ingest.cost.clamp(1, qm.npu_ingest_cap().max(1));

        let mut stats = MixedStats {
            embed: SimStats {
                arrived: 0,
                served_npu: 0,
                served_cpu: 0,
                rejected: 0,
                latency_us: Histogram::new(),
                slo_violations: 0,
                makespan: 0.0,
            },
            retrieve_arrived: 0,
            retrieve_served: 0,
            retrieve_served_npu: 0,
            retrieve_rejected: 0,
            ingest_arrived: 0,
            ingest_served: 0,
            ingest_served_npu: 0,
            ingest_rejected: 0,
            peak_ingest_cost: 0,
            peak_cpu_cost: 0,
            peak_npu_cost: 0,
            peak_admitted_cost: 0,
            oversub_events: 0,
            cpu_depth: cpu_pool,
            npu_depth: self.npu_depth,
            stage_metrics: Registry::new(),
        };

        // Emit stage latencies under the live names so a DES run and a
        // `/v1/stats` snapshot are schema-interchangeable (virtual ns).
        let record_stage = |reg: &Registry,
                            stage: Stage,
                            class: ClassLabel,
                            route: RouteLabel,
                            codec: CodecLabel,
                            secs: f64| {
            if let Some(name) = stage_metric_name(stage, class, route, codec) {
                reg.histogram(name).record((secs.max(0.0) * 1e9) as u64);
            }
        };

        while let Some(Reverse((tkey, _, tag))) = heap.pop() {
            let now = tkey as f64 / 1e9;
            stats.embed.makespan = now;
            match tag {
                0 => {
                    stats.embed.arrived += 1;
                    match qm.dispatch() {
                        Route::Npu => npu_q.push_back(now),
                        Route::Cpu => cpu_q.push_back(now),
                        Route::Busy => stats.embed.rejected += 1,
                    }
                    // Kick idle devices.
                    if !npu_busy && !npu_q.is_empty() {
                        let b = npu_q.len().min(self.npu_depth.max(1));
                        npu_inflight = npu_q.drain(..b).collect();
                        let st = self.npu.noisy_service_time(b, self.qlen, &mut rng);
                        npu_busy = true;
                        npu_started = now;
                        push(&mut heap, now + st, 1, &mut seq);
                    }
                    if hetero && !cpu_busy && !cpu_q.is_empty() {
                        let b = cpu_q.len().min(self.cpu_depth.max(1));
                        cpu_inflight = cpu_q.drain(..b).collect();
                        let st = self
                            .cpu
                            .as_ref()
                            .unwrap()
                            .noisy_service_time(b, self.qlen, &mut rng);
                        cpu_busy = true;
                        cpu_started = now;
                        push(&mut heap, now + st, 2, &mut seq);
                    }
                }
                1 | 2 => {
                    let is_npu = tag == 1;
                    let (inflight, q, busy, depth, started) = if is_npu {
                        (&mut npu_inflight, &mut npu_q, &mut npu_busy, self.npu_depth, &mut npu_started)
                    } else {
                        (&mut cpu_inflight, &mut cpu_q, &mut cpu_busy, self.cpu_depth, &mut cpu_started)
                    };
                    let route = if is_npu { RouteLabel::Npu } else { RouteLabel::Cpu };
                    for enq in inflight.drain(..) {
                        let lat = now - enq;
                        stats.embed.latency_us.record((lat * 1e6) as u64);
                        if lat > self.slo {
                            stats.embed.slo_violations += 1;
                        }
                        record_stage(
                            &stats.stage_metrics,
                            Stage::QueueWait,
                            ClassLabel::Embed,
                            route,
                            CodecLabel::All,
                            *started - enq,
                        );
                        record_stage(
                            &stats.stage_metrics,
                            Stage::Embed,
                            ClassLabel::Embed,
                            route,
                            CodecLabel::All,
                            now - *started,
                        );
                        if is_npu {
                            stats.embed.served_npu += 1;
                        } else {
                            stats.embed.served_cpu += 1;
                        }
                        qm.release(if is_npu { Route::Npu } else { Route::Cpu });
                    }
                    *busy = false;
                    if !q.is_empty() {
                        let b = q.len().min(depth.max(1));
                        let batch: Vec<f64> = q.drain(..b).collect();
                        let profile =
                            if is_npu { &self.npu } else { self.cpu.as_ref().unwrap() };
                        let st = profile.noisy_service_time(b, self.qlen, &mut rng);
                        *inflight = batch;
                        *busy = true;
                        *started = now;
                        push(&mut heap, now + st, tag, &mut seq);
                    }
                }
                3 => {
                    stats.retrieve_arrived += 1;
                    // NPU offload policy (mirrors coordinator/service.rs):
                    // under admission, with the leg enabled and embed-side
                    // NPU occupancy at or below the low-water mark, the
                    // scan is admitted to the device leg first; a full leg
                    // falls back to the CPU leg.
                    let low_water = load.npu_low_water * self.npu_depth as f64;
                    let offload = load.admission
                        && load.npu_cap > 0
                        && qm.embed_npu_occupancy() as f64 <= low_water;
                    if offload && qm.dispatch_retrieve_npu(npu_scan_cost) == Route::Npu {
                        retr_npu_inflight += npu_scan_cost;
                        push(&mut heap, now + load.service_time, 5, &mut seq);
                    } else {
                        let admitted = if load.admission {
                            qm.dispatch_class(WorkClass::Retrieve, scan_cost) != Route::Busy
                        } else {
                            true // baseline: scans run unaccounted
                        };
                        if admitted {
                            retr_inflight += scan_cost;
                            push(&mut heap, now + load.service_time, 4, &mut seq);
                        } else {
                            stats.retrieve_rejected += 1;
                        }
                    }
                }
                4 => {
                    stats.retrieve_served += 1;
                    retr_inflight = retr_inflight.saturating_sub(scan_cost);
                    if load.admission {
                        qm.release_class(WorkClass::Retrieve, Route::Cpu, scan_cost);
                    }
                    record_stage(
                        &stats.stage_metrics,
                        Stage::Scan,
                        ClassLabel::Retrieve,
                        RouteLabel::Cpu,
                        CodecLabel::F32,
                        load.service_time,
                    );
                }
                5 => {
                    stats.retrieve_served += 1;
                    stats.retrieve_served_npu += 1;
                    retr_npu_inflight = retr_npu_inflight.saturating_sub(npu_scan_cost);
                    qm.release_class(WorkClass::Retrieve, Route::Npu, npu_scan_cost);
                    record_stage(
                        &stats.stage_metrics,
                        Stage::Scan,
                        ClassLabel::Retrieve,
                        RouteLabel::Npu,
                        CodecLabel::F32,
                        load.service_time,
                    );
                }
                6 => {
                    stats.ingest_arrived += 1;
                    // Valley policy (mirrors WindVE::submit_ingest): the
                    // NPU leg only while embed-side NPU occupancy is at
                    // or below the ingest low-water mark; CPU leg
                    // otherwise. A declined unit is a backpressure event
                    // (the real pipeline retries; the sim counts).
                    let low = ingest.low_water * self.npu_depth as f64;
                    let try_npu = ingest.npu_cap > 0
                        && qm.embed_npu_occupancy() as f64 <= low;
                    if try_npu && qm.dispatch_ingest_npu(npu_ingest_cost) == Route::Npu {
                        ingest_npu_inflight += npu_ingest_cost;
                        push(&mut heap, now + ingest.service_time, 8, &mut seq);
                    } else if ingest.cap > 0
                        && qm.dispatch_class(WorkClass::Ingest, ingest_cost) == Route::Cpu
                    {
                        ingest_inflight += ingest_cost;
                        push(&mut heap, now + ingest.service_time, 7, &mut seq);
                    } else {
                        stats.ingest_rejected += 1;
                    }
                }
                7 => {
                    stats.ingest_served += 1;
                    ingest_inflight = ingest_inflight.saturating_sub(ingest_cost);
                    qm.release_class(WorkClass::Ingest, Route::Cpu, ingest_cost);
                    record_stage(
                        &stats.stage_metrics,
                        Stage::Embed,
                        ClassLabel::Ingest,
                        RouteLabel::Cpu,
                        CodecLabel::All,
                        ingest.service_time,
                    );
                }
                8 => {
                    stats.ingest_served += 1;
                    stats.ingest_served_npu += 1;
                    ingest_npu_inflight = ingest_npu_inflight.saturating_sub(npu_ingest_cost);
                    qm.release_class(WorkClass::Ingest, Route::Npu, npu_ingest_cost);
                    record_stage(
                        &stats.stage_metrics,
                        Stage::Embed,
                        ClassLabel::Ingest,
                        RouteLabel::Npu,
                        CodecLabel::All,
                        ingest.service_time,
                    );
                }
                _ => unreachable!(),
            }
            // Oversubscription probe at every event instant: per pool,
            // embed slots + scan slot-cost + ingest cost against the
            // calibrated depth.
            let combined_cpu = qm.embed_cpu_occupancy() + retr_inflight + ingest_inflight;
            let combined_npu =
                qm.embed_npu_occupancy() + retr_npu_inflight + ingest_npu_inflight;
            stats.peak_cpu_cost = stats.peak_cpu_cost.max(combined_cpu);
            stats.peak_npu_cost = stats.peak_npu_cost.max(combined_npu);
            stats.peak_ingest_cost =
                stats.peak_ingest_cost.max(ingest_inflight + ingest_npu_inflight);
            stats.peak_admitted_cost =
                stats.peak_admitted_cost.max(combined_cpu + combined_npu);
            if combined_cpu > cpu_pool || combined_npu > self.npu_depth {
                stats.oversub_events += 1;
            }
        }
        stats
    }

    /// Poisson arrivals at `rate(t)` q/s over `[0, horizon)` seconds via
    /// thinning against `peak_rate`. Delegates to the shared generator
    /// in `workload::mixed`; fraction 0 skips the marking draw, so
    /// seeded streams are draw-for-draw identical to the historic
    /// implementation.
    pub fn poisson_arrivals(
        rate: impl Fn(f64) -> f64,
        peak_rate: f64,
        horizon: f64,
        seed: u64,
    ) -> Vec<f64> {
        crate::workload::mixed::MixedArrivals::thinned(rate, peak_rate, 0.0, horizon, seed).embed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(mut p: DeviceProfile) -> DeviceProfile {
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        p
    }

    fn sim(hetero: bool) -> OpenLoopSim {
        OpenLoopSim {
            npu: quiet(DeviceProfile::v100_bge()),
            cpu: hetero.then(|| quiet(DeviceProfile::xeon_e5_2690_bge())),
            npu_depth: 44,
            cpu_depth: 8,
            qlen: 75,
            slo: 1.0,
            seed: 1,
        }
    }

    #[test]
    fn conservation_served_plus_rejected_equals_arrived() {
        let s = sim(true);
        let arrivals: Vec<f64> = (0..500).map(|i| i as f64 * 0.01).collect();
        let st = s.run(&arrivals);
        assert_eq!(st.arrived, 500);
        assert_eq!(st.served() + st.rejected, st.arrived);
    }

    #[test]
    fn light_load_all_served_in_slo() {
        let s = sim(false);
        // One query per 2 s: every batch has size 1, latency β + α ≈ 0.29 s.
        let arrivals: Vec<f64> = (0..50).map(|i| i as f64 * 2.0).collect();
        let st = s.run(&arrivals);
        assert_eq!(st.rejected, 0);
        assert_eq!(st.slo_violations, 0);
        assert_eq!(st.served_npu, 50);
    }

    #[test]
    fn burst_overflows_to_cpu_with_hetero() {
        // 50-query instantaneous burst: NPU takes 44, CPU the rest.
        let arrivals = vec![0.0; 50];
        let st = sim(true).run(&arrivals);
        assert_eq!(st.rejected, 0);
        assert!(st.served_cpu >= 6, "cpu served {}", st.served_cpu);
        // Without hetero the same burst rejects.
        let st2 = sim(false).run(&arrivals);
        assert!(st2.rejected >= 6, "rejected {}", st2.rejected);
    }

    #[test]
    fn heavier_sustained_load_violates_slo_or_rejects() {
        let mut s = sim(false);
        s.npu_depth = 16;
        // 100 q/s sustained far beyond one instance's ~40 q/s capacity.
        let arrivals: Vec<f64> = (0..400).map(|i| i as f64 * 0.01).collect();
        let st = s.run(&arrivals);
        assert!(st.rejected > 0 || st.slo_violations > 0);
    }

    #[test]
    fn poisson_thinning_rate_roughly_matches() {
        let arr = OpenLoopSim::poisson_arrivals(|_| 20.0, 20.0, 100.0, 3);
        let rate = arr.len() as f64 / 100.0;
        assert!((rate - 20.0).abs() < 2.5, "rate {rate}");
    }

    #[test]
    fn deterministic_per_seed() {
        let s = sim(true);
        let arrivals: Vec<f64> = (0..100).map(|i| i as f64 * 0.02).collect();
        let a = s.run(&arrivals);
        let b = s.run(&arrivals);
        assert_eq!(a.served_npu, b.served_npu);
        assert_eq!(a.rejected, b.rejected);
    }

    fn scan_load(admission: bool) -> RetrievalLoad {
        RetrievalLoad {
            cost: 4,
            service_time: 0.5,
            cap: 8,
            admission,
            ..RetrievalLoad::default()
        }
    }

    #[test]
    fn mixed_conservation_both_classes() {
        let s = sim(true);
        let embeds: Vec<f64> = (0..200).map(|i| i as f64 * 0.02).collect();
        let scans: Vec<f64> = (0..40).map(|i| 0.01 + i as f64 * 0.1).collect();
        let st = s.run_mixed(&scan_load(true), &embeds, &scans);
        assert_eq!(st.embed.arrived, 200);
        assert_eq!(st.embed.served() + st.embed.rejected, st.embed.arrived);
        assert_eq!(st.retrieve_arrived, 40);
        assert_eq!(st.retrieve_served + st.retrieve_rejected, st.retrieve_arrived);
    }

    #[test]
    fn mixed_admission_bounds_cpu_baseline_oversubscribes() {
        // 8 CPU units, cost-4 scans every 100 ms lasting 500 ms: ~5 scans
        // (20 units) of steady-state demand, plus embed overflow filling
        // the CPU queue. Admission must keep the combined occupancy at or
        // under depth; the unaccounted baseline must blow through it.
        let mut s = sim(true);
        s.npu_depth = 4; // force embed overflow onto the CPU queue
        let embeds: Vec<f64> = (0..300).map(|i| i as f64 * 0.01).collect();
        let scans: Vec<f64> = (0..30).map(|i| i as f64 * 0.1).collect();
        let on = s.run_mixed(&scan_load(true), &embeds, &scans);
        assert_eq!(on.cpu_depth, 8);
        assert!(on.peak_cpu_cost <= 8, "admitted peak {}", on.peak_cpu_cost);
        assert_eq!(on.oversub_events, 0);
        // Contention is real: some scans were declined.
        assert!(on.retrieve_rejected > 0);
        let off = s.run_mixed(&scan_load(false), &embeds, &scans);
        assert_eq!(off.retrieve_rejected, 0); // baseline never declines
        assert!(off.peak_cpu_cost > 8, "baseline peak {}", off.peak_cpu_cost);
        assert!(off.oversub_events > on.oversub_events);
    }

    #[test]
    fn mixed_determinism_bit_for_bit() {
        let s = sim(true);
        let embeds: Vec<f64> = (0..150).map(|i| i as f64 * 0.015).collect();
        let scans: Vec<f64> = (0..25).map(|i| 0.05 + i as f64 * 0.08).collect();
        let load = scan_load(true);
        let a = s.run_mixed(&load, &embeds, &scans);
        let b = s.run_mixed(&load, &embeds, &scans);
        assert_eq!(a.embed.reject_rate().to_bits(), b.embed.reject_rate().to_bits());
        assert_eq!(a.embed.slo_attainment().to_bits(), b.embed.slo_attainment().to_bits());
        assert_eq!(a.retrieve_served, b.retrieve_served);
        assert_eq!(a.retrieve_rejected, b.retrieve_rejected);
        assert_eq!(a.peak_cpu_cost, b.peak_cpu_cost);
        assert_eq!(a.oversub_events, b.oversub_events);
    }

    #[test]
    fn mixed_without_cpu_rejects_scans_under_admission() {
        let s = sim(false); // no CPU device: pool is 0
        let scans: Vec<f64> = (0..5).map(|i| i as f64 * 0.1).collect();
        let st = s.run_mixed(&scan_load(true), &[], &scans);
        assert_eq!(st.retrieve_rejected, 5);
        assert_eq!(st.peak_cpu_cost, 0);
        // Baseline "runs" them anyway — every one an oversubscription.
        let base = s.run_mixed(&scan_load(false), &[], &scans);
        assert_eq!(base.retrieve_served, 5);
        assert!(base.oversub_events > 0);
    }

    #[test]
    fn mixed_oversized_scan_cost_clamps_like_the_service() {
        // cost 20 against cap 8: the service clamps to the full budget
        // and serializes; the DES must predict the same, not 100% reject.
        let s = sim(true);
        let load = RetrievalLoad {
            cost: 20,
            service_time: 0.1,
            cap: 8,
            ..RetrievalLoad::default()
        };
        let scans: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let st = s.run_mixed(&load, &[], &scans);
        assert_eq!(st.retrieve_served, 5);
        assert_eq!(st.retrieve_rejected, 0);
        assert!(st.peak_cpu_cost <= 8, "peak {}", st.peak_cpu_cost);
    }

    fn offload_load(npu_cap: usize) -> RetrievalLoad {
        RetrievalLoad {
            cost: 4,
            service_time: 0.5,
            cap: 8,
            admission: true,
            npu_cap,
            npu_low_water: 0.5,
        }
    }

    /// The PR's acceptance criterion: with the NPU leg enabled, sustained
    /// admitted concurrency strictly exceeds the CPU-only admission
    /// baseline at equal oversubscription (0 oversub events either way).
    #[test]
    fn npu_offload_strictly_raises_admitted_concurrency_at_zero_oversub() {
        let s = sim(true);
        // Light embeds leave the NPU in a load valley; the sustained scan
        // burst (≈40 cost units of steady-state demand) oversubscribes
        // the CPU retrieval budget (cap 8) on its own.
        let embeds: Vec<f64> = (0..20).map(|i| i as f64 * 0.5).collect();
        let scans: Vec<f64> = (0..40).map(|i| i as f64 * 0.05).collect();
        let cpu_only = s.run_mixed(&offload_load(0), &embeds, &scans);
        let offload = s.run_mixed(&offload_load(16), &embeds, &scans);
        // Equal oversubscription: none — admission bounds both pools.
        assert_eq!(cpu_only.oversub_events, 0);
        assert_eq!(offload.oversub_events, 0);
        assert!(offload.peak_npu_cost <= offload.npu_depth);
        assert!(offload.peak_cpu_cost <= offload.cpu_depth);
        // The device leg strictly raises peak admitted concurrency and
        // absorbs scans the CPU-only budget declined.
        assert!(
            offload.peak_admitted_cost > cpu_only.peak_admitted_cost,
            "offload peak {} vs cpu-only {}",
            offload.peak_admitted_cost,
            cpu_only.peak_admitted_cost
        );
        assert!(
            offload.retrieve_served > cpu_only.retrieve_served,
            "offload served {} vs cpu-only {}",
            offload.retrieve_served,
            cpu_only.retrieve_served
        );
        assert!(offload.retrieve_served_npu > 0);
        assert!(offload.retrieve_rejected < cpu_only.retrieve_rejected);
        assert_eq!(cpu_only.retrieve_served_npu, 0);
    }

    /// The low-water policy in the sim mirrors the service: an NPU
    /// saturated by embedding traffic gets no scans.
    #[test]
    fn npu_offload_defers_to_embedding_traffic() {
        let mut s = sim(true);
        s.npu_depth = 8;
        let embeds = vec![0.0; 8]; // fills the NPU pool instantly
        let load = RetrievalLoad {
            cost: 2,
            service_time: 0.2,
            cap: 8,
            npu_cap: 8,
            npu_low_water: 0.0, // offload only on an idle NPU
            ..RetrievalLoad::default()
        };
        let scans = vec![0.1, 0.15]; // while the embed burst is in flight
        let st = s.run_mixed(&load, &embeds, &scans);
        assert_eq!(st.retrieve_served_npu, 0);
        assert_eq!(st.retrieve_served, 2); // the CPU leg absorbed them
        assert_eq!(st.retrieve_rejected, 0);
    }

    /// NPU-leg cost clamps like the service's: an over-budget scan
    /// serializes at the full leg budget instead of being permanently
    /// unschedulable.
    #[test]
    fn npu_oversized_scan_cost_clamps_like_the_service() {
        let s = sim(true);
        let load = RetrievalLoad {
            cost: 20,
            service_time: 0.1,
            cap: 0, // no CPU budget at all: the NPU leg is the only path
            npu_cap: 8,
            npu_low_water: 1.0,
            ..RetrievalLoad::default()
        };
        let scans: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let st = s.run_mixed(&load, &[], &scans);
        assert_eq!(st.retrieve_served, 5);
        assert_eq!(st.retrieve_served_npu, 5);
        assert_eq!(st.retrieve_rejected, 0);
        assert!(st.peak_npu_cost <= 8, "peak {}", st.peak_npu_cost);
        assert_eq!(st.oversub_events, 0);
    }

    /// Offloaded runs stay bit-for-bit reproducible per seed.
    #[test]
    fn npu_offload_determinism_bit_for_bit() {
        let s = sim(true);
        let embeds: Vec<f64> = (0..60).map(|i| i as f64 * 0.12).collect();
        let scans: Vec<f64> = (0..25).map(|i| 0.03 + i as f64 * 0.09).collect();
        let load = offload_load(12);
        let a = s.run_mixed(&load, &embeds, &scans);
        let b = s.run_mixed(&load, &embeds, &scans);
        assert_eq!(a.retrieve_served, b.retrieve_served);
        assert_eq!(a.retrieve_served_npu, b.retrieve_served_npu);
        assert_eq!(a.retrieve_rejected, b.retrieve_rejected);
        assert_eq!(a.peak_cpu_cost, b.peak_cpu_cost);
        assert_eq!(a.peak_npu_cost, b.peak_npu_cost);
        assert_eq!(a.peak_admitted_cost, b.peak_admitted_cost);
        assert_eq!(a.oversub_events, b.oversub_events);
        assert_eq!(a.embed.reject_rate().to_bits(), b.embed.reject_rate().to_bits());
    }

    /// The ingest-load axis acceptance scenario: a bulk-upload storm
    /// runs alongside embed+retrieve traffic and (1) never pushes either
    /// pool past its calibrated depth, (2) never holds more than its
    /// strict caps, and (3) leaves the serving classes' outcomes
    /// untouched when its caps fit in the pool slack — queries keep
    /// their depth under the storm.
    #[test]
    fn ingest_storm_keeps_query_depths() {
        let s = sim(true); // npu 44 / cpu 8
        // Serving traffic: light embeds (never overflow the NPU, so the
        // CPU pool is scans+ingest only) and scans holding ≤ 4 CPU
        // units. With retrieve cap 4 + ingest cap 2 ≤ pool 8, the
        // serving classes never contend with the storm — which makes
        // "queries keep their depth" checkable bit-for-bit.
        let embeds: Vec<f64> = (0..50).map(|i| i as f64 * 0.5).collect();
        let scans: Vec<f64> = (0..20).map(|i| 0.01 + i as f64 * 0.2).collect();
        let retrieval = RetrievalLoad {
            cost: 2,
            service_time: 0.3,
            cap: 4,
            ..RetrievalLoad::default()
        };
        // The storm: ingest every 10 ms for 4 s, each unit holding 1 CPU
        // cost unit for 100 ms — ~10 units of steady-state demand against
        // a strict cap of 2.
        let storm: Vec<f64> = (0..400).map(|i| i as f64 * 0.01).collect();
        let ingest = IngestLoad {
            cost: 1,
            service_time: 0.1,
            cap: 2,
            ..IngestLoad::default()
        };

        let quiet = s.run_mixed(&retrieval, &embeds, &scans);
        let stormy = s.run_mixed_ingest(&retrieval, &ingest, &embeds, &scans, &storm);

        // (1) Depths hold; the probe never fires.
        assert!(stormy.peak_cpu_cost <= stormy.cpu_depth, "{}", stormy.peak_cpu_cost);
        assert!(stormy.peak_npu_cost <= stormy.npu_depth, "{}", stormy.peak_npu_cost);
        assert_eq!(stormy.oversub_events, 0);
        // (2) The strict cap binds: ingest soaks at most 2 units and the
        // over-demand shows up as backpressure, not oversubscription.
        assert!(stormy.peak_ingest_cost <= 2, "{}", stormy.peak_ingest_cost);
        assert_eq!(stormy.ingest_arrived, 400);
        assert!(stormy.ingest_served > 0);
        assert!(stormy.ingest_rejected > 0, "a 10x-over-cap storm must backpressure");
        assert_eq!(stormy.ingest_served + stormy.ingest_rejected, stormy.ingest_arrived);
        // (3) Caps (retrieve 4 + ingest 2) fit inside the pool of 8, so
        // serving traffic is bit-for-bit what it was without the storm.
        assert_eq!(stormy.embed.served(), quiet.embed.served());
        assert_eq!(stormy.embed.rejected, quiet.embed.rejected);
        assert_eq!(stormy.retrieve_served, quiet.retrieve_served);
        assert_eq!(stormy.retrieve_rejected, quiet.retrieve_rejected);
    }

    /// The valley-soak leg: an idle NPU absorbs ingest; an embed-busy
    /// NPU pushes it to the CPU leg (or backpressure).
    #[test]
    fn ingest_valley_soak_defers_to_embedding_traffic() {
        let mut s = sim(true);
        s.npu_depth = 8;
        let ingest = IngestLoad {
            cost: 1,
            service_time: 0.2,
            cap: 0,        // no CPU leg: the NPU valley is the only path
            npu_cap: 4,
            low_water: 0.0, // only a fully embed-idle NPU
        };
        // Idle NPU: the storm soaks the valley.
        let uploads: Vec<f64> = (0..4).map(|i| i as f64 * 0.01).collect();
        let idle = s.run_mixed_ingest(&RetrievalLoad::default(), &ingest, &[], &[], &uploads);
        assert_eq!(idle.ingest_served_npu, 4);
        assert_eq!(idle.ingest_rejected, 0);
        assert!(idle.peak_npu_cost <= 8);
        // Embed burst in flight: the same uploads are pushed out.
        let embeds = vec![0.0; 8];
        let busy = s.run_mixed_ingest(
            &RetrievalLoad::default(),
            &ingest,
            &embeds,
            &[],
            &[0.1, 0.15],
        );
        assert_eq!(busy.ingest_served_npu, 0);
        assert_eq!(busy.ingest_rejected, 2);
        assert_eq!(busy.oversub_events, 0);
    }

    /// Ingest runs stay bit-for-bit reproducible per seed.
    #[test]
    fn ingest_axis_determinism_bit_for_bit() {
        let s = sim(true);
        let embeds: Vec<f64> = (0..80).map(|i| i as f64 * 0.03).collect();
        let scans: Vec<f64> = (0..15).map(|i| 0.02 + i as f64 * 0.15).collect();
        let storm: Vec<f64> = (0..120).map(|i| i as f64 * 0.015).collect();
        let retrieval =
            RetrievalLoad { cost: 2, service_time: 0.2, cap: 4, ..RetrievalLoad::default() };
        let ingest = IngestLoad { cost: 1, service_time: 0.1, cap: 2, npu_cap: 4, low_water: 0.5 };
        let a = s.run_mixed_ingest(&retrieval, &ingest, &embeds, &scans, &storm);
        let b = s.run_mixed_ingest(&retrieval, &ingest, &embeds, &scans, &storm);
        assert_eq!(a.ingest_served, b.ingest_served);
        assert_eq!(a.ingest_served_npu, b.ingest_served_npu);
        assert_eq!(a.ingest_rejected, b.ingest_rejected);
        assert_eq!(a.peak_ingest_cost, b.peak_ingest_cost);
        assert_eq!(a.peak_cpu_cost, b.peak_cpu_cost);
        assert_eq!(a.peak_npu_cost, b.peak_npu_cost);
        assert_eq!(a.oversub_events, b.oversub_events);
        assert_eq!(a.embed.reject_rate().to_bits(), b.embed.reject_rate().to_bits());
    }

    /// The DES emits per-stage histograms under the exact live metric
    /// schema: every emitted name is one of `STAGE_METRICS`, and the
    /// stage counts reconcile with the serving counters — a DES run and
    /// a `/v1/stats` snapshot are directly comparable.
    #[test]
    fn stage_metrics_match_live_schema() {
        use crate::metrics::trace::STAGE_METRICS;
        let s = sim(true);
        let embeds: Vec<f64> = (0..200).map(|i| i as f64 * 0.02).collect();
        let scans: Vec<f64> = (0..40).map(|i| 0.01 + i as f64 * 0.1).collect();
        let st = s.run_mixed(&offload_load(16), &embeds, &scans);

        let live: Vec<&str> = STAGE_METRICS.iter().map(|&(n, ..)| n).collect();
        let mut embed_count = 0;
        let mut wait_count = 0;
        let mut scan_count = 0;
        for (name, h) in st.stage_metrics.histograms() {
            assert!(live.contains(&name.as_str()), "{name} not in the live schema");
            if name.starts_with("trace.embed.embed.") {
                embed_count += h.count();
            }
            if name.starts_with("trace.queue_wait.embed.") {
                wait_count += h.count();
            }
            if name.starts_with("trace.scan.retrieve.") {
                scan_count += h.count();
            }
        }
        assert_eq!(embed_count, st.embed.served());
        assert_eq!(wait_count, st.embed.served());
        assert_eq!(scan_count, st.retrieve_served);
        // Both retrieval legs ran, so both labeled series exist.
        assert!(st.retrieve_served_npu > 0);
        assert!(st
            .stage_metrics
            .histograms()
            .iter()
            .any(|(n, h)| n.as_str() == "trace.scan.retrieve.npu.f32" && h.count() > 0));
        assert!(st
            .stage_metrics
            .histograms()
            .iter()
            .any(|(n, h)| n.as_str() == "trace.scan.retrieve.cpu.f32" && h.count() > 0));
    }

    #[test]
    fn mixed_scans_backpressure_embeds_on_shared_pool() {
        // A standing scan (cost = whole pool) admitted before an embed
        // burst: with admission the burst's CPU overflow shrinks to zero
        // and rejects rise vs. the baseline where the scan is invisible.
        let mut s = sim(true);
        s.npu_depth = 2;
        let load = RetrievalLoad {
            cost: 8,
            service_time: 10.0,
            cap: 8,
            ..RetrievalLoad::default()
        };
        let embeds = vec![0.5; 20]; // burst while the scan holds the pool
        let on = s.run_mixed(&load, &embeds, &[0.0]);
        let base = RetrievalLoad { admission: false, ..load.clone() };
        let off = s.run_mixed(&base, &embeds, &[0.0]);
        assert_eq!(on.retrieve_served, 1);
        assert!(on.embed.rejected > off.embed.rejected);
        assert!(off.embed.served_cpu > 0);
        assert_eq!(on.embed.served_cpu, 0);
    }
}
