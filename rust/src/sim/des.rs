//! Open-loop discrete-event simulation.
//!
//! Arrival-driven companion to [`super::cluster`]: queries arrive on a
//! timestamp stream (e.g. Poisson thinning of the Fig. 2 diurnal curve),
//! are admitted by the production [`QueueManager`], wait in their device
//! queue, and are served batch-at-a-time. Virtual time, no sleeping.
//!
//! Used by the motivation experiments: what happens to SLO attainment and
//! reject rate when evening-peak traffic hits an average-provisioned
//! NPU — and how much of it the CPU queue absorbs.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::queue_manager::{QueueManager, Route};
use crate::devices::profile::DeviceProfile;
use crate::metrics::Histogram;
use crate::util::rng::Pcg;

/// Aggregate results of an open-loop run.
pub struct SimStats {
    pub arrived: u64,
    pub served_npu: u64,
    pub served_cpu: u64,
    pub rejected: u64,
    /// e2e latency (wait + service) in microseconds of virtual time.
    pub latency_us: Histogram,
    pub slo_violations: u64,
    pub makespan: f64,
}

impl SimStats {
    pub fn served(&self) -> u64 {
        self.served_npu + self.served_cpu
    }

    pub fn reject_rate(&self) -> f64 {
        if self.arrived == 0 {
            0.0
        } else {
            self.rejected as f64 / self.arrived as f64
        }
    }

    pub fn slo_attainment(&self) -> f64 {
        let s = self.served();
        if s == 0 {
            1.0
        } else {
            1.0 - self.slo_violations as f64 / s as f64
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    Arrival,
    DeviceDone(bool), // true = NPU
}

/// Open-loop simulator: one NPU instance + optional CPU instance.
pub struct OpenLoopSim {
    pub npu: DeviceProfile,
    pub cpu: Option<DeviceProfile>,
    pub npu_depth: usize,
    pub cpu_depth: usize,
    pub qlen: usize,
    pub slo: f64,
    pub seed: u64,
}

impl OpenLoopSim {
    /// Run over explicit arrival timestamps (seconds, ascending).
    pub fn run(&self, arrivals: &[f64]) -> SimStats {
        let hetero = self.cpu.is_some();
        let qm = QueueManager::new(self.npu_depth, if hetero { self.cpu_depth } else { 0 }, hetero);
        let mut rng = Pcg::new(self.seed);

        // Event heap keyed by (time, seq) — seq breaks ties deterministically.
        let mut heap: BinaryHeap<Reverse<(u64, u64, u8)>> = BinaryHeap::new();
        let to_key = |t: f64| (t * 1e9) as u64;
        let mut seq = 0u64;
        let push = |heap: &mut BinaryHeap<_>, t: f64, e: Event, seq: &mut u64| {
            let tag = match e {
                Event::Arrival => 0u8,
                Event::DeviceDone(true) => 1,
                Event::DeviceDone(false) => 2,
            };
            heap.push(Reverse((to_key(t), *seq, tag)));
            *seq += 1;
        };

        for &t in arrivals {
            push(&mut heap, t, Event::Arrival, &mut seq);
        }
        let mut next_arrival = 0usize;

        let mut npu_q: VecDeque<f64> = VecDeque::new(); // enqueue times
        let mut cpu_q: VecDeque<f64> = VecDeque::new();
        let mut npu_busy = false;
        let mut cpu_busy = false;
        let mut npu_inflight: Vec<f64> = Vec::new();
        let mut cpu_inflight: Vec<f64> = Vec::new();

        let mut stats = SimStats {
            arrived: 0,
            served_npu: 0,
            served_cpu: 0,
            rejected: 0,
            latency_us: Histogram::new(),
            slo_violations: 0,
            makespan: 0.0,
        };

        while let Some(Reverse((tkey, _, tag))) = heap.pop() {
            let now = tkey as f64 / 1e9;
            stats.makespan = now;
            match tag {
                0 => {
                    // Arrival → Algorithm 1 admission.
                    stats.arrived += 1;
                    next_arrival += 1;
                    let _ = next_arrival;
                    match qm.dispatch() {
                        Route::Npu => npu_q.push_back(now),
                        Route::Cpu => cpu_q.push_back(now),
                        Route::Busy => stats.rejected += 1,
                    }
                    // Kick idle devices.
                    if !npu_busy && !npu_q.is_empty() {
                        let b = npu_q.len().min(self.npu_depth.max(1));
                        npu_inflight = npu_q.drain(..b).collect();
                        let st = self.npu.noisy_service_time(b, self.qlen, &mut rng);
                        npu_busy = true;
                        push(&mut heap, now + st, Event::DeviceDone(true), &mut seq);
                    }
                    if hetero && !cpu_busy && !cpu_q.is_empty() {
                        let b = cpu_q.len().min(self.cpu_depth.max(1));
                        cpu_inflight = cpu_q.drain(..b).collect();
                        let st = self
                            .cpu
                            .as_ref()
                            .unwrap()
                            .noisy_service_time(b, self.qlen, &mut rng);
                        cpu_busy = true;
                        push(&mut heap, now + st, Event::DeviceDone(false), &mut seq);
                    }
                }
                1 | 2 => {
                    let is_npu = tag == 1;
                    let (inflight, q, busy, depth) = if is_npu {
                        (&mut npu_inflight, &mut npu_q, &mut npu_busy, self.npu_depth)
                    } else {
                        (&mut cpu_inflight, &mut cpu_q, &mut cpu_busy, self.cpu_depth)
                    };
                    for enq in inflight.drain(..) {
                        let lat = now - enq;
                        stats.latency_us.record((lat * 1e6) as u64);
                        if lat > self.slo {
                            stats.slo_violations += 1;
                        }
                        if is_npu {
                            stats.served_npu += 1;
                        } else {
                            stats.served_cpu += 1;
                        }
                        qm.release(if is_npu { Route::Npu } else { Route::Cpu });
                    }
                    *busy = false;
                    if !q.is_empty() {
                        let b = q.len().min(depth.max(1));
                        let batch: Vec<f64> = q.drain(..b).collect();
                        let profile = if is_npu { &self.npu } else { self.cpu.as_ref().unwrap() };
                        let st = profile.noisy_service_time(b, self.qlen, &mut rng);
                        *inflight = batch;
                        *busy = true;
                        push(
                            &mut heap,
                            now + st,
                            Event::DeviceDone(is_npu),
                            &mut seq,
                        );
                    }
                }
                _ => unreachable!(),
            }
        }
        stats
    }

    /// Poisson arrivals at `rate(t)` q/s over `[0, horizon)` seconds via
    /// thinning against `peak_rate`.
    pub fn poisson_arrivals(
        rate: impl Fn(f64) -> f64,
        peak_rate: f64,
        horizon: f64,
        seed: u64,
    ) -> Vec<f64> {
        let mut rng = Pcg::new(seed);
        let mut t = 0.0;
        let mut out = Vec::new();
        while t < horizon {
            t += rng.exp(peak_rate);
            if t < horizon && rng.f64() < rate(t) / peak_rate {
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(mut p: DeviceProfile) -> DeviceProfile {
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        p
    }

    fn sim(hetero: bool) -> OpenLoopSim {
        OpenLoopSim {
            npu: quiet(DeviceProfile::v100_bge()),
            cpu: hetero.then(|| quiet(DeviceProfile::xeon_e5_2690_bge())),
            npu_depth: 44,
            cpu_depth: 8,
            qlen: 75,
            slo: 1.0,
            seed: 1,
        }
    }

    #[test]
    fn conservation_served_plus_rejected_equals_arrived() {
        let s = sim(true);
        let arrivals: Vec<f64> = (0..500).map(|i| i as f64 * 0.01).collect();
        let st = s.run(&arrivals);
        assert_eq!(st.arrived, 500);
        assert_eq!(st.served() + st.rejected, st.arrived);
    }

    #[test]
    fn light_load_all_served_in_slo() {
        let s = sim(false);
        // One query per 2 s: every batch has size 1, latency β + α ≈ 0.29 s.
        let arrivals: Vec<f64> = (0..50).map(|i| i as f64 * 2.0).collect();
        let st = s.run(&arrivals);
        assert_eq!(st.rejected, 0);
        assert_eq!(st.slo_violations, 0);
        assert_eq!(st.served_npu, 50);
    }

    #[test]
    fn burst_overflows_to_cpu_with_hetero() {
        // 50-query instantaneous burst: NPU takes 44, CPU the rest.
        let arrivals = vec![0.0; 50];
        let st = sim(true).run(&arrivals);
        assert_eq!(st.rejected, 0);
        assert!(st.served_cpu >= 6, "cpu served {}", st.served_cpu);
        // Without hetero the same burst rejects.
        let st2 = sim(false).run(&arrivals);
        assert!(st2.rejected >= 6, "rejected {}", st2.rejected);
    }

    #[test]
    fn heavier_sustained_load_violates_slo_or_rejects() {
        let mut s = sim(false);
        s.npu_depth = 16;
        // 100 q/s sustained far beyond one instance's ~40 q/s capacity.
        let arrivals: Vec<f64> = (0..400).map(|i| i as f64 * 0.01).collect();
        let st = s.run(&arrivals);
        assert!(st.rejected > 0 || st.slo_violations > 0);
    }

    #[test]
    fn poisson_thinning_rate_roughly_matches() {
        let arr = OpenLoopSim::poisson_arrivals(|_| 20.0, 20.0, 100.0, 3);
        let rate = arr.len() as f64 / 100.0;
        assert!((rate - 20.0).abs() < 2.5, "rate {rate}");
    }

    #[test]
    fn deterministic_per_seed() {
        let s = sim(true);
        let arrivals: Vec<f64> = (0..100).map(|i| i as f64 * 0.02).collect();
        let a = s.run(&arrivals);
        let b = s.run(&arrivals);
        assert_eq!(a.served_npu, b.served_npu);
        assert_eq!(a.rejected, b.rejected);
    }
}
