//! Segmented, checksummed write-ahead log for corpus mutations.
//!
//! Records are length-prefixed and CRC-protected:
//!
//! ```text
//! [len: u32][crc32(payload): u32][payload: seq u64 · tag u8 · body]
//! ```
//!
//! The log is a sequence of segment files `wal-<first-seq>.log`; a
//! segment seals when it crosses `segment_bytes` and the next record
//! starts a new file. Sealing is what makes truncation cheap: once a
//! snapshot covers sequence `w`, every segment whose records are all
//! ≤ `w` is deleted whole — no rewriting (see [`Wal::truncate_through`]).
//!
//! **Torn tails.** Appends go to the page cache and are fsynced once per
//! ingest commit batch (the caller's one [`Wal::sync`] per
//! [`Wal::append_batch`]). A crash can therefore leave a partial record
//! at the end of the last segment. [`Wal::open`] scans every segment
//! record-by-record, verifying length bounds and CRC; at the first bad
//! record it truncates that file there and ignores any later segments
//! (nothing after a torn record was acknowledged — the ack waits for the
//! fsync). Everything that *was* acked re-reads intact, by CRC.
//!
//! **Short writes.** A *failed* append (EIO mid-write) can leave partial
//! record bytes at the tail while the process keeps running — and a later
//! successful append would then bury acked records behind a torn region
//! that [`Wal::open`] cuts away. [`Wal::append_batch`] therefore repairs
//! the tail on append failure (truncating the segment back to its last
//! known-good length); if the repair itself fails, the log poisons
//! itself and refuses every further append — read-only beats silently
//! lossy.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::faultfs::Fs;

/// CRC-32 (IEEE 802.3, reflected). Bitwise — the WAL appends a few
/// dozen records per commit, so table-free keeps the module dependency-
/// and allocation-free at negligible cost.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

const TAG_UPSERT: u8 = 1;
const TAG_DELETE: u8 = 2;

/// One logged corpus mutation. `seq` is assigned by the log, dense and
/// strictly increasing; replay applies records in `seq` order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    /// Insert-or-replace document `id` with `text` (re-embedded on
    /// replay — embeddings are deterministic per text, so the replayed
    /// row scores bit-identically).
    Upsert { seq: u64, id: u64, text: String },
    /// Tombstone document `id`.
    Delete { seq: u64, id: u64 },
}

impl WalRecord {
    pub fn seq(&self) -> u64 {
        match self {
            WalRecord::Upsert { seq, .. } | WalRecord::Delete { seq, .. } => *seq,
        }
    }

    fn encode_payload(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Upsert { seq, id, text } => {
                out.extend_from_slice(&seq.to_le_bytes());
                out.push(TAG_UPSERT);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(text.as_bytes());
            }
            WalRecord::Delete { seq, id } => {
                out.extend_from_slice(&seq.to_le_bytes());
                out.push(TAG_DELETE);
                out.extend_from_slice(&id.to_le_bytes());
            }
        }
    }

    fn decode_payload(payload: &[u8]) -> Option<WalRecord> {
        if payload.len() < 17 {
            return None;
        }
        let seq = u64::from_le_bytes(payload[0..8].try_into().unwrap());
        let tag = payload[8];
        let id = u64::from_le_bytes(payload[9..17].try_into().unwrap());
        match tag {
            TAG_UPSERT => {
                let text = std::str::from_utf8(&payload[17..]).ok()?.to_string();
                Some(WalRecord::Upsert { seq, id, text })
            }
            TAG_DELETE if payload.len() == 17 => Some(WalRecord::Delete { seq, id }),
            _ => None,
        }
    }
}

/// Append `rec` (length prefix + CRC + payload) to `out`.
fn encode_record(out: &mut Vec<u8>, rec: &WalRecord) {
    let mut payload = Vec::new();
    rec.encode_payload(&mut payload);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
}

/// Largest payload [`Wal::open`] will believe; anything bigger is a
/// corrupt length prefix, treated like a torn tail.
const MAX_PAYLOAD: usize = 16 << 20;

/// Decode records from `buf` until the end or the first bad record.
/// Returns the records and the byte offset of the valid prefix.
fn decode_valid_prefix(buf: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut recs = Vec::new();
    let mut pos = 0usize;
    while buf.len() - pos >= 8 {
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        if len > MAX_PAYLOAD || buf.len() - pos - 8 < len {
            break; // torn or corrupt length
        }
        let payload = &buf[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            break; // torn or corrupt payload
        }
        match WalRecord::decode_payload(payload) {
            Some(rec) => recs.push(rec),
            None => break, // structurally invalid payload
        }
        pos += 8 + len;
    }
    (recs, pos)
}

/// One on-disk segment and the seq range it holds.
struct Segment {
    path: PathBuf,
    first_seq: u64,
    last_seq: u64,
    bytes: usize,
}

fn segment_name(first_seq: u64) -> String {
    format!("wal-{first_seq:016x}.log")
}

fn parse_segment_name(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("wal-")?.strip_suffix(".log")?;
    u64::from_str_radix(hex, 16).ok()
}

/// The write-ahead log. Single-writer: callers serialize appends (the
/// durable store holds its commit lock across append + sync + index
/// commit).
pub struct Wal {
    fs: Arc<dyn Fs>,
    dir: PathBuf,
    segment_bytes: usize,
    segments: Vec<Segment>,
    next_seq: u64,
    /// Set when a failed append could not be repaired: the tail may hold
    /// partial bytes that a later append would entomb acked records
    /// behind, so every further append is refused.
    poisoned: bool,
}

impl Wal {
    /// Open (or create) the log in `dir`. Scans all segments, truncates
    /// the torn tail if any, and returns the surviving records in seq
    /// order alongside the ready-to-append log.
    pub fn open(fs: Arc<dyn Fs>, dir: &Path, segment_bytes: usize) -> io::Result<(Wal, Vec<WalRecord>)> {
        fs.create_dir_all(dir)?;
        let mut firsts: Vec<u64> =
            fs.list(dir)?.iter().filter_map(|n| parse_segment_name(n)).collect();
        firsts.sort_unstable();

        let mut segments = Vec::new();
        let mut records = Vec::new();
        let mut next_seq = 1u64;
        let mut torn = false;
        for (i, first) in firsts.iter().enumerate() {
            let path = dir.join(segment_name(*first));
            if torn {
                // Nothing after a torn record was acked; drop the file.
                fs.remove(&path)?;
                continue;
            }
            let buf = fs.read(&path)?;
            let (recs, valid) = decode_valid_prefix(&buf);
            if valid < buf.len() {
                torn = true;
                fs.truncate(&path, valid as u64)?;
            }
            if recs.is_empty() {
                // Fully torn (or empty) segment: keep only if it is the
                // last — it stays the active segment.
                if torn || i + 1 < firsts.len() {
                    fs.remove(&path)?;
                    continue;
                }
                segments.push(Segment { path, first_seq: *first, last_seq: 0, bytes: 0 });
                continue;
            }
            let seg = Segment {
                path,
                first_seq: recs[0].seq(),
                last_seq: recs[recs.len() - 1].seq(),
                bytes: valid,
            };
            next_seq = seg.last_seq + 1;
            segments.push(seg);
            records.extend(recs);
        }
        let wal =
            Wal { fs, dir: dir.to_path_buf(), segment_bytes, segments, next_seq, poisoned: false };
        Ok((wal, records))
    }

    /// Next sequence number [`Wal::append_batch`] will assign.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Raise the next sequence number to at least `floor`. Needed when a
    /// snapshot watermark outlives every WAL segment (the log was fully
    /// truncated behind it): without the floor a reopened empty log
    /// would hand out seqs the watermark already covers, and replay
    /// would silently skip them.
    pub fn ensure_next_seq(&mut self, floor: u64) {
        if floor > self.next_seq {
            self.next_seq = floor;
        }
    }

    /// Live segment files (observability).
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Bytes across live segments (observability).
    pub fn bytes(&self) -> usize {
        self.segments.iter().map(|s| s.bytes).sum()
    }

    /// Assign sequence numbers to `recs` (in order), encode them into
    /// one buffer, and append it with a single write. NOT durable until
    /// [`Wal::sync`] — the caller fsyncs once per commit batch. On error
    /// the in-memory log state is unchanged (the next open re-scans the
    /// tail and drops any partial bytes by CRC).
    pub fn append_batch(&mut self, recs: &mut [WalRecord]) -> io::Result<()> {
        if recs.is_empty() {
            return Ok(());
        }
        if self.poisoned {
            return Err(io::Error::other(
                "wal: poisoned by an unrepaired append failure, refusing to append",
            ));
        }
        let first_seq = self.next_seq;
        for (i, rec) in recs.iter_mut().enumerate() {
            let seq = first_seq + i as u64;
            match rec {
                WalRecord::Upsert { seq: s, .. } | WalRecord::Delete { seq: s, .. } => *s = seq,
            }
        }
        let mut buf = Vec::new();
        for rec in recs.iter() {
            encode_record(&mut buf, rec);
        }
        // Roll to a new segment when the active one is full (never
        // mid-batch: a commit's records stay contiguous in one file).
        let need_new = match self.segments.last() {
            Some(s) => s.bytes >= self.segment_bytes,
            None => true,
        };
        if need_new {
            self.segments.push(Segment {
                path: self.dir.join(segment_name(first_seq)),
                first_seq,
                last_seq: 0,
                bytes: 0,
            });
        }
        let seg = self.segments.last_mut().unwrap();
        if let Err(e) = self.fs.append(&seg.path, &buf) {
            // A short write may have left partial bytes at the tail. Cut
            // the file back to its last known-good length so a later
            // successful append cannot bury acked records behind a torn
            // region (open() stops at the first bad record). If even the
            // repair fails, poison the log: no more appends.
            if seg.bytes > 0 && self.fs.truncate(&seg.path, seg.bytes as u64).is_err() {
                self.poisoned = true;
            } else if seg.bytes == 0 && self.fs.exists(&seg.path) {
                // Fresh segment whose very first append short-wrote: the
                // partial bytes ARE the whole file.
                if self.fs.truncate(&seg.path, 0).is_err() {
                    self.poisoned = true;
                }
            }
            return Err(e);
        }
        seg.bytes += buf.len();
        if seg.last_seq == 0 && seg.first_seq > first_seq {
            // Reopened empty active segment named ahead of these seqs —
            // cannot happen with dense seq assignment, but keep the range
            // honest if it ever did.
            seg.first_seq = first_seq;
        }
        seg.last_seq = first_seq + recs.len() as u64 - 1;
        self.next_seq = seg.last_seq + 1;
        Ok(())
    }

    /// fsync the active segment: everything appended so far is durable.
    pub fn sync(&self) -> io::Result<()> {
        match self.segments.last() {
            Some(s) if s.bytes > 0 => self.fs.sync(&s.path),
            _ => Ok(()),
        }
    }

    /// Drop every segment whose records are all covered by a snapshot at
    /// sequence `through` (kept: any segment holding a record > `through`,
    /// plus an empty active segment for future appends). Returns segments
    /// deleted.
    pub fn truncate_through(&mut self, through: u64) -> io::Result<usize> {
        let mut deleted = 0;
        let mut kept = Vec::new();
        let n = self.segments.len();
        for (i, seg) in self.segments.drain(..).enumerate() {
            let covered = seg.bytes > 0 && seg.last_seq <= through;
            let is_last = i + 1 == n;
            if covered && !is_last {
                self.fs.remove(&seg.path)?;
                deleted += 1;
            } else if covered && is_last {
                // Fully-covered active segment: delete it and let the
                // next append start a fresh file at the new seq.
                self.fs.remove(&seg.path)?;
                deleted += 1;
            } else {
                kept.push(seg);
            }
        }
        self.segments = kept;
        Ok(deleted)
    }
}

#[cfg(test)]
mod tests {
    use super::super::faultfs::{FaultFs, FaultPlan};
    use super::*;
    use std::path::PathBuf;

    fn dir() -> PathBuf {
        PathBuf::from("/wal")
    }

    fn up(id: u64, text: &str) -> WalRecord {
        WalRecord::Upsert { seq: 0, id, text: text.to_string() }
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC-32 of "123456789" is 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_sync_reopen_replays_in_order() {
        let fs = Arc::new(FaultFs::new());
        let (mut wal, recs) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
        assert!(recs.is_empty());
        let mut batch = vec![up(1, "one"), WalRecord::Delete { seq: 0, id: 9 }, up(2, "two")];
        wal.append_batch(&mut batch).unwrap();
        wal.sync().unwrap();
        assert_eq!(batch[0].seq(), 1);
        assert_eq!(batch[2].seq(), 3);
        assert_eq!(wal.next_seq(), 4);
        drop(wal);
        let (wal, recs) = Wal::open(fs, &dir(), 1 << 20).unwrap();
        assert_eq!(recs, batch);
        assert_eq!(wal.next_seq(), 4);
    }

    #[test]
    fn torn_tail_is_truncated_to_the_acked_prefix() {
        let fs = Arc::new(FaultFs::new());
        let (mut wal, _) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
        let mut a = vec![up(1, "acked")];
        wal.append_batch(&mut a).unwrap();
        wal.sync().unwrap();
        // Second batch appended but NOT synced, then the machine dies
        // keeping a 5-byte torn shred of it.
        let mut b = vec![up(2, "lost")];
        wal.append_batch(&mut b).unwrap();
        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (wal2, recs) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
        assert_eq!(recs, a, "exactly the synced prefix");
        assert_eq!(wal2.next_seq(), 2);
        drop(wal2);
        // And the truncation is idempotent across another reopen.
        let (_, recs) = Wal::open(fs, &dir(), 1 << 20).unwrap();
        assert_eq!(recs, a);
    }

    #[test]
    fn torn_tail_with_partial_bytes_survived() {
        for torn_keep in [1usize, 3, 7, 12] {
            let fs = Arc::new(FaultFs::with_plan(FaultPlan { torn_keep, ..Default::default() }));
            let (mut wal, _) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
            let mut a = vec![up(1, "acked")];
            wal.append_batch(&mut a).unwrap();
            wal.sync().unwrap();
            let mut b = vec![up(2, "torn away")];
            wal.append_batch(&mut b).unwrap();
            fs.crash_now();
            fs.restart(FaultPlan::default());
            let (_, recs) = Wal::open(fs, &dir(), 1 << 20).unwrap();
            assert_eq!(recs, a, "torn_keep={torn_keep}");
        }
    }

    #[test]
    fn corrupt_middle_byte_truncates_from_there() {
        let fs = Arc::new(FaultFs::new());
        let (mut wal, _) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
        let mut batch = vec![up(1, "first"), up(2, "second"), up(3, "third")];
        wal.append_batch(&mut batch).unwrap();
        wal.sync().unwrap();
        // Flip one byte in the middle record's payload.
        let path = dir().join(segment_name(1));
        let mut bytes = fs.read(&path).unwrap();
        let rec1_len = 8 + 17 + 5; // header + fixed payload + "first"
        bytes[rec1_len + 12] ^= 0xff;
        fs.write_atomic(&path, &bytes).unwrap();
        let (_, recs) = Wal::open(fs, &dir(), 1 << 20).unwrap();
        assert_eq!(recs, batch[..1], "valid prefix only");
    }

    #[test]
    fn segments_roll_and_truncate_behind_a_watermark() {
        let fs = Arc::new(FaultFs::new());
        // Tiny segments: every batch rolls a new file.
        let (mut wal, _) = Wal::open(fs.clone(), &dir(), 8).unwrap();
        for i in 0..5u64 {
            let mut b = vec![up(i, "xxxxxxxxxxxxxxxx")];
            wal.append_batch(&mut b).unwrap();
            wal.sync().unwrap();
        }
        assert_eq!(wal.segment_count(), 5);
        assert!(wal.bytes() > 0);
        // Snapshot covered seq ≤ 3: segments 1..=3 go, 4..=5 stay.
        let deleted = wal.truncate_through(3).unwrap();
        assert_eq!(deleted, 3);
        assert_eq!(wal.segment_count(), 2);
        let (_, recs) = Wal::open(fs.clone(), &dir(), 8).unwrap();
        let seqs: Vec<u64> = recs.iter().map(|r| r.seq()).collect();
        assert_eq!(seqs, vec![4, 5]);
        // Covering everything empties the log; appends still work after.
        let (mut wal, _) = Wal::open(fs.clone(), &dir(), 8).unwrap();
        wal.truncate_through(5).unwrap();
        assert_eq!(wal.segment_count(), 0);
        let mut b = vec![up(9, "after")];
        wal.append_batch(&mut b).unwrap();
        wal.sync().unwrap();
        assert_eq!(b[0].seq(), 6, "seq continues after truncation");
        let (_, recs) = Wal::open(fs, &dir(), 8).unwrap();
        assert_eq!(recs, b);
    }

    #[test]
    fn short_write_is_repaired_and_later_acks_survive() {
        // Op 2 short-writes half a record; the repair (op 3) cuts it
        // away, so the NEXT append lands on a clean tail and its record
        // must survive replay — the failure mode this guards against is
        // a torn region mid-log entombing everything after it.
        let fs = Arc::new(FaultFs::with_plan(FaultPlan {
            short_write_at: Some(2),
            ..Default::default()
        }));
        let (mut wal, _) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
        let mut a = vec![up(1, "first acked")];
        wal.append_batch(&mut a).unwrap(); // op 0
        wal.sync().unwrap(); // op 1
        let mut b = vec![up(2, "short-written, refused")];
        assert!(wal.append_batch(&mut b).is_err()); // op 2 + repair op 3
        let mut c = vec![up(3, "acked after the repair")];
        wal.append_batch(&mut c).unwrap(); // op 4
        wal.sync().unwrap(); // op 5
        assert_eq!(c[0].seq(), 2, "the refused batch's seq is reassigned");
        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (_, recs) = Wal::open(fs, &dir(), 1 << 20).unwrap();
        assert_eq!(recs, vec![a[0].clone(), c[0].clone()]);
    }

    #[test]
    fn unrepairable_append_failure_poisons_the_log() {
        // Short write at op 2 AND a crash at the repair truncate (op 3):
        // the wal cannot prove its tail is clean, so it must refuse
        // every further append rather than risk burying acked records.
        let fs = Arc::new(FaultFs::with_plan(FaultPlan {
            short_write_at: Some(2),
            crash_at_op: Some(3),
            ..Default::default()
        }));
        let (mut wal, _) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
        let mut a = vec![up(1, "acked")];
        wal.append_batch(&mut a).unwrap(); // op 0
        wal.sync().unwrap(); // op 1
        let mut b = vec![up(2, "short write, repair dies")];
        assert!(wal.append_batch(&mut b).is_err());
        // Even after the machine comes back, this wal handle stays
        // read-only; recovery reopens a fresh one.
        fs.restart(FaultPlan::default());
        let mut c = vec![up(3, "refused")];
        assert!(wal.append_batch(&mut c).is_err(), "poisoned wal refuses appends");
        let (_, recs) = Wal::open(fs, &dir(), 1 << 20).unwrap();
        assert_eq!(recs, a, "exactly the acked prefix survives");
    }

    #[test]
    fn unsynced_append_error_leaves_reopenable_log() {
        // An append that fails (machine down) must not wedge reopen.
        let fs = Arc::new(FaultFs::with_plan(FaultPlan {
            crash_at_op: Some(3),
            ..Default::default()
        }));
        let (mut wal, _) = Wal::open(fs.clone(), &dir(), 1 << 20).unwrap();
        let mut a = vec![up(1, "ok")];
        wal.append_batch(&mut a).unwrap(); // op 0
        wal.sync().unwrap(); // op 1
        let mut b = vec![up(2, "ok2")];
        wal.append_batch(&mut b).unwrap(); // op 2
        let mut c = vec![up(3, "dies")];
        assert!(wal.append_batch(&mut c).is_err()); // op 3 crashes
        fs.restart(FaultPlan::default());
        let (_, recs) = Wal::open(fs, &dir(), 1 << 20).unwrap();
        // Only the synced record survives; the unsynced-but-successful
        // append died with the page cache.
        assert_eq!(recs, a);
    }
}
