//! Durable corpus lifecycle: WAL, crash recovery, snapshot + compaction.
//!
//! Before this subsystem, every accepted document lived only in memory —
//! a crash silently lost acknowledged uploads, and the index could never
//! shrink or overwrite. [`DurableStore`] threads a write-ahead log and a
//! snapshot checkpoint under the ingest pipeline and gives the service
//! upsert/delete/compaction on top of the tombstone machinery in
//! `vecstore` (see `vecstore::mask`).
//!
//! # The contract
//!
//! * **ack ⇒ WAL-durable.** The ingest pipeline calls
//!   [`DurableStore::log_upserts`] (and the delete path
//!   [`DurableStore::log_delete`]) *before* a document is acknowledged:
//!   the record batch is appended and fsynced, and only then is the
//!   index mutated and the client acked. A crash at any point therefore
//!   loses no acknowledged write — replay re-embeds and re-commits
//!   whatever the index hadn't absorbed. (The converse is deliberately
//!   weak: a record that was logged but never acked — crash between
//!   fsync and ack, or a torn tail that happened to survive — MAY
//!   replay. Replay applies upserts/deletes in sequence order, so this
//!   is always a prefix extension of the acked state, never a
//!   reordering.)
//! * **snapshot ⇒ WAL-truncatable.** [`DurableStore::snapshot`] takes
//!   the commit lock, serializes the index (encoded arena bytes — see
//!   `vecstore::persist` for why that is bit-exact), stamps it with the
//!   committed sequence watermark, and only after the snapshot file is
//!   atomically durable deletes the log segments behind the watermark.
//!   Recovery = newest valid snapshot + replay of the WAL tail past its
//!   watermark.
//! * **deletes never resurrect.** Tombstones are committed under the
//!   same version seam as adds (mirror invalidation included), snapshots
//!   and corpus exports drop tombstoned rows at encode time, and replay
//!   re-applies logged deletes in order.
//!
//! # Consistency cut
//!
//! One mutex ([`DurableStore`]'s commit lock) is held across
//! [WAL append + fsync → index commit → watermark update] and across
//! [serialize index → write snapshot → truncate WAL]. The watermark a
//! snapshot records therefore exactly matches the index state it
//! serializes — there is no window where a record is reflected in one
//! but not the other. Lock order is always commit lock → index lock.
//!
//! All I/O goes through the injectable [`faultfs::Fs`] layer, so the
//! whole lifecycle is testable under deterministic kill-points
//! ([`faultfs::FaultFs`]) — torn appends, short writes, fsync errors,
//! crashes between WAL append and index commit, crashes mid-compaction.

pub mod faultfs;
pub mod snapshot;
pub mod wal;

pub use faultfs::{FaultFs, FaultPlan, Fs, RealFs};
pub use wal::WalRecord;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::devices::executor::RetrievalExecutor;
use crate::vecstore::{persist, Index};

/// Tuning for a [`DurableStore`].
#[derive(Debug, Clone)]
pub struct DurabilityOptions {
    /// WAL segment roll size. Small segments truncate at finer grain;
    /// large ones amortize file creation.
    pub segment_bytes: usize,
    /// When `tombstones / physical rows` crosses this after a commit,
    /// [`DurableStore::maybe_compact`] rewrites the arenas and
    /// checkpoints. ≤ 0 disables auto-compaction.
    pub compact_tombstone_ratio: f64,
}

impl Default for DurabilityOptions {
    fn default() -> DurabilityOptions {
        DurabilityOptions { segment_bytes: 1 << 20, compact_tombstone_ratio: 0.25 }
    }
}

/// Point-in-time durability counters for `/stats`.
#[derive(Debug, Clone, PartialEq)]
pub struct DurabilityStats {
    /// Highest WAL sequence applied to the index.
    pub committed_seq: u64,
    pub wal_segments: usize,
    pub wal_bytes: usize,
    /// Records re-applied by the last recovery.
    pub replayed_records: u64,
    pub snapshots_written: u64,
    pub compactions: u64,
    /// Commits refused because the WAL append or fsync failed (the
    /// documents were NOT acked).
    pub wal_append_failures: u64,
}

/// What [`DurableStore::open`] found on disk.
pub struct Recovery {
    /// Newest valid snapshot payload (decode with
    /// `vecstore::persist::decode_index`), if any.
    pub snapshot: Option<Vec<u8>>,
    /// Sequence the snapshot covers (0 = no snapshot).
    pub watermark: u64,
    /// WAL records past the watermark, in sequence order — the part of
    /// the acked state the snapshot doesn't cover.
    pub tail: Vec<WalRecord>,
}

/// Summary of a completed [`DurableStore::recover`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryReport {
    pub from_snapshot: bool,
    pub watermark: u64,
    pub replayed: u64,
}

struct Inner {
    wal: wal::Wal,
    /// Highest sequence whose record is applied to the index. Only moves
    /// under the commit lock, after the index mutation it covers.
    committed_seq: u64,
}

/// The durable corpus store: one per service, shared with the ingest
/// pipeline and the server's delete/snapshot endpoints.
pub struct DurableStore {
    fs: Arc<dyn Fs>,
    dir: PathBuf,
    opts: DurabilityOptions,
    inner: Mutex<Inner>,
    replayed: AtomicU64,
    snapshots: AtomicU64,
    compactions: AtomicU64,
    append_failures: AtomicU64,
}

impl DurableStore {
    fn wal_dir(dir: &Path) -> PathBuf {
        dir.join("wal")
    }

    fn snap_dir(dir: &Path) -> PathBuf {
        dir.join("snapshots")
    }

    /// Open (or create) the store in `dir`: load the newest valid
    /// snapshot, open the WAL (truncating any torn tail), and return the
    /// store plus what a caller must replay. Most callers want
    /// [`DurableStore::recover`], which also rebuilds the executor.
    pub fn open(
        fs: Arc<dyn Fs>,
        dir: &Path,
        opts: DurabilityOptions,
    ) -> Result<(DurableStore, Recovery)> {
        fs.create_dir_all(dir).context("durability: create store dir")?;
        let snap = snapshot::load_newest(&fs, &Self::snap_dir(dir))
            .context("durability: scan snapshots")?;
        let (watermark, payload) = match snap {
            Some((w, p)) => (w, Some(p)),
            None => (0, None),
        };
        let (mut wal, records) = wal::Wal::open(fs.clone(), &Self::wal_dir(dir), opts.segment_bytes)
            .context("durability: open WAL")?;
        wal.ensure_next_seq(watermark + 1);
        let tail: Vec<WalRecord> =
            records.into_iter().filter(|r| r.seq() > watermark).collect();
        // Until the caller replays the tail, the index only covers the
        // watermark; `recover` advances this as it applies records.
        let store = DurableStore {
            fs,
            dir: dir.to_path_buf(),
            opts,
            inner: Mutex::new(Inner { wal, committed_seq: watermark }),
            replayed: AtomicU64::new(0),
            snapshots: AtomicU64::new(0),
            compactions: AtomicU64::new(0),
            append_failures: AtomicU64::new(0),
        };
        Ok((store, Recovery { snapshot: payload, watermark, tail }))
    }

    /// Full recovery: open the store, rebuild the index (snapshot if one
    /// verifies, else `make_index`), and replay the WAL tail by
    /// re-embedding each upsert with `embed` (deterministic embeddings ⇒
    /// bit-identical rows) and re-applying deletes, in sequence order.
    pub fn recover<G, F>(
        fs: Arc<dyn Fs>,
        dir: &Path,
        opts: DurabilityOptions,
        make_index: G,
        mut embed: F,
    ) -> Result<(Arc<DurableStore>, Arc<RetrievalExecutor>, RecoveryReport)>
    where
        G: FnOnce() -> Box<dyn Index + Send + Sync>,
        F: FnMut(&str) -> Result<Vec<f32>>,
    {
        let (store, recovery) = DurableStore::open(fs, dir, opts)?;
        let index = match &recovery.snapshot {
            Some(payload) => {
                persist::decode_index(payload).context("durability: decode snapshot")?
            }
            None => make_index(),
        };
        let exec = Arc::new(RetrievalExecutor::new(index));
        let mut last_seq = recovery.watermark;
        for rec in &recovery.tail {
            match rec {
                WalRecord::Upsert { id, text, .. } => {
                    let v = embed(text)
                        .with_context(|| format!("durability: re-embed doc {id} on replay"))?;
                    exec.upsert_batch(&[(*id, v)]);
                }
                WalRecord::Delete { id, .. } => {
                    exec.remove(*id);
                }
            }
            last_seq = rec.seq();
        }
        let replayed = recovery.tail.len() as u64;
        store.inner.lock().unwrap().committed_seq = last_seq;
        store.replayed.store(replayed, Ordering::Relaxed);
        let report = RecoveryReport {
            from_snapshot: recovery.snapshot.is_some(),
            watermark: recovery.watermark,
            replayed,
        };
        Ok((Arc::new(store), exec, report))
    }

    /// Log an upsert batch and, once it is durable, run `commit` (the
    /// index mutation) — the ack ⇒ WAL-durable half of the contract. On
    /// a WAL error `commit` never runs and the error propagates: the
    /// pipeline must NOT ack those documents.
    pub fn log_upserts<F: FnOnce()>(&self, docs: &[(u64, &str)], commit: F) -> Result<()> {
        let recs: Vec<WalRecord> = docs
            .iter()
            .map(|(id, text)| WalRecord::Upsert { seq: 0, id: *id, text: (*text).to_string() })
            .collect();
        self.log_and_commit(recs, commit)
    }

    /// Log one delete and, once durable, run `commit` (the tombstone +
    /// version bump).
    pub fn log_delete<F: FnOnce()>(&self, id: u64, commit: F) -> Result<()> {
        self.log_and_commit(vec![WalRecord::Delete { seq: 0, id }], commit)
    }

    fn log_and_commit<F: FnOnce()>(&self, mut recs: Vec<WalRecord>, commit: F) -> Result<()> {
        if recs.is_empty() {
            commit();
            return Ok(());
        }
        let mut inner = self.inner.lock().unwrap();
        if let Err(e) = inner.wal.append_batch(&mut recs) {
            self.append_failures.fetch_add(1, Ordering::Relaxed);
            return Err(e).context("durability: WAL append failed, refusing to ack");
        }
        // One fsync per commit batch — the batching the pipeline's
        // per-batch commit already provides.
        if let Err(e) = inner.wal.sync() {
            self.append_failures.fetch_add(1, Ordering::Relaxed);
            return Err(e).context("durability: WAL fsync failed, refusing to ack");
        }
        commit();
        inner.committed_seq = recs.last().expect("non-empty batch").seq();
        Ok(())
    }

    /// Checkpoint: serialize the index under the commit lock (so the
    /// watermark exactly matches the serialized state), write the
    /// snapshot atomically, then truncate the WAL behind it. Returns the
    /// watermark covered.
    pub fn snapshot(&self, exec: &RetrievalExecutor) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let (payload, _version) = exec
            .snapshot_bytes()
            .context("durability: index has no snapshot codec")?;
        let watermark = inner.committed_seq;
        snapshot::write(&self.fs, &Self::snap_dir(&self.dir), watermark, &payload)
            .context("durability: write snapshot")?;
        inner
            .wal
            .truncate_through(watermark)
            .context("durability: truncate WAL behind snapshot")?;
        self.snapshots.fetch_add(1, Ordering::Relaxed);
        Ok(watermark)
    }

    /// Compaction trigger, called after ingest commits: when tombstone
    /// density crosses the configured ratio, rewrite the arenas (under
    /// the index's version seam — mirrors re-seed as for any mutation)
    /// and checkpoint so the WAL behind the rewrite truncates. Returns
    /// rows reclaimed, `None` when below threshold or disabled.
    pub fn maybe_compact(&self, exec: &RetrievalExecutor) -> Result<Option<usize>> {
        let ratio = self.opts.compact_tombstone_ratio;
        if ratio <= 0.0 {
            return Ok(None);
        }
        let dead = exec.tombstones();
        let physical = dead + exec.len();
        if physical == 0 || (dead as f64) < ratio * physical as f64 {
            return Ok(None);
        }
        let reclaimed = exec.compact();
        self.snapshot(exec)?;
        self.compactions.fetch_add(1, Ordering::Relaxed);
        Ok(Some(reclaimed))
    }

    /// Current counters for `/stats`.
    pub fn stats(&self) -> DurabilityStats {
        let inner = self.inner.lock().unwrap();
        DurabilityStats {
            committed_seq: inner.committed_seq,
            wal_segments: inner.wal.segment_count(),
            wal_bytes: inner.wal.bytes(),
            replayed_records: self.replayed.load(Ordering::Relaxed),
            snapshots_written: self.snapshots.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            wal_append_failures: self.append_failures.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecstore::FlatIndex;

    const DIM: usize = 8;

    /// Deterministic toy embedding: same text ⇒ same unit vector.
    fn embed(text: &str) -> Result<Vec<f32>> {
        let mut state = crate::runtime::tokenizer::fnv1a64(text.as_bytes());
        let mut v: Vec<f32> = (0..DIM)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        Ok(v)
    }

    fn recover_all(
        fs: &Arc<FaultFs>,
        opts: &DurabilityOptions,
    ) -> (Arc<DurableStore>, Arc<RetrievalExecutor>, RecoveryReport) {
        let dynfs: Arc<dyn Fs> = fs.clone();
        DurableStore::recover(
            dynfs,
            Path::new("/store"),
            opts.clone(),
            || Box::new(FlatIndex::new(DIM)),
            embed,
        )
        .unwrap()
    }

    fn commit_doc(store: &DurableStore, exec: &RetrievalExecutor, id: u64, text: &str) -> Result<()> {
        let v = embed(text)?;
        store.log_upserts(&[(id, text)], || {
            exec.upsert_batch(&[(id, v)]);
        })
    }

    #[test]
    fn acked_docs_survive_a_crash_bit_identically() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions::default();
        let (store, exec, _) = recover_all(&fs, &opts);
        for (id, text) in [(1, "alpha"), (2, "beta"), (3, "gamma")] {
            commit_doc(&store, &exec, id, text).unwrap();
        }
        store.log_delete(2, || {
            exec.remove(2);
        })
        .unwrap();
        let q = embed("alpha").unwrap();
        let want: Vec<(u64, u32)> =
            exec.search(&q, 3).iter().map(|h| (h.id, h.score.to_bits())).collect();

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (store2, exec2, report) = recover_all(&fs, &opts);
        assert_eq!(report.replayed, 4, "3 upserts + 1 delete");
        assert!(!report.from_snapshot);
        assert_eq!(exec2.len(), 2);
        let got: Vec<(u64, u32)> =
            exec2.search(&q, 3).iter().map(|h| (h.id, h.score.to_bits())).collect();
        assert_eq!(got, want, "replayed rows score bit-identically");
        assert!(got.iter().all(|(id, _)| *id != 2), "deleted id stays deleted");
        assert_eq!(store2.stats().committed_seq, 4);
    }

    #[test]
    fn snapshot_truncates_wal_and_recovery_prefers_it() {
        let fs = Arc::new(FaultFs::new());
        // Tiny segments so every commit rolls one.
        let opts = DurabilityOptions { segment_bytes: 16, ..Default::default() };
        let (store, exec, _) = recover_all(&fs, &opts);
        for i in 0..6u64 {
            commit_doc(&store, &exec, i, &format!("doc number {i}")).unwrap();
        }
        assert!(store.stats().wal_segments >= 5);
        let watermark = store.snapshot(&exec).unwrap();
        assert_eq!(watermark, 6);
        assert_eq!(store.stats().wal_segments, 0, "log fully behind the snapshot");
        // Two more commits after the checkpoint.
        commit_doc(&store, &exec, 10, "post snapshot a").unwrap();
        store.log_delete(3, || {
            exec.remove(3);
        })
        .unwrap();

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (store2, exec2, report) = recover_all(&fs, &opts);
        assert!(report.from_snapshot);
        assert_eq!(report.watermark, 6);
        assert_eq!(report.replayed, 2, "only the tail past the watermark");
        assert_eq!(exec2.len(), 6, "6 originals - 1 delete + 1 new");
        assert_eq!(store2.stats().committed_seq, 8);
        // Seqs continue past the recovered point: no reuse.
        commit_doc(&store2, &exec2, 11, "after recovery").unwrap();
        assert_eq!(store2.stats().committed_seq, 9);
    }

    #[test]
    fn wal_failure_refuses_the_ack_and_index_stays_clean() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions::default();
        let (store, exec, _) = recover_all(&fs, &opts);
        commit_doc(&store, &exec, 1, "ok").unwrap();
        // Restart with the NEXT fsync poisoned (recovery itself does no
        // mutating ops, so the first commit's append is op 0, its fsync
        // op 1): the commit must be refused and the index untouched.
        fs.restart(FaultPlan { fsync_fail_at: Some(1), ..Default::default() });
        // Re-recover on the restarted fs (the old store handle is dead).
        let (store, exec, _) = recover_all(&fs, &opts);
        let err = commit_doc(&store, &exec, 2, "will fail");
        assert!(err.is_err(), "fsync EIO must refuse the ack");
        assert_eq!(exec.len(), 1, "index not mutated on a refused commit");
        assert_eq!(store.stats().wal_append_failures, 1);
        // The store keeps working for later commits.
        commit_doc(&store, &exec, 3, "recovers").unwrap();
        assert_eq!(exec.len(), 2);
    }

    #[test]
    fn maybe_compact_fires_on_density_and_checkpoints() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions { compact_tombstone_ratio: 0.4, ..Default::default() };
        let (store, exec, _) = recover_all(&fs, &opts);
        for i in 0..10u64 {
            commit_doc(&store, &exec, i, &format!("doc {i}")).unwrap();
        }
        // 3 deletes of 10: 30% < 40% — below threshold.
        for id in [0u64, 1, 2] {
            store.log_delete(id, || {
                exec.remove(id);
            })
            .unwrap();
        }
        assert_eq!(store.maybe_compact(&exec).unwrap(), None);
        // Two more: 5/10 = 50% ≥ 40% — compact + checkpoint.
        for id in [3u64, 4] {
            store.log_delete(id, || {
                exec.remove(id);
            })
            .unwrap();
        }
        let reclaimed = store.maybe_compact(&exec).unwrap();
        assert_eq!(reclaimed, Some(5));
        assert_eq!(exec.tombstones(), 0);
        let stats = store.stats();
        assert_eq!(stats.compactions, 1);
        assert_eq!(stats.snapshots_written, 1);
        assert_eq!(stats.wal_segments, 0, "churn behind the checkpoint is gone");
        // Crash now: recovery must come entirely from the snapshot, with
        // the deleted ids gone for good.
        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (_, exec2, report) = recover_all(&fs, &opts);
        assert!(report.from_snapshot);
        assert_eq!(report.replayed, 0);
        assert_eq!(exec2.len(), 5);
        for id in 0..5u64 {
            let q = embed(&format!("doc {id}")).unwrap();
            assert!(exec2.search(&q, 5).iter().all(|h| h.id != id), "id {id} resurrected");
        }
    }

    #[test]
    fn disabled_ratio_never_compacts() {
        let fs = Arc::new(FaultFs::new());
        let opts = DurabilityOptions { compact_tombstone_ratio: 0.0, ..Default::default() };
        let (store, exec, _) = recover_all(&fs, &opts);
        commit_doc(&store, &exec, 1, "a").unwrap();
        store.log_delete(1, || {
            exec.remove(1);
        })
        .unwrap();
        assert_eq!(store.maybe_compact(&exec).unwrap(), None);
        assert_eq!(exec.tombstones(), 1);
    }
}
