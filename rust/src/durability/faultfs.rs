//! Injectable I/O layer with deterministic kill-points.
//!
//! Everything the durability subsystem does to disk goes through the
//! [`Fs`] trait: production uses [`RealFs`] (plain `std::fs`), tests use
//! [`FaultFs`] — an in-memory filesystem that models exactly the failure
//! surface a WAL cares about:
//!
//! * **durability boundary** — bytes appended but not yet `sync`ed are
//!   *unsynced*; a crash discards them (except for an optional
//!   `torn_keep` prefix, modeling a torn append where the kernel got
//!   part of the write to the platter before power failed),
//! * **kill-points** — every mutating operation increments an op
//!   counter; a [`FaultPlan`] can crash *before* op N, fail a specific
//!   `sync` with an I/O error, or short-write a specific append. Tests
//!   first run a scenario fault-free to count ops, then re-run it once
//!   per kill-point — a deterministic crash matrix with no timing
//!   dependence,
//! * **crash state** — after a crash every operation fails until
//!   [`FaultFs::restart`], which applies the durability boundary and
//!   brings the "machine" back up, exactly like a process restart over a
//!   real disk.

use std::collections::HashMap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// The slice of filesystem behavior the durability layer depends on.
/// Object-safe and `Send + Sync` so one instance can back a store shared
/// across server threads.
pub trait Fs: Send + Sync {
    /// Append `data` to `path`, creating it if absent. Appended bytes
    /// are NOT durable until [`Fs::sync`].
    fn append(&self, path: &Path, data: &[u8]) -> io::Result<()>;
    /// fsync `path`: everything appended so far survives a crash.
    fn sync(&self, path: &Path) -> io::Result<()>;
    /// Read the whole file.
    fn read(&self, path: &Path) -> io::Result<Vec<u8>>;
    /// Replace `path` with `data` atomically (tmp + rename + sync): after
    /// this returns, a crash sees either the old content or the new,
    /// never a mix.
    fn write_atomic(&self, path: &Path, data: &[u8]) -> io::Result<()>;
    /// Truncate `path` to `len` bytes and sync the new length.
    fn truncate(&self, path: &Path, len: u64) -> io::Result<()>;
    /// Delete a file (ok if it exists; error if it does not).
    fn remove(&self, path: &Path) -> io::Result<()>;
    /// File names (not full paths) directly inside `dir`, sorted.
    fn list(&self, dir: &Path) -> io::Result<Vec<String>>;
    /// `mkdir -p`.
    fn create_dir_all(&self, dir: &Path) -> io::Result<()>;
    /// Whether `path` exists as a file.
    fn exists(&self, path: &Path) -> bool;
}

// ---------------------------------------------------------------------------
// Real filesystem.

/// `std::fs`-backed [`Fs`] for production use.
pub struct RealFs;

impl Fs for RealFs {
    fn append(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(data)
    }

    fn sync(&self, path: &Path) -> io::Result<()> {
        std::fs::File::open(path)?.sync_all()
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        std::fs::read(path)
    }

    fn write_atomic(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        let tmp = path.with_extension("tmp");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(data)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        // Best-effort directory sync so the rename itself is durable.
        if let Some(dir) = path.parent() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    fn truncate(&self, path: &Path, len: u64) -> io::Result<()> {
        let f = std::fs::OpenOptions::new().write(true).open(path)?;
        f.set_len(len)?;
        f.sync_all()
    }

    fn remove(&self, path: &Path) -> io::Result<()> {
        std::fs::remove_file(path)
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            if entry.file_type()?.is_file() {
                if let Some(name) = entry.file_name().to_str() {
                    names.push(name.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        std::fs::create_dir_all(dir)
    }

    fn exists(&self, path: &Path) -> bool {
        path.is_file()
    }
}

// ---------------------------------------------------------------------------
// Fault-injecting in-memory filesystem.

/// Where and how to fail. Op numbers are 0-based positions in the
/// sequence of *mutating* operations (`append`/`sync`/`write_atomic`/
/// `truncate`/`remove`); reads and lists don't count, so recovery-side
/// reads never shift a plan's kill-points.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Crash *before* executing mutating op N: the op (and everything
    /// after) fails until [`FaultFs::restart`].
    pub crash_at_op: Option<u64>,
    /// On crash, keep this many *unsynced* bytes per file (a torn
    /// append: part of the in-flight write reached the platter). 0 = the
    /// classic "everything unsynced is gone".
    pub torn_keep: usize,
    /// Mutating op N, if it is a `sync`, returns EIO instead (the write
    /// cache could not be flushed). The op still counts.
    pub fsync_fail_at: Option<u64>,
    /// Mutating op N, if it is an `append`, writes only the first half
    /// of its bytes and then returns EIO — a short write whose partial
    /// bytes are sitting unsynced in the page cache.
    pub short_write_at: Option<u64>,
}

struct FileState {
    data: Vec<u8>,
    /// Bytes guaranteed to survive a crash.
    synced: usize,
}

struct State {
    files: HashMap<PathBuf, FileState>,
    dirs: Vec<PathBuf>,
    plan: FaultPlan,
    ops: u64,
    crashed: bool,
}

/// Deterministic in-memory [`Fs`] with injected faults. See the module
/// docs for the model.
pub struct FaultFs {
    state: Mutex<State>,
}

fn eio(msg: &str) -> io::Error {
    io::Error::other(msg.to_string())
}

impl Default for FaultFs {
    fn default() -> Self {
        FaultFs::new()
    }
}

impl FaultFs {
    pub fn new() -> FaultFs {
        FaultFs::with_plan(FaultPlan::default())
    }

    pub fn with_plan(plan: FaultPlan) -> FaultFs {
        FaultFs {
            state: Mutex::new(State {
                files: HashMap::new(),
                dirs: Vec::new(),
                plan,
                ops: 0,
                crashed: false,
            }),
        }
    }

    /// Mutating operations executed (or crashed on) so far. Run a
    /// scenario fault-free, read this, and you have the kill-point space
    /// to sweep.
    pub fn ops(&self) -> u64 {
        self.state.lock().unwrap().ops
    }

    /// Whether an injected crash has fired.
    pub fn crashed(&self) -> bool {
        self.state.lock().unwrap().crashed
    }

    /// Crash now (as if the process lost power between two ops).
    pub fn crash_now(&self) {
        self.state.lock().unwrap().crashed = true;
    }

    /// Bring the machine back up: apply the durability boundary (drop
    /// unsynced bytes, minus the plan's `torn_keep` survivors), clear the
    /// crashed flag, and install `plan` for the next life.
    pub fn restart(&self, plan: FaultPlan) {
        let mut st = self.state.lock().unwrap();
        let torn = st.plan.torn_keep;
        for f in st.files.values_mut() {
            let unsynced = f.data.len() - f.synced;
            let keep = f.synced + unsynced.min(torn);
            f.data.truncate(keep);
            // Survivors are on the platter now.
            f.synced = f.data.len();
        }
        st.plan = plan;
        st.ops = 0;
        st.crashed = false;
    }

    /// Gate every mutating op: count it, then fire any due fault.
    /// Returns the op number just consumed.
    fn gate(st: &mut State) -> io::Result<u64> {
        if st.crashed {
            return Err(eio("simulated crash: machine is down"));
        }
        let op = st.ops;
        if st.plan.crash_at_op == Some(op) {
            st.crashed = true;
            return Err(eio("simulated crash (kill-point)"));
        }
        st.ops += 1;
        Ok(op)
    }

    fn check_up(st: &State) -> io::Result<()> {
        if st.crashed {
            return Err(eio("simulated crash: machine is down"));
        }
        Ok(())
    }
}

impl Fs for FaultFs {
    fn append(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        let op = Self::gate(&mut st)?;
        let short = st.plan.short_write_at == Some(op);
        let f = st
            .files
            .entry(path.to_path_buf())
            .or_insert(FileState { data: Vec::new(), synced: 0 });
        if short {
            f.data.extend_from_slice(&data[..data.len() / 2]);
            return Err(eio("simulated short write"));
        }
        f.data.extend_from_slice(data);
        Ok(())
    }

    fn sync(&self, path: &Path) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        let op = Self::gate(&mut st)?;
        if st.plan.fsync_fail_at == Some(op) {
            return Err(eio("simulated fsync failure"));
        }
        match st.files.get_mut(path) {
            Some(f) => {
                f.synced = f.data.len();
                Ok(())
            }
            None => Err(io::Error::new(io::ErrorKind::NotFound, "no such file")),
        }
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        let st = self.state.lock().unwrap();
        Self::check_up(&st)?;
        st.files
            .get(path)
            .map(|f| f.data.clone())
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "no such file"))
    }

    fn write_atomic(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        Self::gate(&mut st)?;
        // Atomic by construction: old content until the op succeeds, new
        // content (fully synced) after.
        st.files
            .insert(path.to_path_buf(), FileState { data: data.to_vec(), synced: data.len() });
        Ok(())
    }

    fn truncate(&self, path: &Path, len: u64) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        Self::gate(&mut st)?;
        match st.files.get_mut(path) {
            Some(f) => {
                f.data.truncate(len as usize);
                f.synced = f.data.len();
                Ok(())
            }
            None => Err(io::Error::new(io::ErrorKind::NotFound, "no such file")),
        }
    }

    fn remove(&self, path: &Path) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        Self::gate(&mut st)?;
        match st.files.remove(path) {
            Some(_) => Ok(()),
            None => Err(io::Error::new(io::ErrorKind::NotFound, "no such file")),
        }
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<String>> {
        let st = self.state.lock().unwrap();
        Self::check_up(&st)?;
        let mut names: Vec<String> = st
            .files
            .keys()
            .filter(|p| p.parent() == Some(dir))
            .filter_map(|p| p.file_name().and_then(|n| n.to_str()).map(String::from))
            .collect();
        names.sort();
        Ok(names)
    }

    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        Self::check_up(&st)?;
        if !st.dirs.iter().any(|d| d == dir) {
            st.dirs.push(dir.to_path_buf());
        }
        Ok(())
    }

    fn exists(&self, path: &Path) -> bool {
        self.state.lock().unwrap().files.contains_key(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn p(s: &str) -> PathBuf {
        PathBuf::from(s)
    }

    #[test]
    fn unsynced_bytes_die_in_a_crash_synced_survive() {
        let fs = FaultFs::new();
        fs.append(&p("/d/wal"), b"abcd").unwrap();
        fs.sync(&p("/d/wal")).unwrap();
        fs.append(&p("/d/wal"), b"efgh").unwrap();
        fs.crash_now();
        assert!(fs.read(&p("/d/wal")).is_err(), "reads fail while down");
        fs.restart(FaultPlan::default());
        assert_eq!(fs.read(&p("/d/wal")).unwrap(), b"abcd");
    }

    #[test]
    fn torn_keep_leaves_a_partial_tail() {
        let fs = FaultFs::with_plan(FaultPlan { torn_keep: 2, ..Default::default() });
        fs.append(&p("/d/wal"), b"abcd").unwrap();
        fs.sync(&p("/d/wal")).unwrap();
        fs.append(&p("/d/wal"), b"efgh").unwrap();
        fs.crash_now();
        fs.restart(FaultPlan::default());
        assert_eq!(fs.read(&p("/d/wal")).unwrap(), b"abcdef");
    }

    #[test]
    fn crash_at_op_fires_deterministically() {
        // Fault-free run counts ops.
        let fs = FaultFs::new();
        fs.append(&p("/w"), b"x").unwrap();
        fs.sync(&p("/w")).unwrap();
        fs.append(&p("/w"), b"y").unwrap();
        assert_eq!(fs.ops(), 3);
        // Crash before op 2: the second append never lands.
        let fs = FaultFs::with_plan(FaultPlan { crash_at_op: Some(2), ..Default::default() });
        fs.append(&p("/w"), b"x").unwrap();
        fs.sync(&p("/w")).unwrap();
        assert!(fs.append(&p("/w"), b"y").is_err());
        assert!(fs.crashed());
        assert!(fs.append(&p("/w"), b"z").is_err(), "down until restart");
        fs.restart(FaultPlan::default());
        assert_eq!(fs.read(&p("/w")).unwrap(), b"x");
    }

    #[test]
    fn fsync_failure_and_short_write_inject() {
        let fs = FaultFs::with_plan(FaultPlan { fsync_fail_at: Some(1), ..Default::default() });
        fs.append(&p("/w"), b"abcd").unwrap();
        assert!(fs.sync(&p("/w")).is_err(), "injected EIO");
        assert!(!fs.crashed(), "fsync failure is an error, not a crash");
        // The bytes are still unsynced: a later crash eats them.
        fs.crash_now();
        fs.restart(FaultPlan::default());
        assert_eq!(fs.read(&p("/w")).unwrap(), b"");

        let fs = FaultFs::with_plan(FaultPlan { short_write_at: Some(0), ..Default::default() });
        assert!(fs.append(&p("/w"), b"abcdef").is_err());
        assert_eq!(fs.read(&p("/w")).unwrap(), b"abc", "half landed in cache");
    }

    #[test]
    fn write_atomic_is_all_or_nothing() {
        let fs = FaultFs::new();
        fs.write_atomic(&p("/snap"), b"v1").unwrap();
        // Crash at the op: old content intact.
        fs.restart(FaultPlan { crash_at_op: Some(0), ..Default::default() });
        assert!(fs.write_atomic(&p("/snap"), b"v2").is_err());
        fs.restart(FaultPlan::default());
        assert_eq!(fs.read(&p("/snap")).unwrap(), b"v1");
        // Success: new content, durable with no explicit sync.
        fs.write_atomic(&p("/snap"), b"v2").unwrap();
        fs.crash_now();
        fs.restart(FaultPlan::default());
        assert_eq!(fs.read(&p("/snap")).unwrap(), b"v2");
    }

    #[test]
    fn list_and_remove_scope_to_directory() {
        let fs = FaultFs::new();
        fs.create_dir_all(&p("/data/wal")).unwrap();
        fs.append(&p("/data/wal/wal-0.log"), b"a").unwrap();
        fs.append(&p("/data/wal/wal-1.log"), b"b").unwrap();
        fs.append(&p("/data/other"), b"c").unwrap();
        assert_eq!(fs.list(&p("/data/wal")).unwrap(), vec!["wal-0.log", "wal-1.log"]);
        fs.remove(&p("/data/wal/wal-0.log")).unwrap();
        assert_eq!(fs.list(&p("/data/wal")).unwrap(), vec!["wal-1.log"]);
        assert!(fs.remove(&p("/data/wal/wal-0.log")).is_err());
        assert!(fs.exists(&p("/data/wal/wal-1.log")));
        assert!(!fs.exists(&p("/data/wal/wal-0.log")));
    }
}
