//! Snapshot files: the checkpoint that lets the WAL truncate.
//!
//! A snapshot is the serialized index (see `vecstore::persist`) plus the
//! WAL sequence number it covers (its *watermark*): every logged record
//! with `seq <= watermark` is reflected in the payload, so after a
//! snapshot lands the log behind the watermark is dead weight.
//!
//! Files are `snap-<watermark>.snap`, written atomically (tmp + rename
//! via [`Fs::write_atomic`]) and CRC-protected:
//!
//! ```text
//! [magic "WVSN"][version u8][watermark u64][crc32(payload) u32][payload]
//! ```
//!
//! [`load_newest`] walks snapshots newest-first and returns the first
//! one that verifies — a crash mid-snapshot leaves either no new file
//! (rename never happened) or a complete one, and a corrupt file is
//! skipped in favor of the previous checkpoint rather than trusted.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::faultfs::Fs;
use super::wal::crc32;

const MAGIC: &[u8; 4] = b"WVSN";
const VERSION: u8 = 1;

fn snapshot_name(watermark: u64) -> String {
    format!("snap-{watermark:016x}.snap")
}

fn parse_snapshot_name(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("snap-")?.strip_suffix(".snap")?;
    u64::from_str_radix(hex, 16).ok()
}

fn encode(watermark: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(17 + payload.len());
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&watermark.to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn decode(bytes: &[u8]) -> Option<(u64, Vec<u8>)> {
    if bytes.len() < 17 || &bytes[0..4] != MAGIC || bytes[4] != VERSION {
        return None;
    }
    let watermark = u64::from_le_bytes(bytes[5..13].try_into().unwrap());
    let crc = u32::from_le_bytes(bytes[13..17].try_into().unwrap());
    let payload = &bytes[17..];
    if crc32(payload) != crc {
        return None;
    }
    Some((watermark, payload.to_vec()))
}

/// Write a snapshot covering `watermark`, then delete every older
/// snapshot file (the new one is already durable — `write_atomic`
/// syncs). Returns the path written.
pub fn write(fs: &Arc<dyn Fs>, dir: &Path, watermark: u64, payload: &[u8]) -> io::Result<PathBuf> {
    fs.create_dir_all(dir)?;
    let path = dir.join(snapshot_name(watermark));
    fs.write_atomic(&path, &encode(watermark, payload))?;
    for name in fs.list(dir)? {
        if let Some(w) = parse_snapshot_name(&name) {
            if w < watermark {
                // Older checkpoints are strictly dominated; best-effort
                // removal (a leftover is re-collected next time).
                let _ = fs.remove(&dir.join(name));
            }
        }
    }
    Ok(path)
}

/// Load the newest snapshot that verifies: `(watermark, index payload)`,
/// or `None` when no usable snapshot exists. Corrupt candidates are
/// skipped (never deleted here — recovery stays read-only).
pub fn load_newest(fs: &Arc<dyn Fs>, dir: &Path) -> io::Result<Option<(u64, Vec<u8>)>> {
    let mut marks: Vec<u64> = match fs.list(dir) {
        Ok(names) => names.iter().filter_map(|n| parse_snapshot_name(n)).collect(),
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    };
    marks.sort_unstable_by(|a, b| b.cmp(a));
    for w in marks {
        let bytes = fs.read(&dir.join(snapshot_name(w)))?;
        if let Some(found) = decode(&bytes) {
            return Ok(Some(found));
        }
        log::warn!("durability: snapshot {} failed verification, skipping", snapshot_name(w));
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::super::faultfs::{FaultFs, FaultPlan};
    use super::*;
    use std::path::PathBuf;

    fn dir() -> PathBuf {
        PathBuf::from("/snaps")
    }

    fn fx() -> Arc<dyn Fs> {
        Arc::new(FaultFs::new())
    }

    #[test]
    fn write_then_load_roundtrips_and_prunes_older() {
        let fs = fx();
        write(&fs, &dir(), 5, b"five").unwrap();
        write(&fs, &dir(), 9, b"nine").unwrap();
        assert_eq!(load_newest(&fs, &dir()).unwrap(), Some((9, b"nine".to_vec())));
        // The older file was pruned.
        assert!(!fs.exists(&dir().join(snapshot_name(5))));
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous() {
        let fs = fx();
        write(&fs, &dir(), 5, b"five").unwrap();
        // Hand-craft a newer snapshot with a bad CRC (bypassing prune).
        let mut bad = encode(9, b"nine");
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        fs.write_atomic(&dir().join(snapshot_name(9)), &bad).unwrap();
        assert_eq!(load_newest(&fs, &dir()).unwrap(), Some((5, b"five".to_vec())));
    }

    #[test]
    fn crash_during_write_keeps_the_old_checkpoint() {
        let fs: Arc<FaultFs> = Arc::new(FaultFs::new());
        let dynfs: Arc<dyn Fs> = fs.clone();
        write(&dynfs, &dir(), 3, b"three").unwrap();
        // Crash exactly at the atomic write of the next snapshot
        // (restart zeroes the op counter; the `write_atomic` is op 0).
        fs.restart(FaultPlan { crash_at_op: Some(0), ..Default::default() });
        assert!(write(&dynfs, &dir(), 7, b"seven").is_err());
        fs.restart(FaultPlan::default());
        assert_eq!(load_newest(&dynfs, &dir()).unwrap(), Some((3, b"three".to_vec())));
    }

    #[test]
    fn empty_dir_loads_none() {
        let fs = fx();
        assert_eq!(load_newest(&fs, &dir()).unwrap(), None);
    }
}
