//! The paper's §3 deployment-cost model (Eqs. 1-6) and the §3.2 savings
//! bounds for CPU peak-query offloading.

/// Inputs shared by both deployment strategies.
#[derive(Debug, Clone, Copy)]
pub struct CostInputs {
    /// Devices per serving instance (paper D).
    pub devices_per_instance: f64,
    /// Price per device, $ (paper P).
    pub price_per_device: f64,
}

/// Eq. 4: how many other queries can be processed while one waits,
/// given the max acceptable total latency and the per-query processing
/// time: `n = floor((t_total_max - t_proc) / t_proc)`.
pub fn waiting_slots(t_total_max: f64, t_proc: f64) -> u64 {
    assert!(t_proc > 0.0, "t_proc must be positive");
    if t_total_max <= t_proc {
        return 0;
    }
    ((t_total_max - t_proc) / t_proc).floor() as u64
}

/// Eq. 5: average-rate deployment cost. `n_per_sec` = queries/s received
/// (paper N), `n_slots` = Eq. 4's n, `throughput` = queries/s one
/// instance sustains (paper T).
pub fn cost_average(n_per_sec: f64, n_slots: u64, throughput: f64, inp: CostInputs) -> f64 {
    assert!(throughput > 0.0 && n_slots > 0);
    (n_per_sec / n_slots as f64) / throughput
        * inp.devices_per_instance
        * inp.price_per_device
}

/// Eq. 6: peak-provisioned deployment cost. `n_peak` = peak concurrent
/// queries (paper N_peak), `capacity` = instance max concurrency (C).
pub fn cost_peak(n_peak: f64, capacity: f64, inp: CostInputs) -> f64 {
    assert!(capacity > 0.0);
    (n_peak / capacity) * inp.devices_per_instance * inp.price_per_device
}

/// §3.2: fractional cost saved under *peak* provisioning when offloading
/// lifts capacity from C_NPU to C_NPU + C_CPU:
/// `C_CPU / (C_CPU + C_NPU)`.
pub fn savings_peak(c_npu: usize, c_cpu: usize) -> f64 {
    if c_npu + c_cpu == 0 {
        return 0.0;
    }
    c_cpu as f64 / (c_cpu + c_npu) as f64
}

/// §3.2: throughput (and max cost) improvement under *average*
/// provisioning: `C_CPU / C_NPU`.
pub fn improvement_average(c_npu: usize, c_cpu: usize) -> f64 {
    assert!(c_npu > 0);
    c_cpu as f64 / c_npu as f64
}

/// Theoretical offloading-gain ceiling, Inequality 19:
/// `C_CPU / C_NPU < α_NPU / α_CPU`. Returns the bound.
pub fn concurrency_gain_bound(alpha_npu: f64, alpha_cpu: f64) -> f64 {
    assert!(alpha_cpu > 0.0);
    alpha_npu / alpha_cpu
}

#[cfg(test)]
mod tests {
    use super::*;

    const INP: CostInputs = CostInputs { devices_per_instance: 1.0, price_per_device: 10_000.0 };

    #[test]
    fn waiting_slots_eq4() {
        // t_max = 1s, t_proc = 0.3s → n = floor(0.7/0.3) = 2
        assert_eq!(waiting_slots(1.0, 0.3), 2);
        assert_eq!(waiting_slots(1.0, 1.0), 0);
        assert_eq!(waiting_slots(2.0, 0.5), 3);
        assert_eq!(waiting_slots(0.5, 1.0), 0);
    }

    #[test]
    fn average_cost_scales_with_load() {
        let c1 = cost_average(100.0, 2, 10.0, INP);
        let c2 = cost_average(200.0, 2, 10.0, INP);
        assert!((c2 / c1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn peak_cost_inverse_in_capacity() {
        let base = cost_peak(1000.0, 44.0, INP);
        let boosted = cost_peak(1000.0, 52.0, INP); // 44 + 8 offloaded
        assert!(boosted < base);
        let saved = 1.0 - boosted / base;
        // paper: 8/(44+8) = 15.4% at 1s... and 22/(96+22) = 18.6% at 2s.
        assert!((saved - savings_peak(44, 8)).abs() < 1e-9);
    }

    #[test]
    fn paper_headline_numbers() {
        // Table 1 bge @ 2s: 96 + 22 → 18.6% peak savings, 22.9% throughput.
        assert!((savings_peak(96, 22) - 0.186).abs() < 0.005);
        assert!((improvement_average(96, 22) - 0.229).abs() < 0.005);
        // Table 2 jina @ 2s: 112 + 30 → 21.1% / 26.7%.
        assert!((savings_peak(112, 30) - 0.211).abs() < 0.005);
        assert!((improvement_average(112, 30) - 0.267).abs() < 0.005);
    }

    #[test]
    fn gain_bound_ineq19() {
        // V100/Xeon: α ratio ≈ 0.195 bounds C_CPU/C_NPU; observed
        // 8/44 = 0.18 respects the bound.
        let bound = concurrency_gain_bound(0.0166, 0.085);
        assert!(8.0 / 44.0 < bound);
    }

    #[test]
    fn zero_capacity_degenerate() {
        assert_eq!(savings_peak(0, 0), 0.0);
        assert_eq!(savings_peak(10, 0), 0.0);
    }
}
