//! Multi-instance load balancing within a device class.
//!
//! Algorithm 2 allows `worker_num_main = I` NPU instances; the paper
//! keeps the per-class queue single (one queue feeding I workers is
//! naturally work-conserving). For deployments that want *partitioned*
//! queues (per-card VRAM isolation, §4.3's one-instance-per-machine CPU
//! guidance), this module provides the dispatch policies to choose the
//! instance: round-robin and least-loaded (join-shortest-queue).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Instance-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    /// Join-shortest-queue over reported instance loads.
    LeastLoaded,
}

/// Balancer over `n` instances of one device class.
pub struct Balancer {
    policy: Policy,
    rr: AtomicUsize,
    loads: Vec<AtomicUsize>,
}

impl Balancer {
    pub fn new(n: usize, policy: Policy) -> Balancer {
        assert!(n > 0);
        Balancer {
            policy,
            rr: AtomicUsize::new(0),
            loads: (0..n).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.loads.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Pick the instance for the next query and bump its load. Pair with
    /// [`Balancer::complete`].
    pub fn pick(&self) -> usize {
        let idx = match self.policy {
            Policy::RoundRobin => self.rr.fetch_add(1, Ordering::Relaxed) % self.loads.len(),
            Policy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = usize::MAX;
                for (i, l) in self.loads.iter().enumerate() {
                    let v = l.load(Ordering::Relaxed);
                    if v < best_load {
                        best = i;
                        best_load = v;
                    }
                }
                best
            }
        };
        self.loads[idx].fetch_add(1, Ordering::AcqRel);
        idx
    }

    /// Report a query finished on `idx`.
    pub fn complete(&self, idx: usize) {
        let prev = self.loads[idx].fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0);
    }

    pub fn load(&self, idx: usize) -> usize {
        self.loads[idx].load(Ordering::Relaxed)
    }

    pub fn total_load(&self) -> usize {
        self.loads.iter().map(|l| l.load(Ordering::Relaxed)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let b = Balancer::new(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| b.pick()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let b = Balancer::new(3, Policy::LeastLoaded);
        let a = b.pick(); // load [1,0,0] → 0
        let c = b.pick(); // → 1
        let d = b.pick(); // → 2
        assert_eq!((a, c, d), (0, 1, 2));
        b.complete(1); // loads [1,0,1]
        assert_eq!(b.pick(), 1);
    }

    #[test]
    fn least_loaded_balances_unequal_service_times() {
        // Instance 0's queries never complete; everything else should
        // drift to instances 1 and 2.
        let b = Balancer::new(3, Policy::LeastLoaded);
        let mut on_zero = 0;
        for _ in 0..30 {
            let i = b.pick();
            if i == 0 {
                on_zero += 1; // stuck: never complete
            } else {
                b.complete(i);
            }
        }
        assert!(on_zero <= 2, "slow instance took {on_zero} picks");
    }

    #[test]
    fn load_accounting_consistent_under_threads() {
        use std::sync::Arc;
        let b = Arc::new(Balancer::new(4, Policy::LeastLoaded));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        let i = b.pick();
                        b.complete(i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(b.total_load(), 0);
    }
}
