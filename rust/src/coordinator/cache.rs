//! Embedding cache: LRU over token-stream hashes.
//!
//! The paper's motivation (§1) notes the embedding service is called
//! "tens of millions of times within a month" with every request passing
//! through it online; production RAG traffic repeats queries heavily
//! (reformulations, pagination, retries). A cache in front of the queue
//! manager serves repeats without consuming NPU/CPU queue slots — a
//! natural WindVE extension that compounds the concurrency gains.
//!
//! Keyed by the FNV-1a hash of the *token stream* (not raw text), so
//! "Hello, World" and "hello world" share an entry exactly when they
//! embed identically.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::runtime::tokenizer;

/// Thread-safe LRU embedding cache.
pub struct EmbeddingCache {
    inner: Mutex<Lru>,
}

struct Lru {
    capacity: usize,
    map: HashMap<u64, Node>,
    /// Monotone access clock (usize ticks; eviction = smallest tick).
    clock: u64,
    hits: u64,
    misses: u64,
}

struct Node {
    vector: Vec<f32>,
    last_used: u64,
}

impl EmbeddingCache {
    pub fn new(capacity: usize) -> EmbeddingCache {
        EmbeddingCache {
            inner: Mutex::new(Lru {
                capacity,
                map: HashMap::new(),
                clock: 0,
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Cache key for a query: hash of its normalised token ids.
    pub fn key(text: &str, vocab_size: u32, max_len: usize) -> u64 {
        let e = tokenizer::encode(text, vocab_size, max_len);
        let mut bytes = Vec::with_capacity(e.len * 4);
        for id in &e.ids[..e.len] {
            bytes.extend_from_slice(&id.to_le_bytes());
        }
        tokenizer::fnv1a64(&bytes)
    }

    pub fn get(&self, key: u64) -> Option<Vec<f32>> {
        let mut lru = self.inner.lock().unwrap();
        lru.clock += 1;
        let clock = lru.clock;
        match lru.map.get_mut(&key) {
            Some(node) => {
                node.last_used = clock;
                let v = node.vector.clone();
                lru.hits += 1;
                Some(v)
            }
            None => {
                lru.misses += 1;
                None
            }
        }
    }

    pub fn put(&self, key: u64, vector: Vec<f32>) {
        let mut lru = self.inner.lock().unwrap();
        if lru.capacity == 0 {
            return;
        }
        lru.clock += 1;
        let clock = lru.clock;
        if lru.map.len() >= lru.capacity && !lru.map.contains_key(&key) {
            // Evict the least recently used entry.
            if let Some((&victim, _)) = lru.map.iter().min_by_key(|(_, n)| n.last_used) {
                lru.map.remove(&victim);
            }
        }
        lru.map.insert(key, Node { vector, last_used: clock });
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses, hit-rate).
    pub fn stats(&self) -> (u64, u64, f64) {
        let lru = self.inner.lock().unwrap();
        let total = lru.hits + lru.misses;
        let rate = if total == 0 { 0.0 } else { lru.hits as f64 / total as f64 };
        (lru.hits, lru.misses, rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_put() {
        let c = EmbeddingCache::new(8);
        let k = EmbeddingCache::key("hello world", 8192, 80);
        assert!(c.get(k).is_none());
        c.put(k, vec![1.0, 2.0]);
        assert_eq!(c.get(k), Some(vec![1.0, 2.0]));
        let (h, m, rate) = c.stats();
        assert_eq!((h, m), (1, 1));
        assert!((rate - 0.5).abs() < 1e-9);
    }

    #[test]
    fn normalised_texts_share_entries() {
        let a = EmbeddingCache::key("Hello, World!", 8192, 80);
        let b = EmbeddingCache::key("hello world", 8192, 80);
        let c = EmbeddingCache::key("hello worlds", 8192, 80);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn evicts_least_recently_used() {
        let c = EmbeddingCache::new(2);
        c.put(1, vec![1.0]);
        c.put(2, vec![2.0]);
        assert!(c.get(1).is_some()); // touch 1 → 2 becomes LRU
        c.put(3, vec![3.0]);
        assert!(c.get(2).is_none(), "2 should be evicted");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn zero_capacity_never_stores() {
        let c = EmbeddingCache::new(0);
        c.put(1, vec![1.0]);
        assert!(c.get(1).is_none());
    }

    #[test]
    fn concurrent_access_consistent() {
        use std::sync::Arc;
        let c = Arc::new(EmbeddingCache::new(64));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let k = i % 32;
                        if let Some(v) = c.get(k) {
                            assert_eq!(v[0] as u64, k, "thread {t} read torn value");
                        } else {
                            c.put(k, vec![k as f32]);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= 64);
    }
}
