//! Embedding cache: LRU over token-stream hashes.
//!
//! The paper's motivation (§1) notes the embedding service is called
//! "tens of millions of times within a month" with every request passing
//! through it online; production RAG traffic repeats queries heavily
//! (reformulations, pagination, retries). A cache in front of the queue
//! manager serves repeats without consuming NPU/CPU queue slots — a
//! natural WindVE extension that compounds the concurrency gains.
//!
//! Keyed by the FNV-1a hash of the *token stream* (not raw text), so
//! "Hello, World" and "hello world" share an entry exactly when they
//! embed identically.
//!
//! Recency is an intrusive doubly-linked list threaded through a slab of
//! nodes (`prev`/`next` are slab indices, not pointers), so `get`, `put`,
//! and eviction are all O(1) under the mutex. The previous implementation
//! scanned every entry for the minimum access tick on each eviction —
//! O(n) work holding the hot-path lock, which at production capacities
//! turned the cache from a latency shield into a latency source once it
//! filled. Misses leave recency untouched: only hits and inserts reorder
//! the list, so a flood of unique (uncacheable) queries cannot reshuffle
//! which resident entry is considered least recent.

use std::collections::HashMap;

use crate::runtime::tokenizer;
// Loom-switchable mutex: the stats-snapshot consistency argument below is
// model-checked by tests/loom_admission.rs (cache scenarios).
use crate::util::sync::{Mutex, MutexGuard};

/// Slab index sentinel for "no node".
const NIL: usize = usize::MAX;

/// Thread-safe LRU embedding cache.
pub struct EmbeddingCache {
    inner: Mutex<Lru>,
}

/// Point-in-time counter snapshot (see [`EmbeddingCache::snapshot`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub hit_rate: f64,
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
}

struct Lru {
    capacity: usize,
    /// key → slab slot.
    map: HashMap<u64, usize>,
    slots: Vec<Node>,
    /// Recycled slab slots (evicted entries).
    free: Vec<usize>,
    /// Most recently used slot (NIL when empty).
    head: usize,
    /// Least recently used slot (NIL when empty) — the eviction victim.
    tail: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

struct Node {
    key: u64,
    vector: Vec<f32>,
    prev: usize,
    next: usize,
}

impl Lru {
    /// Detach slot `i` from the recency list (it stays in the slab).
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
    }

    /// Attach slot `i` at the most-recently-used end.
    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn touch(&mut self, i: usize) {
        if self.head != i {
            self.unlink(i);
            self.push_front(i);
        }
    }
}

impl EmbeddingCache {
    pub fn new(capacity: usize) -> EmbeddingCache {
        EmbeddingCache {
            inner: Mutex::new(Lru {
                capacity,
                map: HashMap::new(),
                slots: Vec::new(),
                free: Vec::new(),
                head: NIL,
                tail: NIL,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    /// Cache key for a query: hash of its normalised token ids.
    pub fn key(text: &str, vocab_size: u32, max_len: usize) -> u64 {
        let e = tokenizer::encode(text, vocab_size, max_len);
        let mut bytes = Vec::with_capacity(e.len * 4);
        for id in &e.ids[..e.len] {
            bytes.extend_from_slice(&id.to_le_bytes());
        }
        tokenizer::fnv1a64(&bytes)
    }

    /// Take the cache lock, recovering from poisoning. Every panic point
    /// under this lock leaves the structure consistent: the intrusive
    /// list/slab updates are infallible index writes, and the only
    /// fallible operations (map/slab allocation in `put`) sit at seams
    /// where bailing out mid-`put` at worst leaks one slab slot — it
    /// loses a cache entry, never corrupts lookup. A poisoned *cache*
    /// must therefore not take down request threads: it is a shield in
    /// front of admission, not a source of truth.
    fn lock(&self) -> MutexGuard<'_, Lru> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn get(&self, key: u64) -> Option<Vec<f32>> {
        let mut lru = self.lock();
        match lru.map.get(&key).copied() {
            Some(i) => {
                lru.touch(i);
                lru.hits += 1;
                Some(lru.slots[i].vector.clone())
            }
            None => {
                lru.misses += 1;
                None
            }
        }
    }

    pub fn put(&self, key: u64, vector: Vec<f32>) {
        let mut lru = self.lock();
        if lru.capacity == 0 {
            return;
        }
        if let Some(i) = lru.map.get(&key).copied() {
            // Refresh in place: a re-put is a use.
            lru.slots[i].vector = vector;
            lru.touch(i);
            return;
        }
        if lru.map.len() >= lru.capacity {
            // Evict the least recently used entry; its slot is recycled
            // for the insert below, so the slab never outgrows capacity.
            let victim = lru.tail;
            debug_assert_ne!(victim, NIL, "full cache has a tail");
            lru.unlink(victim);
            let vkey = lru.slots[victim].key;
            lru.map.remove(&vkey);
            lru.slots[victim].vector = Vec::new();
            lru.free.push(victim);
            lru.evictions += 1;
        }
        let i = match lru.free.pop() {
            Some(i) => {
                lru.slots[i] = Node { key, vector, prev: NIL, next: NIL };
                i
            }
            None => {
                lru.slots.push(Node { key, vector, prev: NIL, next: NIL });
                lru.slots.len() - 1
            }
        };
        lru.map.insert(key, i);
        lru.push_front(i);
    }

    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses, hit-rate).
    pub fn stats(&self) -> (u64, u64, f64) {
        let s = self.snapshot();
        (s.hits, s.misses, s.hit_rate)
    }

    /// Consistent point-in-time snapshot of every counter: taken under
    /// the one mutex, so `hits + misses` always equals the number of
    /// completed `get` calls, however many threads are hammering the
    /// cache.
    pub fn snapshot(&self) -> CacheStats {
        let lru = self.lock();
        let total = lru.hits + lru.misses;
        CacheStats {
            hits: lru.hits,
            misses: lru.misses,
            hit_rate: if total == 0 { 0.0 } else { lru.hits as f64 / total as f64 },
            evictions: lru.evictions,
            entries: lru.map.len(),
            capacity: lru.capacity,
        }
    }

    /// Zero the hit/miss/eviction counters, leaving the cached entries
    /// (and their recency order) untouched — windowed hit-rate probes
    /// must not have to dump the cache to reset their denominator.
    pub fn reset_stats(&self) {
        let mut lru = self.lock();
        lru.hits = 0;
        lru.misses = 0;
        lru.evictions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_put() {
        let c = EmbeddingCache::new(8);
        let k = EmbeddingCache::key("hello world", 8192, 80);
        assert!(c.get(k).is_none());
        c.put(k, vec![1.0, 2.0]);
        assert_eq!(c.get(k), Some(vec![1.0, 2.0]));
        let (h, m, rate) = c.stats();
        assert_eq!((h, m), (1, 1));
        assert!((rate - 0.5).abs() < 1e-9);
    }

    #[test]
    fn normalised_texts_share_entries() {
        let a = EmbeddingCache::key("Hello, World!", 8192, 80);
        let b = EmbeddingCache::key("hello world", 8192, 80);
        let c = EmbeddingCache::key("hello worlds", 8192, 80);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn evicts_least_recently_used() {
        let c = EmbeddingCache::new(2);
        c.put(1, vec![1.0]);
        c.put(2, vec![2.0]);
        assert!(c.get(1).is_some()); // touch 1 → 2 becomes LRU
        c.put(3, vec![3.0]);
        assert!(c.get(2).is_none(), "2 should be evicted");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.len(), 2);
        assert_eq!(c.snapshot().evictions, 1);
    }

    /// Regression for the old clock-based eviction: a miss must not count
    /// as "recency activity". Here key 1 is the most recently *hit* entry
    /// even though thousands of misses happen after key 2's insert — the
    /// eviction victim must still be 2.
    #[test]
    fn misses_do_not_perturb_recency() {
        let c = EmbeddingCache::new(2);
        c.put(1, vec![1.0]);
        c.put(2, vec![2.0]);
        assert!(c.get(1).is_some());
        for probe in 100..1100u64 {
            assert!(c.get(probe).is_none());
        }
        c.put(3, vec![3.0]);
        assert!(c.get(2).is_none(), "2 was least recently used");
        assert!(c.get(1).is_some(), "1 was touched after 2");
    }

    /// Re-putting an existing key refreshes both value and recency
    /// without consuming a slot or inflating the eviction count.
    #[test]
    fn reput_refreshes_in_place() {
        let c = EmbeddingCache::new(2);
        c.put(1, vec![1.0]);
        c.put(2, vec![2.0]);
        c.put(1, vec![1.5]); // 2 is now LRU
        c.put(3, vec![3.0]);
        assert_eq!(c.get(1), Some(vec![1.5]));
        assert!(c.get(2).is_none());
        assert_eq!(c.len(), 2);
        assert_eq!(c.snapshot().evictions, 1);
    }

    #[test]
    fn zero_capacity_never_stores() {
        let c = EmbeddingCache::new(0);
        c.put(1, vec![1.0]);
        assert!(c.get(1).is_none());
    }

    #[test]
    fn reset_stats_keeps_entries() {
        let c = EmbeddingCache::new(4);
        c.put(1, vec![1.0]);
        assert!(c.get(1).is_some());
        assert!(c.get(9).is_none());
        c.reset_stats();
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 0, 0));
        assert_eq!(s.entries, 1, "reset must not drop entries");
        assert_eq!(c.get(1), Some(vec![1.0]));
    }

    /// Under concurrent load every `get` settles as exactly one hit or
    /// one miss, and the eviction count matches inserts minus residents —
    /// the counters are taken under the same lock as the mutation, so a
    /// snapshot can never observe a torn intermediate state.
    #[test]
    fn concurrent_access_consistent() {
        use std::sync::Arc;
        let c = Arc::new(EmbeddingCache::new(64));
        let gets = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let c = Arc::clone(&c);
                let gets = Arc::clone(&gets);
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let k = i % 32;
                        gets.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if let Some(v) = c.get(k) {
                            assert_eq!(v[0] as u64, k, "thread {t} read torn value");
                        } else {
                            c.put(k, vec![k as f32]);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= 64);
        let s = c.snapshot();
        assert_eq!(
            s.hits + s.misses,
            gets.load(std::sync::atomic::Ordering::Relaxed),
            "every get is exactly one hit or one miss"
        );
        // 32 distinct keys under capacity 64: nothing ever evicts.
        assert_eq!(s.evictions, 0);
        assert_eq!(s.capacity, 64);
    }
}
