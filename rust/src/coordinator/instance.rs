//! Worker instances: one OS thread per instance, each owning its own
//! model copy (paper §4.1: "Each instance employs its own model copy").
//!
//! Backends are constructed *on* the worker thread via a factory because
//! PJRT handles are not `Send`. Workers contain failures: a panicking or
//! erroring backend call fails only the queries in that batch (reported
//! as `Backend` errors to their callers) and the worker keeps serving —
//! exercised by `rust/tests/failure_injection.rs`.

use std::panic::AssertUnwindSafe;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::batcher::DeviceQueue;
use crate::coordinator::queue_manager::{QueueManager, Route, WorkClass};
use crate::devices::executor::Backend;
use crate::devices::affinity;
use crate::metrics::trace::{ClassLabel, CodecLabel, RouteLabel, Stage, Tracer};
use crate::metrics::Registry;

/// Trace label for an admission work class.
pub fn class_label(class: WorkClass) -> ClassLabel {
    match class {
        WorkClass::Embed => ClassLabel::Embed,
        WorkClass::Retrieve => ClassLabel::Retrieve,
        WorkClass::Ingest => ClassLabel::Ingest,
    }
}

/// Trace label for a dispatch route (`Busy` never reaches a worker).
pub fn route_label(route: Route) -> RouteLabel {
    match route {
        Route::Npu => RouteLabel::Npu,
        Route::Cpu => RouteLabel::Cpu,
        Route::Busy => RouteLabel::All,
    }
}

/// What a query's submitter receives.
pub type Reply = Sender<Result<Vec<f32>, String>>;

/// Factory building the worker's backend on its own thread.
pub type BackendFactory = Box<dyn FnOnce() -> anyhow::Result<Box<dyn Backend>> + Send>;

/// Spawn one worker draining `queue`, releasing `route` slots on `qm`.
///
/// `pin_cores`: optional CPU affinity set (paper §4.4 reversed/NUMA-local
/// picking is done by the service; this just applies it).
pub fn spawn_worker(
    name: String,
    queue: Arc<DeviceQueue<Reply>>,
    qm: Arc<QueueManager>,
    route: Route,
    factory: BackendFactory,
    metrics: Registry,
    tracer: Option<Arc<Tracer>>,
    pin_cores: Option<Vec<usize>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(name.clone())
        .spawn(move || {
            if let Some(cores) = pin_cores {
                if let Err(e) = affinity::pin_current_thread(&cores) {
                    log::warn!("{name}: affinity pin failed: {e:#}");
                }
            }
            let mut backend = match factory() {
                Ok(b) => b,
                Err(e) => {
                    // Fail every query this queue will ever see.
                    log::error!("{name}: backend init failed: {e:#}");
                    while let Some(batch) = queue.drain_batch(64) {
                        for p in batch {
                            qm.release_class(p.class, route, 1);
                            let _ = p.reply.send(Err(format!("backend init failed: {e:#}")));
                        }
                    }
                    return;
                }
            };
            log::info!("{name}: serving with {}", backend.describe());
            let lat = metrics.histogram(&format!("worker.{name}.batch_ns"));
            let batches = metrics.counter(&format!("worker.{name}.batches"));
            let queries = metrics.counter(&format!("worker.{name}.queries"));
            let failures = metrics.counter(&format!("worker.{name}.failures"));

            while let Some(batch) = queue.drain_batch(backend.max_batch()) {
                let drained = std::time::Instant::now();
                // Take ownership of the texts (Arc-shared — no per-query
                // payload clone on the hot path); keep each query's
                // (class, trace, enqueued, reply) alongside so its slot
                // is released under the admission class that acquired it
                // (embed vs ingest) and its spans attribute correctly.
                #[allow(clippy::type_complexity)]
                let (texts, batch): (
                    Vec<Arc<str>>,
                    Vec<(WorkClass, u64, std::time::Instant, Reply)>,
                ) = batch
                    .into_iter()
                    .map(|p| (p.text, (p.class, p.trace, p.enqueued, p.reply)))
                    .unzip();
                if let Some(tr) = &tracer {
                    for (class, trace, enqueued, _) in &batch {
                        if *trace != 0 {
                            tr.span(
                                *trace,
                                Stage::QueueWait,
                                class_label(*class),
                                route_label(route),
                                CodecLabel::All,
                                *enqueued,
                                drained.saturating_duration_since(*enqueued),
                            );
                        }
                    }
                }
                let t0 = std::time::Instant::now();
                let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                    backend.embed(&texts)
                }));
                let embed_dur = t0.elapsed();
                lat.record(embed_dur.as_nanos() as u64);
                batches.inc();
                queries.add(batch.len() as u64);
                if let Some(tr) = &tracer {
                    for (class, trace, _, _) in &batch {
                        if *trace != 0 {
                            tr.span(
                                *trace,
                                Stage::BatchForm,
                                class_label(*class),
                                route_label(route),
                                CodecLabel::All,
                                drained,
                                t0.saturating_duration_since(drained),
                            );
                            tr.span(
                                *trace,
                                Stage::Embed,
                                class_label(*class),
                                route_label(route),
                                CodecLabel::All,
                                t0,
                                embed_dur,
                            );
                        }
                    }
                }
                match result {
                    Ok(Ok(vectors)) if vectors.len() == batch.len() => {
                        for ((class, _, _, reply), v) in batch.into_iter().zip(vectors) {
                            qm.release_class(class, route, 1);
                            let _ = reply.send(Ok(v));
                        }
                    }
                    Ok(Ok(vectors)) => {
                        failures.inc();
                        let msg = format!(
                            "backend returned {} vectors for {} queries",
                            vectors.len(),
                            batch.len()
                        );
                        for (class, _, _, reply) in batch {
                            qm.release_class(class, route, 1);
                            let _ = reply.send(Err(msg.clone()));
                        }
                    }
                    Ok(Err(e)) => {
                        failures.inc();
                        for (class, _, _, reply) in batch {
                            qm.release_class(class, route, 1);
                            let _ = reply.send(Err(format!("backend error: {e:#}")));
                        }
                    }
                    Err(panic) => {
                        failures.inc();
                        let msg = panic
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| panic.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "worker panic".into());
                        log::error!("{name}: backend panicked: {msg}");
                        for (class, _, _, reply) in batch {
                            qm.release_class(class, route, 1);
                            let _ = reply.send(Err(format!("backend panic: {msg}")));
                        }
                    }
                }
            }
            log::info!("{name}: queue closed, exiting");
        })
        // lint:allow(unwrap-expect): startup-time only — a host that
        // cannot spawn worker threads cannot run the service at all, and
        // there is no caller to report a half-started instance to.
        .expect("spawn worker thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::Pending;
    use std::sync::mpsc;
    use std::time::Instant;

    struct OkBackend;
    impl Backend for OkBackend {
        fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
            Ok(texts.iter().map(|t| vec![t.len() as f32]).collect())
        }
        fn describe(&self) -> String {
            "ok".into()
        }
        fn max_batch(&self) -> usize {
            4
        }
    }

    struct PanicOnceBackend {
        panicked: bool,
    }
    impl Backend for PanicOnceBackend {
        fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
            if !self.panicked {
                self.panicked = true;
                panic!("injected kernel fault");
            }
            Ok(texts.iter().map(|_| vec![1.0]).collect())
        }
        fn describe(&self) -> String {
            "panic-once".into()
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    fn submit(queue: &DeviceQueue<Reply>, qm: &QueueManager, text: &str) -> mpsc::Receiver<Result<Vec<f32>, String>> {
        assert_eq!(qm.dispatch(), Route::Npu);
        let (tx, rx) = mpsc::channel();
        queue.push(Pending {
            text: Arc::from(text),
            class: WorkClass::Embed,
            enqueued: Instant::now(),
            trace: 0,
            reply: tx,
        });
        rx
    }

    #[test]
    fn worker_serves_and_releases_slots() {
        let queue = Arc::new(DeviceQueue::new());
        let qm = Arc::new(QueueManager::new(16, 0, false));
        let h = spawn_worker(
            "npu0".into(),
            Arc::clone(&queue),
            Arc::clone(&qm),
            Route::Npu,
            Box::new(|| Ok(Box::new(OkBackend) as Box<dyn Backend>)),
            Registry::new(),
            None,
            None,
        );
        let rxs: Vec<_> = (0..6).map(|i| submit(&queue, &qm, &format!("query {i}"))).collect();
        for rx in rxs {
            let v = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(v.len(), 1);
        }
        // All slots released.
        assert_eq!(qm.npu_occupancy(), 0);
        queue.close();
        h.join().unwrap();
    }

    #[test]
    fn backend_panic_fails_batch_but_worker_survives() {
        let queue = Arc::new(DeviceQueue::new());
        let qm = Arc::new(QueueManager::new(16, 0, false));
        let h = spawn_worker(
            "npu0".into(),
            Arc::clone(&queue),
            Arc::clone(&qm),
            Route::Npu,
            Box::new(|| Ok(Box::new(PanicOnceBackend { panicked: false }) as Box<dyn Backend>)),
            Registry::new(),
            None,
            None,
        );
        let rx1 = submit(&queue, &qm, "doomed");
        let err = rx1.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.contains("injected kernel fault"), "{err}");
        // Worker must still serve afterwards.
        let rx2 = submit(&queue, &qm, "survivor");
        assert!(rx2.recv_timeout(std::time::Duration::from_secs(5)).unwrap().is_ok());
        assert_eq!(qm.npu_occupancy(), 0);
        queue.close();
        h.join().unwrap();
    }

    #[test]
    fn worker_releases_ingest_queries_under_their_class() {
        use crate::coordinator::queue_manager::ClassCaps;
        let queue = Arc::new(DeviceQueue::new());
        let qm = Arc::new(QueueManager::with_caps(
            8,
            0,
            false,
            ClassCaps { npu_ingest: 2, ..ClassCaps::default() },
        ));
        let h = spawn_worker(
            "npu0".into(),
            Arc::clone(&queue),
            Arc::clone(&qm),
            Route::Npu,
            Box::new(|| Ok(Box::new(OkBackend) as Box<dyn Backend>)),
            Registry::new(),
            None,
            None,
        );
        assert_eq!(qm.dispatch_ingest_npu(1), Route::Npu);
        let (tx, rx) = mpsc::channel();
        queue.push(Pending {
            text: Arc::from("ingested doc"),
            class: WorkClass::Ingest,
            enqueued: Instant::now(),
            trace: 0,
            reply: tx,
        });
        rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap();
        // Wait for the worker's post-send release to land.
        for _ in 0..100 {
            if qm.ingest_npu_occupancy() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // The slot came back to the INGEST class (embed was never held).
        assert_eq!(qm.ingest_npu_occupancy(), 0);
        assert_eq!(qm.npu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
        queue.close();
        h.join().unwrap();
    }

    #[test]
    fn traced_worker_records_queue_wait_batch_form_embed_spans() {
        let queue = Arc::new(DeviceQueue::new());
        let qm = Arc::new(QueueManager::new(16, 0, false));
        let metrics = Registry::new();
        let tracer = Arc::new(Tracer::new(
            &metrics,
            64,
            std::time::Duration::from_secs(10),
        ));
        let h = spawn_worker(
            "npu0".into(),
            Arc::clone(&queue),
            Arc::clone(&qm),
            Route::Npu,
            Box::new(|| Ok(Box::new(OkBackend) as Box<dyn Backend>)),
            metrics.clone(),
            Some(Arc::clone(&tracer)),
            None,
        );
        let id = tracer.mint();
        assert_eq!(qm.dispatch(), Route::Npu);
        let (tx, rx) = mpsc::channel();
        queue.push(Pending {
            text: Arc::from("traced query"),
            class: WorkClass::Embed,
            enqueued: Instant::now(),
            trace: id,
            reply: tx,
        });
        rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap();
        queue.close();
        h.join().unwrap();
        let spans = tracer.snapshot();
        let stages: Vec<Stage> = spans
            .iter()
            .filter(|s| s.trace_id == id)
            .map(|s| s.stage)
            .collect();
        assert_eq!(stages, vec![Stage::QueueWait, Stage::BatchForm, Stage::Embed]);
        for s in &spans {
            assert_eq!(s.class, ClassLabel::Embed);
            assert_eq!(s.route, RouteLabel::Npu);
        }
        assert_eq!(metrics.histogram("trace.embed.embed.npu.all").count(), 1);
        assert_eq!(metrics.histogram("trace.queue_wait.embed.npu.all").count(), 1);
    }

    #[test]
    fn failed_factory_fails_queries_cleanly() {
        let queue = Arc::new(DeviceQueue::new());
        let qm = Arc::new(QueueManager::new(16, 0, false));
        let h = spawn_worker(
            "npu0".into(),
            Arc::clone(&queue),
            Arc::clone(&qm),
            Route::Npu,
            Box::new(|| anyhow::bail!("no artifacts")),
            Registry::new(),
            None,
            None,
        );
        let rx = submit(&queue, &qm, "orphan");
        let err = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.contains("backend init failed"), "{err}");
        assert_eq!(qm.npu_occupancy(), 0);
        queue.close();
        h.join().unwrap();
    }
}
