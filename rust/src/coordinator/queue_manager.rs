//! The queue manager — Algorithm 1 of the paper, extended from
//! single-class slot counting to **weighted multi-class admission**.
//!
//! # Embedding admission (Algorithm 1, verbatim semantics)
//!
//! ```text
//! foreach query:
//!   if NPU queue is not full:        push NPU,  return 'NPU'
//!   elif heterogeneous enabled:
//!     if CPU queue is not full:      push CPU,  return 'CPU'
//!     else:                          return 'BUSY'
//!   else:                            return 'BUSY'
//! ```
//!
//! Queue *depths* are the paper's C^max_NPU / C^max_CPU (Eqs. 7-10),
//! calibrated by [`crate::estimator`]. Occupancy counts queries from
//! dispatch until their batch completes, so "depth" bounds the device's
//! in-flight concurrency exactly as the paper's C_d does.
//!
//! # Retrieval admission (Eqs. 9-10 extended to scan work)
//!
//! The paper derives the CPU queue depth C^max_CPU (Eq. 9) from the
//! largest concurrency whose latency still meets the SLO (Eq. 10) — a
//! *budget of concurrent CPU work*, not a count of embedding queries
//! specifically. PR 1/2 added batched top-k retrieval scans that run on
//! the same host cores but outside this accounting, so mixed
//! embed+retrieve traffic could oversubscribe the CPU past its
//! calibrated depth. [`WorkClass`] closes that gap:
//!
//! * Each admitted unit of work holds `cost` **slots** (cost units) of
//!   its device pool. An embedding query costs 1 slot — the unit the
//!   depth was calibrated in.
//! * A retrieval scan's cost is its scanned-bytes estimate normalized to
//!   embed-query units: `cost = ceil(rows · bytes_per_row / U)` where
//!   `bytes_per_row` comes from the active `vecstore::Quant` codec and
//!   `U` is the embed cost unit ([`retrieval_slot_cost`]). The scan is
//!   memory-bound, so bytes streamed is the honest proxy for how much of
//!   the calibrated CPU budget one scan consumes.
//! * The CPU pool is **shared**: embed slots + retrieval slot-cost never
//!   exceed `cpu_depth` (the paper's C^max_CPU), and retrieval may
//!   additionally be capped below the pool ([`QueueManager::with_retrieval_cap`])
//!   using the per-class depths from
//!   [`crate::estimator::depth::fine_tune_depths_mixed`].
//!
//! # NPU retrieval offload (the inverse of the paper's CPU offload)
//!
//! The paper routes *embedding* overflow from the saturated NPU onto idle
//! CPUs. The same performance gap runs the other way when embedding
//! traffic is low: the NPU sits idle while scan bursts contend for the
//! CPU budget. [`QueueManager::dispatch_retrieve_npu`] is the device leg
//! for batched scans — the **shared NPU pool** (embed queries + offloaded
//! scan cost ≤ `npu_depth`) with its own per-class cap
//! (`npu_retrieve_cap`, calibrated by
//! `crate::estimator::depth::fine_tune_npu_retrieval_cap`), acquired
//! cap-then-pool with rollback exactly like the CPU leg. Routing *policy*
//! (offload only while embed-side NPU occupancy is under a low-water
//! mark) lives in `coordinator::service`; this type only meters capacity.
//! A cap of 0 (every legacy constructor) disables the leg outright.
//!
//! # Ingest admission (the online-indexing contract)
//!
//! [`WorkClass::Ingest`] is the third class: embedding work done on
//! behalf of streaming corpus ingestion (`crate::ingest`). Its contract
//! is strictly subordinate to serving traffic:
//!
//! * Ingest holds slots of the **same shared pools** as everything else —
//!   every in-flight ingest embed is visible to the oversubscription
//!   accounting, so bulk uploads can never push combined occupancy past
//!   the calibrated depths (Eqs. 9-10).
//! * Ingest has a **strict per-class cap on each pool** (`ingest_cap` on
//!   the CPU pool, `npu_ingest_cap` on the NPU pool, both via
//!   [`ClassCaps`]), normally a small fraction of the depth: latency-
//!   sensitive Embed/Retrieve traffic keeps the rest of the budget and
//!   ingest soaks only the valleys. A full pool or cap answers BUSY —
//!   backpressure the streaming pipeline absorbs by waiting, not a drop.
//! * Ingest **never reserves** capacity: a cap of 0 on both pools (every
//!   legacy constructor) disables the class outright, and an idle ingest
//!   class leaves both pools exactly as before this class existed.
//!
//! Whether an ingest embed *should* try the NPU pool (valley-soak
//! low-water policy, mirroring the retrieval offload leg) is decided in
//! `coordinator::service::WindVE::submit_ingest`; this type only meters.
//!
//! Lock-free: occupancy is a set of atomics with CAS admission, making
//! dispatch safe from any number of front-end threads (and cheap — see
//! benches/micro.rs). Per-class occupancy is acquired before the shared
//! pool (with rollback on pool exhaustion), so the cap and the pool bound
//! both hold at every instant, on both device legs.
//!
//! # Ordering discipline
//!
//! Every atomic here is one of exactly three things, and each has one
//! ordering rule (each use site carries an `// ordering:` note; the
//! `xtask lint` pass rejects un-justified `Relaxed`/`SeqCst`):
//!
//! * **Admission counters** (`npu_len`, `cpu_len`, per-class occupancy):
//!   the *value* is the invariant — a successful CAS proves the bound
//!   held at that instant on the single modification order of that cell.
//!   CAS success uses `AcqRel` so a slot release *happens-before* the
//!   acquisition that reuses the freed capacity (the release edge
//!   publishes the completed work's effects; the acquire edge lets the
//!   next holder read them). Initial/failed loads may be `Relaxed`: they
//!   only seed a CAS that re-validates, and a stale read costs one retry,
//!   never a bound violation.
//! * **Occupancy getters**: `Acquire`, pairing with the `AcqRel` CAS
//!   writes, so a policy read (e.g. the offload low-water check) observes
//!   everything published before the occupancy it sees.
//! * **Stats counters** (`routed_*`, `rejected_*`, `bad_releases`):
//!   monotonic telemetry, read only by `stats()` for `/v1/stats`.
//!   `Relaxed` — no other memory depends on their values; fetch_add's
//!   read-modify-write atomicity alone guarantees no lost increments.
//!
//! `SeqCst` appears nowhere: no protocol here needs a single total order
//! across *different* atomics, only per-cell bounds and release/acquire
//! publication — which is exactly what the loom suite
//! (`tests/loom_admission.rs`) proves on every interleaving.

use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Dispatch decision for one query (Algorithm 1's return value).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Route {
    Npu,
    Cpu,
    /// Both queues full (or CPU disabled): reject with 'busy'.
    Busy,
}

impl std::fmt::Display for Route {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Route::Npu => write!(f, "NPU"),
            Route::Cpu => write!(f, "CPU"),
            Route::Busy => write!(f, "BUSY"),
        }
    }
}

/// Admission class of one unit of work (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkClass {
    /// One embedding query — cost 1, the unit depths are calibrated in.
    Embed,
    /// One batched top-k scan — cost from [`retrieval_slot_cost`].
    Retrieve,
    /// One ingestion embed (streaming corpus upload) — cost 1, strictly
    /// capped per pool so bulk indexing can never starve serving traffic.
    Ingest,
}

impl std::fmt::Display for WorkClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkClass::Embed => write!(f, "embed"),
            WorkClass::Retrieve => write!(f, "retrieve"),
            WorkClass::Ingest => write!(f, "ingest"),
        }
    }
}

/// Per-class caps within the shared device pools (cost units; each is
/// clamped to its pool's depth at construction, 0 disables the leg).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClassCaps {
    /// Retrieval scans' share of the CPU pool.
    pub retrieve: usize,
    /// Offloaded scans' share of the NPU pool.
    pub npu_retrieve: usize,
    /// Ingest embeds' share of the CPU pool.
    pub ingest: usize,
    /// Ingest embeds' share of the NPU pool (valley soak).
    pub npu_ingest: usize,
}

/// Slot cost of one retrieval scan: `scan_bytes` (rows × bytes_per_row of
/// the active codec) normalized to embed-query cost units of `unit_bytes`,
/// rounded up, never below 1 — even a tiny scan holds a slot while it runs
/// so occupancy accounting stays conservative.
pub fn retrieval_slot_cost(scan_bytes: usize, unit_bytes: usize) -> usize {
    scan_bytes.div_ceil(unit_bytes.max(1)).max(1)
}

/// Dispatch/release counters (see [`QueueManager::stats`]). A named
/// struct so new counters don't break existing destructuring call sites.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueueStats {
    pub routed_npu: u64,
    pub routed_cpu: u64,
    pub rejected: u64,
    /// Retrieval scans admitted to the CPU pool.
    pub routed_retrieve: u64,
    /// Retrieval scans rejected (cap or pool full): backpressure.
    pub rejected_retrieve: u64,
    /// Retrieval scans admitted to the NPU leg (offload).
    pub routed_retrieve_npu: u64,
    /// NPU-leg admissions declined (cap or pool full). The service falls
    /// back to the CPU leg on decline, so this counts fallbacks, not
    /// necessarily lost scans.
    pub rejected_retrieve_npu: u64,
    /// Ingest embeds admitted to the CPU pool.
    pub routed_ingest: u64,
    /// Ingest CPU-leg admissions declined (cap or pool full) — the
    /// backpressure the streaming pipeline absorbs by waiting.
    pub rejected_ingest: u64,
    /// Ingest embeds admitted to the NPU pool (valley soak).
    pub routed_ingest_npu: u64,
    /// Ingest NPU-leg admissions declined; the service falls back to the
    /// CPU leg, so this counts fallbacks, not necessarily stalled docs.
    pub rejected_ingest_npu: u64,
    /// Releases without a matching dispatch (see
    /// [`QueueManager::release_class`]); 0 in a healthy service.
    pub bad_releases: u64,
}

/// Bounded multi-class admission state over the two device pools.
#[derive(Debug)]
pub struct QueueManager {
    npu_depth: usize,
    /// Shared CPU pool in cost units (the paper's C^max_CPU).
    cpu_depth: usize,
    hetero: bool,
    /// Per-class cap on retrieval's share of the CPU pool (≤ cpu_depth).
    retrieve_cap: usize,
    /// Per-class cap on offloaded scans' share of the NPU pool
    /// (≤ npu_depth); 0 disables the NPU retrieval leg.
    npu_retrieve_cap: usize,
    /// Per-class cap on ingest's share of the CPU pool (≤ cpu_depth).
    ingest_cap: usize,
    /// Per-class cap on ingest's share of the NPU pool (≤ npu_depth).
    npu_ingest_cap: usize,
    /// Total in-flight cost units per pool (authoritative for admission).
    npu_len: AtomicUsize,
    cpu_len: AtomicUsize,
    /// Per-class CPU occupancy;
    /// embed_cpu + retr_cpu + ingest_cpu == cpu_len at rest.
    embed_cpu: AtomicUsize,
    retr_cpu: AtomicUsize,
    ingest_cpu: AtomicUsize,
    /// Per-class NPU occupancy;
    /// embed_npu + retr_npu + ingest_npu == npu_len at rest.
    embed_npu: AtomicUsize,
    retr_npu: AtomicUsize,
    ingest_npu: AtomicUsize,
    // counters for /stats
    routed_npu: AtomicU64,
    routed_cpu: AtomicU64,
    rejected: AtomicU64,
    routed_retrieve: AtomicU64,
    rejected_retrieve: AtomicU64,
    routed_retrieve_npu: AtomicU64,
    rejected_retrieve_npu: AtomicU64,
    routed_ingest: AtomicU64,
    rejected_ingest: AtomicU64,
    routed_ingest_npu: AtomicU64,
    rejected_ingest_npu: AtomicU64,
    bad_releases: AtomicU64,
}

impl QueueManager {
    /// `cpu_depth` is ignored unless `hetero` (Algorithm 2 forces the
    /// option off when only one device class exists). Retrieval may use
    /// the whole CPU pool; a disabled pool (non-hetero) leaves retrieval
    /// with no budget — use [`QueueManager::with_retrieval_cap`] to
    /// budget scans on an NPU-only embedding deployment.
    pub fn new(npu_depth: usize, cpu_depth: usize, hetero: bool) -> QueueManager {
        let pool = if hetero { cpu_depth } else { 0 };
        QueueManager::with_retrieval_cap(npu_depth, pool, hetero, pool)
    }

    /// Full multi-class wiring: `cpu_depth` is the shared CPU pool (NOT
    /// zeroed by `!hetero` — a non-hetero manager with `cpu_depth > 0`
    /// budgets the CPU purely for retrieval scans; embeds still never
    /// route there), `retrieve_cap` bounds retrieval's share of it. The
    /// NPU retrieval leg stays disabled (cap 0) — use
    /// [`QueueManager::with_class_caps`] to enable offload.
    pub fn with_retrieval_cap(
        npu_depth: usize,
        cpu_depth: usize,
        hetero: bool,
        retrieve_cap: usize,
    ) -> QueueManager {
        QueueManager::with_class_caps(npu_depth, cpu_depth, hetero, retrieve_cap, 0)
    }

    /// [`QueueManager::with_retrieval_cap`] plus the NPU retrieval leg:
    /// `npu_retrieve_cap` bounds offloaded scans' share of the shared NPU
    /// pool (clamped to `npu_depth`; 0 keeps the leg disabled). The
    /// ingest class stays disabled — use [`QueueManager::with_caps`].
    pub fn with_class_caps(
        npu_depth: usize,
        cpu_depth: usize,
        hetero: bool,
        retrieve_cap: usize,
        npu_retrieve_cap: usize,
    ) -> QueueManager {
        QueueManager::with_caps(
            npu_depth,
            cpu_depth,
            hetero,
            ClassCaps {
                retrieve: retrieve_cap,
                npu_retrieve: npu_retrieve_cap,
                ..ClassCaps::default()
            },
        )
    }

    /// Full three-class wiring: every per-class cap in one [`ClassCaps`]
    /// (each clamped to its pool's depth; 0 disables that leg).
    pub fn with_caps(
        npu_depth: usize,
        cpu_depth: usize,
        hetero: bool,
        caps: ClassCaps,
    ) -> QueueManager {
        QueueManager {
            npu_depth,
            cpu_depth,
            hetero,
            retrieve_cap: caps.retrieve.min(cpu_depth),
            npu_retrieve_cap: caps.npu_retrieve.min(npu_depth),
            ingest_cap: caps.ingest.min(cpu_depth),
            npu_ingest_cap: caps.npu_ingest.min(npu_depth),
            npu_len: AtomicUsize::new(0),
            cpu_len: AtomicUsize::new(0),
            embed_cpu: AtomicUsize::new(0),
            retr_cpu: AtomicUsize::new(0),
            ingest_cpu: AtomicUsize::new(0),
            embed_npu: AtomicUsize::new(0),
            retr_npu: AtomicUsize::new(0),
            ingest_npu: AtomicUsize::new(0),
            routed_npu: AtomicU64::new(0),
            routed_cpu: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            routed_retrieve: AtomicU64::new(0),
            rejected_retrieve: AtomicU64::new(0),
            routed_retrieve_npu: AtomicU64::new(0),
            rejected_retrieve_npu: AtomicU64::new(0),
            routed_ingest: AtomicU64::new(0),
            rejected_ingest: AtomicU64::new(0),
            routed_ingest_npu: AtomicU64::new(0),
            rejected_ingest_npu: AtomicU64::new(0),
            bad_releases: AtomicU64::new(0),
        }
    }

    /// Algorithm 1 for one embedding query. On `Npu`/`Cpu` the
    /// corresponding occupancy is incremented; the caller must
    /// [`QueueManager::release`] when the query's batch completes (or the
    /// submit fails downstream).
    pub fn dispatch(&self) -> Route {
        self.dispatch_class(WorkClass::Embed, 1)
    }

    /// Weighted multi-class admission: acquire `cost` slots for one unit
    /// of `class` work. Embeds follow Algorithm 1 (NPU first, CPU
    /// overflow when hetero); retrieval scans acquire CPU slots only,
    /// bounded by both the shared pool depth and the retrieval cap.
    /// `cost` is clamped to ≥ 1. The caller must
    /// [`QueueManager::release_class`] the same `(class, route, cost)`
    /// when the work completes.
    pub fn dispatch_class(&self, class: WorkClass, cost: usize) -> Route {
        let cost = cost.max(1);
        match class {
            WorkClass::Embed => {
                // Embed is pool-first (it has no cap below the pool): the
                // per-class counter is bookkeeping *under* the pool
                // reservation, so its fetch_add can never exceed a bound.
                // AcqRel keeps the class counter ordered with the pool
                // slot it annotates (release pairs via saturating_release).
                if try_acquire(&self.npu_len, self.npu_depth, cost) {
                    self.embed_npu.fetch_add(cost, Ordering::AcqRel);
                    // ordering: Relaxed — monotonic stats counter, see module docs.
                    self.routed_npu.fetch_add(1, Ordering::Relaxed);
                    return Route::Npu;
                }
                if self.hetero && try_acquire(&self.cpu_len, self.cpu_depth, cost) {
                    self.embed_cpu.fetch_add(cost, Ordering::AcqRel);
                    // ordering: Relaxed — monotonic stats counter, see module docs.
                    self.routed_cpu.fetch_add(1, Ordering::Relaxed);
                    return Route::Cpu;
                }
                // ordering: Relaxed — monotonic stats counter, see module docs.
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Route::Busy
            }
            WorkClass::Retrieve => {
                // Class cap first, shared pool second; roll the cap
                // acquisition back if the pool is exhausted so a rejected
                // scan leaves no residue.
                if try_acquire(&self.retr_cpu, self.retrieve_cap, cost) {
                    if try_acquire(&self.cpu_len, self.cpu_depth, cost) {
                        // ordering: Relaxed — monotonic stats counter.
                        self.routed_retrieve.fetch_add(1, Ordering::Relaxed);
                        return Route::Cpu;
                    }
                    saturating_release(&self.retr_cpu, cost);
                }
                // ordering: Relaxed — monotonic stats counter.
                self.rejected_retrieve.fetch_add(1, Ordering::Relaxed);
                Route::Busy
            }
            WorkClass::Ingest => {
                // Same cap-then-pool shape as retrieval: ingest's strict
                // cap bounds how much of the shared CPU budget bulk
                // uploads can ever hold, and the pool check keeps the
                // combined occupancy at or under the calibrated depth.
                if try_acquire(&self.ingest_cpu, self.ingest_cap, cost) {
                    if try_acquire(&self.cpu_len, self.cpu_depth, cost) {
                        // ordering: Relaxed — monotonic stats counter.
                        self.routed_ingest.fetch_add(1, Ordering::Relaxed);
                        return Route::Cpu;
                    }
                    saturating_release(&self.ingest_cpu, cost);
                }
                // ordering: Relaxed — monotonic stats counter.
                self.rejected_ingest.fetch_add(1, Ordering::Relaxed);
                Route::Busy
            }
        }
    }

    /// Admit one batched scan to the **NPU retrieval leg**: acquire `cost`
    /// slots of the shared NPU pool, bounded by both `npu_depth` and the
    /// per-class `npu_retrieve_cap` (cap first, pool second, with rollback
    /// so a declined scan leaves no residue — the mirror image of the CPU
    /// leg in [`QueueManager::dispatch_class`]). Returns [`Route::Npu`] or
    /// [`Route::Busy`]; the caller must
    /// `release_class(WorkClass::Retrieve, Route::Npu, cost)` when the
    /// scan completes. Whether a scan *should* offload (embed traffic
    /// low-water, mirror freshness) is the service's routing policy, not
    /// decided here.
    pub fn dispatch_retrieve_npu(&self, cost: usize) -> Route {
        let cost = cost.max(1);
        if try_acquire(&self.retr_npu, self.npu_retrieve_cap, cost) {
            if try_acquire(&self.npu_len, self.npu_depth, cost) {
                // ordering: Relaxed — monotonic stats counter.
                self.routed_retrieve_npu.fetch_add(1, Ordering::Relaxed);
                return Route::Npu;
            }
            saturating_release(&self.retr_npu, cost);
        }
        // ordering: Relaxed — monotonic stats counter.
        self.rejected_retrieve_npu.fetch_add(1, Ordering::Relaxed);
        Route::Busy
    }

    /// Admit one ingest embed to the **NPU pool** (valley soak): acquire
    /// `cost` slots bounded by both `npu_depth` and the strict
    /// `npu_ingest_cap` (cap first, pool second, with rollback — the same
    /// shape as every other leg). Returns [`Route::Npu`] or
    /// [`Route::Busy`]; the caller must
    /// `release_class(WorkClass::Ingest, Route::Npu, cost)` on
    /// completion. Whether ingest *should* touch the NPU at all (the
    /// embed-traffic low-water policy) is decided in the service.
    pub fn dispatch_ingest_npu(&self, cost: usize) -> Route {
        let cost = cost.max(1);
        if try_acquire(&self.ingest_npu, self.npu_ingest_cap, cost) {
            if try_acquire(&self.npu_len, self.npu_depth, cost) {
                // ordering: Relaxed — monotonic stats counter.
                self.routed_ingest_npu.fetch_add(1, Ordering::Relaxed);
                return Route::Npu;
            }
            saturating_release(&self.ingest_npu, cost);
        }
        // ordering: Relaxed — monotonic stats counter.
        self.rejected_ingest_npu.fetch_add(1, Ordering::Relaxed);
        Route::Busy
    }

    /// Return one embedding slot. Must match a prior successful dispatch.
    pub fn release(&self, route: Route) {
        self.release_class(WorkClass::Embed, route, 1);
    }

    /// Return `cost` slots of `class` work. Must match a prior successful
    /// [`QueueManager::dispatch_class`].
    ///
    /// Hardened against mismatched releases in release builds, the same
    /// way for every class: decrements saturate at zero (a plain
    /// `fetch_sub` would wrap occupancy to `usize::MAX` and permanently
    /// wedge admission into BUSY), the shared pool is only decremented by
    /// what the per-class counter actually freed (so a double-released
    /// retrieval slot can never liberate capacity an embed legitimately
    /// holds), and every mismatch is counted in
    /// [`QueueManager::stats`] so operators can see the accounting bug
    /// instead of absorbing it.
    pub fn release_class(&self, class: WorkClass, route: Route, cost: usize) {
        let cost = cost.max(1);
        // Each arm frees the per-class counter FIRST, then credits the
        // shared pool with only what was actually freed: the pool can
        // never be over-credited past what this class provably held, so
        // a double release cannot liberate another class's capacity.
        // ordering: Relaxed on bad_releases — monotonic stats counter,
        // see module docs; the freed-amount feedback, not the counter,
        // carries the containment invariant.
        match (class, route) {
            (_, Route::Busy) => {}
            (WorkClass::Embed, Route::Npu) => {
                let freed = saturating_release(&self.embed_npu, cost);
                if freed < cost {
                    self.bad_releases.fetch_add(1, Ordering::Relaxed);
                }
                saturating_release(&self.npu_len, freed);
            }
            (WorkClass::Embed, Route::Cpu) => {
                let freed = saturating_release(&self.embed_cpu, cost);
                if freed < cost {
                    self.bad_releases.fetch_add(1, Ordering::Relaxed);
                }
                saturating_release(&self.cpu_len, freed);
            }
            (WorkClass::Retrieve, Route::Cpu) => {
                let freed = saturating_release(&self.retr_cpu, cost);
                if freed < cost {
                    self.bad_releases.fetch_add(1, Ordering::Relaxed);
                }
                saturating_release(&self.cpu_len, freed);
            }
            (WorkClass::Retrieve, Route::Npu) => {
                let freed = saturating_release(&self.retr_npu, cost);
                if freed < cost {
                    self.bad_releases.fetch_add(1, Ordering::Relaxed);
                }
                saturating_release(&self.npu_len, freed);
            }
            (WorkClass::Ingest, Route::Cpu) => {
                let freed = saturating_release(&self.ingest_cpu, cost);
                if freed < cost {
                    self.bad_releases.fetch_add(1, Ordering::Relaxed);
                }
                saturating_release(&self.cpu_len, freed);
            }
            (WorkClass::Ingest, Route::Npu) => {
                let freed = saturating_release(&self.ingest_npu, cost);
                if freed < cost {
                    self.bad_releases.fetch_add(1, Ordering::Relaxed);
                }
                saturating_release(&self.npu_len, freed);
            }
        }
    }

    /// Wrap an already-admitted `(class, route, cost)` in an RAII guard
    /// that releases it exactly once on drop. The service's scan legs use
    /// this so every early-return and panic path after admission still
    /// returns the slots (the guard moved out of PR 4's private
    /// `ScanAdmission` into the queue manager so the loom suite can
    /// model-check the guard's drop path itself).
    pub fn guard(&self, class: WorkClass, route: Route, cost: usize) -> AdmissionGuard<'_> {
        AdmissionGuard { qm: self, class, route, cost }
    }

    // Occupancy getters load with Acquire, pairing with the AcqRel CAS
    // writes in try_acquire/saturating_release (see "Ordering discipline"
    // in the module docs): a policy decision made on an observed
    // occupancy also observes everything published before it.

    /// Total NPU-pool occupancy in cost units (embed + offloaded scans).
    pub fn npu_occupancy(&self) -> usize {
        self.npu_len.load(Ordering::Acquire)
    }

    /// Embedding queries' share of the NPU pool — the occupancy the
    /// service's offload low-water policy consults.
    pub fn embed_npu_occupancy(&self) -> usize {
        self.embed_npu.load(Ordering::Acquire)
    }

    /// Offloaded scans' share of the NPU pool (cost units).
    pub fn retrieve_npu_occupancy(&self) -> usize {
        self.retr_npu.load(Ordering::Acquire)
    }

    /// Ingest embeds' share of the CPU pool (cost units).
    pub fn ingest_cpu_occupancy(&self) -> usize {
        self.ingest_cpu.load(Ordering::Acquire)
    }

    /// Ingest embeds' share of the NPU pool (cost units).
    pub fn ingest_npu_occupancy(&self) -> usize {
        self.ingest_npu.load(Ordering::Acquire)
    }

    /// Total CPU-pool occupancy in cost units (embed + retrieval).
    pub fn cpu_occupancy(&self) -> usize {
        self.cpu_len.load(Ordering::Acquire)
    }

    /// Embedding queries' share of the CPU pool.
    pub fn embed_cpu_occupancy(&self) -> usize {
        self.embed_cpu.load(Ordering::Acquire)
    }

    /// Retrieval scans' share of the CPU pool (cost units).
    pub fn retrieve_cpu_occupancy(&self) -> usize {
        self.retr_cpu.load(Ordering::Acquire)
    }

    pub fn npu_depth(&self) -> usize {
        self.npu_depth
    }

    pub fn cpu_depth(&self) -> usize {
        self.cpu_depth
    }

    /// Retrieval's cap within the CPU pool (cost units).
    pub fn retrieve_cap(&self) -> usize {
        self.retrieve_cap
    }

    /// Offloaded scans' cap within the NPU pool (cost units; 0 = leg off).
    pub fn npu_retrieve_cap(&self) -> usize {
        self.npu_retrieve_cap
    }

    /// Ingest's cap within the CPU pool (cost units; 0 = leg off).
    pub fn ingest_cap(&self) -> usize {
        self.ingest_cap
    }

    /// Ingest's cap within the NPU pool (cost units; 0 = leg off).
    pub fn npu_ingest_cap(&self) -> usize {
        self.npu_ingest_cap
    }

    pub fn hetero(&self) -> bool {
        self.hetero
    }

    /// Total admitted capacity (paper: C_NPU + C_CPU).
    pub fn total_depth(&self) -> usize {
        self.npu_depth + self.cpu_depth
    }

    // ordering: Relaxed throughout — pure monotonic stats counters (see
    // module docs); a snapshot is advisory telemetry, not a cut of a
    // consistent state, so no counter's value orders any other memory.
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            routed_npu: self.routed_npu.load(Ordering::Relaxed),
            routed_cpu: self.routed_cpu.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            routed_retrieve: self.routed_retrieve.load(Ordering::Relaxed),
            rejected_retrieve: self.rejected_retrieve.load(Ordering::Relaxed),
            routed_retrieve_npu: self.routed_retrieve_npu.load(Ordering::Relaxed),
            rejected_retrieve_npu: self.rejected_retrieve_npu.load(Ordering::Relaxed),
            routed_ingest: self.routed_ingest.load(Ordering::Relaxed),
            rejected_ingest: self.rejected_ingest.load(Ordering::Relaxed),
            routed_ingest_npu: self.routed_ingest_npu.load(Ordering::Relaxed),
            rejected_ingest_npu: self.rejected_ingest_npu.load(Ordering::Relaxed),
            bad_releases: self.bad_releases.load(Ordering::Relaxed),
        }
    }
}

/// RAII wrapper over an admitted `(class, route, cost)` — releases it on
/// drop via [`QueueManager::release_class`]. Built by
/// [`QueueManager::guard`] *after* a successful dispatch; dropping a
/// guard for work that was never admitted is the double-release case the
/// queue manager already contains (counted in `bad_releases`).
#[derive(Debug)]
pub struct AdmissionGuard<'a> {
    qm: &'a QueueManager,
    class: WorkClass,
    route: Route,
    cost: usize,
}

impl AdmissionGuard<'_> {
    /// The admitted route (handy when the guard travels with the work).
    pub fn route(&self) -> Route {
        self.route
    }

    /// The admitted slot cost.
    pub fn cost(&self) -> usize {
        self.cost
    }
}

impl Drop for AdmissionGuard<'_> {
    fn drop(&mut self) {
        self.qm.release_class(self.class, self.route, self.cost);
    }
}

/// CAS-increment `len` by `cost` if the result stays ≤ `cap`.
///
/// ordering: the initial load is Relaxed — it only seeds the CAS, whose
/// success re-validates the bound against the cell's single modification
/// order (a stale seed costs one retry, never an over-admission). CAS
/// success is AcqRel: Acquire pairs with a releaser's AcqRel so the new
/// holder sees the freed work's writes; Release publishes this
/// acquisition to the eventual releaser. CAS failure reloads Relaxed for
/// the same seed-only reason.
fn try_acquire(len: &AtomicUsize, cap: usize, cost: usize) -> bool {
    let mut cur = len.load(Ordering::Relaxed);
    loop {
        let next = match cur.checked_add(cost) {
            Some(n) if n <= cap => n,
            _ => return false,
        };
        match len.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

/// CAS-decrement `len` by up to `cost`, saturating at zero; returns how
/// much was actually freed.
///
/// ordering: loads are Acquire (initial and on CAS failure) because the
/// *observed value* feeds the freed-amount containment logic in
/// `release_class`, not just a retry seed; success is AcqRel so the
/// release edge publishes the completed work to whichever `try_acquire`
/// next claims the freed capacity.
fn saturating_release(len: &AtomicUsize, cost: usize) -> usize {
    let mut cur = len.load(Ordering::Acquire);
    loop {
        let freed = cur.min(cost);
        if freed == 0 {
            return 0;
        }
        match len.compare_exchange_weak(cur, cur - freed, Ordering::AcqRel, Ordering::Acquire) {
            Ok(_) => return freed,
            Err(now) => cur = now,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn npu_priority_then_cpu_then_busy() {
        // Algorithm 1's dispatch order, exactly.
        let qm = QueueManager::new(2, 1, true);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Busy);
        assert_eq!(qm.npu_occupancy(), 2);
        assert_eq!(qm.cpu_occupancy(), 1);
    }

    #[test]
    fn hetero_disabled_skips_cpu() {
        let qm = QueueManager::new(1, 5, false);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Busy); // CPU never considered
        assert_eq!(qm.cpu_depth(), 0);
    }

    #[test]
    fn release_frees_slot_for_reuse() {
        let qm = QueueManager::new(1, 0, false);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Busy);
        qm.release(Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
    }

    #[test]
    fn busy_release_is_noop() {
        let qm = QueueManager::new(0, 0, true);
        assert_eq!(qm.dispatch(), Route::Busy);
        qm.release(Route::Busy);
        assert_eq!(qm.npu_occupancy(), 0);
    }

    #[test]
    fn zero_depths_always_busy() {
        let qm = QueueManager::new(0, 0, true);
        for _ in 0..5 {
            assert_eq!(qm.dispatch(), Route::Busy);
        }
        assert_eq!(qm.stats().rejected, 5);
    }

    #[test]
    fn stats_count_routes() {
        let qm = QueueManager::new(1, 1, true);
        qm.dispatch();
        qm.dispatch();
        qm.dispatch();
        assert_eq!(
            qm.stats(),
            QueueStats {
                routed_npu: 1,
                routed_cpu: 1,
                rejected: 1,
                ..QueueStats::default()
            }
        );
    }

    #[test]
    fn mismatched_release_saturates_and_is_counted() {
        let qm = QueueManager::new(2, 1, true);
        // No dispatch yet: releases must not wrap occupancy below zero.
        qm.release(Route::Npu);
        qm.release(Route::Cpu);
        assert_eq!(qm.npu_occupancy(), 0);
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 2);
        // Admission still works at full depth afterwards.
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Busy);
        // Matched releases don't count as mismatches.
        qm.release(Route::Npu);
        assert_eq!(qm.stats().bad_releases, 2);
        assert_eq!(qm.npu_occupancy(), 1);
    }

    #[test]
    fn retrieval_cost_shares_cpu_pool_with_embeds() {
        // Pool of 6: a cost-4 scan + 2 embed overflows fill it exactly.
        let qm = QueueManager::with_retrieval_cap(1, 6, true, 6);
        assert_eq!(qm.dispatch(), Route::Npu); // NPU fills first
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 4), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        // Pool is full: both classes now bounce.
        assert_eq!(qm.dispatch(), Route::Busy);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 1), Route::Busy);
        assert_eq!(qm.cpu_occupancy(), 6);
        assert_eq!(qm.embed_cpu_occupancy(), 2);
        assert_eq!(qm.retrieve_cpu_occupancy(), 4);
        // Releasing the scan frees exactly its cost.
        qm.release_class(WorkClass::Retrieve, Route::Cpu, 4);
        assert_eq!(qm.cpu_occupancy(), 2);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 4), Route::Cpu);
        let st = qm.stats();
        assert_eq!(st.routed_retrieve, 2);
        assert_eq!(st.rejected_retrieve, 1);
        assert_eq!(st.bad_releases, 0);
    }

    #[test]
    fn retrieve_cap_bounds_class_below_pool() {
        let qm = QueueManager::with_retrieval_cap(0, 8, true, 3);
        assert_eq!(qm.retrieve_cap(), 3);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 3), Route::Cpu);
        // Cap exhausted even though the pool has 5 free units.
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 1), Route::Busy);
        // Embeds still fill the remaining pool.
        for _ in 0..5 {
            assert_eq!(qm.dispatch(), Route::Cpu);
        }
        assert_eq!(qm.dispatch(), Route::Busy);
        assert_eq!(qm.cpu_occupancy(), 8);
    }

    #[test]
    fn oversized_scan_cost_never_admits_but_leaves_no_residue() {
        let qm = QueueManager::with_retrieval_cap(0, 4, true, 4);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 5), Route::Busy);
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        assert_eq!(qm.cpu_occupancy(), 0);
        // A pool-sized scan still fits afterwards.
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 4), Route::Cpu);
    }

    #[test]
    fn rejected_scan_rolls_back_cap_when_pool_is_full() {
        // Cap 4 of pool 4; embeds hold 2 pool units, so a cost-3 scan
        // passes the cap check but fails the pool check — the cap
        // acquisition must be rolled back.
        let qm = QueueManager::with_retrieval_cap(0, 4, true, 4);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 3), Route::Busy);
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        // A scan that fits the pool remainder is admitted.
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 2), Route::Cpu);
        assert_eq!(qm.cpu_occupancy(), 4);
    }

    #[test]
    fn double_release_of_retrieval_slot_is_contained() {
        // Regression (satellite): the class-aware release must be
        // hardened exactly like the legacy one — saturating decrement,
        // counted in bad_releases, and a double release must not free
        // capacity another class holds.
        let qm = QueueManager::with_retrieval_cap(0, 4, true, 4);
        assert_eq!(qm.dispatch(), Route::Cpu); // embed holds 1 pool unit
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 2), Route::Cpu);
        qm.release_class(WorkClass::Retrieve, Route::Cpu, 2);
        assert_eq!(qm.cpu_occupancy(), 1);
        assert_eq!(qm.stats().bad_releases, 0);
        // The double release: retrieval holds nothing, so nothing may be
        // freed — especially not the embed's pool unit.
        qm.release_class(WorkClass::Retrieve, Route::Cpu, 2);
        assert_eq!(qm.stats().bad_releases, 1);
        assert_eq!(qm.cpu_occupancy(), 1);
        assert_eq!(qm.embed_cpu_occupancy(), 1);
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        // A retrieval release claiming an NPU slot is a pure caller bug.
        qm.release_class(WorkClass::Retrieve, Route::Npu, 1);
        assert_eq!(qm.stats().bad_releases, 2);
        assert_eq!(qm.npu_occupancy(), 0);
        // Accounting is intact: pool still admits exactly the remainder.
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 3), Route::Cpu);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 1), Route::Busy);
    }

    #[test]
    fn zero_cost_dispatch_clamps_to_one_slot() {
        let qm = QueueManager::with_retrieval_cap(0, 1, true, 1);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 0), Route::Cpu);
        assert_eq!(qm.cpu_occupancy(), 1);
        qm.release_class(WorkClass::Retrieve, Route::Cpu, 0);
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
    }

    #[test]
    fn retrieval_slot_cost_formula() {
        // ceil(bytes / unit), floor 1.
        assert_eq!(retrieval_slot_cost(0, 1024), 1);
        assert_eq!(retrieval_slot_cost(1, 1024), 1);
        assert_eq!(retrieval_slot_cost(1024, 1024), 1);
        assert_eq!(retrieval_slot_cost(1025, 1024), 2);
        assert_eq!(retrieval_slot_cost(4096, 1024), 4);
        // Degenerate unit never divides by zero.
        assert_eq!(retrieval_slot_cost(7, 0), 7);
    }

    #[test]
    fn non_hetero_with_retrieval_cap_budgets_scans_only() {
        // NPU-only embedding deployment that still bounds scan work.
        let qm = QueueManager::with_retrieval_cap(1, 4, false, 4);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Busy); // embeds never route CPU
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 4), Route::Cpu);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 1), Route::Busy);
    }

    #[test]
    fn concurrent_dispatch_never_exceeds_depths() {
        let qm = Arc::new(QueueManager::new(40, 10, true));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let qm = Arc::clone(&qm);
            handles.push(std::thread::spawn(move || {
                let mut got = (0u32, 0u32, 0u32);
                for _ in 0..1000 {
                    match qm.dispatch() {
                        Route::Npu => got.0 += 1,
                        Route::Cpu => got.1 += 1,
                        Route::Busy => got.2 += 1,
                    }
                    // occupancy invariant must hold at every instant
                    assert!(qm.npu_occupancy() <= 40);
                    assert!(qm.cpu_occupancy() <= 10);
                }
                got
            }));
        }
        let mut total = (0u32, 0u32, 0u32);
        for h in handles {
            let g = h.join().unwrap();
            total = (total.0 + g.0, total.1 + g.1, total.2 + g.2);
        }
        // conservation: every dispatch returned exactly one route
        assert_eq!(total.0 + total.1 + total.2, 8000);
        // admission never exceeded depth
        assert_eq!(total.0 as usize, 40);
        assert_eq!(total.1 as usize, 10);
    }

    #[test]
    fn npu_leg_shares_pool_with_embeds_and_respects_cap() {
        // NPU pool of 6 with a scan cap of 4: a cost-3 scan + 3 embeds
        // fill the pool exactly; both classes then bounce.
        let qm = QueueManager::with_class_caps(6, 0, false, 0, 4);
        assert_eq!(qm.npu_retrieve_cap(), 4);
        assert_eq!(qm.dispatch_retrieve_npu(3), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Busy);
        assert_eq!(qm.dispatch_retrieve_npu(1), Route::Busy); // cap has 1 left, pool has 0
        assert_eq!(qm.npu_occupancy(), 6);
        assert_eq!(qm.embed_npu_occupancy(), 3);
        assert_eq!(qm.retrieve_npu_occupancy(), 3);
        // Releasing the scan frees exactly its cost for either class.
        qm.release_class(WorkClass::Retrieve, Route::Npu, 3);
        assert_eq!(qm.npu_occupancy(), 3);
        assert_eq!(qm.retrieve_npu_occupancy(), 0);
        assert_eq!(qm.dispatch_retrieve_npu(3), Route::Npu);
        let st = qm.stats();
        assert_eq!(st.routed_retrieve_npu, 2);
        assert_eq!(st.rejected_retrieve_npu, 1);
        assert_eq!(st.bad_releases, 0);
    }

    #[test]
    fn npu_leg_cap_bounds_class_below_pool() {
        let qm = QueueManager::with_class_caps(8, 0, false, 0, 3);
        assert_eq!(qm.dispatch_retrieve_npu(3), Route::Npu);
        // Cap exhausted even though the pool has 5 free units.
        assert_eq!(qm.dispatch_retrieve_npu(1), Route::Busy);
        // Embeds still fill the remaining pool.
        for _ in 0..5 {
            assert_eq!(qm.dispatch(), Route::Npu);
        }
        assert_eq!(qm.dispatch(), Route::Busy);
        assert_eq!(qm.npu_occupancy(), 8);
    }

    #[test]
    fn npu_leg_rejected_scan_rolls_back_cap_when_pool_is_full() {
        // Cap 4 of pool 4; embeds hold 2 pool units, so a cost-3 scan
        // passes the cap check but fails the pool check — the cap
        // acquisition must be rolled back.
        let qm = QueueManager::with_class_caps(4, 0, false, 0, 4);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch_retrieve_npu(3), Route::Busy);
        assert_eq!(qm.retrieve_npu_occupancy(), 0);
        // A scan that fits the pool remainder is admitted.
        assert_eq!(qm.dispatch_retrieve_npu(2), Route::Npu);
        assert_eq!(qm.npu_occupancy(), 4);
    }

    #[test]
    fn npu_leg_disabled_by_legacy_constructors() {
        let qm = QueueManager::with_retrieval_cap(8, 4, true, 4);
        assert_eq!(qm.npu_retrieve_cap(), 0);
        assert_eq!(qm.dispatch_retrieve_npu(1), Route::Busy);
        assert_eq!(qm.npu_occupancy(), 0);
        let qm = QueueManager::new(8, 4, true);
        assert_eq!(qm.dispatch_retrieve_npu(1), Route::Busy);
    }

    #[test]
    fn npu_leg_double_release_cannot_free_embed_slots() {
        // Cross-class containment on the device leg, mirroring the CPU
        // regression: a double-released NPU scan must not liberate
        // capacity embed queries legitimately hold.
        let qm = QueueManager::with_class_caps(4, 0, false, 0, 4);
        assert_eq!(qm.dispatch(), Route::Npu); // embed holds 1 pool unit
        assert_eq!(qm.dispatch_retrieve_npu(2), Route::Npu);
        qm.release_class(WorkClass::Retrieve, Route::Npu, 2);
        assert_eq!(qm.npu_occupancy(), 1);
        assert_eq!(qm.stats().bad_releases, 0);
        // The double release frees nothing and is counted.
        qm.release_class(WorkClass::Retrieve, Route::Npu, 2);
        assert_eq!(qm.stats().bad_releases, 1);
        assert_eq!(qm.npu_occupancy(), 1);
        assert_eq!(qm.embed_npu_occupancy(), 1);
        assert_eq!(qm.retrieve_npu_occupancy(), 0);
        // And the inverse: a rogue embed NPU release cannot free what the
        // retrieval leg holds.
        assert_eq!(qm.dispatch_retrieve_npu(3), Route::Npu);
        qm.release(Route::Npu); // matched: embed held 1
        qm.release(Route::Npu); // rogue: embed holds 0 now
        assert_eq!(qm.stats().bad_releases, 2);
        assert_eq!(qm.npu_occupancy(), 3);
        assert_eq!(qm.retrieve_npu_occupancy(), 3);
    }

    #[test]
    fn npu_leg_oversized_cost_never_admits_but_leaves_no_residue() {
        let qm = QueueManager::with_class_caps(4, 0, false, 0, 4);
        assert_eq!(qm.dispatch_retrieve_npu(5), Route::Busy);
        assert_eq!(qm.retrieve_npu_occupancy(), 0);
        assert_eq!(qm.npu_occupancy(), 0);
        assert_eq!(qm.dispatch_retrieve_npu(4), Route::Npu);
    }

    #[test]
    fn ingest_cap_strictly_bounds_bulk_uploads() {
        // Pool of 8 with an ingest cap of 2: ingest can hold at most 2
        // units no matter how hard the upload storm pushes, and the rest
        // of the pool stays available to serving traffic.
        let qm = QueueManager::with_caps(
            0,
            8,
            true,
            ClassCaps { retrieve: 4, ingest: 2, ..ClassCaps::default() },
        );
        assert_eq!(qm.ingest_cap(), 2);
        assert_eq!(qm.dispatch_class(WorkClass::Ingest, 1), Route::Cpu);
        assert_eq!(qm.dispatch_class(WorkClass::Ingest, 1), Route::Cpu);
        assert_eq!(qm.dispatch_class(WorkClass::Ingest, 1), Route::Busy);
        assert_eq!(qm.ingest_cpu_occupancy(), 2);
        // Serving traffic still fills the remaining 6 units.
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 4), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Busy);
        assert_eq!(qm.cpu_occupancy(), 8);
        // Releasing an ingest slot frees exactly its cost, and only for
        // work that fits its own cap.
        qm.release_class(WorkClass::Ingest, Route::Cpu, 1);
        assert_eq!(qm.cpu_occupancy(), 7);
        assert_eq!(qm.dispatch_class(WorkClass::Ingest, 1), Route::Cpu);
        let st = qm.stats();
        assert_eq!(st.routed_ingest, 3);
        assert_eq!(st.rejected_ingest, 1);
        assert_eq!(st.bad_releases, 0);
    }

    #[test]
    fn ingest_npu_leg_shares_pool_and_rolls_back() {
        // NPU pool of 4, ingest NPU cap 2; embeds hold 3 pool units, so
        // a cost-2 ingest passes the cap but fails the pool — rollback.
        let qm = QueueManager::with_caps(
            4,
            0,
            false,
            ClassCaps { npu_ingest: 2, ..ClassCaps::default() },
        );
        assert_eq!(qm.npu_ingest_cap(), 2);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch_ingest_npu(2), Route::Busy);
        assert_eq!(qm.ingest_npu_occupancy(), 0);
        // A unit that fits the pool remainder is admitted.
        assert_eq!(qm.dispatch_ingest_npu(1), Route::Npu);
        assert_eq!(qm.npu_occupancy(), 4);
        assert_eq!(qm.ingest_npu_occupancy(), 1);
        // Double release is contained exactly like the other classes.
        qm.release_class(WorkClass::Ingest, Route::Npu, 1);
        qm.release_class(WorkClass::Ingest, Route::Npu, 1);
        assert_eq!(qm.stats().bad_releases, 1);
        assert_eq!(qm.npu_occupancy(), 3);
        assert_eq!(qm.embed_npu_occupancy(), 3);
    }

    #[test]
    fn ingest_disabled_by_legacy_constructors() {
        let qm = QueueManager::with_class_caps(8, 4, true, 4, 2);
        assert_eq!(qm.ingest_cap(), 0);
        assert_eq!(qm.npu_ingest_cap(), 0);
        assert_eq!(qm.dispatch_class(WorkClass::Ingest, 1), Route::Busy);
        assert_eq!(qm.dispatch_ingest_npu(1), Route::Busy);
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_eq!(qm.npu_occupancy(), 0);
    }

    #[test]
    fn ingest_release_cannot_free_other_classes() {
        let qm = QueueManager::with_caps(
            0,
            6,
            true,
            ClassCaps { retrieve: 3, ingest: 3, ..ClassCaps::default() },
        );
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, 3), Route::Cpu);
        assert_eq!(qm.dispatch_class(WorkClass::Ingest, 2), Route::Cpu);
        // A rogue over-release from the ingest class frees only what
        // ingest actually holds — never the retrieval slots.
        qm.release_class(WorkClass::Ingest, Route::Cpu, 5);
        assert_eq!(qm.stats().bad_releases, 1);
        assert_eq!(qm.cpu_occupancy(), 3);
        assert_eq!(qm.retrieve_cpu_occupancy(), 3);
        assert_eq!(qm.ingest_cpu_occupancy(), 0);
    }

    #[test]
    fn concurrent_mixed_classes_never_exceed_pool() {
        let qm = Arc::new(QueueManager::with_caps(
            8,
            16,
            true,
            ClassCaps { retrieve: 12, npu_retrieve: 5, ingest: 3, npu_ingest: 2 },
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let qm = Arc::clone(&qm);
            handles.push(std::thread::spawn(move || {
                for i in 0..500 {
                    let (class, cost) = match (t + i) % 4 {
                        0 => (WorkClass::Retrieve, 1 + (i % 4)),
                        1 => (WorkClass::Ingest, 1),
                        _ => (WorkClass::Embed, 1),
                    };
                    let route = match class {
                        WorkClass::Retrieve if (t + i) % 2 == 0 => {
                            qm.dispatch_retrieve_npu(cost) // the offload leg
                        }
                        WorkClass::Ingest if (t + i) % 2 == 0 => {
                            qm.dispatch_ingest_npu(cost) // the valley-soak leg
                        }
                        _ => qm.dispatch_class(class, cost),
                    };
                    // pool + cap bounds hold at every instant, every leg
                    assert!(qm.cpu_occupancy() <= 16);
                    assert!(qm.retrieve_cpu_occupancy() <= 12);
                    assert!(qm.ingest_cpu_occupancy() <= 3);
                    assert!(qm.npu_occupancy() <= 8);
                    assert!(qm.retrieve_npu_occupancy() <= 5);
                    assert!(qm.ingest_npu_occupancy() <= 2);
                    qm.release_class(class, route, cost);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(qm.npu_occupancy(), 0);
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_eq!(qm.embed_cpu_occupancy(), 0);
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        assert_eq!(qm.ingest_cpu_occupancy(), 0);
        assert_eq!(qm.embed_npu_occupancy(), 0);
        assert_eq!(qm.retrieve_npu_occupancy(), 0);
        assert_eq!(qm.ingest_npu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
    }
}
