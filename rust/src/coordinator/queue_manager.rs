//! The queue manager — Algorithm 1 of the paper, verbatim semantics:
//!
//! ```text
//! foreach query:
//!   if NPU queue is not full:        push NPU,  return 'NPU'
//!   elif heterogeneous enabled:
//!     if CPU queue is not full:      push CPU,  return 'CPU'
//!     else:                          return 'BUSY'
//!   else:                            return 'BUSY'
//! ```
//!
//! Queue *depths* are the paper's C^max_NPU / C^max_CPU (Eqs. 7-10),
//! calibrated by [`crate::estimator`]. Occupancy counts queries from
//! dispatch until their batch completes, so "depth" bounds the device's
//! in-flight concurrency exactly as the paper's C_d does.
//!
//! Lock-free: occupancy is a pair of atomics with CAS admission, making
//! dispatch safe from any number of front-end threads (and cheap — see
//! benches/micro.rs).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Dispatch decision for one query (Algorithm 1's return value).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Route {
    Npu,
    Cpu,
    /// Both queues full (or CPU disabled): reject with 'busy'.
    Busy,
}

impl std::fmt::Display for Route {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Route::Npu => write!(f, "NPU"),
            Route::Cpu => write!(f, "CPU"),
            Route::Busy => write!(f, "BUSY"),
        }
    }
}

/// Dispatch/release counters (see [`QueueManager::stats`]). A named
/// struct so new counters don't break existing destructuring call sites.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueueStats {
    pub routed_npu: u64,
    pub routed_cpu: u64,
    pub rejected: u64,
    /// Releases without a matching dispatch (see
    /// [`QueueManager::release`]); 0 in a healthy service.
    pub bad_releases: u64,
}

/// Bounded two-queue admission state.
#[derive(Debug)]
pub struct QueueManager {
    npu_depth: usize,
    cpu_depth: usize,
    hetero: bool,
    npu_len: AtomicUsize,
    cpu_len: AtomicUsize,
    // counters for /stats
    routed_npu: AtomicU64,
    routed_cpu: AtomicU64,
    rejected: AtomicU64,
    bad_releases: AtomicU64,
}

impl QueueManager {
    /// `cpu_depth` is ignored unless `hetero` (Algorithm 2 forces the
    /// option off when only one device class exists).
    pub fn new(npu_depth: usize, cpu_depth: usize, hetero: bool) -> QueueManager {
        QueueManager {
            npu_depth,
            cpu_depth: if hetero { cpu_depth } else { 0 },
            hetero,
            npu_len: AtomicUsize::new(0),
            cpu_len: AtomicUsize::new(0),
            routed_npu: AtomicU64::new(0),
            routed_cpu: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            bad_releases: AtomicU64::new(0),
        }
    }

    /// Algorithm 1 for one query. On `Npu`/`Cpu` the corresponding
    /// occupancy is incremented; the caller must [`QueueManager::release`]
    /// when the query's batch completes (or the submit fails downstream).
    pub fn dispatch(&self) -> Route {
        if try_acquire(&self.npu_len, self.npu_depth) {
            self.routed_npu.fetch_add(1, Ordering::Relaxed);
            return Route::Npu;
        }
        if self.hetero && try_acquire(&self.cpu_len, self.cpu_depth) {
            self.routed_cpu.fetch_add(1, Ordering::Relaxed);
            return Route::Cpu;
        }
        self.rejected.fetch_add(1, Ordering::Relaxed);
        Route::Busy
    }

    /// Return one slot. Must match a prior successful dispatch.
    ///
    /// Hardened against mismatched releases in release builds: the
    /// decrement saturates at zero (a plain `fetch_sub` would wrap the
    /// occupancy to `usize::MAX` and permanently wedge admission into
    /// BUSY), and every mismatch is counted in [`QueueManager::stats`]
    /// so operators can see the accounting bug instead of absorbing it.
    pub fn release(&self, route: Route) {
        let q = match route {
            Route::Npu => &self.npu_len,
            Route::Cpu => &self.cpu_len,
            Route::Busy => return,
        };
        let mut cur = q.load(Ordering::Acquire);
        loop {
            if cur == 0 {
                self.bad_releases.fetch_add(1, Ordering::Relaxed);
                return;
            }
            match q.compare_exchange_weak(cur, cur - 1, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    pub fn npu_occupancy(&self) -> usize {
        self.npu_len.load(Ordering::Acquire)
    }

    pub fn cpu_occupancy(&self) -> usize {
        self.cpu_len.load(Ordering::Acquire)
    }

    pub fn npu_depth(&self) -> usize {
        self.npu_depth
    }

    pub fn cpu_depth(&self) -> usize {
        self.cpu_depth
    }

    pub fn hetero(&self) -> bool {
        self.hetero
    }

    /// Total admitted capacity (paper: C_NPU + C_CPU).
    pub fn total_depth(&self) -> usize {
        self.npu_depth + self.cpu_depth
    }

    pub fn stats(&self) -> QueueStats {
        QueueStats {
            routed_npu: self.routed_npu.load(Ordering::Relaxed),
            routed_cpu: self.routed_cpu.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            bad_releases: self.bad_releases.load(Ordering::Relaxed),
        }
    }
}

/// CAS-increment `len` if below `cap`.
fn try_acquire(len: &AtomicUsize, cap: usize) -> bool {
    let mut cur = len.load(Ordering::Relaxed);
    loop {
        if cur >= cap {
            return false;
        }
        match len.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn npu_priority_then_cpu_then_busy() {
        // Algorithm 1's dispatch order, exactly.
        let qm = QueueManager::new(2, 1, true);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Busy);
        assert_eq!(qm.npu_occupancy(), 2);
        assert_eq!(qm.cpu_occupancy(), 1);
    }

    #[test]
    fn hetero_disabled_skips_cpu() {
        let qm = QueueManager::new(1, 5, false);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Busy); // CPU never considered
        assert_eq!(qm.cpu_depth(), 0);
    }

    #[test]
    fn release_frees_slot_for_reuse() {
        let qm = QueueManager::new(1, 0, false);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Busy);
        qm.release(Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
    }

    #[test]
    fn busy_release_is_noop() {
        let qm = QueueManager::new(0, 0, true);
        assert_eq!(qm.dispatch(), Route::Busy);
        qm.release(Route::Busy);
        assert_eq!(qm.npu_occupancy(), 0);
    }

    #[test]
    fn zero_depths_always_busy() {
        let qm = QueueManager::new(0, 0, true);
        for _ in 0..5 {
            assert_eq!(qm.dispatch(), Route::Busy);
        }
        assert_eq!(qm.stats().rejected, 5);
    }

    #[test]
    fn stats_count_routes() {
        let qm = QueueManager::new(1, 1, true);
        qm.dispatch();
        qm.dispatch();
        qm.dispatch();
        assert_eq!(
            qm.stats(),
            QueueStats { routed_npu: 1, routed_cpu: 1, rejected: 1, bad_releases: 0 }
        );
    }

    #[test]
    fn mismatched_release_saturates_and_is_counted() {
        let qm = QueueManager::new(2, 1, true);
        // No dispatch yet: releases must not wrap occupancy below zero.
        qm.release(Route::Npu);
        qm.release(Route::Cpu);
        assert_eq!(qm.npu_occupancy(), 0);
        assert_eq!(qm.cpu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 2);
        // Admission still works at full depth afterwards.
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Npu);
        assert_eq!(qm.dispatch(), Route::Cpu);
        assert_eq!(qm.dispatch(), Route::Busy);
        // Matched releases don't count as mismatches.
        qm.release(Route::Npu);
        assert_eq!(qm.stats().bad_releases, 2);
        assert_eq!(qm.npu_occupancy(), 1);
    }

    #[test]
    fn concurrent_dispatch_never_exceeds_depths() {
        let qm = Arc::new(QueueManager::new(40, 10, true));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let qm = Arc::clone(&qm);
            handles.push(std::thread::spawn(move || {
                let mut got = (0u32, 0u32, 0u32);
                for _ in 0..1000 {
                    match qm.dispatch() {
                        Route::Npu => got.0 += 1,
                        Route::Cpu => got.1 += 1,
                        Route::Busy => got.2 += 1,
                    }
                    // occupancy invariant must hold at every instant
                    assert!(qm.npu_occupancy() <= 40);
                    assert!(qm.cpu_occupancy() <= 10);
                }
                got
            }));
        }
        let mut total = (0u32, 0u32, 0u32);
        for h in handles {
            let g = h.join().unwrap();
            total = (total.0 + g.0, total.1 + g.1, total.2 + g.2);
        }
        // conservation: every dispatch returned exactly one route
        assert_eq!(total.0 + total.1 + total.2, 8000);
        // admission never exceeded depth
        assert_eq!(total.0 as usize, 40);
        assert_eq!(total.1 as usize, 10);
    }
}
