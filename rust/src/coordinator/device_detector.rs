//! The device detector — Algorithm 2 of the paper.
//!
//! At service initialisation the detector enumerates available devices
//! and decides the main/auxiliary roles plus worker counts; heterogeneous
//! computing is *forced off* unless both device classes are present and
//! the operator asked for it.

use crate::devices::profile::DeviceKind;

/// Detected hardware (paper inputs NPU_i, CPU_j).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Inventory {
    /// Number of NPU/GPU cards (I in Algorithm 2).
    pub npus: usize,
    /// Number of CPU instances worth of cores (J in Algorithm 2; the
    /// paper recommends one CPU instance per machine, §4.3).
    pub cpus: usize,
}

impl Inventory {
    /// Detect the running host. This image has no NPUs; NPU count can be
    /// injected for simulation via `WINDVE_NPUS`.
    pub fn detect() -> Inventory {
        let npus = std::env::var("WINDVE_NPUS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let cpus = 1; // one CPU instance per machine (paper §4.3)
        Inventory { npus, cpus }
    }
}

/// Algorithm 2's outputs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Detection {
    pub device_main: Option<DeviceKind>,
    pub device_auxiliary: Option<DeviceKind>,
    pub worker_num_main: usize,
    pub worker_num_auxiliary: usize,
    pub heter_enable: bool,
}

/// Algorithm 2, line for line. `heter_requested` is the operator's
/// heterogeneous-computing option.
pub fn detect(inv: Inventory, heter_requested: bool) -> Detection {
    if inv.npus > 0 {
        if heter_requested && inv.cpus > 0 {
            Detection {
                device_main: Some(DeviceKind::Npu),
                device_auxiliary: Some(DeviceKind::Cpu),
                worker_num_main: inv.npus,
                worker_num_auxiliary: inv.cpus,
                heter_enable: true,
            }
        } else {
            // NPUs only establish a queue "to ensure high performance".
            Detection {
                device_main: Some(DeviceKind::Npu),
                device_auxiliary: None,
                worker_num_main: inv.npus,
                worker_num_auxiliary: 0,
                heter_enable: false,
            }
        }
    } else if inv.cpus > 0 {
        // CPU-only host: single queue, hetero forced off.
        Detection {
            device_main: Some(DeviceKind::Cpu),
            device_auxiliary: None,
            worker_num_main: inv.cpus,
            worker_num_auxiliary: 0,
            heter_enable: false,
        }
    } else {
        Detection {
            device_main: None,
            device_auxiliary: None,
            worker_num_main: 0,
            worker_num_auxiliary: 0,
            heter_enable: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npu_plus_cpu_with_hetero() {
        let d = detect(Inventory { npus: 2, cpus: 1 }, true);
        assert_eq!(d.device_main, Some(DeviceKind::Npu));
        assert_eq!(d.device_auxiliary, Some(DeviceKind::Cpu));
        assert_eq!(d.worker_num_main, 2);
        assert_eq!(d.worker_num_auxiliary, 1);
        assert!(d.heter_enable);
    }

    #[test]
    fn npu_plus_cpu_hetero_declined() {
        // Option off → only the NPU queue is created.
        let d = detect(Inventory { npus: 1, cpus: 1 }, false);
        assert_eq!(d.device_main, Some(DeviceKind::Npu));
        assert_eq!(d.device_auxiliary, None);
        assert_eq!(d.worker_num_auxiliary, 0);
        assert!(!d.heter_enable);
    }

    #[test]
    fn cpu_only_forces_hetero_off() {
        // Algorithm 2's else-branch: single device type → hetero disabled.
        let d = detect(Inventory { npus: 0, cpus: 1 }, true);
        assert_eq!(d.device_main, Some(DeviceKind::Cpu));
        assert_eq!(d.device_auxiliary, None);
        assert_eq!(d.worker_num_main, 1);
        assert!(!d.heter_enable);
    }

    #[test]
    fn nothing_detected() {
        let d = detect(Inventory { npus: 0, cpus: 0 }, true);
        assert_eq!(d.device_main, None);
        assert!(!d.heter_enable);
    }

    #[test]
    fn npu_only_host() {
        let d = detect(Inventory { npus: 4, cpus: 0 }, true);
        assert_eq!(d.device_main, Some(DeviceKind::Npu));
        assert_eq!(d.worker_num_main, 4);
        assert!(!d.heter_enable, "no CPU to offload to");
    }
}
