//! Device queues and dynamic batching.
//!
//! Each device (NPU / CPU) owns one [`DeviceQueue`]: admitted queries are
//! "grouped into batches and processed by the corresponding instances"
//! (paper §4.1). Workers block on the queue and drain up to their
//! backend's max batch in FIFO order — under closed-loop peak load this
//! naturally forms the full-depth batches the paper's latency model
//! assumes, while staying work-conserving at low load (batch of 1 leaves
//! immediately; no artificial batching delay is ever added to the SLO).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::queue_manager::WorkClass;

/// One admitted query travelling through a device queue.
///
/// The text is an `Arc<str>` so the HTTP front end, the cache key, the
/// queue and the backend batch all share one allocation (no per-hop
/// clone of the payload). `class` records which admission class holds
/// the slot — workers release `(class, route)` pairs, so ingest embeds
/// travelling through the same queue free ingest capacity, not embed
/// capacity.
pub struct Pending<T> {
    pub text: Arc<str>,
    pub class: WorkClass,
    pub enqueued: Instant,
    /// Request trace ID (0 = untraced); workers attribute their
    /// queue_wait / batch_form / embed spans to it.
    pub trace: u64,
    /// Response slot (a per-request channel in the real service).
    pub reply: T,
}

/// Blocking MPMC FIFO with batch drain and shutdown.
pub struct DeviceQueue<T> {
    inner: Mutex<VecDeque<Pending<T>>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl<T> Default for DeviceQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DeviceQueue<T> {
    pub fn new() -> DeviceQueue<T> {
        DeviceQueue {
            inner: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    /// Take the queue lock, recovering from poisoning. Every panic point
    /// in the critical sections below leaves the deque structurally
    /// intact (allocation failures in `push_back`/`collect` surface
    /// before or between whole-item moves), so recovery can at worst
    /// lose in-flight items — while honoring the poison would instead
    /// panic every worker blocked on this device, wedging the service.
    fn lock(&self) -> std::sync::MutexGuard<'_, VecDeque<Pending<T>>> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Push one admitted query (admission control already happened in the
    /// queue manager; this queue never refuses).
    pub fn push(&self, p: Pending<T>) {
        let mut q = self.lock();
        q.push_back(p);
        drop(q);
        self.cv.notify_one();
    }

    /// Block until at least one query is available (or shutdown), then
    /// drain up to `max` in arrival order. `None` = shut down and empty.
    pub fn drain_batch(&self, max: usize) -> Option<Vec<Pending<T>>> {
        let mut q = self.lock();
        loop {
            if !q.is_empty() {
                let n = q.len().min(max.max(1));
                return Some(q.drain(..n).collect());
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            // Same poison-recovery rationale as `lock`.
            q = self
                .cv
                .wait(q)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Wake all workers and let them exit once the queue is empty.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// Pick the (batch, seq) bucket shape for a drained batch: the max token
/// count decides seq, the batch length decides batch. Returned values are
/// *requested* sizes; the engine rounds up to exported buckets.
pub fn batch_shape(token_counts: &[usize]) -> (usize, usize) {
    let b = token_counts.len();
    let s = token_counts.iter().copied().max().unwrap_or(1);
    (b, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn pending(text: &str) -> Pending<u32> {
        Pending {
            text: Arc::from(text),
            class: WorkClass::Embed,
            enqueued: Instant::now(),
            trace: 0,
            reply: 0,
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let q: DeviceQueue<u32> = DeviceQueue::new();
        for i in 0..5 {
            q.push(pending(&format!("q{i}")));
        }
        let batch = q.drain_batch(10).unwrap();
        let texts: Vec<&str> = batch.iter().map(|p| p.text.as_ref()).collect();
        assert_eq!(texts, vec!["q0", "q1", "q2", "q3", "q4"]);
    }

    #[test]
    fn drain_respects_max() {
        let q: DeviceQueue<u32> = DeviceQueue::new();
        for i in 0..10 {
            q.push(pending(&format!("q{i}")));
        }
        assert_eq!(q.drain_batch(4).unwrap().len(), 4);
        assert_eq!(q.len(), 6);
        assert_eq!(q.drain_batch(100).unwrap().len(), 6);
    }

    #[test]
    fn drain_blocks_until_push() {
        let q: Arc<DeviceQueue<u32>> = Arc::new(DeviceQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.drain_batch(8));
        std::thread::sleep(Duration::from_millis(30));
        q.push(pending("late"));
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].text.as_ref(), "late");
    }

    #[test]
    fn close_unblocks_with_none() {
        let q: Arc<DeviceQueue<u32>> = Arc::new(DeviceQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.drain_batch(8));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_drains_remaining_first() {
        let q: DeviceQueue<u32> = DeviceQueue::new();
        q.push(pending("left over"));
        q.close();
        assert_eq!(q.drain_batch(8).unwrap().len(), 1);
        assert!(q.drain_batch(8).is_none());
    }

    #[test]
    fn batch_shape_uses_max_len() {
        assert_eq!(batch_shape(&[3, 75, 12]), (3, 75));
        assert_eq!(batch_shape(&[1]), (1, 1));
    }

    #[test]
    fn concurrent_producers_consumers_conserve_items() {
        let q: Arc<DeviceQueue<u32>> = Arc::new(DeviceQueue::new());
        let total = 4 * 500;
        let mut producers = Vec::new();
        for t in 0..4 {
            let q = Arc::clone(&q);
            producers.push(std::thread::spawn(move || {
                for i in 0..500 {
                    q.push(Pending {
                        text: Arc::from(format!("{t}-{i}")),
                        class: WorkClass::Embed,
                        enqueued: Instant::now(),
                        trace: 0,
                        reply: 0,
                    });
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            consumers.push(std::thread::spawn(move || {
                let mut seen = 0usize;
                while let Some(batch) = q.drain_batch(16) {
                    seen += batch.len();
                }
                seen
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        // Let consumers finish the backlog, then close.
        while !q.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        }
        q.close();
        let seen: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(seen, total);
    }
}
