//! L3 coordinator — the paper's system contribution.
//!
//! * [`queue_manager`] — Algorithm 1: NPU-priority dispatch with bounded
//!   per-device queues and BUSY rejection.
//! * [`device_detector`] — Algorithm 2: device discovery → main/auxiliary
//!   roles and worker counts.
//! * [`batcher`] — drains a device queue into bucket-sized batches.
//! * [`instance`] — worker threads, each owning one model copy (engine).
//! * [`service`] — the WindVE facade wiring all of it together.

pub mod balancer;
pub mod batcher;
pub mod cache;
pub mod device_detector;
pub mod instance;
pub mod queue_manager;
pub mod service;

pub use device_detector::{detect, Detection, Inventory};
pub use queue_manager::{ClassCaps, QueueManager, QueueStats, Route, WorkClass};
pub use service::{ServiceConfig, WindVE};
