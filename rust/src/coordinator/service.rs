//! The WindVE service facade (paper Figure 3 (B)).
//!
//! Wires the device detector's decision into a [`QueueManager`], one
//! [`DeviceQueue`] per device class, and worker instances. The request
//! path is:
//!
//! ```text
//! submit(text) → QueueManager::dispatch (Algorithm 1)
//!     Npu → NPU queue → NPU worker batch → reply
//!     Cpu → CPU queue → CPU worker batch → reply
//!     Busy → ServeError::Busy ("service declines excessive queries and
//!            responds with a 'busy' status")
//! ```

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{DeviceQueue, Pending};
use super::cache::EmbeddingCache;
use super::instance::{spawn_worker, BackendFactory, Reply};
use super::queue_manager::{QueueManager, Route};
use crate::metrics::Registry;

/// Why a request did not produce an embedding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission — both queues full (Algorithm 1's 'BUSY').
    Busy,
    /// The owning worker failed the batch.
    Backend(String),
    /// The caller's deadline passed.
    Timeout,
    /// Service shut down while the query was in flight.
    Shutdown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Busy => write!(f, "busy"),
            ServeError::Backend(m) => write!(f, "backend: {m}"),
            ServeError::Timeout => write!(f, "timeout"),
            ServeError::Shutdown => write!(f, "shutdown"),
        }
    }
}

/// Static service wiring.
pub struct ServiceConfig {
    /// NPU queue depth (C^max_NPU, Eqs. 7-8).
    pub npu_depth: usize,
    /// CPU queue depth (C^max_CPU, Eqs. 9-10). Ignored unless `hetero`.
    pub cpu_depth: usize,
    /// Heterogeneous-computing option (Algorithm 2 may force it off).
    pub hetero: bool,
    /// Worker instances per device class.
    pub npu_workers: usize,
    pub cpu_workers: usize,
    /// Optional core pinning for CPU workers (paper §4.4).
    pub cpu_pin_cores: Option<Vec<usize>>,
    /// Embedding-cache entries (0 disables). Hits are served without
    /// consuming a queue slot — see coordinator::cache.
    pub cache_entries: usize,
    /// Tokenizer params for cache keys (vocab, max_len); defaults match
    /// bge_micro buckets.
    pub cache_key_space: (u32, usize),
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            npu_depth: 44,
            cpu_depth: 8,
            hetero: true,
            npu_workers: 1,
            cpu_workers: 1,
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
        }
    }
}

/// In-flight request handle.
impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket").field("route", &self.route).finish()
    }
}

pub struct Ticket {
    pub route: Route,
    rx: Receiver<Result<Vec<f32>, String>>,
    submitted: Instant,
}

impl Ticket {
    /// Wait for the embedding (bounded by `timeout`).
    pub fn wait(self, timeout: Duration) -> Result<Vec<f32>, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(m)) => Err(ServeError::Backend(m)),
            Err(RecvTimeoutError::Timeout) => Err(ServeError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Shutdown),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.submitted.elapsed()
    }
}

/// The running WindVE service.
pub struct WindVE {
    qm: Arc<QueueManager>,
    npu_queue: Arc<DeviceQueue<Reply>>,
    cpu_queue: Option<Arc<DeviceQueue<Reply>>>,
    workers: Vec<JoinHandle<()>>,
    cache: Option<Arc<EmbeddingCache>>,
    cache_key_space: (u32, usize),
    pub metrics: Registry,
}

impl WindVE {
    /// Start workers. `npu_factories` / `cpu_factories` supply one backend
    /// factory per worker (backends are built on the worker threads —
    /// PJRT handles are not `Send`).
    pub fn start(
        cfg: ServiceConfig,
        npu_factories: Vec<BackendFactory>,
        cpu_factories: Vec<BackendFactory>,
    ) -> Result<WindVE> {
        anyhow::ensure!(
            npu_factories.len() == cfg.npu_workers,
            "need {} npu factories, got {}",
            cfg.npu_workers,
            npu_factories.len()
        );
        let hetero = cfg.hetero && cfg.cpu_workers > 0;
        anyhow::ensure!(
            !hetero || cpu_factories.len() == cfg.cpu_workers,
            "need {} cpu factories, got {}",
            cfg.cpu_workers,
            cpu_factories.len()
        );

        let metrics = Registry::new();
        let qm = Arc::new(QueueManager::new(cfg.npu_depth, cfg.cpu_depth, hetero));
        let npu_queue = Arc::new(DeviceQueue::new());
        let cpu_queue = hetero.then(|| Arc::new(DeviceQueue::new()));

        let mut workers = Vec::new();
        for (i, f) in npu_factories.into_iter().enumerate() {
            workers.push(spawn_worker(
                format!("npu{i}"),
                Arc::clone(&npu_queue),
                Arc::clone(&qm),
                Route::Npu,
                f,
                metrics.clone(),
                None,
            ));
        }
        if let Some(cq) = &cpu_queue {
            for (i, f) in cpu_factories.into_iter().enumerate() {
                workers.push(spawn_worker(
                    format!("cpu{i}"),
                    Arc::clone(cq),
                    Arc::clone(&qm),
                    Route::Cpu,
                    f,
                    metrics.clone(),
                    cfg.cpu_pin_cores.clone(),
                ));
            }
        }
        let cache = (cfg.cache_entries > 0)
            .then(|| Arc::new(EmbeddingCache::new(cfg.cache_entries)));
        Ok(WindVE {
            qm,
            npu_queue,
            cpu_queue,
            workers,
            cache,
            cache_key_space: cfg.cache_key_space,
            metrics,
        })
    }

    /// Admit and enqueue one query (Algorithm 1). Non-blocking.
    pub fn submit(&self, text: impl Into<String>) -> Result<Ticket, ServeError> {
        let route = self.qm.dispatch();
        let queue = match route {
            Route::Npu => &self.npu_queue,
            Route::Cpu => self.cpu_queue.as_ref().expect("cpu route implies cpu queue"),
            Route::Busy => {
                self.metrics.counter("service.busy").inc();
                return Err(ServeError::Busy);
            }
        };
        let (tx, rx) = std::sync::mpsc::channel();
        queue.push(Pending { text: text.into(), enqueued: Instant::now(), reply: tx });
        self.metrics.counter("service.accepted").inc();
        Ok(Ticket { route, rx, submitted: Instant::now() })
    }

    /// Convenience: submit and wait. Consults the embedding cache first
    /// (a hit never touches the queue manager) and fills it on success.
    pub fn embed_blocking(
        &self,
        text: impl Into<String>,
        timeout: Duration,
    ) -> Result<Vec<f32>, ServeError> {
        let text = text.into();
        let cache_key = self.cache.as_ref().map(|c| {
            let (vocab, max_len) = self.cache_key_space;
            (Arc::clone(c), EmbeddingCache::key(&text, vocab, max_len))
        });
        if let Some((cache, key)) = &cache_key {
            if let Some(v) = cache.get(*key) {
                self.metrics.counter("service.cache_hits").inc();
                return Ok(v);
            }
        }
        let ticket = self.submit(text)?;
        let route = ticket.route;
        let t0 = Instant::now();
        let out = ticket.wait(timeout);
        if let (Some((cache, key)), Ok(v)) = (&cache_key, &out) {
            cache.put(*key, v.clone());
        }
        let h = match route {
            Route::Npu => self.metrics.histogram("service.e2e_npu_ns"),
            Route::Cpu => self.metrics.histogram("service.e2e_cpu_ns"),
            Route::Busy => unreachable!(),
        };
        h.record(t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn queue_manager(&self) -> &QueueManager {
        &self.qm
    }

    /// Close queues and join workers.
    pub fn shutdown(mut self) {
        self.npu_queue.close();
        if let Some(cq) = &self.cpu_queue {
            cq.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for WindVE {
    fn drop(&mut self) {
        self.npu_queue.close();
        if let Some(cq) = &self.cpu_queue {
            cq.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::executor::Backend;

    struct EchoBackend {
        tag: f32,
        delay: Duration,
    }
    impl Backend for EchoBackend {
        fn embed(&mut self, texts: &[String]) -> anyhow::Result<Vec<Vec<f32>>> {
            std::thread::sleep(self.delay);
            Ok(texts.iter().map(|_| vec![self.tag]).collect())
        }
        fn describe(&self) -> String {
            format!("echo{}", self.tag)
        }
        fn max_batch(&self) -> usize {
            16
        }
    }

    fn echo_factory(tag: f32, delay_ms: u64) -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(EchoBackend { tag, delay: Duration::from_millis(delay_ms) })
                as Box<dyn Backend>)
        })
    }

    fn small_service(npu_depth: usize, cpu_depth: usize, hetero: bool) -> WindVE {
        WindVE::start(
            ServiceConfig {
                npu_depth,
                cpu_depth,
                hetero,
                npu_workers: 1,
                cpu_workers: if hetero { 1 } else { 0 },
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
            },
            vec![echo_factory(1.0, 5)],
            if hetero { vec![echo_factory(2.0, 5)] } else { vec![] },
        )
        .unwrap()
    }

    #[test]
    fn basic_embed_roundtrip() {
        let svc = small_service(4, 2, true);
        let v = svc.embed_blocking("hello", Duration::from_secs(5)).unwrap();
        assert_eq!(v, vec![1.0]); // NPU-priority: tag 1.0
        svc.shutdown();
    }

    #[test]
    fn overflow_routes_to_cpu_then_busy() {
        // Slow NPU worker so its queue stays occupied.
        let svc = WindVE::start(
            ServiceConfig {
                npu_depth: 1,
                cpu_depth: 1,
                hetero: true,
                npu_workers: 1,
                cpu_workers: 1,
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
            },
            vec![echo_factory(1.0, 300)],
            vec![echo_factory(2.0, 300)],
        )
        .unwrap();
        let t1 = svc.submit("a").unwrap();
        assert_eq!(t1.route, Route::Npu);
        let t2 = svc.submit("b").unwrap();
        assert_eq!(t2.route, Route::Cpu);
        assert_eq!(svc.submit("c").unwrap_err(), ServeError::Busy);
        // Wait them out; slots free again.
        assert_eq!(t1.wait(Duration::from_secs(5)).unwrap(), vec![1.0]);
        assert_eq!(t2.wait(Duration::from_secs(5)).unwrap(), vec![2.0]);
        let t4 = svc.submit("d").unwrap();
        assert_eq!(t4.route, Route::Npu);
        t4.wait(Duration::from_secs(5)).unwrap();
        svc.shutdown();
    }

    #[test]
    fn hetero_disabled_never_uses_cpu() {
        let svc = small_service(2, 8, false);
        let mut routes = Vec::new();
        for i in 0..3 {
            match svc.submit(format!("q{i}")) {
                Ok(t) => routes.push(t.route),
                Err(e) => {
                    assert_eq!(e, ServeError::Busy);
                    routes.push(Route::Busy);
                }
            }
        }
        assert!(!routes.contains(&Route::Cpu));
        svc.shutdown();
    }

    #[test]
    fn concurrent_submitters_all_complete_or_busy() {
        let svc = Arc::new(small_service(8, 4, true));
        let mut handles = Vec::new();
        for t in 0..6 {
            let svc = Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                let mut busy = 0;
                for i in 0..30 {
                    match svc.embed_blocking(format!("{t}-{i}"), Duration::from_secs(10)) {
                        Ok(_) => ok += 1,
                        Err(ServeError::Busy) => busy += 1,
                        Err(e) => panic!("unexpected error {e}"),
                    }
                }
                (ok, busy)
            }));
        }
        let mut total_ok = 0;
        for h in handles {
            let (ok, _busy) = h.join().unwrap();
            total_ok += ok;
        }
        assert!(total_ok > 0);
        // After the storm, occupancy must drain to zero.
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(svc.queue_manager().npu_occupancy(), 0);
        assert_eq!(svc.queue_manager().cpu_occupancy(), 0);
    }

    #[test]
    fn metrics_track_accept_and_busy() {
        let svc = small_service(1, 0, false);
        let _t = svc.submit("hold").unwrap();
        let _ = svc.submit("reject").unwrap_err();
        assert_eq!(svc.metrics.counter("service.accepted").get(), 1);
        assert_eq!(svc.metrics.counter("service.busy").get(), 1);
    }
}
