//! The WindVE service facade (paper Figure 3 (B)).
//!
//! Wires the device detector's decision into a [`QueueManager`], one
//! [`DeviceQueue`] per device class, and worker instances. The request
//! path is:
//!
//! ```text
//! submit(text) → QueueManager::dispatch (Algorithm 1)
//!     Npu → NPU queue → NPU worker batch → reply
//!     Cpu → CPU queue → CPU worker batch → reply
//!     Busy → ServeError::Busy ("service declines excessive queries and
//!            responds with a 'busy' status")
//! ```

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{DeviceQueue, Pending};
use super::cache::{CacheStats, EmbeddingCache};
use super::instance::{spawn_worker, BackendFactory, Reply};
use super::queue_manager::{AdmissionGuard, ClassCaps, QueueManager, Route, WorkClass};
use crate::devices::executor::RetrievalExecutor;
use crate::durability::DurableStore;
use crate::estimator::SloGovernor;
use crate::ingest::IngestStats;
use crate::metrics::trace::{ClassLabel, CodecLabel, RouteLabel, Stage, Tracer};
use crate::metrics::{Counter, Histogram, Registry};
use crate::runtime::NpuScanner;
use crate::vecstore::{Hit, Quant};

/// Why a request did not produce an embedding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission — both queues full (Algorithm 1's 'BUSY').
    Busy,
    /// The owning worker failed the batch.
    Backend(String),
    /// The caller's deadline passed.
    Timeout,
    /// Service shut down while the query was in flight.
    Shutdown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Busy => write!(f, "busy"),
            ServeError::Backend(m) => write!(f, "backend: {m}"),
            ServeError::Timeout => write!(f, "timeout"),
            ServeError::Shutdown => write!(f, "shutdown"),
        }
    }
}

/// Static service wiring.
pub struct ServiceConfig {
    /// NPU queue depth (C^max_NPU, Eqs. 7-8).
    pub npu_depth: usize,
    /// CPU queue depth (C^max_CPU, Eqs. 9-10): the shared pool embed
    /// overflow queries (when `hetero`) and admitted retrieval scans
    /// draw from. With `cpu_depth == 0` there is no calibrated CPU
    /// budget at all — embeds never overflow and retrieval scans run
    /// unaccounted (admission needs a pool to meter against).
    pub cpu_depth: usize,
    /// Heterogeneous-computing option (Algorithm 2 may force it off).
    pub hetero: bool,
    /// Worker instances per device class.
    pub npu_workers: usize,
    pub cpu_workers: usize,
    /// Optional core pinning for CPU workers (paper §4.4).
    pub cpu_pin_cores: Option<Vec<usize>>,
    /// Embedding-cache entries (0 disables). Hits are served without
    /// consuming a queue slot — see coordinator::cache.
    pub cache_entries: usize,
    /// Tokenizer params for cache keys (vocab, max_len); defaults match
    /// bge_micro buckets.
    pub cache_key_space: (u32, usize),
    /// Gate retrieval scans through the queue manager's CPU admission
    /// (paper Eqs. 9-10 extended to scan work). When false — or when
    /// `cpu_depth == 0`, where there is no calibrated budget to enforce
    /// (an NPU-only deployment must not lose retrieval to a zero cap) —
    /// scans run outside depth accounting, the PR-1/2 behavior.
    /// Admission gates scheduling only, never scoring, so results are
    /// identical either way.
    pub retrieval_admission: bool,
    /// Cap (cost units) on the CPU depth retrieval scans may hold
    /// concurrently; `None` lets scans compete for the whole CPU pool.
    /// Calibrate with `estimator::depth::fine_tune_depths_mixed`.
    pub retrieval_depth: Option<usize>,
    /// Scanned-arena bytes equal to one embed-query cost unit — the
    /// normalizer in `queue_manager::retrieval_slot_cost`.
    pub retrieval_cost_unit_bytes: usize,
    /// Cap (cost units) on the NPU depth offloaded retrieval scans may
    /// hold concurrently — the batched NPU retrieval offload leg, the
    /// inverse of the paper's CPU offload. 0 (the default) disables
    /// offload; `retrieval_admission: false` also disables it (the leg
    /// is admission-aware by construction — un-metered scans never touch
    /// the NPU pool). Calibrate with
    /// `estimator::depth::fine_tune_npu_retrieval_cap`.
    pub npu_retrieval_depth: usize,
    /// Offload low-water mark: a scan is only routed to the NPU leg
    /// while embed-side NPU occupancy is at or below this fraction of
    /// `npu_depth` — the "embedding traffic is low" policy gate.
    pub npu_offload_low_water: f64,
    /// Strict cap (cost units, clamped to `cpu_depth`) on the CPU depth
    /// streaming-ingest embeds may hold concurrently
    /// (`WorkClass::Ingest`). Ingest never reserves capacity — this only
    /// bounds how much of the shared pool a bulk upload can soak, so
    /// online indexing can never starve Embed/Retrieve. Ingest on the
    /// CPU additionally requires a hetero CPU worker to run on.
    pub ingest_depth: usize,
    /// Strict cap (cost units, clamped to `npu_depth`) on the NPU depth
    /// ingest embeds may hold — the valley-soak leg, tried before the
    /// CPU leg while embedding traffic is under `ingest_low_water`.
    /// 0 (the default) keeps ingest off the NPU.
    pub npu_ingest_depth: usize,
    /// Ingest's valley gate: the NPU leg is tried only while embed-side
    /// NPU occupancy is at or below this fraction of `npu_depth`.
    /// Stricter than the retrieval offload gate by default — ingest is
    /// the lowest-priority class.
    pub ingest_low_water: f64,
    /// NUMA-aware retrieval scans (paper §4.4 extended to the scan
    /// path): when true, [`WindVE::attach_retrieval`] detects the host
    /// topology and — only on multi-node hosts — opts the executor's
    /// index into node-banded, thread-pinned scan sharding
    /// (`vecstore::numa`). Single-node hosts (and indexes without NUMA
    /// support) silently keep the plain sharded scan. Results are
    /// bit-identical either way.
    pub numa_scan: bool,
    /// Request-trace span ring capacity; 0 disables tracing entirely
    /// (no trace IDs, no stage spans, no stage histograms — the
    /// untraced baseline the overhead bench row compares against).
    pub trace_capacity: usize,
    /// Spans at or over this duration additionally land in the
    /// slow-query ring served by `GET /v1/trace`.
    pub trace_slow_threshold: Duration,
    /// End-to-end latency SLO. `Some` arms the live [`SloGovernor`]:
    /// windowed attainment over served embeds, with breach-gated NPU
    /// depth retuning recommendations surfaced in `/v1/stats`
    /// (paper Eqs. 9-10 run online instead of offline).
    pub slo: Option<Duration>,
    /// Required SLO attainment fraction (e.g. 0.99).
    pub slo_target: f64,
    /// SLO attainment window in requests (clamped to ≥ 8).
    pub slo_window: usize,
}

/// Default embed-query cost unit: 32 MiB of scanned arena ≈ the memory
/// traffic of one CPU embedding query's working set. At dim-768 f32
/// (3 KiB/row) one unit is ~10k scanned rows; a 1M-row corpus scan
/// nominally costs ~96 units — the service clamps the cost to the
/// retrieval cap, so such a scan holds the whole retrieval budget and
/// scans serialize (visible backpressure, never permanent starvation).
/// Tune per deployment.
pub const EMBED_COST_UNIT_BYTES: usize = 32 << 20;

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            npu_depth: 44,
            cpu_depth: 8,
            hetero: true,
            npu_workers: 1,
            cpu_workers: 1,
            cpu_pin_cores: None,
            cache_entries: 0,
            cache_key_space: (8192, 128),
            retrieval_admission: true,
            retrieval_depth: None,
            retrieval_cost_unit_bytes: EMBED_COST_UNIT_BYTES,
            npu_retrieval_depth: 0,
            npu_offload_low_water: 0.5,
            ingest_depth: 1,
            npu_ingest_depth: 0,
            ingest_low_water: 0.25,
            numa_scan: false,
            trace_capacity: 1024,
            trace_slow_threshold: Duration::from_millis(100),
            slo: None,
            slo_target: 0.99,
            slo_window: 256,
        }
    }
}

/// Pre-resolved metric handles for the serving hot paths: one atomic op
/// per event instead of a `Mutex<BTreeMap>` lock + string lookup per
/// increment. Resolved once at [`WindVE::start`] from the same
/// [`Registry`], so name-based reads (tests, `/v1/metrics`) observe the
/// identical counters. The `metrics` section of `benches/micro.rs`
/// quantifies the lookup-vs-handle delta.
struct HotMetrics {
    busy: Arc<Counter>,
    accepted: Arc<Counter>,
    ingest_busy: Arc<Counter>,
    ingest_accepted: Arc<Counter>,
    cache_hits: Arc<Counter>,
    e2e_npu_ns: Arc<Histogram>,
    e2e_cpu_ns: Arc<Histogram>,
    retrieve_offload_stale: Arc<Counter>,
    retrieve_cost_units_npu: Arc<Counter>,
    retrieve_scan_npu_ns: Arc<Histogram>,
    retrieve_offloaded: Arc<Counter>,
    retrievals: Arc<Counter>,
    retrievals_npu: Arc<Counter>,
    retrieve_busy: Arc<Counter>,
    retrieve_admitted: Arc<Counter>,
    retrieve_cost_units: Arc<Counter>,
    retrieve_scan_ns: Arc<Histogram>,
    retrievals_f32: Arc<Counter>,
    retrievals_f16: Arc<Counter>,
    retrievals_int8: Arc<Counter>,
    retrievals_pq4: Arc<Counter>,
    retrievals_pq8: Arc<Counter>,
}

impl HotMetrics {
    fn resolve(m: &Registry) -> HotMetrics {
        HotMetrics {
            busy: m.counter("service.busy"),
            accepted: m.counter("service.accepted"),
            ingest_busy: m.counter("service.ingest_busy"),
            ingest_accepted: m.counter("service.ingest_accepted"),
            cache_hits: m.counter("service.cache_hits"),
            e2e_npu_ns: m.histogram("service.e2e_npu_ns"),
            e2e_cpu_ns: m.histogram("service.e2e_cpu_ns"),
            retrieve_offload_stale: m.counter("service.retrieve_offload_stale"),
            retrieve_cost_units_npu: m.counter("service.retrieve_cost_units_npu"),
            retrieve_scan_npu_ns: m.histogram("service.retrieve_scan_npu_ns"),
            retrieve_offloaded: m.counter("service.retrieve_offloaded"),
            retrievals: m.counter("service.retrievals"),
            retrievals_npu: m.counter("service.retrievals_npu"),
            retrieve_busy: m.counter("service.retrieve_busy"),
            retrieve_admitted: m.counter("service.retrieve_admitted"),
            retrieve_cost_units: m.counter("service.retrieve_cost_units"),
            retrieve_scan_ns: m.histogram("service.retrieve_scan_ns"),
            retrievals_f32: m.counter("service.retrievals_f32"),
            retrievals_f16: m.counter("service.retrievals_f16"),
            retrievals_int8: m.counter("service.retrievals_int8"),
            retrievals_pq4: m.counter("service.retrievals_pq4"),
            retrievals_pq8: m.counter("service.retrievals_pq8"),
        }
    }

    /// Which per-codec retrieval counter absorbed a scan.
    fn retrievals_by_codec(&self, q: Quant) -> &Counter {
        match q {
            Quant::F32 => &self.retrievals_f32,
            Quant::F16 => &self.retrievals_f16,
            Quant::Int8 => &self.retrievals_int8,
            Quant::Pq { bits: 4, .. } => &self.retrievals_pq4,
            Quant::Pq { .. } => &self.retrievals_pq8,
        }
    }
}

// The scan legs hold admitted slots in a `queue_manager::AdmissionGuard`
// (formerly a private `ScanAdmission` here): releases on drop so the
// slots come back even if the scan panics (poisoned index lock, kernel
// assert) — a leaked scan admission would wedge retrieval into BUSY
// permanently. It lives with the queue manager so the loom suite
// model-checks the guard's drop path alongside dispatch/release.

/// Lock one of the service's attachment slots (`retrieval`,
/// `npu_retrieval`, `durability`), recovering from poisoning: the
/// critical sections only swap or clone an `Option<Arc<_>>`, which can
/// never leave the slot torn, so honoring a poison (from a panic on an
/// unrelated code path that happened to hold the lock) would only turn
/// one thread's panic into a service-wide retrieval outage.
fn attach_lock<T>(slot: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    slot.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Split the embedded panel into (original indexes, query slices) for
/// one batched scan, failing dimension mismatches per query — a
/// backend/index dimension mismatch is a deployment bug; report it
/// instead of letting the index assert and panic the calling thread.
fn split_panel<'a>(
    index_dim: usize,
    embeddings: &'a [Option<Vec<f32>>],
    failures: &mut [Option<ServeError>],
) -> (Vec<usize>, Vec<&'a [f32]>) {
    let mut panel_idx = Vec::new();
    let mut panel: Vec<&[f32]> = Vec::new();
    for (i, e) in embeddings.iter().enumerate() {
        if let Some(v) = e {
            if v.len() != index_dim {
                failures[i] = Some(ServeError::Backend(format!(
                    "embedding dim {} != index dim {index_dim}",
                    v.len()
                )));
                continue;
            }
            panel_idx.push(i);
            panel.push(v.as_slice());
        }
    }
    (panel_idx, panel)
}

/// In-flight request handle.
impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket").field("route", &self.route).finish()
    }
}

pub struct Ticket {
    pub route: Route,
    rx: Receiver<Result<Vec<f32>, String>>,
    submitted: Instant,
}

impl Ticket {
    /// Wait for the embedding (bounded by `timeout`).
    pub fn wait(self, timeout: Duration) -> Result<Vec<f32>, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(m)) => Err(ServeError::Backend(m)),
            Err(RecvTimeoutError::Timeout) => Err(ServeError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Shutdown),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.submitted.elapsed()
    }
}

/// The running WindVE service.
pub struct WindVE {
    qm: Arc<QueueManager>,
    npu_queue: Arc<DeviceQueue<Reply>>,
    cpu_queue: Option<Arc<DeviceQueue<Reply>>>,
    workers: Vec<JoinHandle<()>>,
    cache: Option<Arc<EmbeddingCache>>,
    cache_key_space: (u32, usize),
    /// Attached post-start via [`WindVE::attach_retrieval`]; behind a
    /// mutex so a shared (`Arc<WindVE>`) service can still be wired.
    retrieval: std::sync::Mutex<Option<Arc<RetrievalExecutor>>>,
    /// The NPU offload scanner (a mirror of the attached executor's
    /// corpus); cleared whenever a new executor is attached.
    npu_retrieval: std::sync::Mutex<Option<Arc<NpuScanner>>>,
    /// Durable corpus store ([`WindVE::attach_durability`]): when
    /// attached, ingest commits and deletes are WAL-logged before they
    /// are acked, and the delete/snapshot endpoints become durable.
    durability: std::sync::Mutex<Option<Arc<DurableStore>>>,
    retrieval_admission: bool,
    retrieval_cost_unit_bytes: usize,
    /// The operator's raw `retrieval_admission` intent. Gates the NPU
    /// offload leg, which is admission-aware by construction — but must
    /// not inherit the `cpu_depth == 0` auto-disable above (an NPU-only
    /// deployment has no CPU budget to meter, yet its NPU leg budget is
    /// exactly where offload pays off). Mirrors `RetrievalLoad::admission`
    /// in the DES, so the sim predicts the service for every config.
    npu_offload_admission: bool,
    /// Embed NPU occupancy at or below which scans may offload
    /// (precomputed from `npu_offload_low_water · npu_depth`).
    npu_offload_low_water_slots: usize,
    /// Embed NPU occupancy at or below which ingest may soak the NPU
    /// (precomputed from `ingest_low_water · npu_depth`).
    ingest_low_water_slots: usize,
    /// Service-lifetime streaming-ingest counters (`/v1/ingest/status`).
    ingest_stats: Arc<IngestStats>,
    /// Operator intent from [`ServiceConfig::numa_scan`]: applied to
    /// executors as they are attached (multi-node hosts only).
    numa_scan: bool,
    /// Pre-resolved hot-path metric handles (same Arcs as in `metrics`).
    hot: HotMetrics,
    /// Request tracer; `None` when `trace_capacity == 0`.
    tracer: Option<Arc<Tracer>>,
    /// Live SLO governor; `None` when no SLO is configured.
    slo_gov: Option<SloGovernor>,
    pub metrics: Registry,
}

impl WindVE {
    /// Start workers. `npu_factories` / `cpu_factories` supply one backend
    /// factory per worker (backends are built on the worker threads —
    /// PJRT handles are not `Send`).
    pub fn start(
        cfg: ServiceConfig,
        npu_factories: Vec<BackendFactory>,
        cpu_factories: Vec<BackendFactory>,
    ) -> Result<WindVE> {
        anyhow::ensure!(
            npu_factories.len() == cfg.npu_workers,
            "need {} npu factories, got {}",
            cfg.npu_workers,
            npu_factories.len()
        );
        let hetero = cfg.hetero && cfg.cpu_workers > 0;
        anyhow::ensure!(
            !hetero || cpu_factories.len() == cfg.cpu_workers,
            "need {} cpu factories, got {}",
            cfg.cpu_workers,
            cpu_factories.len()
        );

        let metrics = Registry::new();
        // The CPU pool exists regardless of hetero (retrieval scans run
        // on host cores either way); `hetero` only gates whether embeds
        // may overflow into it (Algorithm 1).
        let retrieve_cap = cfg.retrieval_depth.unwrap_or(cfg.cpu_depth).min(cfg.cpu_depth);
        let qm = Arc::new(QueueManager::with_caps(
            cfg.npu_depth,
            cfg.cpu_depth,
            hetero,
            ClassCaps {
                retrieve: retrieve_cap,
                npu_retrieve: cfg.npu_retrieval_depth,
                ingest: cfg.ingest_depth,
                npu_ingest: cfg.npu_ingest_depth,
            },
        ));
        let npu_queue = Arc::new(DeviceQueue::new());
        let cpu_queue = hetero.then(|| Arc::new(DeviceQueue::new()));
        let tracer = (cfg.trace_capacity > 0).then(|| {
            Arc::new(Tracer::new(
                &metrics,
                cfg.trace_capacity,
                cfg.trace_slow_threshold,
            ))
        });

        let mut workers = Vec::new();
        for (i, f) in npu_factories.into_iter().enumerate() {
            workers.push(spawn_worker(
                format!("npu{i}"),
                Arc::clone(&npu_queue),
                Arc::clone(&qm),
                Route::Npu,
                f,
                metrics.clone(),
                tracer.clone(),
                None,
            ));
        }
        if let Some(cq) = &cpu_queue {
            for (i, f) in cpu_factories.into_iter().enumerate() {
                workers.push(spawn_worker(
                    format!("cpu{i}"),
                    Arc::clone(cq),
                    Arc::clone(&qm),
                    Route::Cpu,
                    f,
                    metrics.clone(),
                    tracer.clone(),
                    cfg.cpu_pin_cores.clone(),
                ));
            }
        }
        let cache = (cfg.cache_entries > 0)
            .then(|| Arc::new(EmbeddingCache::new(cfg.cache_entries)));
        let low_water = cfg.npu_offload_low_water.clamp(0.0, 1.0);
        let npu_offload_low_water_slots = (cfg.npu_depth as f64 * low_water).floor() as usize;
        let ingest_low_water = cfg.ingest_low_water.clamp(0.0, 1.0);
        let ingest_low_water_slots = (cfg.npu_depth as f64 * ingest_low_water).floor() as usize;
        Ok(WindVE {
            qm,
            npu_queue,
            cpu_queue,
            workers,
            cache,
            cache_key_space: cfg.cache_key_space,
            retrieval: std::sync::Mutex::new(None),
            npu_retrieval: std::sync::Mutex::new(None),
            durability: std::sync::Mutex::new(None),
            // A zero CPU pool means there is no calibrated budget to
            // meter scans against; enforcing it would turn every
            // retrieval into BUSY on an NPU-only deployment.
            retrieval_admission: cfg.retrieval_admission && cfg.cpu_depth > 0,
            retrieval_cost_unit_bytes: cfg.retrieval_cost_unit_bytes,
            npu_offload_admission: cfg.retrieval_admission,
            npu_offload_low_water_slots,
            ingest_low_water_slots,
            ingest_stats: Arc::new(IngestStats::default()),
            numa_scan: cfg.numa_scan,
            hot: HotMetrics::resolve(&metrics),
            tracer,
            slo_gov: cfg
                .slo
                .map(|slo| SloGovernor::new(slo, cfg.slo_target, cfg.slo_window, cfg.npu_depth.max(1))),
            metrics,
        })
    }

    /// The request tracer (`None` when tracing is disabled).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Mint a trace ID for a new request; 0 ("untraced") when tracing is
    /// disabled.
    pub fn mint_trace(&self) -> u64 {
        self.tracer.as_ref().map(|t| t.mint()).unwrap_or(0)
    }

    /// The live SLO governor (`None` when no SLO is configured).
    pub fn slo_governor(&self) -> Option<&SloGovernor> {
        self.slo_gov.as_ref()
    }

    /// Feed the SLO governor one served embed: route-side concurrency is
    /// sampled now as the paper's concurrency proxy. Only NPU-routed
    /// samples feed the depth fit (the governor retunes `C^max_NPU`);
    /// every sample counts toward attainment. No-op without an SLO.
    pub fn observe_slo(&self, route: Route, latency: Duration) {
        if let Some(g) = &self.slo_gov {
            let concurrency = match route {
                Route::Npu => self.qm.npu_occupancy(),
                _ => 0, // attainment only; the calibrator ignores 0
            };
            g.observe(concurrency, latency);
        }
    }

    /// Attach the CPU-side retrieval executor (the vector index the
    /// service answers retrieval queries against). Replaces any previous
    /// attachment — and drops any NPU mirror of the old corpus, so a
    /// stale arena can never answer for a new index.
    pub fn attach_retrieval(&self, exec: Arc<RetrievalExecutor>) {
        // NUMA opt-in (`ServiceConfig::numa_scan`): only worth the arena
        // rewrite on a genuinely multi-node host — a single-node
        // topology keeps the plain sharded scan (safe fallback).
        if self.numa_scan {
            let topo = crate::devices::affinity::Topology::detect();
            if topo.numa_nodes > 1 {
                exec.set_numa(Some(topo));
            }
        }
        *attach_lock(&self.retrieval) = Some(exec);
        *attach_lock(&self.npu_retrieval) = None;
    }

    /// The attached retrieval executor, if any.
    pub fn retrieval(&self) -> Option<Arc<RetrievalExecutor>> {
        attach_lock(&self.retrieval).clone()
    }

    /// Attach the NPU offload scanner (a device-side mirror of the
    /// attached executor's corpus). Offload additionally requires
    /// `npu_retrieval_depth > 0` in the service config.
    pub fn attach_npu_offload(&self, scanner: Arc<NpuScanner>) {
        *attach_lock(&self.npu_retrieval) = Some(scanner);
    }

    /// The attached NPU offload scanner, if any.
    pub fn npu_retrieval(&self) -> Option<Arc<NpuScanner>> {
        attach_lock(&self.npu_retrieval).clone()
    }

    /// Mirror the attached executor's corpus into a host-fallback
    /// [`NpuScanner`] and attach it — the one-call wiring for the NPU
    /// retrieval offload leg (attach a device-backed scanner manually
    /// via [`WindVE::attach_npu_offload`] for real PJRT execution).
    /// Errors when no executor is attached or its index cannot export a
    /// bit-identical f32 mirror (quantized arenas, IVF).
    pub fn mirror_retrieval_to_npu(&self) -> Result<()> {
        let exec = self
            .retrieval()
            .ok_or_else(|| anyhow::anyhow!("no retrieval index attached"))?;
        let (ids, rows, version) = exec.export_corpus().ok_or_else(|| {
            anyhow::anyhow!("attached index cannot export a bit-identical f32 mirror")
        })?;
        let scanner = NpuScanner::from_snapshot(exec.dim(), ids, rows, version)?;
        self.attach_npu_offload(Arc::new(scanner));
        Ok(())
    }

    /// Attach the durable corpus store. Pair with
    /// [`WindVE::attach_retrieval`] of the executor recovered from the
    /// same store (`DurableStore::recover`), so the WAL watermark and
    /// the live index describe the same corpus.
    pub fn attach_durability(&self, store: Arc<DurableStore>) {
        *attach_lock(&self.durability) = Some(store);
    }

    /// The attached durable store, if any.
    pub fn durability(&self) -> Option<Arc<DurableStore>> {
        attach_lock(&self.durability).clone()
    }

    /// Delete a document: tombstone + version bump (NPU mirrors
    /// invalidate exactly as for an add). With a durable store attached
    /// the delete is WAL-logged and fsynced *before* the index mutation
    /// — a WAL failure refuses the whole operation. Returns the number
    /// of rows tombstoned (0 = unknown id, still a success).
    pub fn delete_doc(&self, id: u64) -> Result<usize, ServeError> {
        let exec = self
            .retrieval()
            .ok_or_else(|| ServeError::Backend("no retrieval index attached".into()))?;
        let removed = match self.durability() {
            Some(store) => {
                let mut removed = 0;
                store
                    .log_delete(id, || removed = exec.remove(id))
                    .map_err(|e| ServeError::Backend(format!("wal refused delete: {e}")))?;
                removed
            }
            None => exec.remove(id),
        };
        self.metrics.counter("service.deletes").inc();
        Ok(removed)
    }

    /// Checkpoint the corpus: serialize the attached index to a durable
    /// snapshot and truncate the WAL behind it
    /// (`DurableStore::snapshot`). Returns the WAL watermark the
    /// snapshot covers. Requires both an index and a store.
    pub fn snapshot_corpus(&self) -> Result<u64, ServeError> {
        let exec = self
            .retrieval()
            .ok_or_else(|| ServeError::Backend("no retrieval index attached".into()))?;
        let store = self
            .durability()
            .ok_or_else(|| ServeError::Backend("no durable store attached".into()))?;
        store
            .snapshot(&exec)
            .map_err(|e| ServeError::Backend(format!("snapshot failed: {e}")))
    }

    /// Admit and enqueue one query (Algorithm 1). Non-blocking. The text
    /// is an `Arc<str>`: callers holding parsed request bodies submit a
    /// refcount bump, not a copy (`String` and `&str` still convert).
    pub fn submit(&self, text: impl Into<Arc<str>>) -> Result<Ticket, ServeError> {
        self.submit_traced(text, 0)
    }

    /// [`WindVE::submit`] carrying a request trace ID (0 = untraced):
    /// the device worker attributes this query's queue_wait /
    /// batch_form / embed spans to it.
    pub fn submit_traced(
        &self,
        text: impl Into<Arc<str>>,
        trace: u64,
    ) -> Result<Ticket, ServeError> {
        let route = self.qm.dispatch();
        let queue = match route {
            Route::Npu => &self.npu_queue,
            // Unreachable by construction (dispatch routes Cpu only when
            // hetero, and hetero wiring always builds the CPU queue), but
            // the front-end thread must not be panickable on a wiring
            // bug: roll the admitted slot back and answer BUSY.
            Route::Cpu => match self.cpu_queue.as_ref() {
                Some(q) => q,
                None => {
                    self.qm.release_class(WorkClass::Embed, route, 1);
                    self.hot.busy.inc();
                    return Err(ServeError::Busy);
                }
            },
            Route::Busy => {
                self.hot.busy.inc();
                return Err(ServeError::Busy);
            }
        };
        let (tx, rx) = std::sync::mpsc::channel();
        queue.push(Pending {
            text: text.into(),
            class: WorkClass::Embed,
            enqueued: Instant::now(),
            trace,
            reply: tx,
        });
        self.hot.accepted.inc();
        Ok(Ticket { route, rx, submitted: Instant::now() })
    }

    /// Admit and enqueue one **ingest** embed (streaming corpus upload).
    /// Non-blocking; BUSY means the strictly-capped ingest class is at
    /// its cap (or the pools are full) — callers wait and retry, which
    /// is exactly the backpressure contract
    /// (`crate::ingest::pipeline` does this against the upload socket).
    ///
    /// Routing is the valley-soak policy: the NPU leg is tried first,
    /// but only while embed-side NPU occupancy is at or below the ingest
    /// low-water mark (ingest is the lowest-priority class and must
    /// never contend with an embedding burst); otherwise the CPU leg,
    /// which needs a hetero CPU worker to exist.
    pub fn submit_ingest(&self, text: impl Into<Arc<str>>) -> Result<Ticket, ServeError> {
        self.submit_ingest_traced(text, 0)
    }

    /// [`WindVE::submit_ingest`] carrying a request trace ID (0 =
    /// untraced); spans record under the `ingest` class label.
    pub fn submit_ingest_traced(
        &self,
        text: impl Into<Arc<str>>,
        trace: u64,
    ) -> Result<Ticket, ServeError> {
        let mut route = Route::Busy;
        if self.qm.npu_ingest_cap() > 0
            && self.qm.embed_npu_occupancy() <= self.ingest_low_water_slots
        {
            route = self.qm.dispatch_ingest_npu(1);
        }
        if route == Route::Busy && self.cpu_queue.is_some() {
            route = self.qm.dispatch_class(WorkClass::Ingest, 1);
        }
        let queue = match route {
            Route::Npu => &self.npu_queue,
            // Locally provable (the Cpu leg is only tried when
            // `cpu_queue.is_some()` above), but kept panic-free the same
            // way as `submit`: release and refuse rather than unwind.
            Route::Cpu => match self.cpu_queue.as_ref() {
                Some(q) => q,
                None => {
                    self.qm.release_class(WorkClass::Ingest, route, 1);
                    self.hot.ingest_busy.inc();
                    return Err(ServeError::Busy);
                }
            },
            Route::Busy => {
                self.hot.ingest_busy.inc();
                return Err(ServeError::Busy);
            }
        };
        let (tx, rx) = std::sync::mpsc::channel();
        queue.push(Pending {
            text: text.into(),
            class: WorkClass::Ingest,
            enqueued: Instant::now(),
            trace,
            reply: tx,
        });
        self.hot.ingest_accepted.inc();
        Ok(Ticket { route, rx, submitted: Instant::now() })
    }

    /// Service-lifetime streaming-ingest counters.
    pub fn ingest_stats(&self) -> &IngestStats {
        &self.ingest_stats
    }

    /// Embedding-cache counters for observability endpoints (`None` when
    /// caching is disabled). One consistent snapshot per call — see
    /// [`EmbeddingCache::snapshot`].
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.snapshot())
    }

    /// Cache handle (cache + key) for `text`, if caching is enabled.
    fn cache_entry(&self, text: &str) -> Option<(Arc<EmbeddingCache>, u64)> {
        self.cache.as_ref().map(|c| {
            let (vocab, max_len) = self.cache_key_space;
            (Arc::clone(c), EmbeddingCache::key(text, vocab, max_len))
        })
    }

    /// Cached embedding for `entry`, counting the hit.
    fn cache_lookup(&self, entry: &Option<(Arc<EmbeddingCache>, u64)>) -> Option<Vec<f32>> {
        let (cache, key) = entry.as_ref()?;
        let v = cache.get(*key)?;
        self.hot.cache_hits.inc();
        Some(v)
    }

    fn cache_fill(entry: &Option<(Arc<EmbeddingCache>, u64)>, v: &[f32]) {
        if let Some((cache, key)) = entry {
            cache.put(*key, v.to_vec());
        }
    }

    /// Convenience: submit and wait. Consults the embedding cache first
    /// (a hit never touches the queue manager) and fills it on success.
    pub fn embed_blocking(
        &self,
        text: impl Into<Arc<str>>,
        timeout: Duration,
    ) -> Result<Vec<f32>, ServeError> {
        let text: Arc<str> = text.into();
        let cache_key = self.cache_entry(&text);
        if let Some(v) = self.cache_lookup(&cache_key) {
            return Ok(v);
        }
        let ticket = self.submit(text)?;
        let route = ticket.route;
        let t0 = Instant::now();
        let out = ticket.wait(timeout);
        if let Ok(v) = &out {
            Self::cache_fill(&cache_key, v);
        }
        let e2e = t0.elapsed();
        let h = match route {
            Route::Npu => &self.hot.e2e_npu_ns,
            Route::Cpu => &self.hot.e2e_cpu_ns,
            Route::Busy => unreachable!(),
        };
        h.record(e2e.as_nanos() as u64);
        self.observe_slo(route, e2e);
        out
    }

    /// Admit a panel of queries in one pass (Algorithm 1 per query).
    /// Each text gets its own admission verdict; accepted queries are
    /// in flight concurrently, so waiting on the tickets afterwards
    /// overlaps their service times instead of serializing them.
    pub fn submit_batch(
        &self,
        texts: impl IntoIterator<Item = String>,
    ) -> Vec<Result<Ticket, ServeError>> {
        texts.into_iter().map(|t| self.submit(t)).collect()
    }

    /// Embed a panel of retrieval queries and answer all of them with
    /// ONE batched top-k scan over the attached index (the paper's
    /// Figure-1 RAG path). Queries the embedding stage rejects (BUSY) or
    /// fails report their own error; the surviving panel still shares
    /// the batched scan — this is how CPU-offloaded peak queries benefit
    /// from the sharded SIMD kernels instead of scanning one by one.
    pub fn retrieve_blocking(
        &self,
        queries: &[String],
        k: usize,
        timeout: Duration,
    ) -> Vec<Result<Vec<Hit>, ServeError>> {
        self.retrieve_blocking_traced(queries, k, timeout, 0)
    }

    /// [`WindVE::retrieve_blocking`] carrying a request trace ID (0 =
    /// untraced): embed-stage spans ride the submitted tickets, and the
    /// scan + merge stages record here labeled by the leg that ran
    /// (route × codec).
    pub fn retrieve_blocking_traced(
        &self,
        queries: &[String],
        k: usize,
        timeout: Duration,
        trace: u64,
    ) -> Vec<Result<Vec<Hit>, ServeError>> {
        let exec = match self.retrieval() {
            Some(e) => e,
            None => {
                return queries
                    .iter()
                    .map(|_| Err(ServeError::Backend("no retrieval index attached".into())))
                    .collect()
            }
        };
        // `checked_add`: huge timeouts (e.g. Duration::MAX as "no limit")
        // must not panic the serving thread; None means unbounded below.
        let deadline = Instant::now().checked_add(timeout);
        let mut embeddings: Vec<Option<Vec<f32>>> = vec![None; queries.len()];
        let mut failures: Vec<Option<ServeError>> = (0..queries.len()).map(|_| None).collect();

        // Embedding stage: cache hits answer immediately, the rest are
        // admitted in one pass and waited on together.
        let mut tickets = Vec::new();
        for (i, text) in queries.iter().enumerate() {
            let cache_key = self.cache_entry(text);
            if let Some(v) = self.cache_lookup(&cache_key) {
                embeddings[i] = Some(v);
                continue;
            }
            match self.submit_traced(text.as_str(), trace) {
                Ok(t) => tickets.push((i, t, cache_key)),
                Err(e) => failures[i] = Some(e),
            }
        }
        for (i, ticket, cache_key) in tickets {
            let remain = match deadline {
                Some(d) => d.saturating_duration_since(Instant::now()),
                None => timeout,
            };
            match ticket.wait(remain) {
                Ok(v) => {
                    Self::cache_fill(&cache_key, &v);
                    embeddings[i] = Some(v);
                }
                Err(e) => failures[i] = Some(e),
            }
        }

        // Retrieval stage: one batched scan for the whole surviving
        // panel, on one of two legs.
        //
        // **NPU offload leg** (the inverse of the paper's CPU offload):
        // when the config enables it (`npu_retrieval_depth > 0`), a fresh
        // mirror is attached, and embed-side NPU occupancy is at or below
        // the low-water mark, the scan is admitted to the NPU leg (class
        // cap + shared NPU pool) and runs over the mirrored arena — the
        // index lock is never touched. A mirror behind the corpus
        // version is skipped (counted), so an offloaded scan is always
        // equivalent to a CPU scan that took the lock at mirror time.
        //
        // **CPU leg** (Eqs. 9-10 extended to scan work): the admission
        // cost estimate and the scan run under ONE read guard
        // (`RetrievalExecutor::begin_scan`) — estimating with one guard
        // and scanning under another let concurrent corpus `add()`s
        // undercharge the admitted slot cost (TOCTOU). BUSY is
        // backpressure on the whole surviving panel.
        //
        // Nothing survived embedding (e.g. a full-BUSY burst): skip both
        // legs so the latency histograms only record real scan work.
        let unit = self.retrieval_cost_unit_bytes;
        let any_embedded = embeddings.iter().any(Option::is_some);
        let mut offload: Option<(Arc<NpuScanner>, AdmissionGuard<'_>)> = None;
        if any_embedded && self.npu_offload_admission && self.qm.npu_retrieve_cap() > 0 {
            if let Some(scanner) = self.npu_retrieval() {
                if scanner.corpus_version() != exec.version() {
                    self.hot.retrieve_offload_stale.inc();
                } else if self.qm.embed_npu_occupancy() <= self.npu_offload_low_water_slots {
                    // Clamp to the NPU retrieval cap, like the CPU leg:
                    // an over-budget arena serializes at the full budget
                    // instead of becoming permanently unschedulable.
                    let cost = scanner.scan_cost(unit).min(self.qm.npu_retrieve_cap().max(1));
                    if self.qm.dispatch_retrieve_npu(cost) == Route::Npu {
                        self.hot.retrieve_cost_units_npu.add(cost as u64);
                        let admission =
                            self.qm.guard(WorkClass::Retrieve, Route::Npu, cost);
                        offload = Some((scanner, admission));
                    }
                    // NPU leg full: fall through to the CPU leg.
                }
            }
        }

        // Which leg actually scanned (route × codec) — the scan span's
        // labels, and the merge span's route.
        let mut scanned: Option<(RouteLabel, CodecLabel)> = None;
        let (panel_idx, mut hit_lists) = if let Some((scanner, admission)) = offload {
            let (panel_idx, panel) = split_panel(scanner.dim(), &embeddings, &mut failures);
            let lists = if panel.is_empty() {
                Vec::new()
            } else {
                let t0 = Instant::now();
                let lists = scanner.search_batch(&panel, k);
                let dur = t0.elapsed();
                self.hot.retrieve_scan_npu_ns.record(dur.as_nanos() as u64);
                // The NPU mirror is a bit-identical f32 arena by
                // construction, hence the fixed codec label.
                scanned = Some((RouteLabel::Npu, CodecLabel::F32));
                if trace != 0 {
                    if let Some(tr) = &self.tracer {
                        tr.span(
                            trace,
                            Stage::Scan,
                            ClassLabel::Retrieve,
                            RouteLabel::Npu,
                            CodecLabel::F32,
                            t0,
                            dur,
                        );
                    }
                }
                self.hot.retrieve_offloaded.inc();
                self.hot.retrievals.add(panel_idx.len() as u64);
                self.hot.retrievals_npu.add(panel_idx.len() as u64);
                lists
            };
            // Scan complete: hand the NPU slots back (the guard also
            // releases on unwind if the scan panics).
            drop(admission);
            (panel_idx, lists)
        } else if any_embedded {
            let session = exec.begin_scan();
            let (mut panel_idx, mut panel) =
                split_panel(session.dim(), &embeddings, &mut failures);
            let mut admitted: Option<AdmissionGuard<'_>> = None;
            if !panel.is_empty() && self.retrieval_admission {
                // Clamp to the retrieval cap: a scan whose byte-cost
                // exceeds the whole budget degenerates to a full-budget
                // hold (scans serialize) instead of a permanently
                // unschedulable request that would BUSY every retrieval
                // on a large corpus.
                let cap = self.qm.retrieve_cap();
                let cost = session.scan_cost(unit).min(cap.max(1));
                match self.qm.dispatch_class(WorkClass::Retrieve, cost) {
                    Route::Busy => {
                        self.hot.retrieve_busy.inc();
                        for &i in &panel_idx {
                            failures[i] = Some(ServeError::Busy);
                        }
                        panel_idx.clear();
                        panel.clear();
                    }
                    route => {
                        self.hot.retrieve_admitted.inc();
                        self.hot.retrieve_cost_units.add(cost as u64);
                        admitted = Some(self.qm.guard(WorkClass::Retrieve, route, cost));
                    }
                }
            }
            let lists = if panel.is_empty() {
                Vec::new()
            } else {
                let t0 = Instant::now();
                let lists = session.search_batch(&panel, k);
                let dur = t0.elapsed();
                self.hot.retrieve_scan_ns.record(dur.as_nanos() as u64);
                self.hot.retrievals.add(panel_idx.len() as u64);
                // Per-codec counter: which arena (f32/f16/int8/pq)
                // absorbed the scan — the capacity dial the quantized
                // path exists for. Pre-resolved handles: no lock or
                // per-batch allocation on the serving path.
                let codec = session.codec_label();
                self.hot.retrievals_by_codec(exec.quant()).add(panel_idx.len() as u64);
                scanned = Some((RouteLabel::Cpu, codec));
                if trace != 0 {
                    if let Some(tr) = &self.tracer {
                        tr.span(
                            trace,
                            Stage::Scan,
                            ClassLabel::Retrieve,
                            RouteLabel::Cpu,
                            codec,
                            t0,
                            dur,
                        );
                    }
                }
                lists
            };
            // Scan complete (or skipped): release the read session, then
            // hand the slots back. On a panic inside the scan, unwinding
            // drops both guards too.
            drop(session);
            drop(admitted);
            (panel_idx, lists)
        } else {
            (Vec::new(), Vec::new())
        };

        let merge_t0 = Instant::now();
        let mut out: Vec<Result<Vec<Hit>, ServeError>> = failures
            .into_iter()
            .map(|f| Err(f.unwrap_or(ServeError::Shutdown)))
            .collect();
        for (i, hits) in panel_idx.into_iter().zip(hit_lists.drain(..)) {
            out[i] = Ok(hits);
        }
        if trace != 0 {
            if let (Some(tr), Some((route, _))) = (&self.tracer, scanned) {
                tr.span(
                    trace,
                    Stage::Merge,
                    ClassLabel::Retrieve,
                    route,
                    CodecLabel::All,
                    merge_t0,
                    merge_t0.elapsed(),
                );
            }
        }
        out
    }

    pub fn queue_manager(&self) -> &QueueManager {
        &self.qm
    }

    /// Close queues and join workers.
    pub fn shutdown(mut self) {
        self.npu_queue.close();
        if let Some(cq) = &self.cpu_queue {
            cq.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for WindVE {
    fn drop(&mut self) {
        self.npu_queue.close();
        if let Some(cq) = &self.cpu_queue {
            cq.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::executor::{Backend, RetrievalExecutor};

    struct EchoBackend {
        tag: f32,
        delay: Duration,
    }
    impl Backend for EchoBackend {
        fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
            std::thread::sleep(self.delay);
            Ok(texts.iter().map(|_| vec![self.tag]).collect())
        }
        fn describe(&self) -> String {
            format!("echo{}", self.tag)
        }
        fn max_batch(&self) -> usize {
            16
        }
    }

    fn echo_factory(tag: f32, delay_ms: u64) -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(EchoBackend { tag, delay: Duration::from_millis(delay_ms) })
                as Box<dyn Backend>)
        })
    }

    fn small_service(npu_depth: usize, cpu_depth: usize, hetero: bool) -> WindVE {
        WindVE::start(
            ServiceConfig {
                npu_depth,
                cpu_depth,
                hetero,
                npu_workers: 1,
                cpu_workers: if hetero { 1 } else { 0 },
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ..ServiceConfig::default()
            },
            vec![echo_factory(1.0, 5)],
            if hetero { vec![echo_factory(2.0, 5)] } else { vec![] },
        )
        .unwrap()
    }

    #[test]
    fn basic_embed_roundtrip() {
        let svc = small_service(4, 2, true);
        let v = svc.embed_blocking("hello", Duration::from_secs(5)).unwrap();
        assert_eq!(v, vec![1.0]); // NPU-priority: tag 1.0
        svc.shutdown();
    }

    #[test]
    fn overflow_routes_to_cpu_then_busy() {
        // Slow NPU worker so its queue stays occupied.
        let svc = WindVE::start(
            ServiceConfig {
                npu_depth: 1,
                cpu_depth: 1,
                hetero: true,
                npu_workers: 1,
                cpu_workers: 1,
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ..ServiceConfig::default()
            },
            vec![echo_factory(1.0, 300)],
            vec![echo_factory(2.0, 300)],
        )
        .unwrap();
        let t1 = svc.submit("a").unwrap();
        assert_eq!(t1.route, Route::Npu);
        let t2 = svc.submit("b").unwrap();
        assert_eq!(t2.route, Route::Cpu);
        assert_eq!(svc.submit("c").unwrap_err(), ServeError::Busy);
        // Wait them out; slots free again.
        assert_eq!(t1.wait(Duration::from_secs(5)).unwrap(), vec![1.0]);
        assert_eq!(t2.wait(Duration::from_secs(5)).unwrap(), vec![2.0]);
        let t4 = svc.submit("d").unwrap();
        assert_eq!(t4.route, Route::Npu);
        t4.wait(Duration::from_secs(5)).unwrap();
        svc.shutdown();
    }

    #[test]
    fn hetero_disabled_never_uses_cpu() {
        let svc = small_service(2, 8, false);
        let mut routes = Vec::new();
        for i in 0..3 {
            match svc.submit(format!("q{i}")) {
                Ok(t) => routes.push(t.route),
                Err(e) => {
                    assert_eq!(e, ServeError::Busy);
                    routes.push(Route::Busy);
                }
            }
        }
        assert!(!routes.contains(&Route::Cpu));
        svc.shutdown();
    }

    #[test]
    fn concurrent_submitters_all_complete_or_busy() {
        let svc = Arc::new(small_service(8, 4, true));
        let mut handles = Vec::new();
        for t in 0..6 {
            let svc = Arc::clone(&svc);
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                let mut busy = 0;
                for i in 0..30 {
                    match svc.embed_blocking(format!("{t}-{i}"), Duration::from_secs(10)) {
                        Ok(_) => ok += 1,
                        Err(ServeError::Busy) => busy += 1,
                        Err(e) => panic!("unexpected error {e}"),
                    }
                }
                (ok, busy)
            }));
        }
        let mut total_ok = 0;
        for h in handles {
            let (ok, _busy) = h.join().unwrap();
            total_ok += ok;
        }
        assert!(total_ok > 0);
        // After the storm, occupancy must drain to zero.
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(svc.queue_manager().npu_occupancy(), 0);
        assert_eq!(svc.queue_manager().cpu_occupancy(), 0);
    }

    // Deterministic text → unit-vector embedding so retrieval tests can
    // assert exact nearest neighbours without PJRT artifacts.
    use crate::testing::pseudo_embedding;

    struct HashBackend {
        dim: usize,
    }
    impl Backend for HashBackend {
        fn embed(&mut self, texts: &[Arc<str>]) -> anyhow::Result<Vec<Vec<f32>>> {
            Ok(texts.iter().map(|t| pseudo_embedding(t, self.dim)).collect())
        }
        fn describe(&self) -> String {
            "hash".into()
        }
        fn max_batch(&self) -> usize {
            16
        }
    }

    #[test]
    fn retrieve_blocking_serves_batched_topk() {
        let dim = 16;
        let svc = WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                npu_workers: 1,
                cpu_workers: 1,
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ..ServiceConfig::default()
            },
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
        )
        .unwrap();

        // Without an index attached, retrieval reports a backend error.
        let none = svc.retrieve_blocking(&["q".into()], 3, Duration::from_secs(5));
        assert!(matches!(none[0], Err(ServeError::Backend(_))));

        // Index a corpus under the same embedding the backend produces.
        let docs: Vec<String> = (0..24).map(|i| format!("document number {i}")).collect();
        let exec = Arc::new(crate::devices::executor::RetrievalExecutor::flat(dim));
        for (i, d) in docs.iter().enumerate() {
            exec.add(i as u64, &pseudo_embedding(d, dim));
        }
        svc.attach_retrieval(Arc::clone(&exec));
        assert!(svc.retrieval().is_some());

        // Each query is a corpus document: its own id must rank first,
        // and the batched path must equal a direct index search.
        let queries: Vec<String> = vec![docs[3].clone(), docs[17].clone(), docs[8].clone()];
        let results = svc.retrieve_blocking(&queries, 4, Duration::from_secs(5));
        assert_eq!(results.len(), 3);
        for (q, r) in queries.iter().zip(&results) {
            let hits = r.as_ref().expect("retrieval failed");
            assert_eq!(hits.len(), 4);
            let qv = pseudo_embedding(q, dim);
            assert_eq!(hits, &exec.search(&qv, 4));
            assert!((hits[0].score - 1.0).abs() < 1e-4);
        }
        assert_eq!(svc.metrics.counter("service.retrievals").get(), 3);

        // Mis-sized index (deployment bug): a per-query error, not a panic.
        svc.attach_retrieval(Arc::new(crate::devices::executor::RetrievalExecutor::flat(4)));
        let bad = svc.retrieve_blocking(&queries, 2, Duration::from_secs(5));
        for r in &bad {
            match r {
                Err(ServeError::Backend(m)) => assert!(m.contains("dim"), "{m}"),
                other => panic!("expected dim-mismatch backend error, got {other:?}"),
            }
        }
        svc.shutdown();
    }

    /// The retrieval path must serve answers from a quantized arena the
    /// same way it serves f32 — and count scans under the codec's name.
    #[test]
    fn retrieve_blocking_serves_from_quantized_arena() {
        let dim = 16;
        let svc = WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                npu_workers: 1,
                cpu_workers: 1,
                cpu_pin_cores: None,
                cache_entries: 0,
                cache_key_space: (8192, 128),
                ..ServiceConfig::default()
            },
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
        )
        .unwrap();

        let docs: Vec<String> = (0..24).map(|i| format!("document number {i}")).collect();
        let exec =
            Arc::new(crate::devices::executor::RetrievalExecutor::flat_quant(dim, Quant::Int8));
        for (i, d) in docs.iter().enumerate() {
            exec.add(i as u64, &pseudo_embedding(d, dim));
        }
        svc.attach_retrieval(Arc::clone(&exec));
        assert_eq!(svc.retrieval().unwrap().quant(), Quant::Int8);

        let queries: Vec<String> = vec![docs[5].clone(), docs[19].clone()];
        let results = svc.retrieve_blocking(&queries, 3, Duration::from_secs(5));
        for (want, r) in [5u64, 19].iter().zip(&results) {
            let hits = r.as_ref().expect("retrieval failed");
            // Self-similarity survives int8: own id first, score ≈ 1.
            assert_eq!(hits[0].id, *want);
            assert!((hits[0].score - 1.0).abs() < 0.05, "{}", hits[0].score);
        }
        assert_eq!(svc.metrics.counter("service.retrievals_int8").get(), 2);
        assert_eq!(svc.metrics.counter("service.retrievals").get(), 2);
        svc.shutdown();
    }

    /// Admission gates scheduling, never scoring: results under admission
    /// are identical to the unaccounted path, and a held retrieval cap
    /// turns into BUSY backpressure instead of queueing.
    #[test]
    fn retrieval_admission_gates_scheduling_not_scoring() {
        let dim = 16;
        let mk = |admission: bool| {
            WindVE::start(
                ServiceConfig {
                    npu_depth: 8,
                    cpu_depth: 4,
                    hetero: true,
                    retrieval_admission: admission,
                    ..ServiceConfig::default()
                },
                vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
                vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            )
            .unwrap()
        };
        let svc_on = mk(true);
        let svc_off = mk(false);
        let exec = Arc::new(crate::devices::executor::RetrievalExecutor::flat(dim));
        let docs: Vec<String> = (0..32).map(|i| format!("doc {i}")).collect();
        for (i, d) in docs.iter().enumerate() {
            exec.add(i as u64, &pseudo_embedding(d, dim));
        }
        svc_on.attach_retrieval(Arc::clone(&exec));
        svc_off.attach_retrieval(Arc::clone(&exec));
        let queries: Vec<String> = vec![docs[1].clone(), docs[9].clone(), docs[30].clone()];
        let a = svc_on.retrieve_blocking(&queries, 5, Duration::from_secs(5));
        let b = svc_off.retrieve_blocking(&queries, 5, Duration::from_secs(5));
        for (x, y) in a.iter().zip(&b) {
            // Bit-identical hit lists: same ids, same scores, same order.
            assert_eq!(x.as_ref().unwrap(), y.as_ref().unwrap());
        }
        assert_eq!(svc_on.queue_manager().stats().routed_retrieve, 1);
        assert_eq!(svc_off.queue_manager().stats().routed_retrieve, 0);
        assert_eq!(svc_on.metrics.counter("service.retrieve_admitted").get(), 1);

        // Hold the whole retrieval cap: the next panel gets backpressure.
        let qm = svc_on.queue_manager();
        let cap = qm.retrieve_cap();
        assert!(cap > 0);
        assert_eq!(qm.dispatch_class(WorkClass::Retrieve, cap), Route::Cpu);
        let busy = svc_on.retrieve_blocking(&queries, 5, Duration::from_secs(5));
        for r in &busy {
            assert_eq!(r.as_ref().unwrap_err(), &ServeError::Busy);
        }
        assert_eq!(svc_on.metrics.counter("service.retrieve_busy").get(), 1);
        qm.release_class(WorkClass::Retrieve, Route::Cpu, cap);
        // Capacity restored: the same panel serves again, slots drain.
        let again = svc_on.retrieve_blocking(&queries, 5, Duration::from_secs(5));
        assert!(again.iter().all(|r| r.is_ok()));
        assert_eq!(qm.retrieve_cpu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
        svc_on.shutdown();
        svc_off.shutdown();
    }

    /// Regression: a corpus whose byte-cost exceeds the whole retrieval
    /// budget must serialize scans at the cap, not become permanently
    /// unschedulable (cost > cap would otherwise BUSY every retrieval).
    #[test]
    fn oversized_scan_cost_clamps_to_cap_instead_of_starving() {
        let dim = 16;
        let svc = WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                // 1-byte cost unit: the raw scan cost is the arena size
                // in bytes — astronomically over the cap of 4.
                retrieval_cost_unit_bytes: 1,
                ..ServiceConfig::default()
            },
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
        )
        .unwrap();
        let exec = Arc::new(crate::devices::executor::RetrievalExecutor::flat(dim));
        for i in 0..32u64 {
            exec.add(i, &pseudo_embedding(&format!("big {i}"), dim));
        }
        svc.attach_retrieval(Arc::clone(&exec));
        assert!(exec.scan_cost(1) > 4, "test needs cost over the cap");
        let out = svc.retrieve_blocking(&["big 9".into()], 3, Duration::from_secs(5));
        let hits = out[0].as_ref().expect("clamped scan must be schedulable");
        assert_eq!(hits[0].id, 9);
        let st = svc.queue_manager().stats();
        assert_eq!(st.routed_retrieve, 1);
        assert_eq!(st.rejected_retrieve, 0);
        // The clamped cost (the full cap) is what accounting recorded.
        assert_eq!(svc.metrics.counter("service.retrieve_cost_units").get(), 4);
        assert_eq!(svc.queue_manager().retrieve_cpu_occupancy(), 0);
        svc.shutdown();
    }

    /// Regression: an NPU-only deployment (cpu_depth 0, no hetero) has
    /// no calibrated CPU budget — default-on admission must NOT turn
    /// every retrieval into BUSY; scans run unaccounted as before.
    #[test]
    fn npu_only_deployment_still_serves_retrieval() {
        let dim = 16;
        let svc = WindVE::start(
            ServiceConfig {
                npu_depth: 4,
                cpu_depth: 0,
                hetero: false,
                cpu_workers: 0,
                ..ServiceConfig::default()
            },
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            vec![],
        )
        .unwrap();
        let exec = Arc::new(crate::devices::executor::RetrievalExecutor::flat(dim));
        for i in 0..16u64 {
            exec.add(i, &pseudo_embedding(&format!("d{i}"), dim));
        }
        svc.attach_retrieval(Arc::clone(&exec));
        let out = svc.retrieve_blocking(&["d7".into()], 3, Duration::from_secs(5));
        let hits = out[0].as_ref().expect("NPU-only retrieval must serve");
        assert_eq!(hits[0].id, 7);
        // No admission accounting was engaged.
        assert_eq!(svc.queue_manager().stats().routed_retrieve, 0);
        assert_eq!(svc.metrics.counter("service.retrieve_admitted").get(), 0);
        svc.shutdown();
    }

    fn offload_service(npu_retrieval_depth: usize, low_water: f64) -> WindVE {
        let dim = 16;
        WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                npu_retrieval_depth,
                npu_offload_low_water: low_water,
                ..ServiceConfig::default()
            },
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
        )
        .unwrap()
    }

    fn attach_corpus(svc: &WindVE, dim: usize, n: u64) -> Arc<RetrievalExecutor> {
        let exec = Arc::new(RetrievalExecutor::flat(dim));
        for i in 0..n {
            exec.add(i, &pseudo_embedding(&format!("doc {i}"), dim));
        }
        svc.attach_retrieval(Arc::clone(&exec));
        exec
    }

    /// Tentpole: a scan routed to the NPU leg answers from the mirrored
    /// arena with results bit-identical to the CPU index scan, and the
    /// admission accounting lands on the NPU leg, not the CPU pool.
    #[test]
    fn npu_offload_serves_bit_identical_results() {
        let dim = 16;
        let svc = offload_service(4, 0.5);
        let exec = attach_corpus(&svc, dim, 24);
        svc.mirror_retrieval_to_npu().unwrap();
        assert!(svc.npu_retrieval().is_some());

        let queries: Vec<String> = vec!["doc 3".into(), "doc 17".into(), "doc 8".into()];
        let results = svc.retrieve_blocking(&queries, 4, Duration::from_secs(5));
        for (q, r) in queries.iter().zip(&results) {
            let hits = r.as_ref().expect("offloaded retrieval failed");
            let want = exec.search(&pseudo_embedding(q, dim), 4);
            assert_eq!(hits, &want);
            for (a, b) in hits.iter().zip(&want) {
                assert_eq!(a.score.to_bits(), b.score.to_bits());
            }
        }
        let st = svc.queue_manager().stats();
        assert_eq!(st.routed_retrieve_npu, 1);
        assert_eq!(st.routed_retrieve, 0); // CPU leg untouched
        assert_eq!(svc.metrics.counter("service.retrieve_offloaded").get(), 1);
        assert_eq!(svc.metrics.counter("service.retrievals_npu").get(), 3);
        assert_eq!(svc.queue_manager().retrieve_npu_occupancy(), 0); // drained
        assert_eq!(st.bad_releases, 0);
        svc.shutdown();
    }

    /// A mirror behind the corpus version must never answer: the scan
    /// falls back to the CPU leg (which sees the fresh rows) and the
    /// skip is counted for operators.
    #[test]
    fn npu_offload_stale_mirror_falls_back_to_cpu() {
        let dim = 16;
        let svc = offload_service(4, 0.5);
        let exec = attach_corpus(&svc, dim, 16);
        svc.mirror_retrieval_to_npu().unwrap();
        // Corpus moves on after the mirror was taken.
        exec.add(99, &pseudo_embedding("doc 99", dim));
        let out = svc.retrieve_blocking(&["doc 99".into()], 3, Duration::from_secs(5));
        let hits = out[0].as_ref().expect("stale-mirror fallback failed");
        assert_eq!(hits[0].id, 99); // the CPU leg sees the fresh row
        let st = svc.queue_manager().stats();
        assert_eq!(st.routed_retrieve_npu, 0);
        assert_eq!(st.routed_retrieve, 1);
        assert_eq!(svc.metrics.counter("service.retrieve_offload_stale").get(), 1);
        // Re-mirroring restores the offload leg.
        svc.mirror_retrieval_to_npu().unwrap();
        let out = svc.retrieve_blocking(&["doc 99".into()], 3, Duration::from_secs(5));
        assert_eq!(out[0].as_ref().unwrap()[0].id, 99);
        assert_eq!(svc.queue_manager().stats().routed_retrieve_npu, 1);
        svc.shutdown();
    }

    /// The low-water policy gate: scans only offload while embed-side
    /// NPU occupancy is at or below the mark; above it they stay on the
    /// CPU leg so offload never competes with an embedding burst.
    #[test]
    fn npu_offload_respects_embed_low_water_mark() {
        let dim = 16;
        let svc = offload_service(4, 0.0); // offload only on an idle NPU
        attach_corpus(&svc, dim, 16);
        svc.mirror_retrieval_to_npu().unwrap();
        let qm = svc.queue_manager();
        // An embed query in flight on the NPU: policy must keep the scan
        // on the CPU leg.
        assert_eq!(qm.dispatch(), Route::Npu);
        let out = svc.retrieve_blocking(&["doc 5".into()], 3, Duration::from_secs(5));
        assert_eq!(out[0].as_ref().unwrap()[0].id, 5);
        assert_eq!(qm.stats().routed_retrieve_npu, 0);
        assert_eq!(qm.stats().routed_retrieve, 1);
        // NPU idle again: the same scan offloads.
        qm.release(Route::Npu);
        let out = svc.retrieve_blocking(&["doc 5".into()], 3, Duration::from_secs(5));
        assert_eq!(out[0].as_ref().unwrap()[0].id, 5);
        assert_eq!(qm.stats().routed_retrieve_npu, 1);
        svc.shutdown();
    }

    /// A full NPU leg is backpressure on the leg, not on the scan: it
    /// falls back to the CPU leg and still serves.
    #[test]
    fn npu_offload_leg_full_falls_back_to_cpu() {
        let dim = 16;
        let svc = offload_service(2, 1.0);
        attach_corpus(&svc, dim, 16);
        svc.mirror_retrieval_to_npu().unwrap();
        let qm = svc.queue_manager();
        assert_eq!(qm.npu_retrieve_cap(), 2);
        assert_eq!(qm.dispatch_retrieve_npu(2), Route::Npu); // hold the leg
        let out = svc.retrieve_blocking(&["doc 7".into()], 3, Duration::from_secs(5));
        assert_eq!(out[0].as_ref().unwrap()[0].id, 7);
        let st = qm.stats();
        assert_eq!(st.routed_retrieve_npu, 1); // only the manual hold
        assert_eq!(st.routed_retrieve, 1); // the scan fell back
        qm.release_class(WorkClass::Retrieve, Route::Npu, 2);
        svc.shutdown();
    }

    /// Review regression: an operator who disabled retrieval admission
    /// has un-metered scans by choice — the NPU leg (admission-aware by
    /// construction) must stay off too, or scan traffic would consume
    /// shared NPU capacity the DES (admission=false never offloads)
    /// predicts is embed-only.
    #[test]
    fn npu_offload_disabled_when_retrieval_admission_is_off() {
        let dim = 16;
        let svc = WindVE::start(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                retrieval_admission: false,
                npu_retrieval_depth: 4,
                ..ServiceConfig::default()
            },
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
        )
        .unwrap();
        attach_corpus(&svc, dim, 16);
        svc.mirror_retrieval_to_npu().unwrap();
        let out = svc.retrieve_blocking(&["doc 5".into()], 3, Duration::from_secs(5));
        assert_eq!(out[0].as_ref().unwrap()[0].id, 5);
        // Neither leg's accounting was engaged: the scan ran un-metered.
        let st = svc.queue_manager().stats();
        assert_eq!(st.routed_retrieve_npu, 0);
        assert_eq!(st.routed_retrieve, 0);
        assert_eq!(svc.metrics.counter("service.retrieve_offloaded").get(), 0);
        svc.shutdown();
    }

    /// Quantized and IVF arenas cannot export a bit-identical mirror:
    /// the one-call wiring must refuse rather than attach a lying arena.
    #[test]
    fn mirror_refuses_non_exportable_indexes() {
        let dim = 16;
        let svc = offload_service(4, 0.5);
        assert!(svc.mirror_retrieval_to_npu().is_err()); // nothing attached
        let exec = Arc::new(RetrievalExecutor::flat_quant(dim, Quant::Int8));
        exec.add(0, &pseudo_embedding("doc 0", dim));
        svc.attach_retrieval(exec);
        let err = svc.mirror_retrieval_to_npu().unwrap_err();
        assert!(err.to_string().contains("mirror"), "{err}");
        // And attaching a new executor drops any previous mirror, so a
        // stale arena can never answer for a new index.
        attach_corpus(&svc, dim, 4);
        svc.mirror_retrieval_to_npu().unwrap();
        assert!(svc.npu_retrieval().is_some());
        attach_corpus(&svc, dim, 6);
        assert!(svc.npu_retrieval().is_none());
        svc.shutdown();
    }

    fn hash_service(cfg: ServiceConfig, dim: usize) -> WindVE {
        let cpu = cfg.hetero && cfg.cpu_workers > 0;
        WindVE::start(
            cfg,
            vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))],
            if cpu {
                vec![Box::new(move || Ok(Box::new(HashBackend { dim }) as Box<dyn Backend>))]
            } else {
                vec![]
            },
        )
        .unwrap()
    }

    /// The ingest class routes by the valley-soak policy and releases
    /// its slots under its own class.
    #[test]
    fn submit_ingest_routes_npu_valley_then_cpu() {
        let dim = 16;
        let svc = hash_service(
            ServiceConfig {
                npu_depth: 4,
                cpu_depth: 4,
                hetero: true,
                ingest_depth: 2,
                npu_ingest_depth: 2,
                ingest_low_water: 0.0, // NPU only while embed-idle
                ..ServiceConfig::default()
            },
            dim,
        );
        // Idle NPU: ingest soaks the valley.
        let t = svc.submit_ingest("doc a").unwrap();
        assert_eq!(t.route, Route::Npu);
        t.wait(Duration::from_secs(5)).unwrap();
        // An embed in flight on the NPU: policy pushes ingest to the CPU.
        let qm = svc.queue_manager();
        assert_eq!(qm.dispatch(), Route::Npu); // manual hold
        let t = svc.submit_ingest("doc b").unwrap();
        assert_eq!(t.route, Route::Cpu);
        t.wait(Duration::from_secs(5)).unwrap();
        qm.release(Route::Npu);
        // Cap exhaustion is BUSY backpressure, not queueing.
        assert_eq!(qm.dispatch_ingest_npu(2), Route::Npu); // hold the NPU leg
        assert_eq!(qm.dispatch_class(WorkClass::Ingest, 2), Route::Cpu); // and the CPU leg
        assert_eq!(svc.submit_ingest("doc c").unwrap_err(), ServeError::Busy);
        qm.release_class(WorkClass::Ingest, Route::Npu, 2);
        qm.release_class(WorkClass::Ingest, Route::Cpu, 2);
        // Drained: nothing leaked, no bad releases.
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(qm.ingest_cpu_occupancy(), 0);
        assert_eq!(qm.ingest_npu_occupancy(), 0);
        assert_eq!(qm.stats().bad_releases, 0);
        svc.shutdown();
    }

    /// The full pipeline: an NDJSON chunk stream lands in the live index
    /// through ingest admission, and every document becomes retrievable
    /// (version-checked).
    #[test]
    fn ingest_pipeline_indexes_streamed_docs() {
        use crate::ingest::{ingest_ndjson_chunks, IngestOptions};
        let dim = 16;
        let svc = hash_service(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                ingest_depth: 2,
                npu_ingest_depth: 4,
                ingest_low_water: 1.0,
                ..ServiceConfig::default()
            },
            dim,
        );
        let exec = Arc::new(RetrievalExecutor::flat(dim));
        svc.attach_retrieval(Arc::clone(&exec));
        let v0 = exec.version();

        let n = 40u64;
        let mut body = String::new();
        for i in 0..n {
            body.push_str(&format!("{{\"id\":{i},\"text\":\"ingest doc {i}\"}}\n"));
        }
        // Stream in small chunks to cross plenty of token seams.
        let chunks: Vec<std::io::Result<Vec<u8>>> =
            body.as_bytes().chunks(13).map(|c| Ok(c.to_vec())).collect();
        let out = ingest_ndjson_chunks(
            &svc,
            chunks.into_iter(),
            &IngestOptions { commit_batch: 8, ..IngestOptions::default() },
        );
        assert_eq!(out.error, None);
        assert_eq!(out.received, n);
        assert_eq!(out.indexed, n);
        assert_eq!(out.failed, 0);
        assert!(out.batches >= n / 8);
        // Version-checked: the corpus advanced by exactly the committed
        // rows, and the parser never held more than one 13-byte chunk.
        assert_eq!(out.corpus_version, v0 + n);
        assert_eq!(exec.version(), v0 + n);
        assert!(out.peak_chunk_bytes <= 13);
        assert_eq!(exec.len(), n as usize);
        // Every doc is retrievable under the same embedding contract.
        for i in (0..n).step_by(7) {
            let q = pseudo_embedding(&format!("ingest doc {i}"), dim);
            assert_eq!(exec.search(&q, 1)[0].id, i);
        }
        // ...including through the serving path.
        let got = svc.retrieve_blocking(&["ingest doc 3".into()], 2, Duration::from_secs(5));
        assert_eq!(got[0].as_ref().unwrap()[0].id, 3);
        // Service-wide counters absorbed the stream.
        assert_eq!(svc.ingest_stats().docs_indexed(), n);
        assert_eq!(svc.queue_manager().stats().bad_releases, 0);
        svc.shutdown();
    }

    /// Ingest without an attached index fails the stream, not the
    /// process; a dead upload socket keeps everything already committed.
    #[test]
    fn ingest_pipeline_surfaces_stream_errors() {
        use crate::ingest::{ingest_ndjson_chunks, IngestOptions};
        let dim = 8;
        let svc = hash_service(
            ServiceConfig {
                npu_depth: 4,
                cpu_depth: 2,
                hetero: true,
                npu_ingest_depth: 2,
                ingest_low_water: 1.0,
                ..ServiceConfig::default()
            },
            dim,
        );
        // No index attached: stream-level error, nothing counted.
        let chunks: Vec<std::io::Result<Vec<u8>>> =
            vec![Ok(b"{\"id\":1,\"text\":\"a\"}\n".to_vec())];
        let out = ingest_ndjson_chunks(&svc, chunks.into_iter(), &IngestOptions::default());
        assert!(out.error.as_ref().unwrap().contains("no retrieval index"), "{out:?}");
        assert_eq!(out.indexed, 0);

        // Attached, but the socket dies mid-stream: the first doc
        // commits, the error is surfaced.
        let exec = Arc::new(RetrievalExecutor::flat(dim));
        svc.attach_retrieval(Arc::clone(&exec));
        let chunks: Vec<std::io::Result<Vec<u8>>> = vec![
            Ok(b"{\"id\":1,\"text\":\"kept\"}\n{\"id\":2,\"te".to_vec()),
            Err(std::io::Error::new(std::io::ErrorKind::ConnectionReset, "peer reset")),
        ];
        let out = ingest_ndjson_chunks(&svc, chunks.into_iter(), &IngestOptions::default());
        assert_eq!(out.indexed, 1);
        assert!(out.error.is_some(), "{out:?}");
        assert_eq!(exec.len(), 1);
        assert_eq!(exec.search(&pseudo_embedding("kept", dim), 1)[0].id, 1);
        svc.shutdown();
    }

    #[test]
    fn submit_batch_admits_per_query() {
        let svc = small_service(1, 0, false);
        let mut out = svc.submit_batch((0..3).map(|i| format!("q{i}")));
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok());
        assert!(matches!(out[1], Err(ServeError::Busy)));
        assert!(matches!(out[2], Err(ServeError::Busy)));
        let t = out.remove(0).unwrap();
        assert_eq!(t.wait(Duration::from_secs(5)).unwrap(), vec![1.0]);
        svc.shutdown();
    }

    #[test]
    fn metrics_track_accept_and_busy() {
        let svc = small_service(1, 0, false);
        let _t = svc.submit("hold").unwrap();
        let _ = svc.submit("reject").unwrap_err();
        assert_eq!(svc.metrics.counter("service.accepted").get(), 1);
        assert_eq!(svc.metrics.counter("service.busy").get(), 1);
    }

    /// Durable lifecycle through the facade: ingest WAL-logs before ack,
    /// deletes tombstone durably, snapshot truncates the log, and a
    /// crash + recover rebuilds exactly the acked corpus (bit-identical
    /// scores, deleted id gone).
    #[test]
    fn durable_ingest_delete_snapshot_crash_recover() {
        use crate::durability::{DurabilityOptions, DurableStore, FaultFs, FaultPlan, Fs};
        use crate::ingest::{ingest_ndjson_chunks, IngestOptions};
        use std::path::Path;

        let dim = 16;
        let svc = hash_service(
            ServiceConfig {
                npu_depth: 8,
                cpu_depth: 4,
                hetero: true,
                ingest_depth: 2,
                npu_ingest_depth: 4,
                ingest_low_water: 1.0,
                ..ServiceConfig::default()
            },
            dim,
        );
        let fs = Arc::new(FaultFs::new());
        let dynfs: Arc<dyn Fs> = fs.clone();
        let opts = DurabilityOptions::default();
        let embed = |t: &str| Ok(pseudo_embedding(t, dim));
        let (store, exec, report) = DurableStore::recover(
            dynfs.clone(),
            Path::new("/corpus"),
            opts.clone(),
            || Box::new(crate::vecstore::FlatIndex::new(dim)),
            embed,
        )
        .unwrap();
        assert_eq!(report.replayed, 0);
        svc.attach_retrieval(Arc::clone(&exec));
        svc.attach_durability(Arc::clone(&store));

        let mut body = String::new();
        for i in 0..10u64 {
            body.push_str(&format!("{{\"id\":{i},\"text\":\"durable doc {i}\"}}\n"));
        }
        let chunks: Vec<std::io::Result<Vec<u8>>> = vec![Ok(body.into_bytes())];
        let out = ingest_ndjson_chunks(
            &svc,
            chunks.into_iter(),
            &IngestOptions { commit_batch: 4, ..IngestOptions::default() },
        );
        assert_eq!(out.indexed, 10);
        assert_eq!(out.wal_refused, 0);
        assert_eq!(store.stats().committed_seq, 10);

        // Durable delete through the facade; the version seam moves so
        // NPU mirrors invalidate. Unknown id: still logged, 0 rows.
        let v = exec.version();
        assert_eq!(svc.delete_doc(4).unwrap(), 1);
        assert_eq!(svc.delete_doc(4).unwrap(), 0);
        assert!(exec.version() > v);

        // Checkpoint: the WAL behind the watermark is gone.
        let w = svc.snapshot_corpus().unwrap();
        assert_eq!(w, 12, "10 upserts + 2 delete records");
        assert_eq!(store.stats().wal_segments, 0);

        // One post-checkpoint commit, then crash.
        let chunks: Vec<std::io::Result<Vec<u8>>> =
            vec![Ok(b"{\"id\":99,\"text\":\"late doc\"}\n".to_vec())];
        let late = ingest_ndjson_chunks(&svc, chunks.into_iter(), &IngestOptions::default());
        assert_eq!(late.indexed, 1);
        let q = pseudo_embedding("durable doc 7", dim);
        let want: Vec<(u64, u32)> =
            exec.search(&q, 3).iter().map(|h| (h.id, h.score.to_bits())).collect();

        fs.crash_now();
        fs.restart(FaultPlan::default());
        let (_store2, exec2, report) = DurableStore::recover(
            dynfs,
            Path::new("/corpus"),
            opts,
            || Box::new(crate::vecstore::FlatIndex::new(dim)),
            embed,
        )
        .unwrap();
        assert!(report.from_snapshot);
        assert_eq!(report.replayed, 1, "only the post-checkpoint doc");
        assert_eq!(exec2.len(), 10, "10 ingested - 1 deleted + 1 late");
        let got: Vec<(u64, u32)> =
            exec2.search(&q, 3).iter().map(|h| (h.id, h.score.to_bits())).collect();
        assert_eq!(got, want, "recovered rows score bit-identically");
        let gone = pseudo_embedding("durable doc 4", dim);
        assert!(exec2.search(&gone, 10).iter().all(|h| h.id != 4), "deleted id resurrected");
        svc.shutdown();
    }
}
