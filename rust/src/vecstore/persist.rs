//! Snapshot codec for the `RowArena`-backed indexes.
//!
//! A snapshot serializes the *encoded* arena bytes — never a
//! dequantize→requantize round trip, which is not bit-exact for int8
//! (the per-row scale arithmetic rounds). Decoding therefore restores an
//! index whose scans score bit-for-bit what the source index scored.
//!
//! Tombstoned rows are dropped at encode time, preserving the relative
//! order of live rows. The deterministic top-k merge keys ties on global
//! row order, and dropping dead rows never reorders live ones, so a
//! restored index resolves score ties exactly like the source did with
//! its skip masks engaged — and deleted ids can never reappear from a
//! snapshot.
//!
//! The format is self-describing (magic + version + kind + quant + dim;
//! the PQ quant tag additionally carries `m` + `bits`, and PQ indexes
//! serialize their shared codebook once, ahead of the arenas) so
//! [`decode_index`] can rebuild the right index type without any
//! out-of-band configuration. All integers are little-endian.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::flat::FlatIndex;
use super::ivf::{InvList, IvfIndex};
use super::mask::SkipMask;
use super::pq;
use super::qflat::QuantizedFlatIndex;
use super::quant::{Quant, RowArena};
use super::Index;

const MAGIC: &[u8; 4] = b"WVIX";
const VERSION: u8 = 1;

const KIND_FLAT: u8 = 1;
const KIND_QFLAT: u8 = 2;
const KIND_IVF: u8 = 3;

/// Product quantization. Only this tag widens the header: `m` (u32) and
/// `bits` (u8) follow `dim`, so pre-PQ snapshots decode byte-for-byte as
/// before.
const TAG_PQ: u8 = 3;

fn quant_tag(q: Quant) -> u8 {
    match q {
        Quant::F32 => 0,
        Quant::F16 => 1,
        Quant::Int8 => 2,
        Quant::Pq { .. } => TAG_PQ,
    }
}

fn quant_from_tag(t: u8) -> Result<Quant> {
    Ok(match t {
        0 => Quant::F32,
        1 => Quant::F16,
        2 => Quant::Int8,
        other => bail!("snapshot: unknown quant tag {other}"),
    })
}

// ---------------------------------------------------------------------------
// Little-endian write helpers.

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian reader over a snapshot byte slice.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "snapshot: truncated (need {n} bytes at offset {}, have {})",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Sanity ceiling for decoded element counts: any count implying more
/// bytes than remain in the buffer is corruption, not data.
fn check_count(r: &Reader<'_>, n: u64, elem_bytes: usize) -> Result<usize> {
    let n = usize::try_from(n).context("snapshot: count overflows usize")?;
    let need = n.checked_mul(elem_bytes.max(1)).context("snapshot: count overflows")?;
    if need > r.buf.len() - r.pos {
        bail!("snapshot: count {n} implies {need} bytes but only {} remain", r.buf.len() - r.pos);
    }
    Ok(n)
}

// ---------------------------------------------------------------------------
// Arena codec: live rows only, encoded bytes copied verbatim.

/// Append the live rows of `(arena, dead)` to `out`: row count, then the
/// raw encoded payload (f32/f16 words, int8 codes then scales, or a PQ
/// state flag followed by staged f32 rows / packed codes).
fn put_arena(out: &mut Vec<u8>, arena: &RowArena, dead: &SkipMask, rows: usize, dim: usize) {
    // Compact the live rows into a scratch arena first — `push_row_from`
    // copies encoded bytes (sharing any trained PQ codebook via
    // `new_like`), so this is exact. When nothing is dead the scratch is
    // byte-identical to the source.
    let mut live = RowArena::new_like(arena);
    let mut ids_kept = 0u64;
    for r in 0..rows {
        if !dead.is_dead(r) {
            live.push_row_from(arena, r, dim);
            ids_kept += 1;
        }
    }
    put_u64(out, ids_kept);
    match &live {
        RowArena::F32(d) => {
            for &x in d {
                put_f32(out, x);
            }
        }
        RowArena::F16(d) => {
            for &h in d {
                out.extend_from_slice(&h.to_le_bytes());
            }
        }
        RowArena::I8 { codes, scales } => {
            out.extend(codes.iter().map(|&c| c as u8));
            for &s in scales {
                put_f32(out, s);
            }
        }
        RowArena::Pq(a) => {
            // State flag: 0 = staged (raw f32 rows, pre-training),
            // 1 = trained (packed codes; the codebook itself is written
            // once per index — see `put_pq_book` — not per arena).
            if let Some(codes) = a.codes() {
                out.push(1);
                out.extend_from_slice(codes);
            } else {
                out.push(0);
                for &x in a.staged().expect("untrained pq arena has staged rows") {
                    put_f32(out, x);
                }
            }
        }
    }
}

/// Read one arena section written by [`put_arena`]; returns the arena
/// and its row count. `book` is the index-level PQ codebook (required
/// when a PQ arena's state flag says "trained"; ignored otherwise).
fn get_arena(
    r: &mut Reader<'_>,
    quant: Quant,
    dim: usize,
    book: Option<&Arc<pq::Codebook>>,
) -> Result<(RowArena, usize)> {
    let nrows = r.u64()?;
    let arena = match quant {
        Quant::F32 => {
            let rows = check_count(r, nrows, dim * 4)?;
            let raw = r.take(rows * dim * 4)?;
            let mut d = Vec::with_capacity(rows * dim);
            for c in raw.chunks_exact(4) {
                d.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            RowArena::F32(d)
        }
        Quant::F16 => {
            let rows = check_count(r, nrows, dim * 2)?;
            let raw = r.take(rows * dim * 2)?;
            let mut d = Vec::with_capacity(rows * dim);
            for c in raw.chunks_exact(2) {
                d.push(u16::from_le_bytes(c.try_into().unwrap()));
            }
            RowArena::F16(d)
        }
        Quant::Int8 => {
            let rows = check_count(r, nrows, dim + 4)?;
            let codes: Vec<i8> = r.take(rows * dim)?.iter().map(|&b| b as i8).collect();
            let mut scales = Vec::with_capacity(rows);
            for _ in 0..rows {
                scales.push(r.f32()?);
            }
            RowArena::I8 { codes, scales }
        }
        Quant::Pq { m, bits } => {
            let mut a = pq::PqArena::new(m, bits);
            match r.u8()? {
                0 => {
                    // Staged: raw f32 rows, sized by the *unpacked* width.
                    let rows = check_count(r, nrows, dim * 4)?;
                    let raw = r.take(rows * dim * 4)?;
                    let mut d = Vec::with_capacity(rows * dim);
                    for c in raw.chunks_exact(4) {
                        d.push(f32::from_le_bytes(c.try_into().unwrap()));
                    }
                    a.restore_staged(d);
                }
                1 => {
                    let Some(book) = book else {
                        bail!("snapshot: trained pq arena but no codebook section");
                    };
                    let pb = pq::packed_row_bytes(m, bits);
                    let rows = check_count(r, nrows, pb)?;
                    a.restore_trained(Arc::clone(book), r.take(rows * pb)?.to_vec());
                }
                other => bail!("snapshot: unknown pq arena state {other}"),
            }
            RowArena::Pq(a)
        }
    };
    let rows = arena.rows(dim);
    Ok((arena, rows))
}

/// Index-level PQ codebook section: presence flag, then the center count
/// and raw f32 centers. Written (and read) only when the header quant is
/// PQ; all arenas of the index share the one book.
fn put_pq_book(out: &mut Vec<u8>, book: Option<&Arc<pq::Codebook>>) {
    match book {
        Some(b) => {
            out.push(1);
            put_u64(out, b.centers.len() as u64);
            for &c in &b.centers {
                put_f32(out, c);
            }
        }
        None => out.push(0),
    }
}

fn get_pq_book(
    r: &mut Reader<'_>,
    quant: Quant,
    dim: usize,
) -> Result<Option<Arc<pq::Codebook>>> {
    let Quant::Pq { m, bits } = quant else {
        return Ok(None);
    };
    if r.u8()? == 0 {
        return Ok(None);
    }
    let nc = r.u64()?;
    let nc = check_count(r, nc, 4)?;
    let mut centers = Vec::with_capacity(nc);
    for _ in 0..nc {
        centers.push(r.f32()?);
    }
    let book = pq::Codebook::from_parts(dim, m, bits, centers)
        .map_err(|e| anyhow::anyhow!("snapshot: {e}"))?;
    Ok(Some(Arc::new(book)))
}

fn put_ids(out: &mut Vec<u8>, ids: &[u64], dead: &SkipMask) {
    let live = ids.len() - dead.dead();
    put_u64(out, live as u64);
    for (r, &id) in ids.iter().enumerate() {
        if !dead.is_dead(r) {
            put_u64(out, id);
        }
    }
}

fn get_ids(r: &mut Reader<'_>) -> Result<Vec<u64>> {
    let n = r.u64()?;
    let n = check_count(r, n, 8)?;
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        ids.push(r.u64()?);
    }
    Ok(ids)
}

fn header(out: &mut Vec<u8>, kind: u8, quant: Quant, dim: usize) {
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(kind);
    out.push(quant_tag(quant));
    put_u32(out, dim as u32);
    // Only the PQ tag carries codec parameters; every other header keeps
    // the original fixed 11-byte layout.
    if let Quant::Pq { m, bits } = quant {
        put_u32(out, m as u32);
        out.push(bits);
    }
}

// ---------------------------------------------------------------------------
// Per-index encoders (fields are pub(crate); all layout knowledge stays
// in this module).

pub(crate) fn encode_flat(idx: &FlatIndex) -> Vec<u8> {
    let mut out = Vec::new();
    header(&mut out, KIND_FLAT, Quant::F32, idx.dim);
    put_ids(&mut out, &idx.ids, &idx.dead);
    let live = idx.ids.len() - idx.dead.dead();
    put_u64(&mut out, live as u64);
    for r in 0..idx.ids.len() {
        if !idx.dead.is_dead(r) {
            for &x in &idx.data[r * idx.dim..(r + 1) * idx.dim] {
                put_f32(&mut out, x);
            }
        }
    }
    out
}

pub(crate) fn encode_qflat(idx: &QuantizedFlatIndex) -> Vec<u8> {
    let mut out = Vec::new();
    let quant = idx.arena.quant();
    header(&mut out, KIND_QFLAT, quant, idx.dim);
    put_ids(&mut out, &idx.ids, &idx.dead);
    if matches!(quant, Quant::Pq { .. }) {
        put_pq_book(&mut out, idx.arena.as_pq().and_then(|a| a.book()));
    }
    put_arena(&mut out, &idx.arena, &idx.dead, idx.ids.len(), idx.dim);
    out
}

pub(crate) fn encode_ivf(idx: &IvfIndex) -> Vec<u8> {
    let mut out = Vec::new();
    header(&mut out, KIND_IVF, idx.quant, idx.dim);
    put_u32(&mut out, idx.nlist as u32);
    put_u32(&mut out, idx.nprobe as u32);
    out.push(idx.built as u8);
    put_f64(&mut out, idx.rebalance_threshold);
    put_u64(&mut out, idx.rebalance_seed);
    put_u64(&mut out, idx.centroids.len() as u64);
    for &c in &idx.centroids {
        put_f32(&mut out, c);
    }
    if matches!(idx.quant, Quant::Pq { .. }) {
        // All lists share the corpus codebook (see `IvfIndex::build`),
        // so one section covers every arena below.
        put_pq_book(
            &mut out,
            idx.lists.first().and_then(|l| l.arena.as_pq()).and_then(|a| a.book()),
        );
    }
    put_u32(&mut out, idx.lists.len() as u32);
    for list in &idx.lists {
        put_ids(&mut out, &list.ids, &list.dead);
        put_arena(&mut out, &list.arena, &list.dead, list.ids.len(), idx.dim);
    }
    put_u64(&mut out, idx.pending.len() as u64);
    for (id, v) in &idx.pending {
        put_u64(&mut out, *id);
        for &x in v {
            put_f32(&mut out, x);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Decoder.

/// Rebuild an index from snapshot bytes produced by
/// [`Index::snapshot_bytes`]. The restored index holds exactly the live
/// rows of the source (tombstones were dropped at encode time) and its
/// scans score bit-identically.
pub fn decode_index(bytes: &[u8]) -> Result<Box<dyn Index + Send + Sync>> {
    let mut r = Reader::new(bytes);
    let magic = r.take(4)?;
    if magic != MAGIC {
        bail!("snapshot: bad magic {magic:02x?}");
    }
    let version = r.u8()?;
    if version != VERSION {
        bail!("snapshot: unsupported version {version}");
    }
    let kind = r.u8()?;
    let qtag = r.u8()?;
    let dim = r.u32()? as usize;
    if dim == 0 {
        bail!("snapshot: zero dimension");
    }
    let quant = if qtag == TAG_PQ {
        let m = r.u32()? as usize;
        let bits = r.u8()?;
        if !matches!(bits, 4 | 8) {
            bail!("snapshot: pq bits {bits} not in {{4, 8}}");
        }
        if m == 0 || dim % m != 0 {
            bail!("snapshot: pq m {m} does not divide dim {dim}");
        }
        Quant::Pq { m, bits }
    } else {
        quant_from_tag(qtag)?
    };

    let idx: Box<dyn Index + Send + Sync> = match kind {
        KIND_FLAT => {
            let ids = get_ids(&mut r)?;
            let (arena, rows) = get_arena(&mut r, Quant::F32, dim, None)?;
            if rows != ids.len() {
                bail!("snapshot: flat ids/rows mismatch ({} vs {rows})", ids.len());
            }
            let data = match arena {
                RowArena::F32(d) => d,
                _ => unreachable!("flat arena decoded as f32"),
            };
            Box::new(FlatIndex { dim, ids, data, dead: SkipMask::new(), numa: None })
        }
        KIND_QFLAT => {
            let ids = get_ids(&mut r)?;
            let book = get_pq_book(&mut r, quant, dim)?;
            let (arena, rows) = get_arena(&mut r, quant, dim, book.as_ref())?;
            if rows != ids.len() {
                bail!("snapshot: qflat ids/rows mismatch ({} vs {rows})", ids.len());
            }
            Box::new(QuantizedFlatIndex { dim, ids, arena, dead: SkipMask::new(), numa: None })
        }
        KIND_IVF => {
            let nlist = r.u32()? as usize;
            let nprobe = r.u32()? as usize;
            let built = r.u8()? != 0;
            let rebalance_threshold = r.f64()?;
            let rebalance_seed = r.u64()?;
            let nc = r.u64()?;
            let nc = check_count(&r, nc, 4)?;
            let mut centroids = Vec::with_capacity(nc);
            for _ in 0..nc {
                centroids.push(r.f32()?);
            }
            let book = get_pq_book(&mut r, quant, dim)?;
            let nlists = r.u32()? as usize;
            let mut lists = Vec::with_capacity(nlists);
            let mut len = 0usize;
            for _ in 0..nlists {
                let ids = get_ids(&mut r)?;
                let (arena, rows) = get_arena(&mut r, quant, dim, book.as_ref())?;
                if rows != ids.len() {
                    bail!("snapshot: ivf ids/rows mismatch ({} vs {rows})", ids.len());
                }
                len += ids.len();
                lists.push(InvList { ids, arena, dead: SkipMask::new() });
            }
            let np = r.u64()?;
            let np = check_count(&r, np, 8 + dim * 4)?;
            let mut pending = Vec::with_capacity(np);
            for _ in 0..np {
                let id = r.u64()?;
                let mut v = Vec::with_capacity(dim);
                for _ in 0..dim {
                    v.push(r.f32()?);
                }
                pending.push((id, v));
            }
            len += pending.len();
            if nlist == 0 || nprobe == 0 {
                bail!("snapshot: ivf with zero nlist/nprobe");
            }
            Box::new(IvfIndex {
                dim,
                nlist,
                nprobe,
                quant,
                pending,
                centroids,
                lists,
                built,
                len,
                rebalance_threshold,
                rebalance_seed,
                rebalances: 0,
                retrigger_skew: 0.0,
            })
        }
        other => bail!("snapshot: unknown index kind {other}"),
    };
    if !r.done() {
        bail!("snapshot: {} trailing bytes", bytes.len() - r.pos);
    }
    Ok(idx)
}

#[cfg(test)]
mod tests {
    use super::super::{FlatIndex, Index, IvfIndex, Quant, QuantizedFlatIndex};
    use super::decode_index;
    use crate::util::rng::Pcg;

    fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    fn bit_hits(hits: &[super::super::Hit]) -> Vec<(u64, u32)> {
        hits.iter().map(|h| (h.id, h.score.to_bits())).collect()
    }

    #[test]
    fn flat_roundtrip_is_bit_identical() {
        let mut rng = Pcg::new(71);
        let mut idx = FlatIndex::new(12);
        let vs: Vec<Vec<f32>> = (0..40).map(|_| unit(&mut rng, 12)).collect();
        for (i, v) in vs.iter().enumerate() {
            idx.add(i as u64, v);
        }
        idx.remove(7);
        idx.remove(31);
        let restored = decode_index(&idx.snapshot_bytes().unwrap()).unwrap();
        assert_eq!(restored.len(), idx.len());
        assert_eq!(restored.tombstones(), 0, "snapshots drop tombstones");
        for _ in 0..6 {
            let q = unit(&mut rng, 12);
            assert_eq!(bit_hits(&restored.search(&q, 5)), bit_hits(&idx.search(&q, 5)));
        }
    }

    #[test]
    fn qflat_roundtrip_is_bit_identical_per_quant() {
        for quant in [Quant::F32, Quant::F16, Quant::Int8] {
            let mut rng = Pcg::new(73);
            let mut idx = QuantizedFlatIndex::new(16, quant);
            let vs: Vec<Vec<f32>> = (0..50).map(|_| unit(&mut rng, 16)).collect();
            for (i, v) in vs.iter().enumerate() {
                idx.add(i as u64, v);
            }
            idx.remove(3);
            idx.remove(49);
            let restored = decode_index(&idx.snapshot_bytes().unwrap()).unwrap();
            assert_eq!(restored.len(), idx.len(), "{quant:?}");
            assert_eq!(restored.quant(), quant);
            for _ in 0..6 {
                let q = unit(&mut rng, 16);
                assert_eq!(
                    bit_hits(&restored.search(&q, 7)),
                    bit_hits(&idx.search(&q, 7)),
                    "{quant:?}"
                );
            }
        }
    }

    #[test]
    fn ivf_roundtrip_preserves_lists_and_results() {
        for quant in [Quant::F32, Quant::Int8] {
            let mut rng = Pcg::new(79);
            let mut idx = IvfIndex::with_quant(16, 6, 3, quant);
            let vs: Vec<Vec<f32>> = (0..120).map(|_| unit(&mut rng, 16)).collect();
            for (i, v) in vs.iter().enumerate() {
                idx.add(i as u64, v);
            }
            idx.build(17);
            idx.remove(11);
            idx.remove(90);
            // Post-build adds land in `lists`; leave a couple pre-build by
            // decoding an unbuilt index too (covered below).
            let restored = decode_index(&idx.snapshot_bytes().unwrap()).unwrap();
            assert_eq!(restored.len(), idx.len(), "{quant:?}");
            for _ in 0..6 {
                let q = unit(&mut rng, 16);
                assert_eq!(
                    bit_hits(&restored.search(&q, 5)),
                    bit_hits(&idx.search(&q, 5)),
                    "{quant:?}"
                );
            }
        }
    }

    #[test]
    fn ivf_unbuilt_roundtrip_keeps_pending() {
        let mut rng = Pcg::new(83);
        let mut idx = IvfIndex::new(8, 4, 2);
        for i in 0..20u64 {
            let v = unit(&mut rng, 8);
            idx.add(i, &v);
        }
        idx.remove(5);
        let restored = decode_index(&idx.snapshot_bytes().unwrap()).unwrap();
        assert_eq!(restored.len(), 19);
        let q = unit(&mut rng, 8);
        assert_eq!(bit_hits(&restored.search(&q, 4)), bit_hits(&idx.search(&q, 4)));
    }

    /// PQ snapshots round-trip both arena states: a staged (pre-training)
    /// arena restores its raw rows, and a trained arena restores the
    /// codebook + packed codes byte-for-byte — searches on the restored
    /// index are bit-identical either way.
    #[test]
    fn pq_roundtrip_staged_and_trained() {
        for (n, quant) in
            [(50, Quant::pq(4)), (50, Quant::pq(8)), (300, Quant::pq(4)), (300, Quant::pq(8))]
        {
            let mut rng = Pcg::new(91);
            let mut idx = QuantizedFlatIndex::new(16, quant);
            let vs: Vec<Vec<f32>> = (0..n).map(|_| unit(&mut rng, 16)).collect();
            for (i, v) in vs.iter().enumerate() {
                idx.add(i as u64, v);
            }
            idx.remove(3);
            idx.remove(n as u64 - 1);
            let restored = decode_index(&idx.snapshot_bytes().unwrap()).unwrap();
            assert_eq!(restored.len(), idx.len(), "{quant:?} n={n}");
            assert_eq!(restored.quant(), quant.resolved(16));
            for _ in 0..6 {
                let q = unit(&mut rng, 16);
                assert_eq!(
                    bit_hits(&restored.search(&q, 7)),
                    bit_hits(&idx.search(&q, 7)),
                    "{quant:?} n={n}"
                );
            }
        }
    }

    /// PQ IVF: build trains one codebook shared by all lists; the
    /// snapshot stores it once and the restored index scores
    /// bit-identically (tombstones dropped at encode time, as ever).
    #[test]
    fn pq_ivf_roundtrip_shares_one_codebook() {
        for quant in [Quant::pq(4), Quant::pq(8)] {
            let mut rng = Pcg::new(97);
            let mut idx = IvfIndex::with_quant(16, 6, 3, quant);
            let vs: Vec<Vec<f32>> = (0..120).map(|_| unit(&mut rng, 16)).collect();
            for (i, v) in vs.iter().enumerate() {
                idx.add(i as u64, v);
            }
            idx.build(17);
            idx.remove(11);
            idx.remove(90);
            let restored = decode_index(&idx.snapshot_bytes().unwrap()).unwrap();
            assert_eq!(restored.len(), idx.len(), "{quant:?}");
            assert_eq!(restored.quant(), quant.resolved(16));
            for _ in 0..6 {
                let q = unit(&mut rng, 16);
                assert_eq!(
                    bit_hits(&restored.search(&q, 5)),
                    bit_hits(&idx.search(&q, 5)),
                    "{quant:?}"
                );
            }
        }
    }

    #[test]
    fn corrupt_snapshots_are_rejected_not_misread() {
        let mut idx = FlatIndex::new(4);
        idx.add(1, &[1.0, 0.0, 0.0, 0.0]);
        let good = idx.snapshot_bytes().unwrap();
        assert!(decode_index(&good).is_ok());
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(decode_index(&bad).is_err());
        // Truncation at every prefix length must error, never panic.
        for cut in 0..good.len() {
            assert!(decode_index(&good[..cut]).is_err(), "prefix {cut} accepted");
        }
        // Trailing garbage.
        let mut long = good.clone();
        long.push(0);
        assert!(decode_index(&long).is_err());
        // An absurd count is caught by the bytes-remaining ceiling.
        let mut huge = good.clone();
        let idpos = 4 + 1 + 1 + 1 + 4; // header end = ids count offset
        huge[idpos..idpos + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_index(&huge).is_err());
    }
}
