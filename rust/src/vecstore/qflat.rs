//! Quantized exact index: [`super::FlatIndex`]'s scan engine over a
//! compact [`RowArena`] — same blocked panels, same sharded scoped-thread
//! scans, same deterministic seq-numbered top-k merge, but the rows cross
//! the memory bus at 2 B (f16) or ~1 B (int8) per element instead of 4.
//!
//! With [`Quant::F32`] this is byte-for-byte the flat layout, so results
//! equal [`super::FlatIndex`] exactly; quantized arenas trade a bounded
//! score error (see [`super::quant`]) for 2-4× less scan bandwidth, which
//! is what raises concurrent-scan capacity per instance once the kernels
//! are memory-bound.

use super::mask::SkipMask;
use super::quant::{PanelCtx, Quant, RowArena};
use super::{numa, Hit, Index, TopK};
use crate::devices::affinity::{pin_current_thread, Topology};

/// Row tile per kernel call — matches `flat.rs` so a tile stays
/// cache-resident while the query panel sweeps it (quantized tiles are
/// 2-4× smaller still).
const SCAN_BLOCK_ROWS: usize = 64;

/// Below this many rows per shard, thread spawn/merge overhead beats the
/// scan itself — stay sequential.
const MIN_ROWS_PER_SHARD: usize = 2048;

/// Flat (exact-scan) index over a quantized row arena.
pub struct QuantizedFlatIndex {
    pub(crate) dim: usize,
    pub(crate) ids: Vec<u64>,
    pub(crate) arena: RowArena,
    /// Tombstoned rows (same skip-mask contract as `FlatIndex`).
    pub(crate) dead: SkipMask,
    /// NUMA plan ([`Index::set_numa`]): when set (and multi-node),
    /// batched scans shard along node bands with pinned threads.
    pub(crate) numa: Option<Topology>,
}

impl QuantizedFlatIndex {
    pub fn new(dim: usize, quant: Quant) -> QuantizedFlatIndex {
        assert!(dim > 0);
        QuantizedFlatIndex {
            dim,
            ids: Vec::new(),
            // PQ's "derive m from dim" sentinel resolves here, so the
            // arena (and `quant()`) always carry concrete geometry.
            arena: RowArena::new(quant.resolved(dim)),
            dead: SkipMask::new(),
            numa: None,
        }
    }

    /// Storage codec of the row arena.
    pub fn quant(&self) -> Quant {
        self.arena.quant()
    }

    /// Arena footprint in bytes — the bytes a full scan reads.
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Row `row` decoded back to f32 (diagnostics; scans never do this).
    pub fn dequant_vector(&self, row: usize) -> Vec<f32> {
        self.arena.dequant_row(row, self.dim)
    }

    /// Whether a PQ arena has trained its codebook (i.e. left the exact
    /// staging regime — see `vecstore::pq`). Always `false` for other
    /// codecs; tests use this to assert which regime they exercise.
    pub fn pq_trained(&self) -> bool {
        self.arena.as_pq().map(|a| a.trained()).unwrap_or(false)
    }

    /// Shard count for a parallel scan over `rows` rows.
    fn auto_shards(rows: usize) -> usize {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        avail.min(rows / MIN_ROWS_PER_SHARD).max(1)
    }

    /// Batched search with an explicit shard count (1 = sequential).
    /// Results are identical to per-query [`Index::search`].
    pub fn search_batch_with_threads(
        &self,
        queries: &[&[f32]],
        k: usize,
        threads: usize,
    ) -> Vec<Vec<Hit>> {
        for q in queries {
            assert_eq!(q.len(), self.dim, "dimension mismatch");
        }
        let nq = queries.len();
        let n = self.ids.len();
        if nq == 0 {
            return Vec::new();
        }
        if n == 0 {
            return vec![Vec::new(); nq];
        }
        let mut qbuf = Vec::with_capacity(nq * self.dim);
        for q in queries {
            qbuf.extend_from_slice(q);
        }
        let threads = threads.max(1).min(n);
        // One panel context (the PQ ADC table, a no-op for other codecs)
        // for the whole batch, shared read-only across every shard.
        let ctx = self.arena.begin_panel(&qbuf, nq, self.dim);
        if threads == 1 {
            let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
            let mut scores = vec![0.0f32; nq * SCAN_BLOCK_ROWS];
            self.scan_rows(&ctx, &qbuf, nq, 0, n, &mut tks, &mut scores);
            return tks.into_iter().map(TopK::into_vec).collect();
        }
        // NUMA plan: band shards + pinned threads; bit-identical to the
        // unpinned path (global row seqs — see `vecstore::numa`).
        if let Some(topo) = self.numa.as_ref().filter(|t| t.numa_nodes > 1) {
            let shards = numa::band_shards(n, threads, topo);
            let finals = super::parallel_topk_scan(shards.len(), nq, k, |t, tks| {
                let (lo, hi, node) = shards[t];
                let _ = pin_current_thread(&topo.cores_of_node(node));
                let mut scores = vec![0.0f32; nq * SCAN_BLOCK_ROWS];
                self.scan_rows(&ctx, &qbuf, nq, lo, hi, tks, &mut scores);
            });
            return finals.into_iter().map(TopK::into_vec).collect();
        }
        let rows_per = n / threads + usize::from(n % threads != 0);
        let finals = super::parallel_topk_scan(threads, nq, k, |t, tks| {
            let lo = t * rows_per;
            let hi = ((t + 1) * rows_per).min(n);
            if lo < hi {
                let mut scores = vec![0.0f32; nq * SCAN_BLOCK_ROWS];
                self.scan_rows(&ctx, &qbuf, nq, lo, hi, tks, &mut scores);
            }
        });
        finals.into_iter().map(TopK::into_vec).collect()
    }

    /// Score rows `[lo, hi)` against the query panel block by block
    /// through the arena's quantized kernel, pushing with the global row
    /// index as the tie-break sequence number (same contract as
    /// `FlatIndex::scan_rows`). `ctx` must come from `begin_panel` on
    /// this arena for the same panel — built once per batch, never per
    /// block.
    fn scan_rows(
        &self,
        ctx: &PanelCtx,
        qbuf: &[f32],
        nq: usize,
        lo: usize,
        hi: usize,
        tks: &mut [TopK],
        scores: &mut [f32],
    ) {
        debug_assert!(scores.len() >= nq * SCAN_BLOCK_ROWS);
        let mut r0 = lo;
        while r0 < hi {
            let r1 = (r0 + SCAN_BLOCK_ROWS).min(hi);
            let nr = r1 - r0;
            self.arena
                .panel_scores_ctx_into(ctx, qbuf, nq, r0, r1, self.dim, &mut scores[..nq * nr]);
            for (qi, tk) in tks.iter_mut().enumerate() {
                for r in 0..nr {
                    // Tombstone skip (see `FlatIndex::scan_rows`).
                    if self.dead.is_dead(r0 + r) {
                        continue;
                    }
                    tk.push_with_seq(self.ids[r0 + r], scores[qi * nr + r], (r0 + r) as u64);
                }
            }
            r0 = r1;
        }
    }
}

impl Index for QuantizedFlatIndex {
    /// Quantizes `vector` into the arena (the f32 original is not kept).
    fn add(&mut self, id: u64, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "dimension mismatch");
        self.ids.push(id);
        self.arena.push(vector);
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "dimension mismatch");
        let mut tk = TopK::new(k);
        // Stack scratch: the single-query request path allocates nothing
        // (the panel context is free for all codecs but trained PQ).
        let ctx = self.arena.begin_panel(query, 1, self.dim);
        let mut scores = [0.0f32; SCAN_BLOCK_ROWS];
        self.scan_rows(
            &ctx,
            query,
            1,
            0,
            self.ids.len(),
            std::slice::from_mut(&mut tk),
            &mut scores,
        );
        tk.into_vec()
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        self.search_batch_with_threads(queries, k, Self::auto_shards(self.ids.len()))
    }

    fn len(&self) -> usize {
        self.ids.len() - self.dead.dead()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn quant(&self) -> Quant {
        self.arena.quant()
    }

    fn remove(&mut self, id: u64) -> usize {
        let mut killed = 0;
        for row in 0..self.ids.len() {
            if self.ids[row] == id && self.dead.kill(row) {
                killed += 1;
            }
        }
        killed
    }

    fn tombstones(&self) -> usize {
        self.dead.dead()
    }

    fn compact(&mut self) -> usize {
        let reclaimed = self.dead.dead();
        if reclaimed == 0 {
            return 0;
        }
        let mut ids = Vec::with_capacity(self.ids.len() - reclaimed);
        // `new_like`, not `new`: a trained PQ scratch arena must share
        // the codebook so the byte-copy below stays valid.
        let mut arena = RowArena::new_like(&self.arena);
        for row in 0..self.ids.len() {
            if !self.dead.is_dead(row) {
                ids.push(self.ids[row]);
                // Byte-exact copy of the already-encoded row: survivors
                // re-encode identically, so post-compaction scans score
                // bit-for-bit what they scored before.
                arena.push_row_from(&self.arena, row, self.dim);
            }
        }
        self.ids = ids;
        self.arena = arena;
        self.dead.clear();
        // Restore node-local placement after the on-thread rebuild.
        if let Some(t) = self.numa.as_ref().filter(|t| t.numa_nodes > 1) {
            self.arena.numa_realign(self.dim, t);
        }
        reclaimed
    }

    fn set_numa(&mut self, topo: Option<Topology>) -> bool {
        if let Some(t) = topo.as_ref().filter(|t| t.numa_nodes > 1) {
            self.arena.numa_realign(self.dim, t);
        }
        self.numa = topo;
        true
    }

    fn scan_rows_estimate(&self) -> usize {
        // Dead rows still stream through the kernels (see
        // `FlatIndex::scan_rows_estimate`).
        self.ids.len()
    }

    fn snapshot_bytes(&self) -> Option<Vec<u8>> {
        Some(super::persist::encode_qflat(self))
    }
}

#[cfg(test)]
mod tests {
    use super::super::FlatIndex;
    use super::*;
    use crate::util::rng::Pcg;

    fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    #[test]
    fn f32_mode_equals_flat_index_exactly() {
        let mut rng = Pcg::new(1);
        let dim = 48;
        let mut flat = FlatIndex::new(dim);
        let mut q32 = QuantizedFlatIndex::new(dim, Quant::F32);
        for i in 0..300 {
            let v = unit(&mut rng, dim);
            flat.add(i, &v);
            q32.add(i, &v);
        }
        let queries: Vec<Vec<f32>> = (0..5).map(|_| unit(&mut rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        for (q, (a, b)) in queries
            .iter()
            .zip(flat.search_batch(&qrefs, 7).into_iter().zip(q32.search_batch(&qrefs, 7)))
        {
            assert_eq!(a, b);
            assert_eq!(b, q32.search(q, 7));
        }
    }

    #[test]
    fn quantized_arena_shrinks_bytes_scanned() {
        let mut rng = Pcg::new(2);
        let dim = 768;
        let mut flat = FlatIndex::new(dim);
        for i in 0..32 {
            flat.add(i, &unit(&mut rng, dim));
        }
        let f32_bytes = flat.len() * Quant::F32.bytes_per_row(dim);
        let half = flat.quantize(Quant::F16);
        let int8 = flat.quantize(Quant::Int8);
        // The measured bandwidth win: exactly 2× for f16, ~3.98× for
        // int8 at dim 768 (codes + one f32 scale per row).
        assert_eq!(half.arena_bytes() * 2, f32_bytes);
        assert_eq!(int8.arena_bytes(), 32 * (dim + 4));
        assert!(f32_bytes as f64 / int8.arena_bytes() as f64 > 3.9);
    }

    #[test]
    fn quantized_search_finds_itself_first() {
        let mut rng = Pcg::new(3);
        let dim = 64;
        for (quant, tol) in [(Quant::F16, 2e-3), (Quant::Int8, 3e-2)] {
            let mut idx = QuantizedFlatIndex::new(dim, quant);
            let mut vs = Vec::new();
            for i in 0..80 {
                let v = unit(&mut rng, dim);
                idx.add(i, &v);
                vs.push(v);
            }
            assert_eq!(idx.quant(), quant);
            for (i, v) in vs.iter().enumerate() {
                let hits = idx.search(v, 1);
                assert_eq!(hits[0].id, i as u64, "{quant:?}");
                assert!((hits[0].score - 1.0).abs() < tol, "{quant:?}: {}", hits[0].score);
            }
        }
    }

    #[test]
    fn batch_matches_single_across_shards() {
        let mut rng = Pcg::new(4);
        let dim = 48;
        // 500 rows crosses the PQ staging threshold, so pq4/pq8 exercise
        // the trained ADC scan here, not the staged-exact path.
        for quant in [Quant::F16, Quant::Int8, Quant::pq(4), Quant::pq(8)] {
            let mut idx = QuantizedFlatIndex::new(dim, quant);
            for i in 0..500 {
                idx.add(i, &unit(&mut rng, dim));
            }
            let queries: Vec<Vec<f32>> = (0..9).map(|_| unit(&mut rng, dim)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            for variant in [
                idx.search_batch_with_threads(&qrefs, 7, 4),
                idx.search_batch_with_threads(&qrefs, 7, 1),
                idx.search_batch(&qrefs, 7),
            ] {
                for (q, got) in queries.iter().zip(&variant) {
                    assert_eq!(got, &idx.search(q, 7), "{quant:?}");
                }
            }
        }
    }

    #[test]
    fn duplicate_rows_tie_break_is_row_order() {
        // Quantization maps equal rows to equal codes, so ties must keep
        // first-inserted (lowest row) order exactly like FlatIndex.
        let v = [0.6f32, 0.8, 0.0, 0.0];
        for quant in [Quant::F16, Quant::Int8, Quant::pq(4)] {
            let mut idx = QuantizedFlatIndex::new(4, quant);
            for i in 0..20 {
                idx.add(100 + i, &v);
            }
            let hits = idx.search(&v, 5);
            assert_eq!(
                hits.iter().map(|h| h.id).collect::<Vec<_>>(),
                vec![100, 101, 102, 103, 104],
                "{quant:?}"
            );
            let batch = idx.search_batch_with_threads(&[&v], 5, 3);
            assert_eq!(batch[0], hits);
        }
    }

    #[test]
    fn empty_inputs() {
        let idx = QuantizedFlatIndex::new(8, Quant::Int8);
        assert!(idx.is_empty());
        assert!(idx.search_batch(&[], 3).is_empty());
        let q = [0.0f32; 8];
        assert_eq!(idx.search_batch(&[&q], 3), vec![Vec::new()]);
    }
}
