//! NUMA-aware scan sharding (paper §4.4, extended to retrieval).
//!
//! A flat scan is memory-bound: on a multi-socket host, a shard whose
//! rows live on a remote node's DRAM pays the interconnect on every
//! cache line. This module keeps shards node-local in two steps:
//!
//! 1. **Placement** — [`first_touch_realign`] rewrites an arena through
//!    per-node *pinned* copy threads. Linux backs fresh (calloc'd) pages
//!    physically on first write, on the writing core's node — so copying
//!    band `b` from a thread pinned to node `b`'s cores lands band `b`'s
//!    pages in node `b`'s DRAM. Contents are bit-identical to the input.
//! 2. **Sharding** — [`band_shards`] partitions the row range into
//!    per-node bands (the same bands placement used) and subdivides each
//!    band into shards, so **no shard ever crosses a node boundary**.
//!    The scan pins each shard's thread to its owning node.
//!
//! Determinism: bands tile `[0, n)` in order and shards push hits with
//! the *global* row index as the tie-break sequence number (see
//! `TopK::push_with_seq`), so the merged result is bit-identical to a
//! sequential or unpinned sharded scan — placement moves bytes, never
//! scores. On single-node hosts both functions degrade to plain
//! chunking / a plain copy, and callers skip the machinery entirely.

use crate::devices::affinity::{pin_current_thread, Topology};

/// Row range `[lo, hi)` of the node band `b` out of `nodes` equal bands
/// (remainder rows fold into the later bands; bands tile `[0, rows)`).
pub fn band_rows(rows: usize, nodes: usize, b: usize) -> (usize, usize) {
    debug_assert!(nodes > 0 && b < nodes);
    (b * rows / nodes, (b + 1) * rows / nodes)
}

/// Partition `rows` into scan shards that never cross a NUMA band:
/// each band gets a share of `want_threads` proportional to its row
/// count (at least one shard per non-empty band), then splits evenly.
/// Returns `(lo, hi, node)` triples tiling `[0, rows)` in row order;
/// the total shard count is within `numa_nodes` of `want_threads`.
pub fn band_shards(
    rows: usize,
    want_threads: usize,
    topo: &Topology,
) -> Vec<(usize, usize, usize)> {
    let nodes = topo.numa_nodes.max(1);
    let want = want_threads.max(1);
    let mut shards = Vec::with_capacity(want + nodes);
    if rows == 0 {
        return shards;
    }
    for node in 0..nodes {
        let (lo, hi) = band_rows(rows, nodes, node);
        if lo >= hi {
            continue;
        }
        let band = hi - lo;
        // Ceil of the proportional thread share, clamped to the band.
        let share = (band * want).div_ceil(rows).clamp(1, band);
        let per = band / share + usize::from(band % share != 0);
        let mut s_lo = lo;
        while s_lo < hi {
            let s_hi = (s_lo + per).min(hi);
            shards.push((s_lo, s_hi, node));
            s_lo = s_hi;
        }
    }
    shards
}

/// Copy `data` (rows of `stride` elements) into a fresh allocation whose
/// per-node bands are first-touched by threads pinned to the owning
/// node, placing each band's pages in that node's DRAM. The zeroed
/// allocation itself is copy-on-write zero pages (calloc/mmap), so the
/// pinned writes are the first physical touch. Returns a bit-identical
/// copy; on single-node topologies this is just a plain copy.
pub fn first_touch_realign<T>(data: &[T], stride: usize, topo: &Topology) -> Vec<T>
where
    T: Copy + Default + Send + Sync,
{
    assert!(stride > 0, "zero row stride");
    let rows = data.len() / stride;
    let mut out = vec![T::default(); data.len()];
    if rows == 0 || topo.numa_nodes <= 1 {
        out.copy_from_slice(data);
        return out;
    }
    std::thread::scope(|s| {
        let mut rest: &mut [T] = &mut out;
        for node in 0..topo.numa_nodes {
            let (lo, hi) = band_rows(rows, topo.numa_nodes, node);
            let band_elems = (hi - lo) * stride;
            let taken = std::mem::take(&mut rest);
            let (band, tail) = taken.split_at_mut(band_elems);
            rest = tail;
            if band_elems == 0 {
                continue;
            }
            let src = &data[lo * stride..hi * stride];
            let cores = topo.cores_of_node(node);
            s.spawn(move || {
                // Pinning is best-effort: an unpinned copy still
                // produces correct bytes, just without the placement win.
                let _ = pin_current_thread(&cores);
                band.copy_from_slice(src);
            });
        }
        // Row-incomplete trailing elements (never scanned) still copy.
        let data_tail = &data[rows * stride..];
        rest.copy_from_slice(data_tail);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bands_tile_the_row_range() {
        for (rows, nodes) in [(10, 3), (7, 4), (1, 2), (100, 1), (4, 4)] {
            let mut next = 0;
            for b in 0..nodes {
                let (lo, hi) = band_rows(rows, nodes, b);
                assert_eq!(lo, next, "rows={rows} nodes={nodes} b={b}");
                assert!(hi >= lo);
                next = hi;
            }
            assert_eq!(next, rows);
        }
    }

    #[test]
    fn shards_tile_and_never_cross_bands() {
        for (rows, want, nodes) in
            [(10_000, 8, 4), (10_000, 3, 4), (5, 8, 4), (8192, 16, 2), (1000, 1, 4)]
        {
            let topo = Topology::new(nodes * 2, nodes);
            let shards = band_shards(rows, want, &topo);
            let mut next = 0;
            for &(lo, hi, node) in &shards {
                assert_eq!(lo, next, "rows={rows} want={want} nodes={nodes}");
                assert!(hi > lo, "empty shard");
                let (blo, bhi) = band_rows(rows, nodes, node);
                assert!(lo >= blo && hi <= bhi, "shard [{lo},{hi}) crosses band {node}");
                next = hi;
            }
            assert_eq!(next, rows);
            assert!(shards.len() <= want.max(1) + nodes, "{} shards", shards.len());
        }
    }

    #[test]
    fn zero_rows_yield_no_shards() {
        let topo = Topology::new(8, 4);
        assert!(band_shards(0, 8, &topo).is_empty());
    }

    #[test]
    fn single_node_shards_match_plain_chunking() {
        let topo = Topology::new(8, 1);
        let shards = band_shards(100, 4, &topo);
        assert_eq!(shards, vec![(0, 25, 0), (25, 50, 0), (50, 75, 0), (75, 100, 0)]);
    }

    #[test]
    fn realign_is_bit_identical() {
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect();
        for nodes in [1, 2, 4] {
            let topo = Topology::new(nodes.max(1), nodes);
            let out = first_touch_realign(&data, 8, &topo);
            assert_eq!(out, data, "nodes={nodes}");
        }
        // Odd shapes: stride that doesn't divide the length (trailing
        // partial row), scalar stride, empty input.
        let topo = Topology::new(4, 2);
        let odd: Vec<i8> = (0..101).map(|i| (i % 117) as i8).collect();
        assert_eq!(first_touch_realign(&odd, 10, &topo), odd);
        let scales: Vec<f32> = (0..33).map(|i| i as f32).collect();
        assert_eq!(first_touch_realign(&scales, 1, &topo), scales);
        assert!(first_touch_realign::<f32>(&[], 4, &topo).is_empty());
    }
}
