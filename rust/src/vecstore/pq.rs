//! Product quantization (PQ) — the rung below int8 on the
//! bytes-per-row ladder (EdgeRAG-style, see PAPERS.md).
//!
//! A row of `dim` f32 elements is split into `m` contiguous sub-vectors
//! of `dim / m` elements. Each sub-space gets its own codebook of
//! `k = 2^bits` centroids (trained with the deterministic k-means in
//! [`super::kmeans`], L2 objective), and the row is stored as `m` packed
//! code indices — 4 or 8 bits each, so dim-768 / m-96 rows shrink to
//! 48 B (`pq4`) or 96 B (`pq8`) against int8's 772 B.
//!
//! Scoring is **asymmetric distance computation** (ADC): per query,
//! build an `m × k` lookup table `lut[s][c] = query_sub_s · center_c`
//! once, then score every row with `m` table lookups instead of `dim`
//! multiplies: `score(row) = Σ_s lut[s][code(row, s)]` — exactly the
//! inner product of the query with the row's reconstruction, so recall
//! tracks codebook quality, not scan arithmetic. The LUT-gather kernels
//! live in [`super::kernels`] alongside the f16/int8 dispatch.
//!
//! # Training and determinism
//!
//! Codebooks freeze once trained: a flat [`PqArena`] stages raw f32
//! rows until [`PQ_TRAIN_ROWS`] arrive (scoring the staged rows at full
//! precision — exact, not approximate), trains on that prefix with a
//! fixed seed, then encodes incrementally forever after. IVF arenas
//! train at `build(seed)` instead and share one `Arc<Codebook>` across
//! all inverted lists. Both paths reuse the seeded k-means, encoding is
//! a deterministic argmin, and the LUT is built in a fixed scalar
//! order — so re-encoding a row always yields the same bytes and
//! batch/shard determinism invariants carry over unchanged.

use std::sync::Arc;

use super::{kmeans, numa};
use crate::devices::affinity::Topology;

/// Rows a flat PQ arena stages (and scores at full precision) before it
/// trains codebooks on them and switches to packed codes.
pub const PQ_TRAIN_ROWS: usize = 256;

/// Lloyd rounds per sub-space codebook.
const TRAIN_ITERS: usize = 12;

/// Seed for the flat arena's threshold-triggered training (IVF passes
/// its build seed instead). Sub-space `s` derives `seed ^ mix(s)`.
pub const PQ_TRAIN_SEED: u64 = 0x00C0_DEB0_0C51;

fn subspace_seed(seed: u64, s: usize) -> u64 {
    seed ^ (s as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Default sub-vector count for a row width: the largest sub-dim in
/// {8, 4, 2, 1} dividing `dim` (dim 768 → m = 96, the paper-dim
/// layout; awkward dims degrade gracefully toward scalar quantization).
pub fn default_m(dim: usize) -> usize {
    for sub in [8usize, 4, 2] {
        if dim % sub == 0 {
            return dim / sub;
        }
    }
    dim
}

/// Packed bytes per row for `m` codes of `bits` bits (two pq4 codes per
/// byte; an odd trailing code keeps the low nibble).
pub fn packed_row_bytes(m: usize, bits: u8) -> usize {
    (m * bits as usize).div_ceil(8)
}

/// Trained sub-space codebooks: `m` tables of `k = 2^bits` centroids of
/// `sub = dim / m` elements, row-major `[m][k][sub]`. When training had
/// fewer than `k` rows, the tail entries duplicate the last trained
/// centroid (the deterministic argmin encoder never picks a duplicate —
/// first occurrence wins — so the code space stays well-defined).
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub(crate) dim: usize,
    pub(crate) m: usize,
    pub(crate) sub: usize,
    pub(crate) bits: u8,
    pub(crate) centers: Vec<f32>,
}

impl Codebook {
    /// Train on row-major `rows [n, dim]` (n ≥ 1). `k` clamps to `n`
    /// per sub-space; sub-space `s` trains with `subspace_seed(seed, s)`
    /// so the whole book is a pure function of `(rows, m, bits, seed)`.
    pub fn train(rows: &[f32], dim: usize, m: usize, bits: u8, seed: u64) -> Codebook {
        assert!(matches!(bits, 4 | 8), "pq bits must be 4 or 8");
        assert!(m >= 1 && dim % m == 0, "m={m} must divide dim={dim}");
        let n = rows.len() / dim;
        assert!(n >= 1, "cannot train a codebook on zero rows");
        let sub = dim / m;
        let k = 1usize << bits;
        let kt = k.min(n);
        let mut centers = vec![0.0f32; m * k * sub];
        let mut scratch = vec![0.0f32; n * sub];
        for s in 0..m {
            for i in 0..n {
                let row = &rows[i * dim + s * sub..i * dim + (s + 1) * sub];
                scratch[i * sub..(i + 1) * sub].copy_from_slice(row);
            }
            let trained =
                kmeans::train_l2(&scratch, sub, kt, TRAIN_ITERS, subspace_seed(seed, s));
            let base = s * k * sub;
            centers[base..base + kt * sub].copy_from_slice(&trained);
            for pad in kt..k {
                centers.copy_within(base + (kt - 1) * sub..base + kt * sub, base + pad * sub);
            }
        }
        Codebook { dim, m, sub, bits, centers }
    }

    /// Rebuild from persisted parts (validating the geometry).
    pub fn from_parts(
        dim: usize,
        m: usize,
        bits: u8,
        centers: Vec<f32>,
    ) -> Result<Codebook, String> {
        if !matches!(bits, 4 | 8) {
            return Err(format!("pq bits {bits} not in {{4, 8}}"));
        }
        if m == 0 || dim % m != 0 {
            return Err(format!("pq m {m} does not divide dim {dim}"));
        }
        let sub = dim / m;
        let want = m * (1usize << bits) * sub;
        if centers.len() != want {
            return Err(format!("pq codebook has {} centers, want {want}", centers.len()));
        }
        Ok(Codebook { dim, m, sub, bits, centers })
    }

    pub fn k(&self) -> usize {
        1usize << self.bits
    }

    pub fn packed_row_bytes(&self) -> usize {
        packed_row_bytes(self.m, self.bits)
    }

    /// Codebook footprint in bytes (amortized across the whole arena).
    pub fn bytes(&self) -> usize {
        self.centers.len() * 4
    }

    /// Nearest centroid of sub-space `s` to `x` by L2 (first wins on
    /// ties — deterministic, and padded duplicates are never chosen).
    fn nearest_code(&self, s: usize, x: &[f32]) -> usize {
        let k = self.k();
        let base = s * k * self.sub;
        let mut best = (0usize, f64::MAX);
        for c in 0..k {
            let cent = &self.centers[base + c * self.sub..base + (c + 1) * self.sub];
            let d: f64 = x.iter().zip(cent).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            if d < best.1 {
                best = (c, d);
            }
        }
        best.0
    }

    /// Encode one row, appending its packed codes to `out`.
    pub fn encode_append(&self, v: &[f32], out: &mut Vec<u8>) {
        assert_eq!(v.len(), self.dim, "row width mismatch");
        match self.bits {
            8 => {
                for s in 0..self.m {
                    out.push(self.nearest_code(s, &v[s * self.sub..(s + 1) * self.sub]) as u8);
                }
            }
            _ => {
                let mut s = 0;
                while s + 1 < self.m {
                    let lo = self.nearest_code(s, &v[s * self.sub..(s + 1) * self.sub]) as u8;
                    let hi = self
                        .nearest_code(s + 1, &v[(s + 1) * self.sub..(s + 2) * self.sub])
                        as u8;
                    out.push(lo | (hi << 4));
                    s += 2;
                }
                if s < self.m {
                    out.push(self.nearest_code(s, &v[s * self.sub..(s + 1) * self.sub]) as u8);
                }
            }
        }
    }

    /// Reconstruct one packed row (concatenated chosen centroids).
    pub fn decode_row(&self, packed: &[u8]) -> Vec<f32> {
        assert_eq!(packed.len(), self.packed_row_bytes());
        let mut out = Vec::with_capacity(self.dim);
        for s in 0..self.m {
            let c = code_at(packed, s, self.bits);
            let base = s * self.k() * self.sub + c * self.sub;
            out.extend_from_slice(&self.centers[base..base + self.sub]);
        }
        out
    }

    /// Build the ADC lookup table for a query panel: row-major
    /// `[nq][m][k]` with `lut[q][s][c] = queries[q]_sub_s · center_c`.
    /// Fixed scalar evaluation order per (q, s, c), independent of the
    /// panel size — the batch==single bit-identity hinges on it.
    pub fn build_lut(self: &Arc<Codebook>, queries: &[f32], nq: usize) -> PanelLut {
        assert_eq!(queries.len(), nq * self.dim, "query panel shape mismatch");
        let k = self.k();
        let mut lut = vec![0.0f32; nq * self.m * k];
        for q in 0..nq {
            let qrow = &queries[q * self.dim..(q + 1) * self.dim];
            for s in 0..self.m {
                let qs = &qrow[s * self.sub..(s + 1) * self.sub];
                let base = s * k * self.sub;
                let lbase = (q * self.m + s) * k;
                for c in 0..k {
                    let cent = &self.centers[base + c * self.sub..base + (c + 1) * self.sub];
                    let mut acc = 0.0f32;
                    for (a, b) in qs.iter().zip(cent) {
                        acc += a * b;
                    }
                    lut[lbase + c] = acc;
                }
            }
        }
        PanelLut { book: Arc::clone(self), nq, lut }
    }
}

/// Decode code index `s` from a packed row.
#[inline]
pub fn code_at(packed: &[u8], s: usize, bits: u8) -> usize {
    if bits == 8 {
        packed[s] as usize
    } else {
        ((packed[s >> 1] >> ((s & 1) * 4)) & 0xF) as usize
    }
}

/// One query panel's ADC table, built once per scan and shared across
/// row blocks (and across IVF lists — every list shares the arena's
/// `Arc<Codebook>`).
pub struct PanelLut {
    pub(crate) book: Arc<Codebook>,
    pub(crate) nq: usize,
    pub(crate) lut: Vec<f32>,
}

impl PanelLut {
    /// The raw `[nq][m][k]` table (benchmarks drive the scan kernel with
    /// a prebuilt table; scans inside the crate go through `PanelCtx`).
    pub fn table(&self) -> &[f32] {
        &self.lut
    }
}

/// PQ row storage behind [`super::quant::RowArena::Pq`]: raw staged f32
/// rows before training, packed codes + a shared codebook after.
pub struct PqArena {
    m: usize,
    bits: u8,
    state: PqState,
}

enum PqState {
    /// Raw f32 rows, scored at full precision until training triggers.
    Staged(Vec<f32>),
    Trained { book: Arc<Codebook>, codes: Vec<u8> },
}

impl PqArena {
    /// `m == 0` derives the sub-vector count from the row width on
    /// first use ([`default_m`]); callers that know `dim` should pass a
    /// resolved `m` (see `Quant::resolved`).
    pub fn new(m: usize, bits: u8) -> PqArena {
        assert!(matches!(bits, 4 | 8), "pq bits must be 4 or 8");
        PqArena { m, bits, state: PqState::Staged(Vec::new()) }
    }

    /// Empty arena sharing this one's codebook (and training state) —
    /// what compaction and IVF list construction clone so
    /// [`PqArena::push_row_from`] can copy packed bytes verbatim.
    pub fn new_like(&self) -> PqArena {
        let state = match &self.state {
            PqState::Staged(_) => PqState::Staged(Vec::new()),
            PqState::Trained { book, .. } => {
                PqState::Trained { book: Arc::clone(book), codes: Vec::new() }
            }
        };
        PqArena { m: self.m, bits: self.bits, state }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn trained(&self) -> bool {
        matches!(self.state, PqState::Trained { .. })
    }

    pub fn book(&self) -> Option<&Arc<Codebook>> {
        match &self.state {
            PqState::Trained { book, .. } => Some(book),
            PqState::Staged(_) => None,
        }
    }

    /// Packed code bytes (trained arenas; staged return `None`).
    pub fn codes(&self) -> Option<&[u8]> {
        match &self.state {
            PqState::Trained { codes, .. } => Some(codes),
            PqState::Staged(_) => None,
        }
    }

    /// Staged f32 rows (untrained arenas; trained return `None`).
    pub fn staged(&self) -> Option<&[f32]> {
        match &self.state {
            PqState::Staged(d) => Some(d),
            PqState::Trained { .. } => None,
        }
    }

    /// Adopt a restored trained state (persist decode path).
    pub fn restore_trained(&mut self, book: Arc<Codebook>, codes: Vec<u8>) {
        self.m = book.m;
        self.bits = book.bits;
        self.state = PqState::Trained { book, codes };
    }

    /// Adopt restored staged rows (persist decode path).
    pub fn restore_staged(&mut self, rows: Vec<f32>) {
        self.state = PqState::Staged(rows);
    }

    pub fn rows(&self, dim: usize) -> usize {
        match &self.state {
            PqState::Staged(d) => d.len() / dim,
            PqState::Trained { book, codes } => codes.len() / book.packed_row_bytes(),
        }
    }

    /// Append one row: staged arenas buffer the raw f32s (training when
    /// the buffer hits [`PQ_TRAIN_ROWS`]); trained arenas encode with
    /// the frozen codebook — the ingest-time incremental path, so an
    /// upsert re-encodes exactly one row and every untouched row's
    /// bytes stay bit-identical.
    pub fn push(&mut self, v: &[f32]) {
        match &mut self.state {
            PqState::Staged(d) => {
                d.extend_from_slice(v);
                if d.len() / v.len() >= PQ_TRAIN_ROWS {
                    self.train_now(v.len(), PQ_TRAIN_SEED);
                }
            }
            PqState::Trained { book, codes } => book.encode_append(v, codes),
        }
    }

    /// Train codebooks on the staged rows and encode them. No-op when
    /// already trained or nothing is staged. IVF `build(seed)` calls
    /// this so list arenas inherit one deterministic shared book.
    pub fn train_now(&mut self, dim: usize, seed: u64) {
        let PqState::Staged(staged) = &self.state else { return };
        if staged.is_empty() {
            return;
        }
        let m = if self.m == 0 { default_m(dim) } else { self.m };
        assert!(dim % m == 0, "pq m={m} must divide dim={dim}");
        self.m = m;
        let book = Arc::new(Codebook::train(staged, dim, m, self.bits, seed));
        let rows = staged.len() / dim;
        let mut codes = Vec::with_capacity(rows * book.packed_row_bytes());
        for r in 0..rows {
            book.encode_append(&staged[r * dim..(r + 1) * dim], &mut codes);
        }
        self.state = PqState::Trained { book, codes };
    }

    /// Append row `r` of `src` by copying already-encoded bytes. Both
    /// arenas must share one codebook (see [`PqArena::new_like`]).
    pub fn push_row_from(&mut self, src: &PqArena, r: usize, dim: usize) {
        match (&mut self.state, &src.state) {
            (PqState::Staged(d), PqState::Staged(s)) => {
                d.extend_from_slice(&s[r * dim..(r + 1) * dim]);
            }
            (
                PqState::Trained { book, codes },
                PqState::Trained { book: sbook, codes: scodes },
            ) => {
                assert!(Arc::ptr_eq(book, sbook), "pq arenas must share a codebook");
                let pb = book.packed_row_bytes();
                codes.extend_from_slice(&scodes[r * pb..(r + 1) * pb]);
            }
            _ => panic!("pq arena training-state mismatch"),
        }
    }

    pub fn numa_realign(&mut self, dim: usize, topo: &Topology) {
        match &mut self.state {
            PqState::Staged(d) => *d = numa::first_touch_realign(d, dim, topo),
            PqState::Trained { book, codes } => {
                *codes = numa::first_touch_realign(codes, book.packed_row_bytes(), topo);
            }
        }
    }

    /// Arena footprint: packed codes plus the (amortized) codebook.
    pub fn bytes(&self) -> usize {
        match &self.state {
            PqState::Staged(d) => d.len() * 4,
            PqState::Trained { book, codes } => codes.len() + book.bytes(),
        }
    }

    pub fn dequant_row(&self, r: usize, dim: usize) -> Vec<f32> {
        match &self.state {
            PqState::Staged(d) => d[r * dim..(r + 1) * dim].to_vec(),
            PqState::Trained { book, codes } => {
                let pb = book.packed_row_bytes();
                book.decode_row(&codes[r * pb..(r + 1) * pb])
            }
        }
    }

    /// Encoded bytes of row `r` as stored (regression hook: unchanged
    /// rows must stay bit-identical across incremental ingest).
    pub fn row_bytes(&self, r: usize, dim: usize) -> Vec<u8> {
        match &self.state {
            PqState::Staged(d) => {
                d[r * dim..(r + 1) * dim].iter().flat_map(|x| x.to_le_bytes()).collect()
            }
            PqState::Trained { book, codes } => {
                let pb = book.packed_row_bytes();
                codes[r * pb..(r + 1) * pb].to_vec()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn clustered_rows(rng: &mut Pcg, n: usize, dim: usize, ncenters: usize) -> Vec<f32> {
        let centers: Vec<f32> = (0..ncenters * dim).map(|_| rng.normal() as f32).collect();
        let mut rows = Vec::with_capacity(n * dim);
        for i in 0..n {
            let c = i % ncenters;
            for j in 0..dim {
                rows.push(centers[c * dim + j] + 0.05 * rng.normal() as f32);
            }
        }
        rows
    }

    #[test]
    fn default_m_prefers_sub8_and_degrades() {
        assert_eq!(default_m(768), 96);
        assert_eq!(default_m(64), 8);
        assert_eq!(default_m(24), 3);
        assert_eq!(default_m(20), 5); // 20 % 8 != 0 → sub 4
        assert_eq!(default_m(37), 37); // prime → scalar sub-spaces
    }

    #[test]
    fn packed_bytes_and_nibble_codec() {
        assert_eq!(packed_row_bytes(96, 4), 48);
        assert_eq!(packed_row_bytes(96, 8), 96);
        assert_eq!(packed_row_bytes(3, 4), 2); // odd m: trailing nibble
        let packed = vec![0x21u8, 0x03];
        assert_eq!(code_at(&packed, 0, 4), 1);
        assert_eq!(code_at(&packed, 1, 4), 2);
        assert_eq!(code_at(&packed, 2, 4), 3);
        let bytes = vec![7u8, 255, 0];
        assert_eq!(code_at(&bytes, 1, 8), 255);
    }

    #[test]
    fn train_encode_decode_reconstructs_clustered_rows() {
        let mut rng = Pcg::new(11);
        let dim = 16;
        let rows = clustered_rows(&mut rng, 300, dim, 8);
        for bits in [4u8, 8] {
            let book = Arc::new(Codebook::train(&rows, dim, default_m(dim), bits, 1));
            let mut codes = Vec::new();
            book.encode_append(&rows[..dim], &mut codes);
            assert_eq!(codes.len(), book.packed_row_bytes());
            let recon = book.decode_row(&codes);
            let err: f32 = rows[..dim]
                .iter()
                .zip(&recon)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            let norm: f32 = rows[..dim].iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(err < 0.5 * norm, "bits={bits}: recon err {err} vs norm {norm}");
        }
    }

    #[test]
    fn lut_score_equals_dot_with_reconstruction() {
        let mut rng = Pcg::new(12);
        let dim = 24;
        let rows = clustered_rows(&mut rng, 64, dim, 4);
        let book = Arc::new(Codebook::train(&rows, dim, default_m(dim), 4, 3));
        let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let lut = book.build_lut(&q, 1);
        let mut codes = Vec::new();
        book.encode_append(&rows[..dim], &mut codes);
        let k = book.k();
        let mut via_lut = 0.0f32;
        for s in 0..book.m {
            via_lut += lut.lut[s * k + code_at(&codes, s, 4)];
        }
        let recon = book.decode_row(&codes);
        let direct: f32 = q.iter().zip(&recon).map(|(a, b)| a * b).sum();
        assert!(
            (via_lut - direct).abs() <= 1e-4 * (1.0 + direct.abs()),
            "{via_lut} vs {direct}"
        );
    }

    #[test]
    fn training_is_deterministic_per_seed() {
        let mut rng = Pcg::new(13);
        let dim = 16;
        let rows = clustered_rows(&mut rng, 128, dim, 4);
        let a = Codebook::train(&rows, dim, 2, 4, 7);
        let b = Codebook::train(&rows, dim, 2, 4, 7);
        assert_eq!(a, b);
        let c = Codebook::train(&rows, dim, 2, 4, 8);
        assert_ne!(a, c, "different seeds should move centroids");
    }

    #[test]
    fn codebook_pads_when_rows_below_k() {
        let rows = vec![1.0f32, 0.0, 0.0, 1.0, -1.0, 0.0]; // 3 rows, dim 2
        let book = Codebook::train(&rows, 2, 1, 8, 5);
        assert_eq!(book.centers.len(), 256 * 2);
        let mut codes = Vec::new();
        book.encode_append(&rows[..2], &mut codes);
        // Only trained (non-pad) entries are ever selected.
        assert!(code_at(&codes, 0, 8) < 3);
    }

    #[test]
    fn arena_stages_then_trains_and_encodes_incrementally() {
        let mut rng = Pcg::new(14);
        let dim = 8;
        let rows = clustered_rows(&mut rng, PQ_TRAIN_ROWS + 10, dim, 4);
        let mut arena = PqArena::new(0, 4);
        for r in 0..PQ_TRAIN_ROWS - 1 {
            arena.push(&rows[r * dim..(r + 1) * dim]);
        }
        assert!(!arena.trained(), "must stage below the threshold");
        assert_eq!(arena.rows(dim), PQ_TRAIN_ROWS - 1);
        arena.push(&rows[(PQ_TRAIN_ROWS - 1) * dim..PQ_TRAIN_ROWS * dim]);
        assert!(arena.trained(), "threshold row must trigger training");
        assert_eq!(arena.rows(dim), PQ_TRAIN_ROWS);
        // Incremental: later pushes encode without touching earlier rows.
        let before: Vec<Vec<u8>> =
            (0..PQ_TRAIN_ROWS).map(|r| arena.row_bytes(r, dim)).collect();
        for r in PQ_TRAIN_ROWS..PQ_TRAIN_ROWS + 10 {
            arena.push(&rows[r * dim..(r + 1) * dim]);
        }
        for (r, want) in before.iter().enumerate() {
            assert_eq!(&arena.row_bytes(r, dim), want, "row {r} bytes drifted");
        }
    }

    #[test]
    fn new_like_shares_the_book_and_copies_bytes() {
        let mut rng = Pcg::new(15);
        let dim = 8;
        let rows = clustered_rows(&mut rng, 32, dim, 4);
        let mut src = PqArena::new(0, 8);
        for r in 0..32 {
            src.push(&rows[r * dim..(r + 1) * dim]);
        }
        src.train_now(dim, 9);
        let mut dst = src.new_like();
        assert!(dst.trained());
        for r in [3usize, 0, 31] {
            dst.push_row_from(&src, r, dim);
        }
        assert_eq!(dst.row_bytes(0, dim), src.row_bytes(3, dim));
        assert_eq!(dst.row_bytes(1, dim), src.row_bytes(0, dim));
        assert_eq!(dst.row_bytes(2, dim), src.row_bytes(31, dim));
    }
}
