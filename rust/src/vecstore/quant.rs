//! Quantized row storage — the bandwidth half of the retrieval cost.
//!
//! The batched scan is memory-bound once the SIMD kernels saturate the FMA
//! units, so bytes-per-row is the lever that raises concurrent-scan
//! capacity per instance (the paper's deployment-cost formula): f16 halves
//! it, per-row-scaled symmetric int8 quarters it. Codes are decoded **in
//! registers** by the quantized panel kernels in [`super::kernels`] — the
//! arena is never materialized back to f32.
//!
//! # Codecs
//!
//! * [`Quant::F16`] — IEEE 754 binary16, round-to-nearest-even. Exact
//!   round-trip for every representable value; relative error ≤ 2⁻¹¹ per
//!   element, so inner products of unit vectors err by ≲ 1e-3.
//! * [`Quant::Int8`] — symmetric per-row scaling: `scale = max|x| / 127`,
//!   `code = round(x / scale)`. Per-element absolute error ≤ `scale / 2`,
//!   so a score errs by at most `‖query‖₁ · scale / 2`.
//! * [`Quant::Pq`] — product-quantized codes (see [`super::pq`]): `m`
//!   sub-vector codebooks of `2^bits` centroids, 4 or 8 bits per code,
//!   scanned via a per-query-panel ADC lookup table. At dim 768 / m 96
//!   that is 48 B/row (`pq4`) or 96 B/row (`pq8`) against int8's 772 —
//!   recall is data-dependent (≥ 0.9 top-10 on clustered corpora,
//!   property-tested) rather than ε-bounded.
//!
//! # Codec tier table (dim 768, the paper's embedding width)
//!
//! | codec | bytes/row | vs f32 | score error            |
//! |-------|-----------|--------|------------------------|
//! | f32   | 3072      | 1×     | exact                  |
//! | f16   | 1536      | 2×     | ≲ 1e-3 relative        |
//! | int8  | 772       | 3.98×  | ≤ ‖q‖₁·scale/2         |
//! | pq8   | 96        | 32×    | recall ≥ 0.9 (top-10)  |
//! | pq4   | 48        | 64×    | recall ≥ 0.9 (top-10)  |
//!
//! The admission cost model charges scans by `bytes_per_row`, so every
//! tier down this ladder buys proportionally more concurrent scan slots.
//!
//! All codecs are deterministic, so re-encoding a row always yields the
//! same bytes and quantized scan results are reproducible bit-for-bit
//! under a fixed kernel variant (PQ codebooks freeze after seeded
//! training, keeping encode deterministic too).

use super::{kernels, pq};

/// Storage codec for an index's row arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quant {
    /// Full-precision f32 rows (the seed layout).
    F32,
    /// IEEE binary16 rows: 2 bytes/element, ~1e-3 score error.
    F16,
    /// Symmetric per-row-scaled int8: 1 byte/element + 4 bytes/row scale.
    Int8,
    /// Product-quantized codes: `m` sub-vector codebooks, `bits` ∈ {4, 8}
    /// per code. `m == 0` is the "derive from dim" sentinel (see
    /// [`Quant::resolved`]); index constructors resolve it before any
    /// arena is built.
    Pq { m: usize, bits: u8 },
}

impl Quant {
    /// The `pq4`/`pq8` codec with dim-derived sub-vector count.
    pub fn pq(bits: u8) -> Quant {
        Quant::Pq { m: 0, bits }
    }

    pub fn name(self) -> &'static str {
        match self {
            Quant::F32 => "f32",
            Quant::F16 => "f16",
            Quant::Int8 => "int8",
            Quant::Pq { bits: 4, .. } => "pq4",
            Quant::Pq { .. } => "pq8",
        }
    }

    /// Parse `"f32" | "f16" | "int8" | "i8" | "pq4" | "pq8"`
    /// (case-insensitive).
    pub fn parse(s: &str) -> Option<Quant> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Some(Quant::F32),
            "f16" | "fp16" | "half" => Some(Quant::F16),
            "int8" | "i8" => Some(Quant::Int8),
            "pq4" | "int4" => Some(Quant::pq(4)),
            "pq8" => Some(Quant::pq(8)),
            _ => None,
        }
    }

    /// Resolve the PQ `m == 0` sentinel against a concrete row width;
    /// other codecs (and already-resolved PQ) pass through unchanged.
    pub fn resolved(self, dim: usize) -> Quant {
        match self {
            Quant::Pq { m: 0, bits } => Quant::Pq { m: pq::default_m(dim), bits },
            q => q,
        }
    }

    /// The `WINDVE_QUANT` override, if set to a recognized codec.
    pub fn env_override() -> Option<Quant> {
        std::env::var("WINDVE_QUANT").ok().and_then(|s| Quant::parse(&s))
    }

    /// `WINDVE_QUANT` or [`Quant::F32`].
    pub fn from_env() -> Quant {
        Quant::env_override().unwrap_or(Quant::F32)
    }

    /// Codecs a test run should cover: the `WINDVE_QUANT` cell when the CI
    /// matrix pins one, otherwise the whole ladder.
    pub fn modes_under_test() -> Vec<Quant> {
        match Quant::env_override() {
            Some(q) => vec![q],
            None => {
                vec![Quant::F32, Quant::F16, Quant::Int8, Quant::pq(4), Quant::pq(8)]
            }
        }
    }

    /// Arena bytes one row of `dim` elements occupies (including the
    /// per-row scale for int8; packed code bytes for PQ, excluding the
    /// arena-amortized codebook). Pure in `dim` — the admission cost
    /// model calls this on unresolved modes, so the PQ sentinel resolves
    /// here too.
    pub fn bytes_per_row(self, dim: usize) -> usize {
        match self {
            Quant::F32 => dim * 4,
            Quant::F16 => dim * 2,
            Quant::Int8 => dim + 4,
            Quant::Pq { m, bits } => {
                let m = if m == 0 { pq::default_m(dim) } else { m };
                pq::packed_row_bytes(m, bits)
            }
        }
    }
}

/// f32 → IEEE binary16 bits, round-to-nearest-even. Overflow saturates to
/// ±inf, NaN collapses to the canonical quiet NaN, sub-f16-subnormal
/// magnitudes flush to signed zero.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN.
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }
    let half_exp = exp - 112; // re-bias 127 → 15
    if half_exp >= 0x1F {
        return sign | 0x7C00; // overflow → inf
    }
    if half_exp <= 0 {
        // Subnormal half (or underflow to zero). Shift the mantissa —
        // with its implicit bit — into subnormal position, rounding to
        // nearest-even: round bit set AND (result-LSB or any sticky bit).
        if half_exp < -10 {
            return sign;
        }
        let man = man | 0x0080_0000;
        let shift = (14 - half_exp) as u32;
        let mut half_man = man >> shift;
        let round_bit = 1u32 << (shift - 1);
        if (man & round_bit) != 0 && (man & (3 * round_bit - 1)) != 0 {
            half_man += 1;
        }
        return sign | half_man as u16;
    }
    let mut half = (((half_exp as u32) << 10) | (man >> 13)) as u16;
    let round_bit = 0x1000u32;
    if (man & round_bit) != 0 && (man & (3 * round_bit - 1)) != 0 {
        // Mantissa carry propagates into the exponent bits — and on to
        // the inf pattern at the very top — by construction.
        half += 1;
    }
    sign | half
}

/// IEEE binary16 bits → f32 (exact: every f16 value is representable).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    match exp {
        0 => {
            // Zero / subnormal: man · 2⁻²⁴, exact in f32.
            let mag = man as f32 * f32::from_bits(0x3380_0000);
            if sign != 0 {
                -mag
            } else {
                mag
            }
        }
        0x1F => f32::from_bits(sign | 0x7F80_0000 | (man << 13)),
        _ => f32::from_bits(sign | ((exp + 112) << 23) | (man << 13)),
    }
}

/// Symmetric per-row int8 quantization: writes codes into `out`, returns
/// the row scale (`dequant = code · scale`). An all-zero row encodes to
/// all-zero codes with scale 0.
pub fn quantize_i8_row(v: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(v.len(), out.len());
    let max_abs = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    if max_abs == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let inv = 127.0 / max_abs;
    for (o, x) in out.iter_mut().zip(v) {
        *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    max_abs / 127.0
}

/// Contiguous row-major row storage under one codec — the arena both flat
/// and IVF indexes scan. Rows are quantized on [`RowArena::push`] and
/// scored straight from the encoded bytes by the quantized panel kernels.
pub enum RowArena {
    F32(Vec<f32>),
    F16(Vec<u16>),
    I8 { codes: Vec<i8>, scales: Vec<f32> },
    Pq(pq::PqArena),
}

/// Per-query-panel scan context from [`RowArena::begin_panel`]: the ADC
/// lookup table for PQ-trained arenas, a free no-op for every other
/// codec. Build it **once per scan** (per shard / per query), not per
/// row block — for pq8 the table is `nq · m · 256` dots and rebuilding
/// it per 64-row block would cost more than the scan it accelerates.
pub struct PanelCtx(Option<pq::PanelLut>);

impl PanelCtx {
    /// The no-op context (valid for any non-PQ scan).
    pub fn none() -> PanelCtx {
        PanelCtx(None)
    }
}

impl RowArena {
    pub fn new(quant: Quant) -> RowArena {
        match quant {
            Quant::F32 => RowArena::F32(Vec::new()),
            Quant::F16 => RowArena::F16(Vec::new()),
            Quant::Int8 => RowArena::I8 { codes: Vec::new(), scales: Vec::new() },
            Quant::Pq { m, bits } => RowArena::Pq(pq::PqArena::new(m, bits)),
        }
    }

    /// Empty arena with `src`'s codec **and trained state**: a PQ clone
    /// shares `src`'s codebook (`Arc`), so [`RowArena::push_row_from`]
    /// between the two copies packed bytes verbatim. Compaction and IVF
    /// list construction must use this instead of [`RowArena::new`] —
    /// a fresh PQ arena would restart staging and lose the codebook.
    pub fn new_like(src: &RowArena) -> RowArena {
        match src {
            RowArena::Pq(a) => RowArena::Pq(a.new_like()),
            other => RowArena::new(other.quant()),
        }
    }

    pub fn quant(&self) -> Quant {
        match self {
            RowArena::F32(_) => Quant::F32,
            RowArena::F16(_) => Quant::F16,
            RowArena::I8 { .. } => Quant::Int8,
            RowArena::Pq(a) => Quant::Pq { m: a.m(), bits: a.bits() },
        }
    }

    /// Number of stored rows, given the row width.
    pub fn rows(&self, dim: usize) -> usize {
        match self {
            RowArena::F32(d) => d.len() / dim,
            RowArena::F16(d) => d.len() / dim,
            RowArena::I8 { codes, .. } => codes.len() / dim,
            RowArena::Pq(a) => a.rows(dim),
        }
    }

    /// Encode and append one row. A PQ arena stages raw rows until
    /// [`pq::PQ_TRAIN_ROWS`] arrive (scored exactly until then), then
    /// trains once and encodes this and every later row incrementally
    /// with the frozen codebook.
    pub fn push(&mut self, v: &[f32]) {
        match self {
            RowArena::F32(d) => d.extend_from_slice(v),
            RowArena::F16(d) => d.extend(v.iter().map(|&x| f32_to_f16(x))),
            RowArena::I8 { codes, scales } => {
                let start = codes.len();
                codes.resize(start + v.len(), 0);
                scales.push(quantize_i8_row(v, &mut codes[start..]));
            }
            RowArena::Pq(a) => a.push(v),
        }
    }

    /// Force PQ codebook training on whatever is staged (IVF `build`
    /// uses its build seed here so books are deterministic per seed even
    /// below the staging threshold). No-op for other codecs or an
    /// already-trained arena.
    pub fn pq_train(&mut self, dim: usize, seed: u64) {
        if let RowArena::Pq(a) = self {
            a.train_now(dim, seed);
        }
    }

    /// Direct access to the PQ state (persist round-trips codebooks and
    /// packed codes; `None` for other codecs).
    pub fn as_pq(&self) -> Option<&pq::PqArena> {
        match self {
            RowArena::Pq(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_pq_mut(&mut self) -> Option<&mut pq::PqArena> {
        match self {
            RowArena::Pq(a) => Some(a),
            _ => None,
        }
    }

    /// Encoded bytes of row `r` exactly as stored (regression hook for
    /// the incremental-encode guarantee: ingest must never silently
    /// re-encode untouched rows).
    pub fn row_bytes(&self, r: usize, dim: usize) -> Vec<u8> {
        match self {
            RowArena::F32(d) => {
                d[r * dim..(r + 1) * dim].iter().flat_map(|x| x.to_le_bytes()).collect()
            }
            RowArena::F16(d) => {
                d[r * dim..(r + 1) * dim].iter().flat_map(|x| x.to_le_bytes()).collect()
            }
            RowArena::I8 { codes, scales } => {
                let mut out: Vec<u8> =
                    codes[r * dim..(r + 1) * dim].iter().map(|&c| c as u8).collect();
                out.extend_from_slice(&scales[r].to_le_bytes());
                out
            }
            RowArena::Pq(a) => a.row_bytes(r, dim),
        }
    }

    /// Append row `r` of `src` (same codec, same row width) by copying
    /// the already-encoded bytes — every codec is deterministic, so this
    /// equals re-encoding the original f32 row without paying for it.
    /// PQ requires the arenas to share one codebook ([`RowArena::new_like`]).
    pub fn push_row_from(&mut self, src: &RowArena, r: usize, dim: usize) {
        match (self, src) {
            (RowArena::F32(d), RowArena::F32(s)) => {
                d.extend_from_slice(&s[r * dim..(r + 1) * dim])
            }
            (RowArena::F16(d), RowArena::F16(s)) => {
                d.extend_from_slice(&s[r * dim..(r + 1) * dim])
            }
            (RowArena::I8 { codes, scales }, RowArena::I8 { codes: sc, scales: ss }) => {
                codes.extend_from_slice(&sc[r * dim..(r + 1) * dim]);
                scales.push(ss[r]);
            }
            (RowArena::Pq(d), RowArena::Pq(s)) => d.push_row_from(s, r, dim),
            _ => panic!("arena codec mismatch"),
        }
    }

    /// Rewrite the arena through per-node pinned copy threads so each
    /// NUMA band's pages are first-touched on the node that will scan
    /// them (see [`super::numa`]). Contents are bit-identical; int8
    /// realigns codes (stride `dim`) and per-row scales (stride 1) with
    /// the same row bands, so a band shard reads both node-locally.
    pub fn numa_realign(&mut self, dim: usize, topo: &crate::devices::affinity::Topology) {
        match self {
            RowArena::F32(d) => *d = super::numa::first_touch_realign(d, dim, topo),
            RowArena::F16(d) => *d = super::numa::first_touch_realign(d, dim, topo),
            RowArena::I8 { codes, scales } => {
                *codes = super::numa::first_touch_realign(codes, dim, topo);
                *scales = super::numa::first_touch_realign(scales, 1, topo);
            }
            RowArena::Pq(a) => a.numa_realign(dim, topo),
        }
    }

    /// Arena footprint in bytes (codes plus per-row scales; packed codes
    /// plus the amortized codebook for trained PQ).
    pub fn bytes(&self) -> usize {
        match self {
            RowArena::F32(d) => d.len() * 4,
            RowArena::F16(d) => d.len() * 2,
            RowArena::I8 { codes, scales } => codes.len() + scales.len() * 4,
            RowArena::Pq(a) => a.bytes(),
        }
    }

    /// Decode row `r` back to f32 (tests and diagnostics; the scan path
    /// never does this — it decodes in registers). PQ reconstructs from
    /// the chosen centroids.
    pub fn dequant_row(&self, r: usize, dim: usize) -> Vec<f32> {
        match self {
            RowArena::F32(d) => d[r * dim..(r + 1) * dim].to_vec(),
            RowArena::F16(d) => d[r * dim..(r + 1) * dim].iter().map(|&h| f16_to_f32(h)).collect(),
            RowArena::I8 { codes, scales } => codes[r * dim..(r + 1) * dim]
                .iter()
                .map(|&c| c as f32 * scales[r])
                .collect(),
            RowArena::Pq(a) => a.dequant_row(r, dim),
        }
    }

    /// Build the scan context for a query panel: the ADC lookup table
    /// when this arena is PQ-trained, a free no-op otherwise. Hoist this
    /// out of block loops — one call per (panel, scan), reused across
    /// every `[lo, hi)` block and across arenas **sharing the same
    /// codebook** (IVF lists).
    pub fn begin_panel(&self, queries: &[f32], nq: usize, dim: usize) -> PanelCtx {
        debug_assert_eq!(queries.len(), nq * dim, "query panel shape mismatch");
        match self {
            RowArena::Pq(a) => PanelCtx(a.book().map(|book| book.build_lut(queries, nq))),
            _ => PanelCtx(None),
        }
    }

    /// Score the query panel against rows `[lo, hi)` through the codec's
    /// panel kernel: `out[q * (hi - lo) + r] = queries[q] · row[lo + r]`.
    /// Convenience form that builds the panel context itself — scans that
    /// loop over blocks must use [`RowArena::begin_panel`] +
    /// [`RowArena::panel_scores_ctx_into`] instead.
    pub fn panel_scores_into(
        &self,
        queries: &[f32],
        nq: usize,
        lo: usize,
        hi: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let ctx = self.begin_panel(queries, nq, dim);
        self.panel_scores_ctx_into(&ctx, queries, nq, lo, hi, dim, out);
    }

    /// [`RowArena::panel_scores_into`] with a caller-held context. The
    /// context must come from [`RowArena::begin_panel`] on this arena
    /// (or one sharing its codebook) for the same query panel.
    pub fn panel_scores_ctx_into(
        &self,
        ctx: &PanelCtx,
        queries: &[f32],
        nq: usize,
        lo: usize,
        hi: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let nr = hi - lo;
        match self {
            RowArena::F32(d) => {
                kernels::panel_scores_into(queries, nq, &d[lo * dim..hi * dim], nr, dim, out)
            }
            RowArena::F16(d) => {
                kernels::panel_scores_f16_into(queries, nq, &d[lo * dim..hi * dim], nr, dim, out)
            }
            RowArena::I8 { codes, scales } => kernels::panel_scores_i8_into(
                queries,
                nq,
                &codes[lo * dim..hi * dim],
                &scales[lo..hi],
                nr,
                dim,
                out,
            ),
            RowArena::Pq(a) => match (a.book(), a.codes()) {
                (Some(book), Some(codes)) => {
                    let lut = ctx.0.as_ref().expect("PQ scan without a panel context");
                    debug_assert!(
                        std::sync::Arc::ptr_eq(&lut.book, book),
                        "panel context built for a different codebook"
                    );
                    assert_eq!(lut.nq, nq, "panel context query count mismatch");
                    let pb = book.packed_row_bytes();
                    kernels::panel_scores_pq_into(
                        &lut.lut,
                        nq,
                        &codes[lo * pb..hi * pb],
                        nr,
                        book.m,
                        book.k(),
                        book.bits,
                        out,
                    );
                }
                // Staged rows are raw f32 — scored exactly.
                _ => kernels::panel_scores_into(
                    queries,
                    nq,
                    &a.staged().expect("staged PQ arena")[lo * dim..hi * dim],
                    nr,
                    dim,
                    out,
                ),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn f16_exact_values_roundtrip() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 65504.0, 1024.0, -3.5] {
            let h = f32_to_f16(x);
            assert_eq!(f16_to_f32(h), x, "{x} not exact through f16");
        }
    }

    #[test]
    fn f16_signed_zero_and_specials() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // Overflow saturates to inf; 65520 is the f16 max + half an ulp
        // and rounds to even (inf).
        assert_eq!(f32_to_f16(1e6), 0x7C00);
        assert_eq!(f32_to_f16(65520.0), 0x7C00);
        assert_eq!(f32_to_f16(-1e6), 0xFC00);
    }

    #[test]
    fn f16_subnormals() {
        // Smallest positive f16 subnormal: 2^-24.
        let tiny = f32::from_bits(0x3380_0000);
        assert_eq!(f32_to_f16(tiny), 0x0001);
        assert_eq!(f16_to_f32(0x0001), tiny);
        // Below half the smallest subnormal → flush to zero.
        assert_eq!(f32_to_f16(tiny * 0.49), 0x0000);
        // Largest subnormal.
        let h = 0x03FF;
        assert_eq!(f32_to_f16(f16_to_f32(h)), h);
    }

    #[test]
    fn f16_all_finite_bit_patterns_roundtrip() {
        // decode → encode must be the identity on every finite f16.
        for h in 0u16..=0xFFFF {
            if (h >> 10) & 0x1F == 0x1F {
                continue; // inf/nan
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "bits {h:#06x}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16
        // (1 + 2^-10); RNE keeps the even mantissa (1.0).
        let halfway = 1.0f32 + f32::from_bits(0x3A00_0000); // 2^-11
        assert_eq!(f32_to_f16(halfway), f32_to_f16(1.0));
        // One f32-ulp above halfway rounds up.
        let above = f32::from_bits(halfway.to_bits() + 1);
        assert_eq!(f32_to_f16(above), f32_to_f16(1.0) + 1);
        // 1 + 1.5·ulp is halfway between odd and even mantissa → even.
        let odd_even = 1.0f32 + 3.0 * f32::from_bits(0x3A00_0000);
        assert_eq!(f32_to_f16(odd_even), f32_to_f16(1.0) + 2);
    }

    #[test]
    fn i8_roundtrip_error_bounded_by_half_scale() {
        let mut rng = Pcg::new(7);
        for _ in 0..200 {
            let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let mut codes = vec![0i8; v.len()];
            let scale = quantize_i8_row(&v, &mut codes);
            for (x, c) in v.iter().zip(&codes) {
                let err = (*c as f32 * scale - x).abs();
                assert!(err <= scale * 0.5001 + 1e-7, "err {err} vs scale {scale}");
            }
        }
    }

    #[test]
    fn i8_zero_row_and_extremes() {
        let mut codes = vec![7i8; 4];
        assert_eq!(quantize_i8_row(&[0.0; 4], &mut codes), 0.0);
        assert_eq!(codes, vec![0i8; 4]);
        let v = [3.0f32, -3.0, 1.5, 0.0];
        let scale = quantize_i8_row(&v, &mut codes);
        assert_eq!(codes[0], 127);
        assert_eq!(codes[1], -127);
        assert!((127.0 * scale - 3.0).abs() < 1e-6);
    }

    #[test]
    fn quant_parse_and_bytes() {
        assert_eq!(Quant::parse("F16"), Some(Quant::F16));
        assert_eq!(Quant::parse("i8"), Some(Quant::Int8));
        assert_eq!(Quant::parse("fp32"), Some(Quant::F32));
        assert_eq!(Quant::parse("pq4"), Some(Quant::pq(4)));
        assert_eq!(Quant::parse("PQ8"), Some(Quant::pq(8)));
        assert_eq!(Quant::parse("pq2"), None);
        assert_eq!(Quant::F32.bytes_per_row(768), 3072);
        assert_eq!(Quant::F16.bytes_per_row(768), 1536);
        assert_eq!(Quant::Int8.bytes_per_row(768), 772);
        assert_eq!(Quant::Int8.name(), "int8");
        // PQ: dim 768 resolves to m = 96 (sub-dim 8); pq4 packs two
        // codes per byte — the ≤ 0.15× of int8 the admission model sees.
        assert_eq!(Quant::pq(4).resolved(768), Quant::Pq { m: 96, bits: 4 });
        assert_eq!(Quant::pq(4).bytes_per_row(768), 48);
        assert_eq!(Quant::pq(8).bytes_per_row(768), 96);
        assert_eq!(Quant::Pq { m: 64, bits: 4 }.bytes_per_row(768), 32);
        assert_eq!(Quant::pq(4).name(), "pq4");
        assert_eq!(Quant::pq(8).name(), "pq8");
        assert!(Quant::pq(4).bytes_per_row(768) * 100 <= Quant::Int8.bytes_per_row(768) * 15);
    }

    #[test]
    fn arena_push_scores_match_dequant_dot() {
        let mut rng = Pcg::new(9);
        let dim = 37; // awkward: exercises every kernel tail
        for quant in [Quant::F32, Quant::F16, Quant::Int8] {
            let mut arena = RowArena::new(quant);
            let rows: Vec<Vec<f32>> =
                (0..11).map(|_| (0..dim).map(|_| rng.normal() as f32).collect()).collect();
            for r in &rows {
                arena.push(r);
            }
            assert_eq!(arena.rows(dim), 11);
            assert_eq!(arena.bytes(), 11 * quant.bytes_per_row(dim));
            let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let mut out = vec![0.0f32; 11];
            arena.panel_scores_into(&q, 1, 0, 11, dim, &mut out);
            for (r, got) in out.iter().enumerate() {
                let deq = arena.dequant_row(r, dim);
                let want: f32 = q.iter().zip(&deq).map(|(a, b)| a * b).sum();
                // Kernel vs naive dot differ only by f32 reassociation.
                assert!(
                    (got - want).abs() <= 1e-3 * (1.0 + want.abs()),
                    "{quant:?} row {r}: {got} vs {want}"
                );
            }
        }
    }

    /// Below the staging threshold a PQ arena scores raw f32 rows —
    /// bit-identical to an f32 arena; once trained, the ADC kernel must
    /// match the dot with the row's reconstruction (the definition of
    /// asymmetric distance), and the footprint must collapse to packed
    /// codes + codebook.
    #[test]
    fn pq_arena_staged_exact_then_adc_matches_reconstruction() {
        let mut rng = Pcg::new(21);
        let dim = 16;
        let n = super::pq::PQ_TRAIN_ROWS + 20;
        let rows: Vec<Vec<f32>> =
            (0..n).map(|_| (0..dim).map(|_| rng.normal() as f32).collect()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        for quant in [Quant::pq(4), Quant::pq(8)] {
            let quant = quant.resolved(dim);
            let mut arena = RowArena::new(quant);
            let mut exact = RowArena::new(Quant::F32);
            for r in rows.iter().take(100) {
                arena.push(r);
                exact.push(r);
            }
            let (mut got, mut want) = (vec![0.0f32; 100], vec![0.0f32; 100]);
            arena.panel_scores_into(&q, 1, 0, 100, dim, &mut got);
            exact.panel_scores_into(&q, 1, 0, 100, dim, &mut want);
            assert_eq!(got, want, "{quant:?}: staged PQ scan must be exact");
            assert_eq!(arena.bytes(), 100 * dim * 4, "staged rows are raw f32");

            for r in rows.iter().skip(100) {
                arena.push(r);
            }
            assert!(arena.as_pq().unwrap().trained());
            assert_eq!(arena.rows(dim), n);
            let book_bytes = arena.as_pq().unwrap().book().unwrap().bytes();
            assert_eq!(arena.bytes(), n * quant.bytes_per_row(dim) + book_bytes);
            let mut got = vec![0.0f32; n];
            arena.panel_scores_into(&q, 1, 0, n, dim, &mut got);
            for r in 0..n {
                let recon = arena.dequant_row(r, dim);
                let adc: f32 = q.iter().zip(&recon).map(|(a, b)| a * b).sum();
                assert!(
                    (got[r] - adc).abs() <= 1e-3 * (1.0 + adc.abs()),
                    "{quant:?} row {r}: {} vs {adc}",
                    got[r]
                );
            }
        }
    }

    /// `new_like` + `push_row_from` (the compaction path) must copy
    /// packed PQ bytes verbatim and keep scoring identical.
    #[test]
    fn pq_compaction_copies_bytes_bit_identically() {
        let mut rng = Pcg::new(22);
        let dim = 8;
        let n = super::pq::PQ_TRAIN_ROWS + 5;
        let mut arena = RowArena::new(Quant::pq(4).resolved(dim));
        for _ in 0..n {
            let v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            arena.push(&v);
        }
        let mut scratch = RowArena::new_like(&arena);
        let keep: Vec<usize> = (0..n).filter(|r| r % 3 != 0).collect();
        for &r in &keep {
            scratch.push_row_from(&arena, r, dim);
        }
        assert_eq!(scratch.rows(dim), keep.len());
        for (i, &r) in keep.iter().enumerate() {
            assert_eq!(scratch.row_bytes(i, dim), arena.row_bytes(r, dim), "row {r}");
        }
    }
}
