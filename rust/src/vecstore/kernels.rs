//! Runtime-dispatched similarity kernels — the innermost loop of retrieval.
//!
//! # Dispatch strategy
//!
//! The CPU's vector ISA is probed **once** (first call) and the verdict is
//! cached in a process-wide atomic, so the hot path pays one relaxed load
//! per kernel call instead of a `cpuid` per dot product:
//!
//! * x86_64 with AVX2+FMA → 8-lane fused-multiply-add kernels
//!   (`std::arch::x86_64`), detected via `is_x86_feature_detected!`.
//! * aarch64 with NEON → 4-lane `vfmaq_f32` kernels
//!   (`std::arch::aarch64`).
//! * anything else → the portable 4-accumulator scalar loop the seed
//!   shipped ([`dot_scalar`]).
//!
//! `WINDVE_SIMD=scalar|avx2|neon|auto` overrides detection (ops escape
//! hatch and the lever the benches use for baselines). A forced variant the
//! CPU cannot run falls back to scalar rather than faulting.
//!
//! # Determinism across batch shapes (per variant)
//!
//! Within one dispatched variant, every code path computes a given
//! (query, row) pair with the **same floating-point evaluation order**:
//! one accumulator per query, row-major chunks in ascending order,
//! horizontal sum, then a scalar tail. The multi-query panel kernel
//! ([`panel_scores_into`]) keeps one independent accumulator chain per
//! query, so batching queries changes *bandwidth*, never *values*:
//! `search_batch` returns bit-identical scores to per-query `search`
//! under the same dispatched variant. The quantized panels
//! ([`panel_scores_f16_into`], [`panel_scores_i8_into`]) keep the same
//! guarantee: codes are decoded in registers, fed to the same per-query
//! accumulator chains, and (for int8) the row scale multiplies the
//! finished sum exactly once.
//!
//! **Across variants** (scalar vs AVX2 vs NEON) the summation order
//! differs — scalar interleaves 4 width-1 accumulators, SIMD reduces
//! 8/4 lanes — so scores agree only to floating-point reassociation
//! error (~1e-4 relative on unit vectors; see the property tests). Do
//! not assert bit-equality between runs with different `WINDVE_SIMD`
//! settings or on different CPUs.
//!
//! # The panel micro-kernel
//!
//! [`panel_scores_into`] scores a panel of up to [`PANEL_QUERIES`] queries
//! against a tile of rows in one pass. Each row chunk is loaded once and
//! fed to all accumulators in the panel, cutting row-matrix bandwidth by
//! the panel width and giving the FMA units independent dependency chains
//! to hide latency behind — the cache-blocking half of the win is done by
//! the callers in `flat.rs`/`ivf.rs`, which tile rows so a tile stays
//! cache-resident across panels.

use std::sync::atomic::{AtomicU8, Ordering};

/// Queries scored per panel pass (bounded by architectural registers:
/// 4 accumulators + row vector + query vector stay in-register on both
/// AVX2 and NEON).
pub const PANEL_QUERIES: usize = 4;

/// The kernel variant selected for this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Simd {
    /// Portable 4-accumulator scalar loop.
    Scalar,
    /// x86_64 AVX2 + FMA, 8 f32 lanes.
    Avx2Fma,
    /// aarch64 NEON, 4 f32 lanes.
    Neon,
}

impl Simd {
    pub fn name(self) -> &'static str {
        match self {
            Simd::Scalar => "scalar",
            Simd::Avx2Fma => "avx2+fma",
            Simd::Neon => "neon",
        }
    }
}

const K_UNINIT: u8 = 0;
const K_SCALAR: u8 = 1;
const K_AVX2: u8 = 2;
const K_NEON: u8 = 3;

static ACTIVE: AtomicU8 = AtomicU8::new(K_UNINIT);

/// The dispatched variant (detected once, then cached).
pub fn active() -> Simd {
    match ACTIVE.load(Ordering::Relaxed) {
        K_SCALAR => Simd::Scalar,
        K_AVX2 => Simd::Avx2Fma,
        K_NEON => Simd::Neon,
        _ => {
            let k = detect();
            let code = match k {
                Simd::Scalar => K_SCALAR,
                Simd::Avx2Fma => K_AVX2,
                Simd::Neon => K_NEON,
            };
            ACTIVE.store(code, Ordering::Relaxed);
            k
        }
    }
}

/// Human-readable name of the dispatched variant (for logs and benches).
pub fn name() -> &'static str {
    active().name()
}

fn detect() -> Simd {
    let forced = std::env::var("WINDVE_SIMD").unwrap_or_default();
    match forced.as_str() {
        "scalar" => return Simd::Scalar,
        "avx2" => {
            #[cfg(target_arch = "x86_64")]
            {
                if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                    return Simd::Avx2Fma;
                }
            }
            return Simd::Scalar;
        }
        "neon" => {
            #[cfg(target_arch = "aarch64")]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    return Simd::Neon;
                }
            }
            return Simd::Scalar;
        }
        _ => {}
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Simd::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Simd::Neon;
        }
    }
    Simd::Scalar
}

/// Inner product, dispatched to the active variant.
///
/// The length check is a hard assert: the SIMD paths read `b` through
/// raw pointers at `a`-derived offsets, so a mismatched `b` would be
/// out-of-bounds UB from a safe fn, not just a wrong answer.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` returns `Avx2Fma` only after runtime CPUID
        // detection of AVX2+FMA, and the assert above established
        // `a.len() == b.len()` — both of `avx2::dot`'s preconditions.
        Simd::Avx2Fma => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `active()` returns `Neon` only after runtime detection
        // of NEON, and the assert above established `a.len() == b.len()`.
        Simd::Neon => unsafe { neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// The seed's portable dot product: 4-lane unrolled scalar loop. Kept as
/// the fallback variant and as the baseline the benches compare against.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Score one query against `nrows` contiguous row-major rows:
/// `out[r] = query · rows[r]`.
pub fn scores_into(query: &[f32], rows: &[f32], nrows: usize, dim: usize, out: &mut [f32]) {
    panel_scores_into(query, 1, rows, nrows, dim, out)
}

/// Blocked multi-query × multi-row micro-kernel:
/// `out[q * nrows + r] = queries[q] · rows[r]` for a row-major query panel
/// `[nq, dim]` and row tile `[nrows, dim]`. Queries are processed in
/// panels of [`PANEL_QUERIES`]; each row chunk is loaded once per panel.
pub fn panel_scores_into(
    queries: &[f32],
    nq: usize,
    rows: &[f32],
    nrows: usize,
    dim: usize,
    out: &mut [f32],
) {
    assert_eq!(queries.len(), nq * dim, "query panel shape mismatch");
    assert_eq!(rows.len(), nrows * dim, "row tile shape mismatch");
    assert_eq!(out.len(), nq * nrows, "score buffer shape mismatch");
    if nq == 0 || nrows == 0 {
        return;
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: AVX2+FMA were detected at runtime by `active()`, and the
        // asserts above pinned `queries`/`rows`/`out` to the exact
        // `nq`/`nrows`/`dim` shapes the kernel's pointer arithmetic assumes.
        Simd::Avx2Fma => unsafe { avx2::panel(queries, nq, rows, nrows, dim, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON was detected at runtime by `active()`; shapes were
        // assert-checked above.
        Simd::Neon => unsafe { neon::panel(queries, nq, rows, nrows, dim, out) },
        _ => panel_scalar(queries, nq, rows, nrows, dim, out),
    }
}

/// Quantized f16 twin of [`panel_scores_into`]: rows are IEEE binary16
/// bits, decoded to f32 **in registers** (`vcvtph2ps` on x86 with F16C,
/// scalar bit-decode elsewhere) — the arena's 2 B/element is all that
/// crosses the memory bus. Per (query, row) pair the accumulation order
/// matches the f32 kernel of the same variant, so batching quantized
/// queries is bit-identical to single-query quantized search.
pub fn panel_scores_f16_into(
    queries: &[f32],
    nq: usize,
    rows: &[u16],
    nrows: usize,
    dim: usize,
    out: &mut [f32],
) {
    assert_eq!(queries.len(), nq * dim, "query panel shape mismatch");
    assert_eq!(rows.len(), nrows * dim, "row tile shape mismatch");
    assert_eq!(out.len(), nq * nrows, "score buffer shape mismatch");
    if nq == 0 || nrows == 0 {
        return;
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: AVX2+FMA were detected by `active()` and F16C by the
        // explicit `f16c_available()` guard (a separate CPUID bit — the
        // kernel's `vcvtph2ps` would be UB without it); shapes were
        // assert-checked above.
        Simd::Avx2Fma if f16c_available() => unsafe {
            avx2::panel_f16(queries, nq, rows, nrows, dim, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON was detected at runtime by `active()`; shapes were
        // assert-checked above.
        Simd::Neon => unsafe { neon::panel_f16(queries, nq, rows, nrows, dim, out) },
        _ => panel_f16_scalar(queries, nq, rows, nrows, dim, out),
    }
}

/// Quantized int8 twin of [`panel_scores_into`]: rows are symmetric
/// per-row-scaled codes (`scales[r]`, see `quant::quantize_i8_row`),
/// widened to f32 in registers and accumulated unscaled; the row scale
/// multiplies the finished sum once. 1 B/element of bandwidth.
pub fn panel_scores_i8_into(
    queries: &[f32],
    nq: usize,
    rows: &[i8],
    scales: &[f32],
    nrows: usize,
    dim: usize,
    out: &mut [f32],
) {
    assert_eq!(queries.len(), nq * dim, "query panel shape mismatch");
    assert_eq!(rows.len(), nrows * dim, "row tile shape mismatch");
    assert_eq!(scales.len(), nrows, "row scale count mismatch");
    assert_eq!(out.len(), nq * nrows, "score buffer shape mismatch");
    if nq == 0 || nrows == 0 {
        return;
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: AVX2+FMA were detected at runtime by `active()`; shapes
        // (including `scales.len() == nrows`) were assert-checked above.
        Simd::Avx2Fma => unsafe { avx2::panel_i8(queries, nq, rows, scales, nrows, dim, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON was detected at runtime by `active()`; shapes were
        // assert-checked above.
        Simd::Neon => unsafe { neon::panel_i8(queries, nq, rows, scales, nrows, dim, out) },
        _ => panel_i8_scalar(queries, nq, rows, scales, nrows, dim, out),
    }
}

/// Product-quantized (ADC) twin of [`panel_scores_into`]: rows are
/// packed code indices (`bits` ∈ {4, 8}, see `pq`), scored by `m` table
/// lookups per (query, row) into the per-panel LUT built by
/// `pq::Codebook::build_lut` — row-major `[nq][m][kc]` with
/// `lut[q][s][c] = query_q_sub_s · center_c`. No multiplies touch the
/// arena at all: the scan streams `(m·bits)/8` bytes per row and adds
/// `m` table entries. Per (query, row) pair every variant sums
/// sub-spaces in the same fixed order, so batching queries stays
/// bit-identical to single-query scans under one dispatched variant.
pub fn panel_scores_pq_into(
    lut: &[f32],
    nq: usize,
    codes: &[u8],
    nrows: usize,
    m: usize,
    kc: usize,
    bits: u8,
    out: &mut [f32],
) {
    assert!(matches!(bits, 4 | 8), "pq bits must be 4 or 8");
    assert_eq!(kc, 1usize << bits, "pq table width mismatch");
    let packed = (m * bits as usize).div_ceil(8);
    assert_eq!(lut.len(), nq * m * kc, "pq lut shape mismatch");
    assert_eq!(codes.len(), nrows * packed, "pq code tile shape mismatch");
    assert_eq!(out.len(), nq * nrows, "score buffer shape mismatch");
    if nq == 0 || nrows == 0 {
        return;
    }
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: AVX2+FMA were detected at runtime by `active()`; the
        // asserts above pinned `lut`/`codes`/`out` to the `nq·m·kc` /
        // `nrows·packed` / `nq·nrows` shapes, `kc == 1 << bits` bounds
        // every decoded code strictly inside its LUT sub-table, and
        // `bits ∈ {4, 8}` was checked — the gather indices cannot escape.
        Simd::Avx2Fma => unsafe { avx2::panel_pq(lut, nq, codes, nrows, m, kc, bits, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON was detected at runtime by `active()`; shapes and
        // `kc == 1 << bits` were assert-checked above.
        Simd::Neon => unsafe { neon::panel_pq(lut, nq, codes, nrows, m, kc, bits, out) },
        _ => panel_pq_scalar(lut, nq, codes, nrows, m, kc, bits, out),
    }
}

/// Code index of sub-space `s` in a packed row (low nibble = even
/// sub-space for 4-bit codes).
#[inline(always)]
fn pq_code(row: &[u8], s: usize, bits: u8) -> usize {
    if bits == 8 {
        row[s] as usize
    } else {
        ((row[s >> 1] >> ((s & 1) * 4)) & 0xF) as usize
    }
}

/// Scalar ADC row sum: [`dot_scalar`]'s 4-accumulator shape over table
/// lookups instead of multiplies.
#[inline]
fn dot_pq_scalar(lq: &[f32], row: &[u8], m: usize, kc: usize, bits: u8) -> f32 {
    let mut acc = [0.0f32; 4];
    let chunks = m / 4;
    for i in 0..chunks {
        let s = i * 4;
        acc[0] += lq[s * kc + pq_code(row, s, bits)];
        acc[1] += lq[(s + 1) * kc + pq_code(row, s + 1, bits)];
        acc[2] += lq[(s + 2) * kc + pq_code(row, s + 2, bits)];
        acc[3] += lq[(s + 3) * kc + pq_code(row, s + 3, bits)];
    }
    let mut sum = acc[0] + acc[1] + acc[2] + acc[3];
    for s in chunks * 4..m {
        sum += lq[s * kc + pq_code(row, s, bits)];
    }
    sum
}

/// Scalar PQ panel: same per-pair math as [`dot_pq_scalar`].
#[allow(clippy::too_many_arguments)]
pub fn panel_pq_scalar(
    lut: &[f32],
    nq: usize,
    codes: &[u8],
    nrows: usize,
    m: usize,
    kc: usize,
    bits: u8,
    out: &mut [f32],
) {
    let packed = (m * bits as usize).div_ceil(8);
    for q in 0..nq {
        let lq = &lut[q * m * kc..(q + 1) * m * kc];
        for r in 0..nrows {
            out[q * nrows + r] =
                dot_pq_scalar(lq, &codes[r * packed..(r + 1) * packed], m, kc, bits);
        }
    }
}

/// F16C (`vcvtph2ps`) is a separate CPUID bit from AVX2 — probe it before
/// taking the in-register f16 decode path. `is_x86_feature_detected!`
/// caches the CPUID result process-wide, so this is one relaxed load.
#[cfg(target_arch = "x86_64")]
fn f16c_available() -> bool {
    is_x86_feature_detected!("f16c")
}

/// Scalar f16 dot: [`dot_scalar`]'s 4-accumulator shape with a bit-decode
/// per row element.
fn dot_f16_scalar(a: &[f32], h: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), h.len());
    let f16 = super::quant::f16_to_f32;
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * f16(h[j]);
        acc[1] += a[j + 1] * f16(h[j + 1]);
        acc[2] += a[j + 2] * f16(h[j + 2]);
        acc[3] += a[j + 3] * f16(h[j + 3]);
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * f16(h[j]);
    }
    s
}

/// Scalar int8 dot: accumulate `query · code` unscaled in [`dot_scalar`]'s
/// 4-accumulator shape, then apply the row scale once.
fn dot_i8_scalar(a: &[f32], codes: &[i8], scale: f32) -> f32 {
    debug_assert_eq!(a.len(), codes.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * codes[j] as f32;
        acc[1] += a[j + 1] * codes[j + 1] as f32;
        acc[2] += a[j + 2] * codes[j + 2] as f32;
        acc[3] += a[j + 3] * codes[j + 3] as f32;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * codes[j] as f32;
    }
    s * scale
}

/// Scalar f16 panel: same per-pair math as [`dot_f16_scalar`].
pub fn panel_f16_scalar(
    queries: &[f32],
    nq: usize,
    rows: &[u16],
    nrows: usize,
    dim: usize,
    out: &mut [f32],
) {
    for q in 0..nq {
        let qv = &queries[q * dim..(q + 1) * dim];
        for r in 0..nrows {
            out[q * nrows + r] = dot_f16_scalar(qv, &rows[r * dim..(r + 1) * dim]);
        }
    }
}

/// Scalar int8 panel: same per-pair math as [`dot_i8_scalar`].
pub fn panel_i8_scalar(
    queries: &[f32],
    nq: usize,
    rows: &[i8],
    scales: &[f32],
    nrows: usize,
    dim: usize,
    out: &mut [f32],
) {
    for q in 0..nq {
        let qv = &queries[q * dim..(q + 1) * dim];
        for r in 0..nrows {
            out[q * nrows + r] = dot_i8_scalar(qv, &rows[r * dim..(r + 1) * dim], scales[r]);
        }
    }
}

/// Scalar panel: same per-pair math as [`dot_scalar`], pair by pair.
pub fn panel_scalar(
    queries: &[f32],
    nq: usize,
    rows: &[f32],
    nrows: usize,
    dim: usize,
    out: &mut [f32],
) {
    for q in 0..nq {
        let qv = &queries[q * dim..(q + 1) * dim];
        for r in 0..nrows {
            out[q * nrows + r] = dot_scalar(qv, &rows[r * dim..(r + 1) * dim]);
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of the 8 lanes. Register-only shuffles and adds —
    /// every intrinsic here is safe inside a matching `#[target_feature]`
    /// context, so this needs no `unsafe` at all.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let shuf = _mm_movehdup_ps(s);
        let sums = _mm_add_ps(s, shuf);
        let shuf2 = _mm_movehl_ps(shuf, sums);
        _mm_cvtss_f32(_mm_add_ss(sums, shuf2))
    }

    /// Canonical per-pair evaluation: one accumulator, ascending 8-lane
    /// chunks, horizontal sum, scalar tail. `panel` must keep this exact
    /// order per query so batched and single-query scores are identical.
    ///
    /// # Safety
    ///
    /// * The running CPU must support AVX2 and FMA (runtime-detected —
    ///   `#[target_feature]` makes merely *calling* this UB otherwise).
    /// * `a.len() == b.len()`: `b` is read through raw pointers at
    ///   `a`-derived offsets, so a shorter `b` is an out-of-bounds read.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let j = c * 8;
            // SAFETY: `j + 8 <= chunks * 8 <= n`, and the caller promised
            // `b.len() == a.len() == n`, so both 8-lane unaligned loads
            // stay inside their slices.
            let (va, vb) = unsafe { (_mm256_loadu_ps(pa.add(j)), _mm256_loadu_ps(pb.add(j))) };
            acc = _mm256_fmadd_ps(va, vb, acc);
        }
        let mut s = hsum(acc);
        for j in chunks * 8..n {
            s += a[j] * b[j];
        }
        s
    }

    /// Multi-query panel: one accumulator chain per query, row chunk
    /// loaded once per panel. Bit-identical per pair to [`dot`].
    ///
    /// # Safety
    ///
    /// * The running CPU must support AVX2 and FMA.
    /// * `queries.len() == nq * dim`, `rows.len() == nrows * dim` and
    ///   `out.len() == nq * nrows` — the raw-pointer offsets below assume
    ///   exactly these shapes (checked by the dispatching wrapper).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn panel(
        queries: &[f32],
        nq: usize,
        rows: &[f32],
        nrows: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let chunks = dim / 8;
        let pq = queries.as_ptr();
        let pr = rows.as_ptr();
        let mut q0 = 0;
        while q0 < nq {
            let pw = (nq - q0).min(super::PANEL_QUERIES);
            for r in 0..nrows {
                // SAFETY: `r < nrows` and `rows.len() == nrows * dim`, so
                // row `r` spans `[r * dim, (r + 1) * dim)` of `rows`.
                let row = unsafe { pr.add(r * dim) };
                let mut acc = [_mm256_setzero_ps(); super::PANEL_QUERIES];
                for c in 0..chunks {
                    let j = c * 8;
                    // SAFETY: `j + 8 <= chunks * 8 <= dim` keeps the load
                    // inside row `r`.
                    let rv = unsafe { _mm256_loadu_ps(row.add(j)) };
                    for p in 0..pw {
                        // SAFETY: `q0 + p < nq` and `j + 8 <= dim`, so the
                        // load stays inside the `nq * dim` query panel.
                        let qv = unsafe { _mm256_loadu_ps(pq.add((q0 + p) * dim + j)) };
                        acc[p] = _mm256_fmadd_ps(qv, rv, acc[p]);
                    }
                }
                for p in 0..pw {
                    let mut s = hsum(acc[p]);
                    for j in chunks * 8..dim {
                        s += queries[(q0 + p) * dim + j] * rows[r * dim + j];
                    }
                    out[(q0 + p) * nrows + r] = s;
                }
            }
            q0 += pw;
        }
    }

    /// f16 panel: row chunks are 8 half-floats (16 B) widened in-register
    /// with `vcvtph2ps`; accumulation order per pair matches [`panel`].
    ///
    /// # Safety
    ///
    /// * The running CPU must support AVX2, FMA **and F16C** (a separate
    ///   CPUID bit — the dispatcher guards it with `f16c_available()`).
    /// * `queries.len() == nq * dim`, `rows.len() == nrows * dim` and
    ///   `out.len() == nq * nrows` (checked by the dispatching wrapper).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    #[target_feature(enable = "f16c")]
    pub(super) unsafe fn panel_f16(
        queries: &[f32],
        nq: usize,
        rows: &[u16],
        nrows: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let f16 = crate::vecstore::quant::f16_to_f32;
        let chunks = dim / 8;
        let pq = queries.as_ptr();
        let pr = rows.as_ptr();
        let mut q0 = 0;
        while q0 < nq {
            let pw = (nq - q0).min(super::PANEL_QUERIES);
            for r in 0..nrows {
                // SAFETY: `r < nrows` and `rows.len() == nrows * dim`.
                let row = unsafe { pr.add(r * dim) };
                let mut acc = [_mm256_setzero_ps(); super::PANEL_QUERIES];
                for c in 0..chunks {
                    let j = c * 8;
                    // SAFETY: `j + 8 <= dim`, so the 16-byte load covers
                    // exactly 8 in-bounds u16 codes of row `r`; no
                    // alignment requirement (`loadu`).
                    let rv = unsafe {
                        _mm256_cvtph_ps(_mm_loadu_si128(row.add(j) as *const __m128i))
                    };
                    for p in 0..pw {
                        // SAFETY: `q0 + p < nq` and `j + 8 <= dim` stay
                        // inside the `nq * dim` query panel.
                        let qv = unsafe { _mm256_loadu_ps(pq.add((q0 + p) * dim + j)) };
                        acc[p] = _mm256_fmadd_ps(qv, rv, acc[p]);
                    }
                }
                for p in 0..pw {
                    let mut s = hsum(acc[p]);
                    for j in chunks * 8..dim {
                        s += queries[(q0 + p) * dim + j] * f16(rows[r * dim + j]);
                    }
                    out[(q0 + p) * nrows + r] = s;
                }
            }
            q0 += pw;
        }
    }

    /// int8 panel: row chunks are 8 codes (8 B) sign-extended and widened
    /// to f32 in-register (`vpmovsxbd` + `vcvtdq2ps`); the row scale
    /// multiplies the finished per-pair sum once.
    ///
    /// # Safety
    ///
    /// * The running CPU must support AVX2 and FMA.
    /// * `queries.len() == nq * dim`, `rows.len() == nrows * dim`,
    ///   `scales.len() == nrows` and `out.len() == nq * nrows` (checked
    ///   by the dispatching wrapper).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn panel_i8(
        queries: &[f32],
        nq: usize,
        rows: &[i8],
        scales: &[f32],
        nrows: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let chunks = dim / 8;
        let pq = queries.as_ptr();
        let pr = rows.as_ptr();
        let mut q0 = 0;
        while q0 < nq {
            let pw = (nq - q0).min(super::PANEL_QUERIES);
            for r in 0..nrows {
                // SAFETY: `r < nrows` and `rows.len() == nrows * dim`.
                let row = unsafe { pr.add(r * dim) };
                let mut acc = [_mm256_setzero_ps(); super::PANEL_QUERIES];
                for c in 0..chunks {
                    let j = c * 8;
                    // SAFETY: `_mm_loadl_epi64` reads exactly 8 bytes and
                    // `j + 8 <= dim`, so the read covers 8 in-bounds codes
                    // of row `r`; no alignment requirement.
                    let codes = unsafe { _mm_loadl_epi64(row.add(j) as *const __m128i) };
                    let rv = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(codes));
                    for p in 0..pw {
                        // SAFETY: `q0 + p < nq` and `j + 8 <= dim` stay
                        // inside the `nq * dim` query panel.
                        let qv = unsafe { _mm256_loadu_ps(pq.add((q0 + p) * dim + j)) };
                        acc[p] = _mm256_fmadd_ps(qv, rv, acc[p]);
                    }
                }
                let scale = scales[r];
                for p in 0..pw {
                    let mut s = hsum(acc[p]);
                    for j in chunks * 8..dim {
                        s += queries[(q0 + p) * dim + j] * rows[r * dim + j] as f32;
                    }
                    out[(q0 + p) * nrows + r] = s * scale;
                }
            }
            q0 += pw;
        }
    }

    /// PQ/ADC panel: decode 8 packed codes, turn them into absolute LUT
    /// offsets (`s · kc + code`) and fetch all 8 table entries with one
    /// `vgatherdps`, accumulating 8 sub-spaces per add. Ascending
    /// sub-space order + horizontal sum + scalar tail per (query, row),
    /// independent of the panel shape — the batch==single guarantee.
    ///
    /// # Safety
    ///
    /// * The running CPU must support AVX2 and FMA.
    /// * `lut.len() == nq * m * kc`, `codes.len() == nrows * packed`,
    ///   `out.len() == nq * nrows`, and `kc == 1 << bits` with
    ///   `bits ∈ {4, 8}` — the last pair is what bounds every decoded
    ///   code below `kc`, keeping each gathered LUT index in range
    ///   (checked by the dispatching wrapper).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn panel_pq(
        lut: &[f32],
        nq: usize,
        codes: &[u8],
        nrows: usize,
        m: usize,
        kc: usize,
        bits: u8,
        out: &mut [f32],
    ) {
        let packed = (m * bits as usize).div_ceil(8);
        let chunks = m / 8;
        for q in 0..nq {
            let lq = &lut[q * m * kc..(q + 1) * m * kc];
            let plq = lq.as_ptr();
            for r in 0..nrows {
                let row = &codes[r * packed..(r + 1) * packed];
                let mut acc = _mm256_setzero_ps();
                let mut idx = [0i32; 8];
                for c in 0..chunks {
                    let s0 = c * 8;
                    for l in 0..8 {
                        let s = s0 + l;
                        idx[l] = (s * kc + super::pq_code(row, s, bits)) as i32;
                    }
                    // SAFETY: the index load reads the 8-entry stack array
                    // just written. Each gather lane reads `plq[idx[l]]`
                    // where `idx[l] = s * kc + code` with `s < m` and
                    // `code < kc` (`pq_code` masks to `bits` bits and the
                    // caller promised `kc == 1 << bits`), so every lane
                    // lands strictly inside `lq` (`m * kc` entries).
                    acc = unsafe {
                        let vindex = _mm256_loadu_si256(idx.as_ptr() as *const __m256i);
                        _mm256_add_ps(acc, _mm256_i32gather_ps::<4>(plq, vindex))
                    };
                }
                let mut sum = hsum(acc);
                for s in chunks * 8..m {
                    sum += lq[s * kc + super::pq_code(row, s, bits)];
                }
                out[q * nrows + r] = sum;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Canonical per-pair evaluation (see the avx2 twin): one accumulator,
    /// ascending 4-lane chunks, horizontal sum, scalar tail.
    ///
    /// # Safety
    ///
    /// * The running CPU must support NEON (runtime-detected —
    ///   `#[target_feature]` makes merely *calling* this UB otherwise).
    /// * `a.len() == b.len()`: `b` is read through raw pointers at
    ///   `a`-derived offsets, so a shorter `b` is an out-of-bounds read.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 4;
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let j = c * 4;
            // SAFETY: `j + 4 <= chunks * 4 <= n`, and the caller promised
            // `b.len() == a.len() == n`, so both 4-lane loads stay inside
            // their slices.
            let (va, vb) = unsafe { (vld1q_f32(pa.add(j)), vld1q_f32(pb.add(j))) };
            acc = vfmaq_f32(acc, va, vb);
        }
        let mut s = vaddvq_f32(acc);
        for j in chunks * 4..n {
            s += a[j] * b[j];
        }
        s
    }

    /// Multi-query panel, one accumulator chain per query; bit-identical
    /// per pair to [`dot`].
    ///
    /// # Safety
    ///
    /// * The running CPU must support NEON.
    /// * `queries.len() == nq * dim`, `rows.len() == nrows * dim` and
    ///   `out.len() == nq * nrows` — the raw-pointer offsets below assume
    ///   exactly these shapes (checked by the dispatching wrapper).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn panel(
        queries: &[f32],
        nq: usize,
        rows: &[f32],
        nrows: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let chunks = dim / 4;
        let pq = queries.as_ptr();
        let pr = rows.as_ptr();
        let mut q0 = 0;
        while q0 < nq {
            let pw = (nq - q0).min(super::PANEL_QUERIES);
            for r in 0..nrows {
                // SAFETY: `r < nrows` and `rows.len() == nrows * dim`, so
                // row `r` spans `[r * dim, (r + 1) * dim)` of `rows`.
                let row = unsafe { pr.add(r * dim) };
                let mut acc = [vdupq_n_f32(0.0); super::PANEL_QUERIES];
                for c in 0..chunks {
                    let j = c * 4;
                    // SAFETY: `j + 4 <= chunks * 4 <= dim` keeps the load
                    // inside row `r`.
                    let rv = unsafe { vld1q_f32(row.add(j)) };
                    for p in 0..pw {
                        // SAFETY: `q0 + p < nq` and `j + 4 <= dim`, so the
                        // load stays inside the `nq * dim` query panel.
                        let qv = unsafe { vld1q_f32(pq.add((q0 + p) * dim + j)) };
                        acc[p] = vfmaq_f32(acc[p], qv, rv);
                    }
                }
                for p in 0..pw {
                    let mut s = vaddvq_f32(acc[p]);
                    for j in chunks * 4..dim {
                        s += queries[(q0 + p) * dim + j] * rows[r * dim + j];
                    }
                    out[(q0 + p) * nrows + r] = s;
                }
            }
            q0 += pw;
        }
    }

    /// f16 panel: stable Rust has no aarch64 f16 vector intrinsics, so
    /// each 4-element row chunk is bit-decoded once into a stack buffer
    /// (shared across the whole query panel — rows still cross the memory
    /// bus at 2 B/element) and fed to the f32 FMA lanes.
    ///
    /// # Safety
    ///
    /// * The running CPU must support NEON.
    /// * `queries.len() == nq * dim`, `rows.len() == nrows * dim` and
    ///   `out.len() == nq * nrows` (checked by the dispatching wrapper).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn panel_f16(
        queries: &[f32],
        nq: usize,
        rows: &[u16],
        nrows: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let f16 = crate::vecstore::quant::f16_to_f32;
        let chunks = dim / 4;
        let pq = queries.as_ptr();
        let mut q0 = 0;
        while q0 < nq {
            let pw = (nq - q0).min(super::PANEL_QUERIES);
            for r in 0..nrows {
                let row = &rows[r * dim..(r + 1) * dim];
                let mut acc = [vdupq_n_f32(0.0); super::PANEL_QUERIES];
                for c in 0..chunks {
                    let j = c * 4;
                    let buf = [f16(row[j]), f16(row[j + 1]), f16(row[j + 2]), f16(row[j + 3])];
                    // SAFETY: `buf` is a live 4-element stack array, so
                    // the 4-lane load reads exactly its extent.
                    let rv = unsafe { vld1q_f32(buf.as_ptr()) };
                    for p in 0..pw {
                        // SAFETY: `q0 + p < nq` and `j + 4 <= dim` stay
                        // inside the `nq * dim` query panel.
                        let qv = unsafe { vld1q_f32(pq.add((q0 + p) * dim + j)) };
                        acc[p] = vfmaq_f32(acc[p], qv, rv);
                    }
                }
                for p in 0..pw {
                    let mut s = vaddvq_f32(acc[p]);
                    for j in chunks * 4..dim {
                        s += queries[(q0 + p) * dim + j] * f16(row[j]);
                    }
                    out[(q0 + p) * nrows + r] = s;
                }
            }
            q0 += pw;
        }
    }

    /// int8 panel: 8 codes per chunk widened in-register
    /// (`vmovl_s8`/`vmovl_s16`/`vcvtq_f32_s32`), two FMAs per chunk per
    /// query; the row scale multiplies the finished sum once.
    ///
    /// # Safety
    ///
    /// * The running CPU must support NEON.
    /// * `queries.len() == nq * dim`, `rows.len() == nrows * dim`,
    ///   `scales.len() == nrows` and `out.len() == nq * nrows` (checked
    ///   by the dispatching wrapper).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn panel_i8(
        queries: &[f32],
        nq: usize,
        rows: &[i8],
        scales: &[f32],
        nrows: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let chunks = dim / 8;
        let pq = queries.as_ptr();
        let pr = rows.as_ptr();
        let mut q0 = 0;
        while q0 < nq {
            let pw = (nq - q0).min(super::PANEL_QUERIES);
            for r in 0..nrows {
                // SAFETY: `r < nrows` and `rows.len() == nrows * dim`.
                let row = unsafe { pr.add(r * dim) };
                let mut acc = [vdupq_n_f32(0.0); super::PANEL_QUERIES];
                for c in 0..chunks {
                    let j = c * 8;
                    // SAFETY: `vld1_s8` reads 8 bytes and `j + 8 <= dim`,
                    // so the read covers 8 in-bounds codes of row `r`.
                    let wide = vmovl_s8(unsafe { vld1_s8(row.add(j)) });
                    let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(wide)));
                    let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(wide)));
                    for p in 0..pw {
                        let qoff = (q0 + p) * dim + j;
                        // SAFETY: `q0 + p < nq` and `j + 8 <= dim`, so both
                        // 4-lane loads (`qoff`, `qoff + 4`) stay inside the
                        // `nq * dim` query panel.
                        let (qlo, qhi) =
                            unsafe { (vld1q_f32(pq.add(qoff)), vld1q_f32(pq.add(qoff + 4))) };
                        acc[p] = vfmaq_f32(acc[p], qlo, lo);
                        acc[p] = vfmaq_f32(acc[p], qhi, hi);
                    }
                }
                let scale = scales[r];
                for p in 0..pw {
                    let mut s = vaddvq_f32(acc[p]);
                    for j in chunks * 8..dim {
                        s += queries[(q0 + p) * dim + j] * rows[r * dim + j] as f32;
                    }
                    out[(q0 + p) * nrows + r] = s * scale;
                }
            }
            q0 += pw;
        }
    }

    /// PQ/ADC panel: aarch64 has no gather, so 4 looked-up table entries
    /// are staged through a stack buffer per chunk and added with one
    /// `vaddq_f32`. Ascending sub-space order + horizontal sum + scalar
    /// tail per (query, row), independent of the panel shape.
    ///
    /// # Safety
    ///
    /// * The running CPU must support NEON.
    /// * `lut.len() == nq * m * kc`, `codes.len() == nrows * packed` and
    ///   `out.len() == nq * nrows` (checked by the dispatching wrapper;
    ///    the table lookups themselves are bounds-checked slice indexing).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn panel_pq(
        lut: &[f32],
        nq: usize,
        codes: &[u8],
        nrows: usize,
        m: usize,
        kc: usize,
        bits: u8,
        out: &mut [f32],
    ) {
        let packed = (m * bits as usize).div_ceil(8);
        let chunks = m / 4;
        for q in 0..nq {
            let lq = &lut[q * m * kc..(q + 1) * m * kc];
            for r in 0..nrows {
                let row = &codes[r * packed..(r + 1) * packed];
                let mut acc = vdupq_n_f32(0.0);
                for c in 0..chunks {
                    let s = c * 4;
                    let buf = [
                        lq[s * kc + super::pq_code(row, s, bits)],
                        lq[(s + 1) * kc + super::pq_code(row, s + 1, bits)],
                        lq[(s + 2) * kc + super::pq_code(row, s + 2, bits)],
                        lq[(s + 3) * kc + super::pq_code(row, s + 3, bits)],
                    ];
                    // SAFETY: `buf` is a live 4-element stack array, so
                    // the 4-lane load reads exactly its extent.
                    acc = vaddq_f32(acc, unsafe { vld1q_f32(buf.as_ptr()) });
                }
                let mut sum = vaddvq_f32(acc);
                for s in chunks * 4..m {
                    sum += lq[s * kc + super::pq_code(row, s, bits)];
                }
                out[q * nrows + r] = sum;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randvec(rng: &mut Pcg, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
    }

    fn naive(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (*x as f64 * *y as f64) as f32).sum()
    }

    #[test]
    fn dispatched_dot_matches_scalar_all_lengths() {
        let mut rng = Pcg::new(1);
        // Cover sub-lane, non-multiple-of-8, and large lengths.
        for n in [1usize, 3, 4, 7, 8, 9, 15, 16, 17, 31, 32, 33, 64, 100, 768, 1024] {
            let a = randvec(&mut rng, n);
            let b = randvec(&mut rng, n);
            let want = dot_scalar(&a, &b);
            let got = dot(&a, &b);
            let tol = 1e-4 * (1.0 + want.abs());
            assert!((got - want).abs() <= tol, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn dot_scalar_matches_naive() {
        let mut rng = Pcg::new(2);
        let a = randvec(&mut rng, 37);
        let b = randvec(&mut rng, 37);
        assert!((dot_scalar(&a, &b) - naive(&a, &b)).abs() < 1e-3);
    }

    #[test]
    fn panel_matches_per_pair_dot_exactly() {
        // The panel kernel must be *bit-identical* per pair to the single
        // dot under the same variant — that is what makes search_batch
        // results equal per-query search results.
        let mut rng = Pcg::new(3);
        for (nq, nrows, dim) in [(1, 1, 8), (3, 5, 17), (4, 4, 32), (5, 9, 768), (9, 2, 1)] {
            let queries = randvec(&mut rng, nq * dim);
            let rows = randvec(&mut rng, nrows * dim);
            let mut out = vec![0.0f32; nq * nrows];
            panel_scores_into(&queries, nq, &rows, nrows, dim, &mut out);
            for q in 0..nq {
                for r in 0..nrows {
                    let want = dot(&queries[q * dim..(q + 1) * dim], &rows[r * dim..(r + 1) * dim]);
                    let got = out[q * nrows + r];
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "pair ({q},{r}) nq={nq} nrows={nrows} dim={dim}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn panel_scalar_matches_dispatched_within_tolerance() {
        let mut rng = Pcg::new(4);
        let (nq, nrows, dim) = (6, 11, 96);
        let queries = randvec(&mut rng, nq * dim);
        let rows = randvec(&mut rng, nrows * dim);
        let mut fast = vec![0.0f32; nq * nrows];
        let mut slow = vec![0.0f32; nq * nrows];
        panel_scores_into(&queries, nq, &rows, nrows, dim, &mut fast);
        panel_scalar(&queries, nq, &rows, nrows, dim, &mut slow);
        for (f, s) in fast.iter().zip(&slow) {
            assert!((f - s).abs() <= 1e-4 * (1.0 + s.abs()), "{f} vs {s}");
        }
    }

    #[test]
    fn scores_into_is_single_query_panel() {
        let mut rng = Pcg::new(5);
        let dim = 24;
        let q = randvec(&mut rng, dim);
        let rows = randvec(&mut rng, 7 * dim);
        let mut out = vec![0.0f32; 7];
        scores_into(&q, &rows, 7, dim, &mut out);
        for r in 0..7 {
            assert_eq!(out[r].to_bits(), dot(&q, &rows[r * dim..(r + 1) * dim]).to_bits());
        }
    }

    #[test]
    fn empty_panel_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        panel_scores_into(&[], 0, &[], 0, 16, &mut out);
        panel_scores_into(&[0.0; 16], 1, &[], 0, 16, &mut out);
        panel_scores_f16_into(&[], 0, &[], 0, 16, &mut out);
        panel_scores_i8_into(&[0.0; 16], 1, &[], &[], 0, 16, &mut out);
    }

    #[test]
    fn f16_panel_matches_scalar_twin_and_is_batch_invariant() {
        let mut rng = Pcg::new(6);
        for (nq, nrows, dim) in [(1, 1, 8), (3, 5, 17), (5, 9, 768), (9, 2, 1), (4, 7, 96)] {
            let queries = randvec(&mut rng, nq * dim);
            let rows: Vec<u16> = randvec(&mut rng, nrows * dim)
                .iter()
                .map(|&x| crate::vecstore::quant::f32_to_f16(x))
                .collect();
            let mut fast = vec![0.0f32; nq * nrows];
            let mut slow = vec![0.0f32; nq * nrows];
            panel_scores_f16_into(&queries, nq, &rows, nrows, dim, &mut fast);
            panel_f16_scalar(&queries, nq, &rows, nrows, dim, &mut slow);
            for (f, s) in fast.iter().zip(&slow) {
                assert!((f - s).abs() <= 1e-4 * (1.0 + s.abs()), "dim={dim}: {f} vs {s}");
            }
            // Batch shape must not change values: single-query calls give
            // bit-identical pairs under the same dispatched variant.
            for q in 0..nq {
                let mut one = vec![0.0f32; nrows];
                panel_scores_f16_into(
                    &queries[q * dim..(q + 1) * dim],
                    1,
                    &rows,
                    nrows,
                    dim,
                    &mut one,
                );
                for r in 0..nrows {
                    assert_eq!(one[r].to_bits(), fast[q * nrows + r].to_bits());
                }
            }
        }
    }

    #[test]
    fn i8_panel_matches_scalar_twin_and_is_batch_invariant() {
        let mut rng = Pcg::new(7);
        for (nq, nrows, dim) in [(1, 1, 8), (3, 5, 17), (5, 9, 768), (9, 2, 1), (4, 7, 96)] {
            let queries = randvec(&mut rng, nq * dim);
            let mut rows = vec![0i8; nrows * dim];
            let mut scales = vec![0.0f32; nrows];
            for r in 0..nrows {
                let v = randvec(&mut rng, dim);
                scales[r] =
                    crate::vecstore::quant::quantize_i8_row(&v, &mut rows[r * dim..(r + 1) * dim]);
            }
            let mut fast = vec![0.0f32; nq * nrows];
            let mut slow = vec![0.0f32; nq * nrows];
            panel_scores_i8_into(&queries, nq, &rows, &scales, nrows, dim, &mut fast);
            panel_i8_scalar(&queries, nq, &rows, &scales, nrows, dim, &mut slow);
            for (f, s) in fast.iter().zip(&slow) {
                assert!((f - s).abs() <= 1e-4 * (1.0 + s.abs()), "dim={dim}: {f} vs {s}");
            }
            for q in 0..nq {
                let mut one = vec![0.0f32; nrows];
                panel_scores_i8_into(
                    &queries[q * dim..(q + 1) * dim],
                    1,
                    &rows,
                    &scales,
                    nrows,
                    dim,
                    &mut one,
                );
                for r in 0..nrows {
                    assert_eq!(one[r].to_bits(), fast[q * nrows + r].to_bits());
                }
            }
        }
    }

    #[test]
    fn pq_panel_matches_scalar_twin_and_is_batch_invariant() {
        let mut rng = Pcg::new(8);
        // (nq, nrows, m): odd m exercises the trailing nibble + tail.
        for (nq, nrows, m) in [(1, 1, 4), (3, 5, 7), (5, 9, 96), (9, 2, 1), (4, 7, 12)] {
            for bits in [4u8, 8] {
                let kc = 1usize << bits;
                let packed = (m * bits as usize).div_ceil(8);
                let lut = randvec(&mut rng, nq * m * kc);
                let codes: Vec<u8> = (0..nrows * packed).map(|_| rng.usize(0, 256) as u8).collect();
                let mut fast = vec![0.0f32; nq * nrows];
                let mut slow = vec![0.0f32; nq * nrows];
                panel_scores_pq_into(&lut, nq, &codes, nrows, m, kc, bits, &mut fast);
                panel_pq_scalar(&lut, nq, &codes, nrows, m, kc, bits, &mut slow);
                for (q, (f, s)) in fast.iter().zip(&slow).enumerate() {
                    assert!(
                        (f - s).abs() <= 1e-4 * (1.0 + s.abs()),
                        "m={m} bits={bits} pair {q}: {f} vs {s}"
                    );
                }
                for q in 0..nq {
                    let mut one = vec![0.0f32; nrows];
                    panel_scores_pq_into(
                        &lut[q * m * kc..(q + 1) * m * kc],
                        1,
                        &codes,
                        nrows,
                        m,
                        kc,
                        bits,
                        &mut one,
                    );
                    for r in 0..nrows {
                        assert_eq!(
                            one[r].to_bits(),
                            fast[q * nrows + r].to_bits(),
                            "m={m} bits={bits} pair ({q},{r})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn pq_scalar_sums_the_looked_up_entries() {
        // m=3, bits=4, kc=16: hand-checkable — row codes [1, 2, 3].
        let m = 3;
        let kc = 16;
        let mut lut = vec![0.0f32; m * kc];
        lut[1] = 0.5; // s=0, code 1
        lut[kc + 2] = 1.25; // s=1, code 2
        lut[2 * kc + 3] = -2.0; // s=2, code 3
        let codes = [0x21u8, 0x03]; // low nibble first: 1, 2, then 3
        let mut out = [0.0f32; 1];
        panel_pq_scalar(&lut, 1, &codes, 1, m, kc, 4, &mut out);
        assert_eq!(out[0], 0.5 + 1.25 - 2.0);
        let mut out2 = [0.0f32; 1];
        panel_scores_pq_into(&lut, 1, &codes, 1, m, kc, 4, &mut out2);
        assert!((out2[0] - out[0]).abs() <= 1e-6);
    }

    #[test]
    fn empty_pq_panel_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        panel_scores_pq_into(&[], 0, &[], 0, 8, 16, 4, &mut out);
        panel_scores_pq_into(&[0.0; 8 * 16], 1, &[], 0, 8, 16, 4, &mut out);
    }

    #[test]
    fn active_is_cached_and_named() {
        let a = active();
        let b = active();
        assert_eq!(a, b);
        assert!(!name().is_empty());
    }
}
