//! IVF-Flat approximate index: k-means coarse quantizer + inverted lists.
//!
//! Build: train centroids over the (buffered) corpus, bucket each vector
//! into its nearest cell. Search: score the `nprobe` nearest cells only.
//!
//! Inverted lists are contiguous row-major [`RowArena`]s (one per cell),
//! so probed lists are scanned block-by-block through the same panel
//! kernels as the flat index — and, via [`IvfIndex::with_quant`], can be
//! stored f16, int8, or product-quantized (pq8/pq4, scanned via an ADC
//! lookup table built once per query) for 2× to 64× less probe
//! bandwidth. Build-time
//! assignment is quantization-aware: rows are bucketed by scoring their
//! *stored* representation against the centroids (see
//! [`super::kmeans::assign_arena`]), so the cell geometry matches what
//! search-time scans actually score.
//!
//! The batched path ranks every query's cells against the contiguous
//! centroid matrix with one panel-kernel call, then fans the resulting
//! (query, probe-list) tasks out across scoped threads; per-list scan
//! results merge through sequence-numbered top-k so the output is
//! identical to per-query [`Index::search`].

use super::kmeans;
use super::mask::SkipMask;
use super::quant::{PanelCtx, Quant, RowArena};
use super::{dot, kernels, Hit, Index, TopK};

/// Don't spin up probe threads for less scan work than this many rows.
const MIN_PROBED_ROWS_PARALLEL: usize = 4096;

/// Rows scored per panel call when scanning a probed list.
const LIST_SCAN_BLOCK: usize = 64;

/// One inverted list: parallel id vector + contiguous (possibly
/// quantized) row arena + tombstone mask (see `vecstore::mask`).
pub(crate) struct InvList {
    pub(crate) ids: Vec<u64>,
    pub(crate) arena: RowArena,
    pub(crate) dead: SkipMask,
}

/// IVF-Flat index. Vectors are buffered (at full precision) until
/// [`IvfIndex::build`]; before that, search falls back to exact scan over
/// the buffer. Quantization applies to the built lists.
pub struct IvfIndex {
    pub(crate) dim: usize,
    pub(crate) nlist: usize,
    pub nprobe: usize,
    pub(crate) quant: Quant,
    // Buffered (pre-build) rows.
    pub(crate) pending: Vec<(u64, Vec<f32>)>,
    pub(crate) centroids: Vec<f32>,
    pub(crate) lists: Vec<InvList>,
    pub(crate) built: bool,
    /// Live (non-tombstoned) rows — see [`Index::len`].
    pub(crate) len: usize,
    /// Online-rebalance trigger: when post-build adds push
    /// `max list size / mean list size` past this ratio, the next
    /// [`Index::add_batch`] re-trains and re-assigns in place
    /// (0.0 disables — the default, matching historic behavior).
    pub(crate) rebalance_threshold: f64,
    /// Seed for online re-trains (fixed so streaming rebuilds are
    /// deterministic for a given add sequence).
    pub(crate) rebalance_seed: u64,
    /// Completed online rebalances (observability).
    pub(crate) rebalances: u64,
    /// Hysteresis for the auto trigger: when a retrain cannot bring the
    /// skew under the threshold (inherently clustered data), this holds
    /// the achieved skew × margin, and the next retrain only fires once
    /// skew exceeds it — without this, every subsequent `add_batch`
    /// would re-run a full O(n·k) retrain under the executor's write
    /// lock for nothing.
    pub(crate) retrigger_skew: f64,
}

/// One unit of batched scan work: probe `cell` for query `qi`, with the
/// query's cumulative row offset for deterministic tie-breaking.
struct Probe {
    qi: usize,
    cell: usize,
    seq_base: u64,
}

impl IvfIndex {
    pub fn new(dim: usize, nlist: usize, nprobe: usize) -> IvfIndex {
        IvfIndex::with_quant(dim, nlist, nprobe, Quant::F32)
    }

    /// An IVF index whose inverted lists store rows under `quant`.
    pub fn with_quant(dim: usize, nlist: usize, nprobe: usize, quant: Quant) -> IvfIndex {
        assert!(dim > 0 && nlist > 0 && nprobe > 0);
        IvfIndex {
            dim,
            nlist,
            nprobe: nprobe.min(nlist),
            // Resolve `m = 0` PQ placeholders now so `quant()` and the
            // snapshot header always carry the concrete layout.
            quant: quant.resolved(dim),
            pending: Vec::new(),
            centroids: Vec::new(),
            lists: Vec::new(),
            built: false,
            len: 0,
            rebalance_threshold: 0.0,
            rebalance_seed: 0x1f5,
            rebalances: 0,
            retrigger_skew: 0.0,
        }
    }

    /// Enable online list rebalancing: when a post-build [`Index::add_batch`]
    /// leaves `max/mean` list size above `ratio`, the index re-trains its
    /// coarse quantizer and re-assigns every row in place (the ROADMAP's
    /// streaming-IVF hook — skewed streams stop degrading probe recall
    /// without a periodic offline rebuild). `ratio` ≤ 1 is clamped to
    /// disabled; a practical setting is 2.0-4.0.
    pub fn with_rebalance_threshold(mut self, ratio: f64) -> IvfIndex {
        self.rebalance_threshold = if ratio > 1.0 { ratio } else { 0.0 };
        self
    }

    /// `max list size / mean list size` over the built inverted lists —
    /// the skew statistic the online-rebalance trigger watches. 1.0 is
    /// perfectly balanced; unbuilt (or empty) indexes report 0.
    pub fn skew(&self) -> f64 {
        if !self.built || self.len == 0 || self.lists.is_empty() {
            return 0.0;
        }
        // Physical list sizes: tombstoned rows still stream through the
        // probe kernels, so they count toward probe-cost skew.
        let max = self.lists.iter().map(|l| l.ids.len()).max().unwrap_or(0);
        let total: usize = self.lists.iter().map(|l| l.ids.len()).sum();
        let mean = total as f64 / self.lists.len() as f64;
        max as f64 / mean.max(f64::MIN_POSITIVE)
    }

    /// Completed online rebalances since construction.
    pub fn rebalances(&self) -> u64 {
        self.rebalances
    }

    /// Re-train the coarse quantizer over the *current* corpus and
    /// re-assign every row — the online answer to post-build adds skewing
    /// `list_sizes()`. Rows round-trip through their stored codec
    /// (deterministic codecs re-encode to identical bytes, so search
    /// results for unmoved rows are unchanged). No-op before `build`.
    pub fn rebalance(&mut self, seed: u64) {
        if !self.built {
            return;
        }
        let mut rows: Vec<(u64, Vec<f32>)> = Vec::with_capacity(self.len);
        for list in &self.lists {
            for (i, &id) in list.ids.iter().enumerate() {
                // Tombstoned rows are dropped here: a rebalance doubles
                // as a compaction (relative live-row order is preserved,
                // so deterministic tie-breaks are unaffected).
                if list.dead.is_dead(i) {
                    continue;
                }
                rows.push((id, list.arena.dequant_row(i, self.dim)));
            }
        }
        self.pending = rows;
        self.lists.clear();
        self.centroids.clear();
        self.built = false;
        self.build(seed);
        self.rebalances += 1;
    }

    /// Train the quantizer and assign all buffered vectors.
    pub fn build(&mut self, seed: u64) {
        let n = self.pending.len();
        if n == 0 {
            return;
        }
        let k = self.nlist.min(n);
        let mut flat = Vec::with_capacity(n * self.dim);
        for (_, v) in &self.pending {
            flat.extend_from_slice(v);
        }
        self.centroids = kmeans::train(&flat, self.dim, k, 15, seed);
        // Quantization-aware bucketing: score each row's *stored*
        // (quantized) representation against the centroids so build-time
        // cells match search-time scans. For F32 arenas this is
        // bit-identical to per-row `kmeans::nearest`.
        let mut corpus = RowArena::new(self.quant);
        for (_, v) in &self.pending {
            corpus.push(v);
        }
        // PQ lists must be trained before bucketing so assignment scores
        // the codes search will actually scan. Below the staging
        // threshold this trains on the full corpus under the build seed;
        // above it the arena already auto-trained (fixed seed) and this
        // is a no-op — either way the outcome is deterministic per
        // (corpus, seed). Non-PQ codecs ignore the call.
        corpus.pq_train(self.dim, seed);
        let mut assign = vec![0usize; n];
        kmeans::assign_arena(&corpus, self.dim, &self.centroids, &mut assign);
        self.lists = (0..k)
            .map(|_| InvList {
                ids: Vec::new(),
                // `new_like` shares the corpus arena's trained PQ
                // codebook, so the per-row copies below stay byte moves.
                arena: RowArena::new_like(&corpus),
                dead: SkipMask::new(),
            })
            .collect();
        // The corpus arena already holds every row's encoded bytes —
        // copy them into the per-list arenas instead of re-quantizing.
        for (i, (id, _)) in self.pending.drain(..).enumerate() {
            let list = &mut self.lists[assign[i]];
            list.ids.push(id);
            list.arena.push_row_from(&corpus, i, self.dim);
        }
        self.built = true;
    }

    pub fn is_built(&self) -> bool {
        self.built
    }

    /// Storage codec of the inverted lists.
    pub fn quant(&self) -> Quant {
        self.quant
    }

    /// Bytes a full-probe scan would read from the list arenas.
    pub fn arena_bytes(&self) -> usize {
        self.lists.iter().map(|l| l.arena.bytes()).sum()
    }

    /// Fraction of searches that would hit each list (balance diagnostic).
    pub fn list_sizes(&self) -> Vec<usize> {
        self.lists.iter().map(|l| l.ids.len()).collect()
    }

    /// Rank cells for `query` (best first). Centroid scores come from the
    /// same kernel math the batched path uses.
    fn ranked_cells(&self, query: &[f32]) -> Vec<(usize, f32)> {
        let ncells = self.lists.len();
        let mut cell_scores: Vec<(usize, f32)> = (0..ncells)
            .map(|c| (c, dot(query, &self.centroids[c * self.dim..(c + 1) * self.dim])))
            .collect();
        cell_scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        cell_scores
    }

    /// One panel context per probed query: under PQ this builds the ADC
    /// lookup table once, amortized over every list the query probes
    /// (all list arenas share the corpus codebook, so a context built
    /// from any one of them is valid for all). Other codecs get a no-op
    /// context.
    fn query_ctx(&self, query: &[f32]) -> PanelCtx {
        match self.lists.first() {
            Some(l) => l.arena.begin_panel(query, 1, self.dim),
            None => PanelCtx::none(),
        }
    }

    /// Scan one inverted list for one query, block by block through the
    /// arena's (possibly quantized) panel kernel.
    fn scan_list(&self, ctx: &PanelCtx, query: &[f32], probe: &Probe, tk: &mut TopK) {
        let list = &self.lists[probe.cell];
        let n = list.ids.len();
        let mut scores = [0.0f32; LIST_SCAN_BLOCK];
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + LIST_SCAN_BLOCK).min(n);
            list.arena.panel_scores_ctx_into(ctx, query, 1, r0, r1, self.dim, &mut scores[..r1 - r0]);
            for r in r0..r1 {
                // Tombstone skip (see `FlatIndex::scan_rows`): the row is
                // scored but never pushed, so seq numbering — and with it
                // batch/single determinism — is untouched.
                if list.dead.is_dead(r) {
                    continue;
                }
                tk.push_with_seq(list.ids[r], scores[r - r0], probe.seq_base + r as u64);
            }
            r0 = r1;
        }
    }
}

impl Index for IvfIndex {
    fn add(&mut self, id: u64, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "dimension mismatch");
        self.len += 1;
        if self.built {
            let (c, _) = kmeans::nearest(vector, &self.centroids, self.dim);
            let list = &mut self.lists[c];
            list.ids.push(id);
            list.arena.push(vector);
        } else {
            self.pending.push((id, vector.to_vec()));
        }
    }

    /// Batched append with the online-rebalance hook: rows are assigned
    /// to their nearest cell as usual, then — once per batch, never per
    /// row — the skew trigger may re-train and re-assign in place. The
    /// trigger checks its own outcome: if the retrain could not bring
    /// skew under the threshold (the data is inherently that clustered),
    /// the bar rises to the achieved skew plus a margin, so steady
    /// ingest onto irreducibly-skewed data costs one retrain, not one
    /// per commit.
    fn add_batch(&mut self, rows: &[(u64, &[f32])]) {
        for (id, v) in rows {
            self.add(*id, v);
        }
        if self.rebalance_threshold > 1.0
            && self.built
            && self.skew() > self.rebalance_threshold.max(self.retrigger_skew)
        {
            self.rebalance(self.rebalance_seed);
            let achieved = self.skew();
            self.retrigger_skew = if achieved > self.rebalance_threshold {
                achieved * 1.25
            } else {
                // The retrain worked: future triggers use the plain
                // threshold again.
                0.0
            };
        }
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "dimension mismatch");
        let mut tk = TopK::new(k);
        if !self.built {
            for (id, v) in &self.pending {
                tk.push(*id, dot(query, v));
            }
            return tk.into_vec();
        }
        // Rank cells by centroid similarity, probe the top nprobe. The
        // cumulative seq numbering matches the batched path exactly.
        let ctx = self.query_ctx(query);
        let mut seq_base = 0u64;
        for &(c, _) in self.ranked_cells(query).iter().take(self.nprobe) {
            let probe = Probe { qi: 0, cell: c, seq_base };
            self.scan_list(&ctx, query, &probe, &mut tk);
            seq_base += self.lists[c].ids.len() as u64;
        }
        tk.into_vec()
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        for q in queries {
            assert_eq!(q.len(), self.dim, "dimension mismatch");
        }
        let nq = queries.len();
        if nq == 0 {
            return Vec::new();
        }
        if !self.built {
            return queries.iter().map(|q| self.search(q, k)).collect();
        }
        let ncells = self.lists.len();
        // Rank all queries' cells in one panel-kernel pass over the
        // contiguous centroid matrix (same math as `ranked_cells`).
        let mut qbuf = Vec::with_capacity(nq * self.dim);
        for q in queries {
            qbuf.extend_from_slice(q);
        }
        let mut cscores = vec![0.0f32; nq * ncells];
        kernels::panel_scores_into(&qbuf, nq, &self.centroids, ncells, self.dim, &mut cscores);

        let mut probes: Vec<Probe> = Vec::with_capacity(nq * self.nprobe);
        let mut probed_rows = 0usize;
        for qi in 0..nq {
            let mut ranked: Vec<(usize, f32)> =
                (0..ncells).map(|c| (c, cscores[qi * ncells + c])).collect();
            ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let mut seq_base = 0u64;
            for &(cell, _) in ranked.iter().take(self.nprobe) {
                let rows = self.lists[cell].ids.len();
                probes.push(Probe { qi, cell, seq_base });
                seq_base += rows as u64;
                probed_rows += rows;
            }
        }

        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = if probed_rows < MIN_PROBED_ROWS_PARALLEL {
            1
        } else {
            avail.min(probes.len()).max(1)
        };

        // One ADC table per query, shared across all its probed lists
        // (and across threads — contexts are read-only during the scan).
        let ctxs: Vec<PanelCtx> = queries.iter().map(|q| self.query_ctx(q)).collect();

        if threads == 1 {
            let mut finals: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
            for p in &probes {
                self.scan_list(&ctxs[p.qi], queries[p.qi], p, &mut finals[p.qi]);
            }
            return finals.into_iter().map(TopK::into_vec).collect();
        }

        // Per-probe-list parallelism: stripe the task list over threads;
        // each thread keeps its own per-query TopK, merged afterwards.
        let finals = super::parallel_topk_scan(threads, nq, k, |t, tks| {
            let mut i = t;
            while i < probes.len() {
                let p = &probes[i];
                self.scan_list(&ctxs[p.qi], queries[p.qi], p, &mut tks[p.qi]);
                i += threads;
            }
        });
        finals.into_iter().map(TopK::into_vec).collect()
    }

    fn len(&self) -> usize {
        self.len
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn quant(&self) -> Quant {
        self.quant
    }

    fn remove(&mut self, id: u64) -> usize {
        let mut killed = 0;
        // Pre-build rows are a plain buffer: drop them outright.
        let before = self.pending.len();
        self.pending.retain(|(pid, _)| *pid != id);
        killed += before - self.pending.len();
        // Built rows tombstone in place (see `vecstore::mask`).
        for list in &mut self.lists {
            for row in 0..list.ids.len() {
                if list.ids[row] == id && list.dead.kill(row) {
                    killed += 1;
                }
            }
        }
        self.len -= killed;
        killed
    }

    fn tombstones(&self) -> usize {
        self.lists.iter().map(|l| l.dead.dead()).sum()
    }

    fn compact(&mut self) -> usize {
        let mut reclaimed = 0;
        for list in &mut self.lists {
            let dead = list.dead.dead();
            if dead == 0 {
                continue;
            }
            reclaimed += dead;
            let mut ids = Vec::with_capacity(list.ids.len() - dead);
            // `new_like` keeps any trained PQ codebook so survivor rows
            // copy byte-for-byte instead of round-tripping through f32.
            let mut arena = RowArena::new_like(&list.arena);
            for row in 0..list.ids.len() {
                if !list.dead.is_dead(row) {
                    ids.push(list.ids[row]);
                    // Byte-exact survivor copy (see `QuantizedFlatIndex::compact`).
                    arena.push_row_from(&list.arena, row, self.dim);
                }
            }
            list.ids = ids;
            list.arena = arena;
            list.dead.clear();
        }
        reclaimed
    }

    fn scan_rows_estimate(&self) -> usize {
        // Physical rows: tombstoned rows still stream through the probe
        // kernels until a compaction reclaims them.
        let physical: usize =
            self.pending.len() + self.lists.iter().map(|l| l.ids.len()).sum::<usize>();
        if !self.is_built() {
            // Pre-build search scans everything.
            return physical;
        }
        // A probe streams nprobe of nlist cells; assume balanced lists
        // (the kmeans build targets that) and round up.
        (physical * self.nprobe).div_ceil(self.nlist)
    }

    fn snapshot_bytes(&self) -> Option<Vec<u8>> {
        Some(super::persist::encode_ivf(self))
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FlatIndex, QuantizedFlatIndex};
    use super::*;
    use crate::util::rng::Pcg;

    fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    fn corpus(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| unit(&mut rng, d)).collect()
    }

    #[test]
    fn unbuilt_index_is_exact() {
        let vs = corpus(50, 16, 1);
        let mut ivf = IvfIndex::new(16, 8, 2);
        let mut flat = FlatIndex::new(16);
        for (i, v) in vs.iter().enumerate() {
            ivf.add(i as u64, v);
            flat.add(i as u64, v);
        }
        let q = &vs[7];
        assert_eq!(ivf.search(q, 5), flat.search(q, 5));
    }

    #[test]
    fn full_probe_equals_exact() {
        // nprobe == nlist must recover exact results.
        let vs = corpus(200, 16, 2);
        let mut ivf = IvfIndex::new(16, 8, 8);
        let mut flat = FlatIndex::new(16);
        for (i, v) in vs.iter().enumerate() {
            ivf.add(i as u64, v);
            flat.add(i as u64, v);
        }
        ivf.build(3);
        let mut rng = Pcg::new(9);
        for _ in 0..10 {
            let q = unit(&mut rng, 16);
            let a: Vec<u64> = ivf.search(&q, 5).into_iter().map(|h| h.id).collect();
            let b: Vec<u64> = flat.search(&q, 5).into_iter().map(|h| h.id).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn recall_improves_with_nprobe() {
        let vs = corpus(500, 24, 4);
        let mut flat = FlatIndex::new(24);
        for (i, v) in vs.iter().enumerate() {
            flat.add(i as u64, v);
        }
        let mut recalls = Vec::new();
        for nprobe in [1usize, 4, 16] {
            let mut ivf = IvfIndex::new(24, 16, nprobe);
            for (i, v) in vs.iter().enumerate() {
                ivf.add(i as u64, v);
            }
            ivf.build(5);
            let mut rng = Pcg::new(11);
            let mut hit = 0;
            let trials = 50;
            for _ in 0..trials {
                let q = unit(&mut rng, 24);
                let truth: Vec<u64> = flat.search(&q, 10).into_iter().map(|h| h.id).collect();
                let approx = ivf.search(&q, 10);
                hit += approx.iter().filter(|h| truth.contains(&h.id)).count();
            }
            recalls.push(hit as f64 / (trials * 10) as f64);
        }
        assert!(recalls[0] <= recalls[1] + 0.05, "{recalls:?}");
        assert!(recalls[1] <= recalls[2] + 0.05, "{recalls:?}");
        assert!(recalls[2] > 0.95, "full-ish probe should be near exact: {recalls:?}");
    }

    #[test]
    fn post_build_adds_are_searchable() {
        let vs = corpus(64, 8, 6);
        let mut ivf = IvfIndex::new(8, 4, 4);
        for (i, v) in vs.iter().enumerate() {
            ivf.add(i as u64, v);
        }
        ivf.build(7);
        let late = vs[0].clone();
        ivf.add(999, &late);
        let hits = ivf.search(&late, 2);
        assert!(hits.iter().any(|h| h.id == 999));
        assert_eq!(ivf.len(), 65);
    }

    #[test]
    fn list_sizes_cover_corpus() {
        let vs = corpus(100, 8, 8);
        let mut ivf = IvfIndex::new(8, 5, 1);
        for (i, v) in vs.iter().enumerate() {
            ivf.add(i as u64, v);
        }
        ivf.build(1);
        assert_eq!(ivf.list_sizes().iter().sum::<usize>(), 100);
    }

    #[test]
    fn search_batch_matches_per_query_search() {
        let vs = corpus(400, 24, 12);
        for nprobe in [1usize, 3, 8] {
            let mut ivf = IvfIndex::new(24, 8, nprobe);
            for (i, v) in vs.iter().enumerate() {
                ivf.add(i as u64, v);
            }
            ivf.build(13);
            let mut rng = Pcg::new(21);
            let queries: Vec<Vec<f32>> = (0..7).map(|_| unit(&mut rng, 24)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = ivf.search_batch(&qrefs, 6);
            for (q, got) in queries.iter().zip(&batch) {
                assert_eq!(got, &ivf.search(q, 6), "nprobe={nprobe}");
            }
        }
    }

    #[test]
    fn search_batch_unbuilt_matches_search() {
        let vs = corpus(60, 12, 14);
        let mut ivf = IvfIndex::new(12, 4, 2);
        for (i, v) in vs.iter().enumerate() {
            ivf.add(i as u64, v);
        }
        let qrefs: Vec<&[f32]> = vs[..4].iter().map(|q| q.as_slice()).collect();
        let batch = ivf.search_batch(&qrefs, 3);
        for (q, got) in qrefs.iter().zip(&batch) {
            assert_eq!(got, &ivf.search(q, 3));
        }
    }

    #[test]
    fn quantized_batch_matches_single_and_shrinks_arena() {
        let vs = corpus(300, 24, 16);
        for quant in [Quant::F16, Quant::Int8] {
            let mut ivf = IvfIndex::with_quant(24, 8, 3, quant);
            for (i, v) in vs.iter().enumerate() {
                ivf.add(i as u64, v);
            }
            ivf.build(17);
            assert_eq!(ivf.quant(), quant);
            assert_eq!(ivf.arena_bytes(), 300 * quant.bytes_per_row(24));
            assert_eq!(ivf.list_sizes().iter().sum::<usize>(), 300);
            let mut rng = Pcg::new(23);
            let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(&mut rng, 24)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = ivf.search_batch(&qrefs, 5);
            for (q, got) in queries.iter().zip(&batch) {
                assert_eq!(got, &ivf.search(q, 5), "{quant:?}");
            }
        }
    }

    /// Full probe over quantized lists scans every row under the same
    /// codec as a quantized flat index, so the *score multisets* must
    /// match exactly (ordering may differ only on quantization ties).
    #[test]
    fn quantized_full_probe_matches_quantized_flat_scores() {
        let vs = corpus(150, 16, 31);
        for quant in [Quant::F16, Quant::Int8] {
            let mut ivf = IvfIndex::with_quant(16, 6, 6, quant);
            let mut qflat = QuantizedFlatIndex::new(16, quant);
            for (i, v) in vs.iter().enumerate() {
                ivf.add(i as u64, v);
                qflat.add(i as u64, v);
            }
            ivf.build(33);
            let mut rng = Pcg::new(35);
            for _ in 0..8 {
                let q = unit(&mut rng, 16);
                let mut a: Vec<u32> =
                    ivf.search(&q, 7).iter().map(|h| h.score.to_bits()).collect();
                let mut b: Vec<u32> =
                    qflat.search(&q, 7).iter().map(|h| h.score.to_bits()).collect();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "{quant:?}");
            }
        }
    }

    /// The streaming-IVF hook: a skewed post-build stream trips the
    /// rebalance, the retrained lists are no more skewed than the stale
    /// ones, and every row (old and new) stays searchable.
    #[test]
    fn ingest_rebalance_evens_skewed_lists() {
        let vs = corpus(128, 8, 41);
        let mut rng = Pcg::new(99);
        let mut mk = || {
            let mut ivf = IvfIndex::new(8, 8, 2);
            for (i, v) in vs.iter().enumerate() {
                ivf.add(i as u64, v);
            }
            ivf.build(7);
            ivf
        };
        // A hot-spot stream: distinct vectors in a tight cap around one
        // direction, so the stale centroids funnel the whole burst into
        // one or two lists.
        let hot = vs[3].clone();
        let burst: Vec<(u64, Vec<f32>)> = (0..256u64)
            .map(|i| {
                let mut v: Vec<f32> =
                    hot.iter().map(|x| x + 0.05 * rng.normal() as f32).collect();
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
                v.iter_mut().for_each(|x| *x /= n);
                (1000 + i, v)
            })
            .collect();
        let refs: Vec<(u64, &[f32])> = burst.iter().map(|(i, v)| (*i, v.as_slice())).collect();

        // Reference run without the hook: measure the skew the burst
        // leaves behind under stale centroids.
        let mut stale = mk();
        stale.add_batch(&refs);
        let skew_before = stale.skew();
        assert!(skew_before > 2.0, "burst must actually skew: {skew_before}");
        assert_eq!(stale.rebalances(), 0);

        // Hook enabled: the same batch trips an in-place retrain.
        let mut ivf = mk().with_rebalance_threshold(2.0);
        ivf.add_batch(&refs);
        assert!(ivf.rebalances() >= 1, "skewed burst must trip the hook");
        assert_eq!(ivf.len(), 128 + 256);
        assert_eq!(ivf.list_sizes().iter().sum::<usize>(), 128 + 256);
        // Retraining over the full corpus (burst included) can only even
        // the lists out relative to the stale assignment.
        assert!(
            ivf.skew() <= skew_before + 1e-9,
            "rebalance made skew worse: {} vs {}",
            ivf.skew(),
            skew_before
        );
        // Old and new rows both remain retrievable.
        for (id, v) in burst.iter().step_by(64) {
            assert_eq!(ivf.search(v, 1)[0].id, *id);
        }
        for (i, v) in vs.iter().enumerate().take(8) {
            assert!(ivf.search(v, 1)[0].score > 0.99, "row {i} lost");
        }
    }

    /// Review regression: when the data is so clustered that a retrain
    /// cannot bring skew under the threshold, the hook must not re-run
    /// a full retrain on every subsequent commit — the bar rises to the
    /// achieved skew and further batches append without retraining.
    #[test]
    fn ingest_rebalance_backs_off_on_irreducible_skew() {
        // 8 identical base rows + one distinct, nlist 4: duplicates all
        // share one cell no matter how the quantizer is trained, so
        // max/mean skew stays well above 1.2 forever.
        let mut ivf = IvfIndex::new(4, 4, 4).with_rebalance_threshold(1.2);
        let dup = [0.6f32, 0.8, 0.0, 0.0];
        for i in 0..8u64 {
            ivf.add(i, &dup);
        }
        ivf.add(8, &[0.0, 0.0, 1.0, 0.0]);
        ivf.build(3);
        assert!(ivf.skew() > 1.2);
        let batch: Vec<(u64, Vec<f32>)> =
            (100..108u64).map(|i| (i, dup.to_vec())).collect();
        for round in 0..5 {
            let refs: Vec<(u64, &[f32])> =
                batch.iter().map(|(i, v)| (*i + round, v.as_slice())).collect();
            ivf.add_batch(&refs);
        }
        // One retrain fired, discovered the skew is irreducible, and
        // the remaining four commits appended without retraining.
        assert_eq!(ivf.rebalances(), 1, "hysteresis must suppress repeat retrains");
        assert_eq!(ivf.len(), 9 + 40);
        assert_eq!(ivf.list_sizes().iter().sum::<usize>(), 49);
    }

    /// Rebalance is deterministic per seed and a no-op before build.
    #[test]
    fn ingest_rebalance_is_deterministic_and_prebuild_noop() {
        let vs = corpus(96, 12, 43);
        let mk = || {
            let mut ivf = IvfIndex::new(12, 6, 6);
            for (i, v) in vs.iter().enumerate() {
                ivf.add(i as u64, v);
            }
            ivf
        };
        // Pre-build: nothing happens.
        let mut unbuilt = mk();
        unbuilt.rebalance(9);
        assert!(!unbuilt.is_built());
        assert_eq!(unbuilt.rebalances(), 0);
        // Built twice with the same seed sequence: identical lists and
        // identical full-probe results.
        let mut a = mk();
        let mut b = mk();
        a.build(5);
        b.build(5);
        a.rebalance(9);
        b.rebalance(9);
        assert_eq!(a.list_sizes(), b.list_sizes());
        let q = &vs[11];
        assert_eq!(a.search(q, 5), b.search(q, 5));
        // Full probe still equals the exact scan after a rebalance.
        let mut flat = FlatIndex::new(12);
        for (i, v) in vs.iter().enumerate() {
            flat.add(i as u64, v);
        }
        let want: Vec<u64> = flat.search(q, 5).into_iter().map(|h| h.id).collect();
        let got: Vec<u64> = a.search(q, 5).into_iter().map(|h| h.id).collect();
        assert_eq!(got, want);
    }

    /// PQ lists: build trains the codebook on the full corpus (below the
    /// staging threshold, under the build seed), batch search is
    /// bit-identical to per-query search, and post-build adds encode
    /// against the frozen codebook and stay searchable.
    #[test]
    fn pq_lists_batch_matches_single_and_accept_adds() {
        let vs = corpus(300, 24, 61);
        for quant in [Quant::pq(4), Quant::pq(8)] {
            let mut ivf = IvfIndex::with_quant(24, 8, 3, quant);
            for (i, v) in vs.iter().enumerate() {
                ivf.add(i as u64, v);
            }
            ivf.build(17);
            assert_eq!(ivf.quant(), quant.resolved(24));
            assert_eq!(ivf.list_sizes().iter().sum::<usize>(), 300);
            let mut rng = Pcg::new(63);
            let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(&mut rng, 24)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = ivf.search_batch(&qrefs, 5);
            for (q, got) in queries.iter().zip(&batch) {
                assert_eq!(got, &ivf.search(q, 5), "{quant:?}");
            }
            // Post-build add encodes against the frozen book.
            let late = vs[0].clone();
            ivf.add(999, &late);
            let hits = ivf.search(&late, 2);
            assert!(hits.iter().any(|h| h.id == 999), "{quant:?}");
            // Tombstone + compact keeps survivors byte-identical.
            ivf.remove(7);
            let before = ivf.search(&queries[0], 5);
            ivf.compact();
            assert_eq!(ivf.search(&queries[0], 5), before, "{quant:?}");
        }
    }

    #[test]
    fn quantized_post_build_adds_are_searchable() {
        let vs = corpus(64, 8, 36);
        let mut ivf = IvfIndex::with_quant(8, 4, 4, Quant::Int8);
        for (i, v) in vs.iter().enumerate() {
            ivf.add(i as u64, v);
        }
        ivf.build(7);
        let late = vs[0].clone();
        ivf.add(999, &late);
        let hits = ivf.search(&late, 2);
        assert!(hits.iter().any(|h| h.id == 999));
    }
}
