//! Lloyd's k-means over unit vectors (the IVF coarse quantizer).
//!
//! The assignment step — the O(n·k·dim) hot loop of `build` — scores
//! point blocks against the whole centroid matrix through the SIMD panel
//! kernel instead of one scalar dot per (point, centroid) pair.

use crate::util::rng::Pcg;

use super::quant::RowArena;
use super::{dot, kernels};

/// Points scored per panel-kernel call during assignment.
const ASSIGN_BLOCK: usize = 64;

/// Train `k` centroids on row-major `data [n, dim]` with `iters` Lloyd
/// rounds. Returns row-major centroids `[k, dim]`. k-means++ seeding.
pub fn train(data: &[f32], dim: usize, k: usize, iters: usize, seed: u64) -> Vec<f32> {
    let n = data.len() / dim;
    assert!(n >= k && k >= 1, "need at least k={k} points, have {n}");
    let mut rng = Pcg::new(seed);

    // k-means++ seeding: first uniform, then distance-weighted.
    let mut centroids = Vec::with_capacity(k * dim);
    let first = rng.usize(0, n);
    centroids.extend_from_slice(&data[first * dim..(first + 1) * dim]);
    let mut d2: Vec<f64> = (0..n)
        .map(|i| sq_dist(&data[i * dim..(i + 1) * dim], &centroids[0..dim]))
        .collect();
    for _ in 1..k {
        let total: f64 = d2.iter().sum();
        let mut target = rng.f64() * total.max(1e-12);
        let mut pick = n - 1;
        for (i, &w) in d2.iter().enumerate() {
            if target <= w {
                pick = i;
                break;
            }
            target -= w;
        }
        let start = centroids.len();
        centroids.extend_from_slice(&data[pick * dim..(pick + 1) * dim]);
        let c = centroids[start..start + dim].to_vec();
        for i in 0..n {
            let d = sq_dist(&data[i * dim..(i + 1) * dim], &c);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    let mut assign = vec![0usize; n];
    let mut scores = vec![0.0f32; ASSIGN_BLOCK * k];
    for _ in 0..iters {
        // Assign: block of points × all centroids per panel-kernel call.
        let mut moved = false;
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + ASSIGN_BLOCK).min(n);
            let np = i1 - i0;
            kernels::panel_scores_into(
                &data[i0 * dim..i1 * dim],
                np,
                &centroids,
                k,
                dim,
                &mut scores[..np * k],
            );
            for p in 0..np {
                let row = &scores[p * k..(p + 1) * k];
                let mut best = (0usize, f32::MIN);
                for (c, &s) in row.iter().enumerate() {
                    if s > best.1 {
                        best = (c, s);
                    }
                }
                if assign[i0 + p] != best.0 {
                    assign[i0 + p] = best.0;
                    moved = true;
                }
            }
            i0 = i1;
        }
        // Update.
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for j in 0..dim {
                sums[c * dim + j] += data[i * dim + j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed empty cluster at a random point.
                let p = rng.usize(0, n);
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&data[p * dim..(p + 1) * dim]);
                continue;
            }
            for j in 0..dim {
                centroids[c * dim + j] = (sums[c * dim + j] / counts[c] as f64) as f32;
            }
        }
        if !moved {
            break;
        }
    }
    centroids
}

/// Train `k` centroids minimizing **L2** distortion (classic Lloyd) —
/// the objective PQ sub-quantizers need, where sub-vectors are not unit
/// vectors and max-inner-product assignment would collapse onto the
/// longest centroid. Same k-means++ seeding and seed discipline as
/// [`train`]; assignment still runs through the SIMD panel kernel,
/// corrected per centroid by its half squared norm
/// (`argmin ‖x − c‖² == argmax x·c − ½‖c‖²`).
pub fn train_l2(data: &[f32], dim: usize, k: usize, iters: usize, seed: u64) -> Vec<f32> {
    let n = data.len() / dim;
    assert!(n >= k && k >= 1, "need at least k={k} points, have {n}");
    let mut rng = Pcg::new(seed);

    // k-means++ seeding (already L2-weighted), as in `train`.
    let mut centroids = Vec::with_capacity(k * dim);
    let first = rng.usize(0, n);
    centroids.extend_from_slice(&data[first * dim..(first + 1) * dim]);
    let mut d2: Vec<f64> = (0..n)
        .map(|i| sq_dist(&data[i * dim..(i + 1) * dim], &centroids[0..dim]))
        .collect();
    for _ in 1..k {
        let total: f64 = d2.iter().sum();
        let mut target = rng.f64() * total.max(1e-12);
        let mut pick = n - 1;
        for (i, &w) in d2.iter().enumerate() {
            if target <= w {
                pick = i;
                break;
            }
            target -= w;
        }
        let start = centroids.len();
        centroids.extend_from_slice(&data[pick * dim..(pick + 1) * dim]);
        let c = centroids[start..start + dim].to_vec();
        for i in 0..n {
            let d = sq_dist(&data[i * dim..(i + 1) * dim], &c);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    let mut assign = vec![0usize; n];
    let mut scores = vec![0.0f32; ASSIGN_BLOCK * k];
    let mut half_norm = vec![0.0f32; k];
    for _ in 0..iters {
        for c in 0..k {
            let row = &centroids[c * dim..(c + 1) * dim];
            half_norm[c] = 0.5 * row.iter().map(|x| x * x).sum::<f32>();
        }
        let mut moved = false;
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + ASSIGN_BLOCK).min(n);
            let np = i1 - i0;
            kernels::panel_scores_into(
                &data[i0 * dim..i1 * dim],
                np,
                &centroids,
                k,
                dim,
                &mut scores[..np * k],
            );
            for p in 0..np {
                let row = &scores[p * k..(p + 1) * k];
                let mut best = (0usize, f32::MIN);
                for (c, &s) in row.iter().enumerate() {
                    let adj = s - half_norm[c];
                    if adj > best.1 {
                        best = (c, adj);
                    }
                }
                if assign[i0 + p] != best.0 {
                    assign[i0 + p] = best.0;
                    moved = true;
                }
            }
            i0 = i1;
        }
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for j in 0..dim {
                sums[c * dim + j] += data[i * dim + j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                let p = rng.usize(0, n);
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&data[p * dim..(p + 1) * dim]);
                continue;
            }
            for j in 0..dim {
                centroids[c * dim + j] = (sums[c * dim + j] / counts[c] as f64) as f32;
            }
        }
        if !moved {
            break;
        }
    }
    centroids
}

/// Assign every arena row to its highest-scoring centroid (first wins on
/// ties, matching [`nearest`]). Blocks of rows are scored against the
/// whole centroid matrix through the arena's quant-aware panel kernel, so
/// the assignment sees exactly the (possibly quantized) representation
/// search-time scans will score — for an f32 arena this is bit-identical
/// to per-row [`nearest`].
pub fn assign_arena(arena: &RowArena, dim: usize, centroids: &[f32], assign: &mut [usize]) {
    let k = centroids.len() / dim;
    let n = arena.rows(dim);
    assert_eq!(assign.len(), n, "assignment buffer size mismatch");
    assert!(k >= 1, "need at least one centroid");
    let mut scores = vec![0.0f32; k * ASSIGN_BLOCK];
    // One ADC table for the whole pass when the arena is PQ-trained
    // (no-op context otherwise) — never rebuilt per block.
    let ctx = arena.begin_panel(centroids, k, dim);
    let mut r0 = 0;
    while r0 < n {
        let r1 = (r0 + ASSIGN_BLOCK).min(n);
        let nr = r1 - r0;
        // Centroids are the query panel here: out[c * nr + r].
        arena.panel_scores_ctx_into(&ctx, centroids, k, r0, r1, dim, &mut scores[..k * nr]);
        for r in 0..nr {
            let mut best = (0usize, f32::MIN);
            for c in 0..k {
                let s = scores[c * nr + r];
                if s > best.1 {
                    best = (c, s);
                }
            }
            assign[r0 + r] = best.0;
        }
        r0 = r1;
    }
}

/// Index and (inner-product) score of the nearest centroid.
pub fn nearest(v: &[f32], centroids: &[f32], dim: usize) -> (usize, f32) {
    let k = centroids.len() / dim;
    let mut best = (0usize, f32::MIN);
    for c in 0..k {
        let s = dot(v, &centroids[c * dim..(c + 1) * dim]);
        if s > best.1 {
            best = (c, s);
        }
    }
    best
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs → k-means must find one centroid each.
    #[test]
    fn separates_blobs() {
        let mut rng = Pcg::new(3);
        let dim = 8;
        let mut data = Vec::new();
        let anchors: [f32; 3] = [0.0, 10.0, -10.0];
        for &a in &anchors {
            for _ in 0..30 {
                for j in 0..dim {
                    data.push(a + 0.1 * rng.normal() as f32 + j as f32 * 0.01);
                }
            }
        }
        let cents = train(&data, dim, 3, 20, 1);
        // Each blob's anchor should be near exactly one centroid.
        let mut used = [false; 3];
        for &a in &anchors {
            let probe: Vec<f32> = (0..dim).map(|j| a + j as f32 * 0.01).collect();
            let (c, _) = {
                // nearest by euclidean here
                let mut best = (0usize, f64::MAX);
                for ci in 0..3 {
                    let d = sq_dist(&probe, &cents[ci * dim..(ci + 1) * dim]);
                    if d < best.1 {
                        best = (ci, d);
                    }
                }
                best
            };
            assert!(!used[c], "two blobs mapped to centroid {c}");
            used[c] = true;
        }
    }

    #[test]
    fn handles_k_equals_n() {
        let data = vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.0];
        let cents = train(&data, 2, 3, 5, 2);
        assert_eq!(cents.len(), 6);
    }

    #[test]
    fn assign_arena_matches_nearest_on_f32() {
        let mut rng = Pcg::new(5);
        let dim = 12;
        let data: Vec<f32> = (0..40 * dim).map(|_| rng.normal() as f32).collect();
        let cents = train(&data, dim, 4, 10, 6);
        let mut arena = RowArena::new(crate::vecstore::Quant::F32);
        for r in 0..40 {
            arena.push(&data[r * dim..(r + 1) * dim]);
        }
        let mut assign = vec![0usize; 40];
        assign_arena(&arena, dim, &cents, &mut assign);
        for r in 0..40 {
            let (c, _) = nearest(&data[r * dim..(r + 1) * dim], &cents, dim);
            assert_eq!(assign[r], c, "row {r}");
        }
    }

    #[test]
    fn assign_arena_quantized_buckets_every_row() {
        let mut rng = Pcg::new(6);
        let dim = 8;
        let data: Vec<f32> = (0..30 * dim).map(|_| rng.normal() as f32).collect();
        let cents = train(&data, dim, 3, 10, 7);
        for quant in [crate::vecstore::Quant::F16, crate::vecstore::Quant::Int8] {
            let mut arena = RowArena::new(quant);
            for r in 0..30 {
                arena.push(&data[r * dim..(r + 1) * dim]);
            }
            let mut assign = vec![usize::MAX; 30];
            assign_arena(&arena, dim, &cents, &mut assign);
            assert!(assign.iter().all(|&c| c < 3), "{quant:?}: {assign:?}");
        }
    }

    /// Max-dot assignment collapses non-unit blobs onto the longest
    /// centroid; the L2 variant must keep them apart.
    #[test]
    fn train_l2_separates_blobs_by_distance_not_norm() {
        let mut rng = Pcg::new(8);
        let dim = 4;
        let mut data = Vec::new();
        // Two blobs on the same ray: max-dot cannot tell them apart,
        // L2 must. Blob A near 1.0, blob B near 6.0 (same direction).
        for &a in &[1.0f32, 6.0] {
            for _ in 0..25 {
                for _ in 0..dim {
                    data.push(a + 0.05 * rng.normal() as f32);
                }
            }
        }
        let cents = train_l2(&data, dim, 2, 20, 1);
        let mut means: Vec<f32> =
            cents.chunks(dim).map(|c| c.iter().sum::<f32>() / dim as f32).collect();
        means.sort_by(f32::total_cmp);
        assert!((means[0] - 1.0).abs() < 0.3, "low blob centroid at {}", means[0]);
        assert!((means[1] - 6.0).abs() < 0.3, "high blob centroid at {}", means[1]);
    }

    #[test]
    fn train_l2_deterministic_per_seed() {
        let mut rng = Pcg::new(10);
        let data: Vec<f32> = (0..60 * 4).map(|_| rng.normal() as f32).collect();
        let a = train_l2(&data, 4, 5, 10, 9);
        let b = train_l2(&data, 4, 5, 10, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Pcg::new(4);
        let data: Vec<f32> = (0..50 * 4).map(|_| rng.normal() as f32).collect();
        let a = train(&data, 4, 5, 10, 9);
        let b = train(&data, 4, 5, 10, 9);
        assert_eq!(a, b);
    }
}
