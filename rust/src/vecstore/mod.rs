//! Vector store substrate — the retrieval half of the paper's Figure 1
//! RAG workflow ("external database" the embeddings are matched against).
//!
//! Two indexes over unit-norm embeddings:
//! * [`FlatIndex`] — exact brute-force inner-product search.
//! * [`IvfIndex`] — IVF-Flat: k-means coarse quantizer + inverted lists,
//!   probing `nprobe` nearest cells. The standard recall/latency trade.
//!
//! Both can store rows quantized ([`quant`]): [`QuantizedFlatIndex`]
//! (and `IvfIndex::with_quant`) keep f16, per-row-scaled int8, or
//! product-quantized ([`pq`]) arenas that the kernels decode in
//! registers (PQ scans via a per-panel ADC lookup table), cutting scan
//! bandwidth 2× / 4× / up to 64× at a bounded score error (PQ trades a
//! property-tested recall floor instead).
//!
//! Scoring runs on the runtime-dispatched SIMD kernels in [`kernels`];
//! both indexes expose a batched [`Index::search_batch`] that shards the
//! scan across scoped threads and merges per-shard top-k, which is what
//! the serving path uses to absorb concurrent retrieval bursts.

pub mod flat;
pub mod ivf;
pub mod kernels;
pub mod kmeans;
pub mod mask;
pub mod numa;
pub mod persist;
pub mod pq;
pub mod qflat;
pub mod quant;

pub use flat::FlatIndex;
pub use ivf::IvfIndex;
pub use mask::SkipMask;
pub use qflat::QuantizedFlatIndex;
pub use quant::Quant;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scored search hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f32,
}

/// Common index interface.
pub trait Index {
    /// Add a vector under `id`. Vectors should be unit-norm (the engine's
    /// output already is); scores are inner products.
    fn add(&mut self, id: u64, vector: &[f32]);
    /// Append a batch of rows in one call — the streaming-ingest commit
    /// unit. The default is the per-row loop; implementations with
    /// post-append maintenance (e.g. [`IvfIndex`]'s online list
    /// rebalance) override to run it once per batch instead of once per
    /// row.
    fn add_batch(&mut self, rows: &[(u64, &[f32])]) {
        for (id, v) in rows {
            self.add(*id, v);
        }
    }
    /// Top-k most similar.
    fn search(&self, query: &[f32], k: usize) -> Vec<Hit>;
    /// Batched top-k: one result list per query, each identical (ids,
    /// order, scores) to what per-query [`Index::search`] returns.
    /// Implementations override this to amortize the scan across the
    /// query panel and shard it over threads; the default is the naive
    /// per-query loop.
    fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        queries.iter().map(|q| self.search(q, k)).collect()
    }
    /// Live (non-tombstoned) rows. Physical arena rows are
    /// `len() + tombstones()`.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn dim(&self) -> usize;
    /// Tombstone every live row stored under `id` (see [`SkipMask`]):
    /// the arena keeps the bytes, scans skip the rows at one bit test
    /// each, and global row indices — the deterministic tie-break
    /// sequence — are untouched. Returns the number of rows killed (0
    /// when the id is absent, already dead, or the implementation does
    /// not support deletes — the default).
    fn remove(&mut self, id: u64) -> usize {
        let _ = id;
        0
    }
    /// Replace: tombstone any live rows under `id`, then append the new
    /// vector. Returns the rows tombstoned (0 ⇒ plain insert).
    fn upsert(&mut self, id: u64, vector: &[f32]) -> usize {
        let dead = self.remove(id);
        self.add(id, vector);
        dead
    }
    /// Rows currently tombstoned (masked out of scans but still in the
    /// arena). The compaction-trigger statistic.
    fn tombstones(&self) -> usize {
        0
    }
    /// Rewrite the arena(s) dropping tombstoned rows, preserving the
    /// relative order of live rows (so tie-breaking among survivors is
    /// unchanged — see `durability` module docs). Returns rows
    /// reclaimed. Default: nothing to do.
    fn compact(&mut self) -> usize {
        0
    }
    /// Serialize the index (live rows only — tombstones are dropped, as
    /// a compaction would) into a self-describing snapshot payload that
    /// [`persist::decode_index`] restores bit-identically. `None` when
    /// the implementation has no snapshot codec.
    fn snapshot_bytes(&self) -> Option<Vec<u8>> {
        None
    }
    /// Storage codec of the index's row arena. [`Quant::F32`] unless the
    /// implementation scans a quantized arena.
    fn quant(&self) -> Quant {
        Quant::F32
    }
    /// Rows one `search`/`search_batch` call actually streams — the
    /// admission cost driver (see `coordinator::queue_manager`). Exact
    /// for exhaustive scans (the default); pruning indexes override with
    /// their expected probe coverage (e.g. IVF's nprobe/nlist share).
    fn scan_rows_estimate(&self) -> usize {
        self.len()
    }
    /// Dense `(ids, row-major f32 rows)` snapshot of the arena for a
    /// device-side mirror (the NPU retrieval offload leg). `Some` only
    /// when an exhaustive scan of the exported rows with the f32 panel
    /// kernels is **bit-identical** to this index's own scan: exact f32
    /// storage, insertion order preserved, no probe pruning. Quantized
    /// arenas (int8 applies its row scale post-sum, so a dequantized
    /// export would accumulate in a different FP order) and IVF (probes a
    /// subset) return `None` — the service then keeps those scans on the
    /// CPU leg.
    fn export_f32_rows(&self) -> Option<(Vec<u64>, Vec<f32>)> {
        None
    }
    /// Opt the index into NUMA-aware scan sharding under `topo`: the
    /// arena is rewritten through per-node pinned first-touch copies
    /// (see [`numa`]) and batched scans shard along node bands with
    /// each shard's thread pinned to its owning node. `None` reverts to
    /// plain sharding. Results are **bit-identical** either way —
    /// placement moves bytes, never scores. Returns `false` (the
    /// default) when the implementation does not support it.
    fn set_numa(&mut self, topo: Option<crate::devices::affinity::Topology>) -> bool {
        let _ = topo;
        false
    }
}

/// Inner product on the dispatched kernel (see [`kernels`]).
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    kernels::dot(a, b)
}

/// One retained candidate: score plus the insertion sequence number that
/// makes tie-breaking deterministic (first-inserted wins).
#[derive(Debug, Clone, Copy)]
struct Entry {
    score: f32,
    seq: u64,
    id: u64,
}

impl Entry {
    /// Ranking order: `Greater` means a better hit. Higher score first;
    /// equal scores rank the earlier-inserted entry higher, so results
    /// are stable across kernel variants and shard merge order.
    fn rank_cmp(&self, other: &Entry) -> Ordering {
        self.score
            .total_cmp(&other.score)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Max-heap wrapper whose maximum is the *worst-ranked* entry, so the
/// heap root is the eviction candidate.
#[derive(Debug, Clone, Copy)]
struct Worst(Entry);

impl PartialEq for Worst {
    fn eq(&self, other: &Self) -> bool {
        self.0.rank_cmp(&other.0) == Ordering::Equal
    }
}
impl Eq for Worst {}
impl PartialOrd for Worst {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Worst {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.rank_cmp(&self.0)
    }
}

/// Keep the top-k (id, score) pairs with a bounded binary heap: O(log k)
/// per displacing push, O(1) rejection of sub-threshold candidates, and
/// no per-push `Vec::insert` shifting — ordering is produced once, in
/// [`TopK::into_vec`]. Ties on score keep the first-inserted entry.
pub(crate) struct TopK {
    k: usize,
    seq: u64,
    heap: BinaryHeap<Worst>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK {
            k,
            seq: 0,
            heap: BinaryHeap::with_capacity(k.min(1 << 20)),
        }
    }

    /// Push with an auto-incremented sequence number (single-scan use;
    /// do not mix with [`TopK::push_with_seq`] on the same instance).
    pub fn push(&mut self, id: u64, score: f32) {
        let seq = self.seq;
        self.seq += 1;
        self.push_with_seq(id, score, seq);
    }

    /// Push with an explicit sequence number — sharded scans pass the
    /// global row position so a cross-shard merge ranks ties exactly as
    /// a sequential scan would.
    pub fn push_with_seq(&mut self, id: u64, score: f32, seq: u64) {
        if self.k == 0 {
            return;
        }
        let e = Entry { score, seq, id };
        if self.heap.len() < self.k {
            self.heap.push(Worst(e));
            return;
        }
        if let Some(mut worst) = self.heap.peek_mut() {
            if e.rank_cmp(&worst.0) == Ordering::Greater {
                *worst = Worst(e);
            }
        }
    }

    /// Fold another TopK (e.g. one shard's survivors) into this one,
    /// preserving the entries' original sequence numbers.
    pub fn merge_from(&mut self, other: TopK) {
        for Worst(e) in other.heap {
            self.push_with_seq(e.id, e.score, e.seq);
        }
    }

    /// Best-first (score desc, insertion order asc on ties).
    pub fn into_vec(self) -> Vec<Hit> {
        let mut entries: Vec<Entry> = self.heap.into_iter().map(|w| w.0).collect();
        entries.sort_by(|a, b| b.rank_cmp(a));
        entries
            .into_iter()
            .map(|e| Hit { id: e.id, score: e.score })
            .collect()
    }
}

/// Shared scaffolding for sharded scans: run `scan(shard, &mut topks)`
/// on `threads` scoped threads — each shard filling one TopK per query —
/// then merge the per-shard survivors into one TopK per query. Shards
/// must push with explicit global sequence numbers so the merge is
/// order-independent (see [`TopK::push_with_seq`]).
pub(crate) fn parallel_topk_scan<F>(threads: usize, nq: usize, k: usize, scan: F) -> Vec<TopK>
where
    F: Fn(usize, &mut [TopK]) + Sync,
{
    let per_shard: Vec<Vec<TopK>> = std::thread::scope(|s| {
        let scan = &scan;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                s.spawn(move || {
                    let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
                    scan(t, &mut tks);
                    tks
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scan shard panicked"))
            .collect()
    });
    let mut finals: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
    for shard in per_shard {
        for (qi, tk) in shard.into_iter().enumerate() {
            finals[qi].merge_from(tk);
        }
    }
    finals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * 0.2).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn topk_keeps_best_sorted() {
        let mut tk = TopK::new(3);
        for (id, s) in [(1, 0.5), (2, 0.9), (3, 0.1), (4, 0.7), (5, 0.8)] {
            tk.push(id, s);
        }
        let hits = tk.into_vec();
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![2, 5, 4]);
        assert!(hits.windows(2).all(|w| w[0].score >= w[1].score));
    }

    #[test]
    fn topk_fewer_than_k() {
        let mut tk = TopK::new(10);
        tk.push(1, 0.3);
        assert_eq!(tk.into_vec().len(), 1);
    }

    #[test]
    fn topk_zero_k_accepts_nothing() {
        let mut tk = TopK::new(0);
        tk.push(1, 0.9);
        assert!(tk.into_vec().is_empty());
    }

    /// Regression: equal scores must keep first-inserted order, both in
    /// the retained set and in the output ordering.
    #[test]
    fn topk_equal_scores_keep_first_inserted() {
        // All ties: later equal pushes must not displace earlier ones.
        let mut tk = TopK::new(2);
        for id in [10, 11, 12, 13] {
            tk.push(id, 0.5);
        }
        let ids: Vec<u64> = tk.into_vec().iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![10, 11]);

        // Mixed: a tie with the current worst is rejected, and output
        // orders equal scores by insertion.
        let mut tk = TopK::new(3);
        for (id, s) in [(1, 0.5), (2, 0.9), (3, 0.5), (4, 0.5), (5, 0.7)] {
            tk.push(id, s);
        }
        let hits = tk.into_vec();
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![2, 5, 1]);
    }

    /// Cross-shard merge with explicit sequence numbers must equal the
    /// sequential scan's result, whatever the merge order.
    #[test]
    fn topk_sharded_merge_equals_sequential() {
        let scores: Vec<f32> = (0..40)
            .map(|i| ((i * 7919) % 13) as f32 / 13.0) // plenty of ties
            .collect();
        let mut seq_tk = TopK::new(5);
        for (i, &s) in scores.iter().enumerate() {
            seq_tk.push(i as u64, s);
        }
        let want = seq_tk.into_vec();

        // Shard into 3 ranges, merge in reverse order.
        let mut merged = TopK::new(5);
        for range in [&scores[27..40], &scores[13..27], &scores[0..13]] {
            let base = range.as_ptr() as usize - scores.as_ptr() as usize;
            let base = base / std::mem::size_of::<f32>();
            let mut shard = TopK::new(5);
            for (i, &s) in range.iter().enumerate() {
                shard.push_with_seq((base + i) as u64, s, (base + i) as u64);
            }
            merged.merge_from(shard);
        }
        assert_eq!(merged.into_vec(), want);
    }
}
