//! Vector store substrate — the retrieval half of the paper's Figure 1
//! RAG workflow ("external database" the embeddings are matched against).
//!
//! Two indexes over unit-norm embeddings:
//! * [`FlatIndex`] — exact brute-force inner-product search.
//! * [`IvfIndex`] — IVF-Flat: k-means coarse quantizer + inverted lists,
//!   probing `nprobe` nearest cells. The standard recall/latency trade.

pub mod flat;
pub mod ivf;
pub mod kmeans;

pub use flat::FlatIndex;
pub use ivf::IvfIndex;

/// A scored search hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f32,
}

/// Common index interface.
pub trait Index {
    /// Add a vector under `id`. Vectors should be unit-norm (the engine's
    /// output already is); scores are inner products.
    fn add(&mut self, id: u64, vector: &[f32]);
    /// Top-k most similar.
    fn search(&self, query: &[f32], k: usize) -> Vec<Hit>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn dim(&self) -> usize;
}

pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolled dot product — the hot loop of retrieval.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Keep the top-k (id, score) pairs with a bounded insertion sort —
/// cheaper than a heap for the small k retrieval uses.
pub(crate) struct TopK {
    k: usize,
    hits: Vec<Hit>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK { k, hits: Vec::with_capacity(k + 1) }
    }

    pub fn push(&mut self, id: u64, score: f32) {
        if self.hits.len() == self.k
            && score <= self.hits.last().map(|h| h.score).unwrap_or(f32::MIN)
        {
            return;
        }
        let pos = self
            .hits
            .iter()
            .position(|h| h.score < score)
            .unwrap_or(self.hits.len());
        self.hits.insert(pos, Hit { id, score });
        self.hits.truncate(self.k);
    }

    pub fn into_vec(self) -> Vec<Hit> {
        self.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * 0.2).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn topk_keeps_best_sorted() {
        let mut tk = TopK::new(3);
        for (id, s) in [(1, 0.5), (2, 0.9), (3, 0.1), (4, 0.7), (5, 0.8)] {
            tk.push(id, s);
        }
        let hits = tk.into_vec();
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![2, 5, 4]);
        assert!(hits.windows(2).all(|w| w[0].score >= w[1].score));
    }

    #[test]
    fn topk_fewer_than_k() {
        let mut tk = TopK::new(10);
        tk.push(1, 0.3);
        assert_eq!(tk.into_vec().len(), 1);
    }
}
