//! Scan-time skip mask: the tombstone representation shared by the
//! `RowArena`-backed indexes.
//!
//! Deleting a row from a contiguous arena would either shift every later
//! row (invalidating the global row indices the deterministic top-k
//! merge keys on) or punch a hole the kernels would have to skip
//! mid-panel. Instead a delete *tombstones* the row: the arena keeps the
//! bytes, scans keep their block shape and global sequence numbers, and
//! the only extra cost is one bit test per row when deciding whether to
//! push a score into the top-k. Reclaiming the bytes is a separate,
//! amortized `compact()` (see `crate::durability`).

/// Bitset over physical row indices; set bit = tombstoned (dead) row.
#[derive(Debug, Default, Clone)]
pub struct SkipMask {
    words: Vec<u64>,
    dead: usize,
}

impl SkipMask {
    pub fn new() -> SkipMask {
        SkipMask::default()
    }

    /// Number of tombstoned rows.
    pub fn dead(&self) -> usize {
        self.dead
    }

    /// True when no row is tombstoned (scans can skip the bit tests).
    pub fn is_clear(&self) -> bool {
        self.dead == 0
    }

    /// Whether physical row `row` is tombstoned. Rows past the mask's
    /// high-water mark (appended after the last kill) are live.
    #[inline]
    pub fn is_dead(&self, row: usize) -> bool {
        match self.words.get(row >> 6) {
            Some(w) => (w >> (row & 63)) & 1 == 1,
            None => false,
        }
    }

    /// Tombstone physical row `row`. Returns true if the row was live
    /// (idempotent: a second kill of the same row is a no-op).
    pub fn kill(&mut self, row: usize) -> bool {
        let word = row >> 6;
        if word >= self.words.len() {
            self.words.resize(word + 1, 0);
        }
        let bit = 1u64 << (row & 63);
        if self.words[word] & bit != 0 {
            return false;
        }
        self.words[word] |= bit;
        self.dead += 1;
        true
    }

    /// Drop every tombstone (after a compaction rewrote the arena).
    pub fn clear(&mut self) {
        self.words.clear();
        self.dead = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_is_idempotent_and_counted() {
        let mut m = SkipMask::new();
        assert!(m.is_clear());
        assert!(!m.is_dead(5));
        assert!(m.kill(5));
        assert!(!m.kill(5));
        assert!(m.is_dead(5));
        assert!(!m.is_dead(4));
        assert_eq!(m.dead(), 1);
        assert!(m.kill(64)); // crosses a word boundary
        assert!(m.is_dead(64));
        assert_eq!(m.dead(), 2);
    }

    #[test]
    fn rows_past_the_mask_are_live() {
        let mut m = SkipMask::new();
        m.kill(3);
        // Appended rows way past the mask's words are live without any
        // resize on the read path.
        assert!(!m.is_dead(1_000_000));
    }

    #[test]
    fn clear_resets_everything() {
        let mut m = SkipMask::new();
        for r in [1usize, 7, 130] {
            m.kill(r);
        }
        assert_eq!(m.dead(), 3);
        m.clear();
        assert!(m.is_clear());
        assert!(!m.is_dead(1));
        assert!(!m.is_dead(130));
    }
}
