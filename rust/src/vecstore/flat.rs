//! Exact brute-force index: contiguous row-major storage, linear scan.

use super::{dot, Hit, Index, TopK};

/// Flat (exact) inner-product index.
pub struct FlatIndex {
    dim: usize,
    ids: Vec<u64>,
    data: Vec<f32>, // row-major [n, dim]
}

impl FlatIndex {
    pub fn new(dim: usize) -> FlatIndex {
        assert!(dim > 0);
        FlatIndex { dim, ids: Vec::new(), data: Vec::new() }
    }

    pub fn vector(&self, row: usize) -> &[f32] {
        &self.data[row * self.dim..(row + 1) * self.dim]
    }
}

impl Index for FlatIndex {
    fn add(&mut self, id: u64, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "dimension mismatch");
        self.ids.push(id);
        self.data.extend_from_slice(vector);
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "dimension mismatch");
        let mut tk = TopK::new(k);
        for (row, &id) in self.ids.iter().enumerate() {
            tk.push(id, dot(query, self.vector(row)));
        }
        tk.into_vec()
    }

    fn len(&self) -> usize {
        self.ids.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    #[test]
    fn finds_itself_first() {
        let mut rng = Pcg::new(1);
        let mut idx = FlatIndex::new(32);
        let mut vs = Vec::new();
        for i in 0..100 {
            let v = unit(&mut rng, 32);
            idx.add(i, &v);
            vs.push(v);
        }
        for (i, v) in vs.iter().enumerate() {
            let hits = idx.search(v, 1);
            assert_eq!(hits[0].id, i as u64);
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn scores_sorted_descending() {
        let mut rng = Pcg::new(2);
        let mut idx = FlatIndex::new(16);
        for i in 0..50 {
            idx.add(i, &unit(&mut rng, 16));
        }
        let hits = idx.search(&unit(&mut rng, 16), 10);
        assert_eq!(hits.len(), 10);
        assert!(hits.windows(2).all(|w| w[0].score >= w[1].score));
    }

    #[test]
    fn k_larger_than_n() {
        let mut idx = FlatIndex::new(4);
        idx.add(7, &[1.0, 0.0, 0.0, 0.0]);
        let hits = idx.search(&[1.0, 0.0, 0.0, 0.0], 5);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 7);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn wrong_dim_panics() {
        let mut idx = FlatIndex::new(4);
        idx.add(1, &[1.0, 2.0]);
    }
}
