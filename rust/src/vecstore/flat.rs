//! Exact brute-force index: contiguous row-major storage, linear scan.
//!
//! Scans run on the dispatched SIMD kernels ([`super::kernels`]); the
//! batched path tiles rows into cache-resident blocks, scores the whole
//! query panel per block, and shards disjoint row ranges across scoped
//! threads with a deterministic per-query top-k merge.

use super::mask::SkipMask;
use super::{kernels, numa, Hit, Index, TopK};
use crate::devices::affinity::{pin_current_thread, Topology};

/// Row tile per kernel call: 64 rows × 768 dims × 4 B ≈ 192 KiB stays
/// L2-resident while the query panel sweeps it.
const SCAN_BLOCK_ROWS: usize = 64;

/// Below this many rows per shard, thread spawn/merge overhead beats the
/// scan itself — stay sequential.
const MIN_ROWS_PER_SHARD: usize = 2048;

/// Flat (exact) inner-product index.
pub struct FlatIndex {
    pub(crate) dim: usize,
    pub(crate) ids: Vec<u64>,
    pub(crate) data: Vec<f32>, // row-major [n, dim]
    /// Tombstoned rows: scanned (the arena is contiguous) but never
    /// pushed into a top-k. See `vecstore::mask`.
    pub(crate) dead: SkipMask,
    /// NUMA plan ([`Index::set_numa`]): when set (and multi-node),
    /// batched scans shard along node bands with pinned threads.
    pub(crate) numa: Option<Topology>,
}

impl FlatIndex {
    pub fn new(dim: usize) -> FlatIndex {
        assert!(dim > 0);
        FlatIndex { dim, ids: Vec::new(), data: Vec::new(), dead: SkipMask::new(), numa: None }
    }

    pub fn vector(&self, row: usize) -> &[f32] {
        &self.data[row * self.dim..(row + 1) * self.dim]
    }

    /// Re-encode the corpus into a [`QuantizedFlatIndex`] under `quant`
    /// (ids and insertion order preserved, so tie-breaking matches). The
    /// f32 original is left untouched — callers drop it to realize the
    /// footprint win.
    pub fn quantize(&self, quant: super::Quant) -> super::QuantizedFlatIndex {
        let mut q = super::QuantizedFlatIndex::new(self.dim, quant);
        for (row, &id) in self.ids.iter().enumerate() {
            if !self.dead.is_dead(row) {
                q.add(id, self.vector(row));
            }
        }
        q
    }

    /// Shard count for a parallel scan over `rows` rows.
    fn auto_shards(rows: usize) -> usize {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        avail.min(rows / MIN_ROWS_PER_SHARD).max(1)
    }

    /// Batched search with an explicit shard count (1 = sequential).
    /// Results are identical to per-query [`Index::search`].
    pub fn search_batch_with_threads(
        &self,
        queries: &[&[f32]],
        k: usize,
        threads: usize,
    ) -> Vec<Vec<Hit>> {
        for q in queries {
            assert_eq!(q.len(), self.dim, "dimension mismatch");
        }
        let nq = queries.len();
        let n = self.ids.len();
        if nq == 0 {
            return Vec::new();
        }
        if n == 0 {
            return vec![Vec::new(); nq];
        }
        // Contiguous query panel for the blocked kernel.
        let mut qbuf = Vec::with_capacity(nq * self.dim);
        for q in queries {
            qbuf.extend_from_slice(q);
        }
        let threads = threads.max(1).min(n);
        if threads == 1 {
            let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
            let mut scores = vec![0.0f32; nq * SCAN_BLOCK_ROWS];
            self.scan_rows(&qbuf, nq, 0, n, &mut tks, &mut scores);
            return tks.into_iter().map(TopK::into_vec).collect();
        }
        // NUMA plan: shard along node bands, pin each shard's thread to
        // the node owning its rows. Shards still push global row seqs,
        // so the merge is bit-identical to the unpinned path below.
        if let Some(topo) = self.numa.as_ref().filter(|t| t.numa_nodes > 1) {
            let shards = numa::band_shards(n, threads, topo);
            let finals = super::parallel_topk_scan(shards.len(), nq, k, |t, tks| {
                let (lo, hi, node) = shards[t];
                let _ = pin_current_thread(&topo.cores_of_node(node));
                let mut scores = vec![0.0f32; nq * SCAN_BLOCK_ROWS];
                self.scan_rows(&qbuf, nq, lo, hi, tks, &mut scores);
            });
            return finals.into_iter().map(TopK::into_vec).collect();
        }
        let rows_per = n / threads + usize::from(n % threads != 0);
        let finals = super::parallel_topk_scan(threads, nq, k, |t, tks| {
            let lo = t * rows_per;
            let hi = ((t + 1) * rows_per).min(n);
            if lo < hi {
                let mut scores = vec![0.0f32; nq * SCAN_BLOCK_ROWS];
                self.scan_rows(&qbuf, nq, lo, hi, tks, &mut scores);
            }
        });
        finals.into_iter().map(TopK::into_vec).collect()
    }

    /// Score rows `[lo, hi)` against the query panel, block by block,
    /// pushing into one TopK per query with the global row index as the
    /// tie-break sequence number. `scores` is caller-provided scratch of
    /// at least `nq * SCAN_BLOCK_ROWS` (so the single-query hot path can
    /// use a stack buffer instead of allocating per search).
    fn scan_rows(
        &self,
        qbuf: &[f32],
        nq: usize,
        lo: usize,
        hi: usize,
        tks: &mut [TopK],
        scores: &mut [f32],
    ) {
        let dim = self.dim;
        debug_assert!(scores.len() >= nq * SCAN_BLOCK_ROWS);
        let mut r0 = lo;
        while r0 < hi {
            let r1 = (r0 + SCAN_BLOCK_ROWS).min(hi);
            let nr = r1 - r0;
            let rows = &self.data[r0 * dim..r1 * dim];
            kernels::panel_scores_into(qbuf, nq, rows, nr, dim, &mut scores[..nq * nr]);
            for (qi, tk) in tks.iter_mut().enumerate() {
                for r in 0..nr {
                    // Tombstone skip: one bit test per row; the global
                    // row index stays the tie-break sequence number.
                    if self.dead.is_dead(r0 + r) {
                        continue;
                    }
                    tk.push_with_seq(self.ids[r0 + r], scores[qi * nr + r], (r0 + r) as u64);
                }
            }
            r0 = r1;
        }
    }
}

impl Index for FlatIndex {
    fn add(&mut self, id: u64, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "dimension mismatch");
        self.ids.push(id);
        self.data.extend_from_slice(vector);
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "dimension mismatch");
        let mut tk = TopK::new(k);
        // Stack scratch: the single-query request path allocates nothing.
        let mut scores = [0.0f32; SCAN_BLOCK_ROWS];
        self.scan_rows(query, 1, 0, self.ids.len(), std::slice::from_mut(&mut tk), &mut scores);
        tk.into_vec()
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        self.search_batch_with_threads(queries, k, Self::auto_shards(self.ids.len()))
    }

    fn len(&self) -> usize {
        self.ids.len() - self.dead.dead()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn remove(&mut self, id: u64) -> usize {
        let mut killed = 0;
        for row in 0..self.ids.len() {
            if self.ids[row] == id && self.dead.kill(row) {
                killed += 1;
            }
        }
        killed
    }

    fn tombstones(&self) -> usize {
        self.dead.dead()
    }

    fn compact(&mut self) -> usize {
        let reclaimed = self.dead.dead();
        if reclaimed == 0 {
            return 0;
        }
        let dim = self.dim;
        let mut ids = Vec::with_capacity(self.ids.len() - reclaimed);
        let mut data = Vec::with_capacity((self.ids.len() - reclaimed) * dim);
        for row in 0..self.ids.len() {
            if !self.dead.is_dead(row) {
                ids.push(self.ids[row]);
                data.extend_from_slice(&self.data[row * dim..(row + 1) * dim]);
            }
        }
        self.ids = ids;
        self.data = data;
        self.dead.clear();
        // Compaction rebuilt the arena on this thread; restore node-local
        // placement when a NUMA plan is active.
        if let Some(t) = self.numa.as_ref().filter(|t| t.numa_nodes > 1) {
            self.data = numa::first_touch_realign(&self.data, dim, t);
        }
        reclaimed
    }

    fn set_numa(&mut self, topo: Option<Topology>) -> bool {
        if let Some(t) = topo.as_ref().filter(|t| t.numa_nodes > 1) {
            self.data = numa::first_touch_realign(&self.data, self.dim, t);
        }
        self.numa = topo;
        true
    }

    fn scan_rows_estimate(&self) -> usize {
        // Tombstoned rows still cross the memory bus — the scan streams
        // the whole arena — so admission charges physical rows.
        self.ids.len()
    }

    fn export_f32_rows(&self) -> Option<(Vec<u64>, Vec<f32>)> {
        // Exact f32 rows in insertion order: a device mirror scanning
        // this snapshot with the same kernels reproduces `search` bit-
        // for-bit (same per-pair scores; ties resolve identically
        // because filtering tombstones preserves the relative order of
        // live rows). Deleted rows are excluded so a mirror can never
        // resurrect them.
        if self.dead.is_clear() {
            return Some((self.ids.clone(), self.data.clone()));
        }
        let live = self.len();
        let mut ids = Vec::with_capacity(live);
        let mut data = Vec::with_capacity(live * self.dim);
        for row in 0..self.ids.len() {
            if !self.dead.is_dead(row) {
                ids.push(self.ids[row]);
                data.extend_from_slice(self.vector(row));
            }
        }
        Some((ids, data))
    }

    fn snapshot_bytes(&self) -> Option<Vec<u8>> {
        Some(super::persist::encode_flat(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn unit(rng: &mut Pcg, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    #[test]
    fn finds_itself_first() {
        let mut rng = Pcg::new(1);
        let mut idx = FlatIndex::new(32);
        let mut vs = Vec::new();
        for i in 0..100 {
            let v = unit(&mut rng, 32);
            idx.add(i, &v);
            vs.push(v);
        }
        for (i, v) in vs.iter().enumerate() {
            let hits = idx.search(v, 1);
            assert_eq!(hits[0].id, i as u64);
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn scores_sorted_descending() {
        let mut rng = Pcg::new(2);
        let mut idx = FlatIndex::new(16);
        for i in 0..50 {
            idx.add(i, &unit(&mut rng, 16));
        }
        let hits = idx.search(&unit(&mut rng, 16), 10);
        assert_eq!(hits.len(), 10);
        assert!(hits.windows(2).all(|w| w[0].score >= w[1].score));
    }

    #[test]
    fn k_larger_than_n() {
        let mut idx = FlatIndex::new(4);
        idx.add(7, &[1.0, 0.0, 0.0, 0.0]);
        let hits = idx.search(&[1.0, 0.0, 0.0, 0.0], 5);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 7);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn wrong_dim_panics() {
        let mut idx = FlatIndex::new(4);
        idx.add(1, &[1.0, 2.0]);
    }

    #[test]
    fn search_batch_matches_per_query_search() {
        let mut rng = Pcg::new(3);
        let dim = 48; // not a multiple of the SIMD block
        let mut idx = FlatIndex::new(dim);
        for i in 0..500 {
            idx.add(i, &unit(&mut rng, dim));
        }
        let queries: Vec<Vec<f32>> = (0..9).map(|_| unit(&mut rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        // Forced multi-shard, auto, and sequential must all agree.
        for variant in [
            idx.search_batch_with_threads(&qrefs, 7, 4),
            idx.search_batch_with_threads(&qrefs, 7, 1),
            idx.search_batch(&qrefs, 7),
        ] {
            assert_eq!(variant.len(), queries.len());
            for (q, got) in queries.iter().zip(&variant) {
                assert_eq!(got, &idx.search(q, 7));
            }
        }
    }

    #[test]
    fn search_batch_duplicate_rows_tie_break_is_row_order() {
        // Duplicate vectors ⇒ equal scores; both paths must keep the
        // first-inserted (lowest row) ids, in insertion order.
        let v = [0.6f32, 0.8, 0.0, 0.0];
        let mut idx = FlatIndex::new(4);
        for i in 0..20 {
            idx.add(100 + i, &v);
        }
        let hits = idx.search(&v, 5);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![100, 101, 102, 103, 104]);
        let batch = idx.search_batch_with_threads(&[&v], 5, 3);
        assert_eq!(batch[0], hits);
    }

    #[test]
    fn remove_hides_rows_and_compact_is_bit_identical() {
        let mut rng = Pcg::new(5);
        let mut idx = FlatIndex::new(16);
        let vs: Vec<Vec<f32>> = (0..60).map(|_| unit(&mut rng, 16)).collect();
        for (i, v) in vs.iter().enumerate() {
            idx.add(i as u64, v);
        }
        assert_eq!(idx.remove(13), 1);
        assert_eq!(idx.remove(13), 0, "second remove is a no-op");
        assert_eq!(idx.remove(777), 0, "absent id");
        idx.remove(40);
        assert_eq!(idx.len(), 58);
        assert_eq!(idx.tombstones(), 2);
        assert_eq!(idx.scan_rows_estimate(), 60, "dead rows still stream");
        // Deleted ids never surface, on either scan path.
        let hits = idx.search(&vs[13], 60);
        assert!(hits.iter().all(|h| h.id != 13 && h.id != 40));
        let batch = idx.search_batch_with_threads(&[vs[13].as_slice()], 60, 3);
        assert_eq!(batch[0], hits);
        // Compaction reclaims the bytes without changing any result bit.
        let before: Vec<(u64, u32)> =
            hits.iter().map(|h| (h.id, h.score.to_bits())).collect();
        assert_eq!(idx.compact(), 2);
        assert_eq!(idx.tombstones(), 0);
        assert_eq!(idx.len(), 58);
        assert_eq!(idx.scan_rows_estimate(), 58);
        let after: Vec<(u64, u32)> = idx
            .search(&vs[13], 60)
            .iter()
            .map(|h| (h.id, h.score.to_bits()))
            .collect();
        assert_eq!(after, before);
    }

    #[test]
    fn upsert_replaces_in_place() {
        let mut idx = FlatIndex::new(4);
        idx.add(1, &[1.0, 0.0, 0.0, 0.0]);
        idx.add(2, &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(idx.upsert(1, &[0.0, 0.0, 1.0, 0.0]), 1);
        assert_eq!(idx.len(), 2);
        let hits = idx.search(&[0.0, 0.0, 1.0, 0.0], 2);
        assert_eq!(hits[0].id, 1);
        assert!((hits[0].score - 1.0).abs() < 1e-6);
        // The old row is gone: nothing matches the original direction.
        let old = idx.search(&[1.0, 0.0, 0.0, 0.0], 2);
        assert!(old.iter().all(|h| h.score < 0.5));
        // Upsert of a new id is a plain insert.
        assert_eq!(idx.upsert(9, &[0.0, 0.0, 0.0, 1.0]), 0);
        assert_eq!(idx.len(), 3);
        // Export excludes tombstones.
        let (ids, rows) = idx.export_f32_rows().unwrap();
        assert!(!ids.is_empty());
        assert_eq!(rows.len(), ids.len() * 4);
        assert_eq!(ids.iter().filter(|&&i| i == 1).count(), 1);
    }

    #[test]
    fn search_batch_empty_inputs() {
        let idx = FlatIndex::new(8);
        assert!(idx.search_batch(&[], 3).is_empty());
        let q = [0.0f32; 8];
        let r = idx.search_batch(&[&q], 3);
        assert_eq!(r, vec![Vec::new()]);
    }
}
