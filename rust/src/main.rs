//! `windve` — CLI entrypoint for the WindVE serving system.
//!
//! Subcommands:
//! * `serve`      — start the HTTP embedding service (real PJRT engines)
//! * `embed`      — one-shot embedding from the command line
//! * `calibrate`  — fit t = α·C + β on this host's real engine (§4.2.2)
//! * `estimate`   — queue-depth estimation on a calibrated device profile
//! * `stress`     — stress-test baseline search on a profile
//! * `cost`       — §3 deployment-cost calculator
//! * `repro`      — regenerate paper tables/figures: table1|table2|table3|
//!                  fig2|fig4|fig5|fig6|all

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use windve::config::Config;
use windve::coordinator::instance::BackendFactory;
use windve::coordinator::{detect, Inventory, ServiceConfig, WindVE};
use windve::costmodel;
use windve::devices::affinity::Topology;
use windve::devices::executor::RealBackend;
use windve::devices::profile::DeviceProfile;
use windve::estimator::{estimate_depth, stress_search};
use windve::repro;
use windve::runtime::EmbeddingEngine;
use windve::sim::cluster::ClosedLoopSim;
use windve::util::cli::Args;
use windve::util::logging;

fn main() {
    logging::init();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("serve") => serve(args),
        Some("embed") => embed(args),
        Some("calibrate") => calibrate(args),
        Some("estimate") => estimate(args),
        Some("stress") => stress(args),
        Some("cost") => cost(args),
        Some("repro") => repro_cmd(args),
        Some("detect") => detect_cmd(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "windve — collaborative CPU-NPU vector embedding (SPAA'25 reproduction)

USAGE: windve <subcommand> [options]

  serve      --model bge_micro --listen 127.0.0.1:8316 --npu-depth 44 --cpu-depth 8 [--no-hetero]
  embed      --model bge_micro <text...>
  calibrate  --model bge_micro --qlen 75 --slo 1.0 [--repeats 3]
  estimate   --device v100 --slo 1.0
  stress     --device v100 --slo 1.0 --step 8
  cost       --n-peak 1000 --slo 1.0 --device v100 [--cpu-device xeon]
  repro      table1|table2|table3|fig2|fig4|fig5|fig6|all [--seed 42]
  detect     show device detector decision (Algorithm 2)

Profiles: v100, xeon, atlas, kunpeng (+ _jina variants)."
    );
}

fn artifacts_path(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

/// Build the real-backend factories for the service; the "NPU" role on
/// this CPU-only image is the PJRT engine with all cores, the "CPU" role
/// is a second engine instance pinned per §4.4.
fn real_factories(cfg: &Config) -> (Vec<BackendFactory>, Vec<BackendFactory>) {
    let mk = |artifacts: PathBuf, model: String| -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(RealBackend::load(&artifacts, &model)?)
                as Box<dyn windve::devices::executor::Backend>)
        })
    };
    let npu = (0..cfg.npu_workers)
        .map(|_| mk(cfg.artifacts.clone(), cfg.model.clone()))
        .collect();
    let cpu = (0..cfg.cpu_workers)
        .map(|_| mk(cfg.artifacts.clone(), cfg.model.clone()))
        .collect();
    (npu, cpu)
}

fn service_config(cfg: &Config) -> ServiceConfig {
    // Reversed, NUMA-local core picking for the CPU instance (§4.4).
    let pin = if cfg.pin_cpu_cores > 0 {
        Topology::detect()
            .pick_cores_reversed(cfg.pin_cpu_cores, 0)
            .ok()
    } else {
        None
    };
    ServiceConfig {
        npu_depth: cfg.npu_depth,
        cpu_depth: cfg.cpu_depth,
        hetero: cfg.hetero,
        npu_workers: cfg.npu_workers,
        cpu_workers: if cfg.hetero { cfg.cpu_workers } else { 0 },
        cpu_pin_cores: pin,
        cache_entries: 4096,
        cache_key_space: (8192, 128),
        ..ServiceConfig::default()
    }
}

fn serve(args: &Args) -> Result<()> {
    let cfg = match args.str_opt("config") {
        Some(p) => Config::from_file(std::path::Path::new(p))?,
        None => Config::default(),
    }
    .apply_args(args);
    let (npu_f, cpu_f) = real_factories(&cfg);
    let svc = Arc::new(WindVE::start(service_config(&cfg), npu_f, cpu_f)?);
    let server = windve::server::Server::start(
        &cfg.listen,
        Arc::clone(&svc),
        Duration::from_secs_f64(cfg.slo_seconds),
    )?;
    println!("windve serving {} on http://{}", cfg.model, server.addr());
    println!("  POST /v1/embed   GET /healthz /metrics /stats   (Ctrl-C to stop)");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn embed(args: &Args) -> Result<()> {
    let model = args.str_or("model", "bge_micro");
    let texts: Vec<String> = if args.positional.is_empty() {
        vec!["hello from windve".to_string()]
    } else {
        args.positional.clone()
    };
    let mut engine = EmbeddingEngine::load(&artifacts_path(args), &model)?;
    let out = engine.embed(&texts)?;
    for (t, v) in texts.iter().zip(&out) {
        let head: Vec<String> = v.iter().take(6).map(|x| format!("{x:.4}")).collect();
        println!("{t:?} -> [{}, ...] (d={})", head.join(", "), v.len());
    }
    Ok(())
}

fn calibrate(args: &Args) -> Result<()> {
    let c = repro::calibrate::calibrate_host(
        &artifacts_path(args),
        &args.str_or("model", "bge_micro"),
        args.usize_or("qlen", 75),
        args.f64_or("slo", 1.0),
        args.usize_or("repeats", 3),
    )?;
    repro::calibrate::print(&c);
    Ok(())
}

fn profile_from_args(args: &Args, key: &str, default: &str) -> Result<DeviceProfile> {
    let name = args.str_or(key, default);
    DeviceProfile::by_name(&name).ok_or_else(|| {
        anyhow::anyhow!("unknown device profile {name:?} (try: v100, xeon, atlas, kunpeng)")
    })
}

fn estimate(args: &Args) -> Result<()> {
    let dev = profile_from_args(args, "device", "v100")?;
    let slo = args.f64_or("slo", 1.0);
    let qlen = args.usize_or("qlen", 75);
    let seed = args.u64_or("seed", 42);
    let mut sim = ClosedLoopSim::new(dev.clone(), None, usize::MAX >> 1, 0, qlen, seed);
    let est = estimate_depth(slo, &[1, 2, 4, 8, 12, 16, 24, 32], |c| {
        sim.measure_latency(c, 3)
    });
    println!(
        "{}: t = {:.4}·C + {:.3} (R² {:.3}{}) → depth {} at SLO {slo}s ({} probes)",
        dev.name,
        est.fit.alpha,
        est.fit.beta,
        est.fit.r2,
        if est.robust { ", robust" } else { "" },
        est.predicted,
        est.probes
    );
    println!("true max concurrency: {}", dev.true_max_concurrency(slo, qlen));
    Ok(())
}

fn stress(args: &Args) -> Result<()> {
    let dev = profile_from_args(args, "device", "v100")?;
    let slo = args.f64_or("slo", 1.0);
    let step = args.usize_or("step", 8);
    let qlen = args.usize_or("qlen", 75);
    let mut sim =
        ClosedLoopSim::new(dev.clone(), None, usize::MAX >> 1, 0, qlen, args.u64_or("seed", 42));
    let r = stress_search(slo, step, 512, |c| sim.measure_latency(c, 3));
    println!(
        "{}: stress (step {step}) → {} at SLO {slo}s in {} probes",
        dev.name, r.max_concurrency, r.probes
    );
    Ok(())
}

fn cost(args: &Args) -> Result<()> {
    let npu = profile_from_args(args, "device", "v100")?;
    let cpu = profile_from_args(args, "cpu-device", "xeon")?;
    let slo = args.f64_or("slo", 1.0);
    let n_peak = args.f64_or("n-peak", 1000.0);
    let price = args.f64_or("price", 10_000.0);
    let c_npu = npu.true_max_concurrency(slo, 75);
    let c_cpu = cpu.true_max_concurrency(slo, 75);
    let inputs = costmodel::CostInputs { devices_per_instance: 1.0, price_per_device: price };
    let base = costmodel::cost_peak(n_peak, c_npu as f64, inputs);
    let offl = costmodel::cost_peak(n_peak, (c_npu + c_cpu) as f64, inputs);
    println!(
        "deployment for N_peak={n_peak} @ SLO {slo}s ({} + {}):",
        npu.name, cpu.name
    );
    println!("  C_NPU = {c_npu}, C_CPU = {c_cpu}");
    println!("  peak-provisioned cost:   ${base:>12.0} (NPU only)");
    println!("  with CPU offloading:     ${offl:>12.0}");
    println!(
        "  savings: {:.1}% (bound C_CPU/(C_CPU+C_NPU) = {:.1}%)",
        100.0 * (1.0 - offl / base),
        100.0 * costmodel::savings_peak(c_npu, c_cpu)
    );
    println!(
        "  avg-provisioning throughput uplift: {:.1}%",
        100.0 * costmodel::improvement_average(c_npu, c_cpu)
    );
    Ok(())
}

fn detect_cmd() -> Result<()> {
    let inv = Inventory::detect();
    let d = detect(inv, true);
    println!(
        "inventory: {} NPUs, {} CPU instances (set WINDVE_NPUS to simulate NPUs)",
        inv.npus, inv.cpus
    );
    println!("detection: {d:?}");
    let topo = Topology::detect();
    println!("topology: {} cores, {} NUMA nodes", topo.cores, topo.numa_nodes);
    Ok(())
}

fn repro_cmd(args: &Args) -> Result<()> {
    let what = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let seed = args.u64_or("seed", 42);
    let all = what == "all";
    if all || what == "table1" {
        repro::table1::print(
            &repro::table1::run(seed),
            "Table 1 — bge model, WindVE vs FlagEmbedding",
            "FlagEmb",
        );
    }
    if all || what == "table2" {
        repro::table2::print(&repro::table2::run(seed));
    }
    if all || what == "table3" {
        repro::table3::print(&repro::table3::run(seed));
    }
    if all || what == "fig2" {
        repro::fig2::print(&repro::fig2::run());
    }
    if all || what == "fig4" {
        repro::fig4::print(&repro::fig4::run(seed));
    }
    if all || what == "fig5" {
        repro::fig5::print(&repro::fig5::run(seed));
    }
    if all || what == "fig6" {
        repro::fig6::print(&repro::fig6::run(seed));
    }
    if !all && !["table1", "table2", "table3", "fig2", "fig4", "fig5", "fig6"].contains(&what) {
        bail!("unknown repro target {what:?}");
    }
    Ok(())
}
