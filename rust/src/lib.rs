//! # WindVE — Collaborative CPU-NPU Vector Embedding
//!
//! Reproduction of Huang et al., *WindVE: Collaborative CPU-NPU Vector
//! Embedding* (SPAA '25). An NPU/GPU serves the steady-state embedding
//! query stream while otherwise-idle host CPUs absorb peak bursts through
//! a second bounded queue; a linear-regression estimator calibrates the
//! queue depths against the SLO.
//!
//! Layering (see `DESIGN.md`):
//! * **L3 (this crate)** — the coordinator: [`coordinator`] (queue manager,
//!   device detector, batcher, worker instances), [`server`] (HTTP front
//!   end), [`estimator`] (queue-depth calibration), [`sim`] (discrete-event
//!   cluster simulator used by the paper-reproduction benches).
//! * **L2/L1 (build time)** — `python/compile/` lowers a JAX encoder whose
//!   hot spots are Pallas kernels to HLO text; [`runtime`] loads those
//!   artifacts via PJRT and executes them on the request path with **no
//!   Python anywhere at runtime**.

// Unsafe hygiene: an `unsafe fn` body gets no implicit unsafe scope —
// every unsafe *operation* must sit in its own `unsafe {}` block, each
// carrying the `// SAFETY:` note that `cargo xtask lint` enforces.
#![deny(unsafe_op_in_unsafe_fn)]
// `Result`s on the admission/durability/IO paths are never
// fire-and-forget; discarding one is a bug, not a style choice.
#![deny(unused_must_use)]
// `pub` that is not reachable from the crate root is a stale API
// surface. Warn (CI promotes warnings to errors); private modules that
// export to their parent use `pub(super)`/`pub(crate)` instead.
#![warn(unreachable_pub)]

pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod devices;
pub mod durability;
pub mod estimator;
pub mod ingest;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod testing;
pub mod util;
pub mod vecstore;
pub mod workload;
