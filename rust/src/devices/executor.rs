//! Batch execution backends for worker instances.
//!
//! [`Backend`] is what a `coordinator::instance::Worker` drives: give it a
//! batch of texts, get embeddings back. Two implementations:
//!
//! * **Real** — wraps [`crate::runtime::EmbeddingEngine`]: PJRT-compiled
//!   AOT artifacts on the CPU PJRT client (the production path). Because
//!   PJRT handles are not `Send`, workers construct this backend on their
//!   own thread via the factory passed to the service.
//! * **[`SyntheticBackend`]** — profile-driven: sleeps for the calibrated
//!   `t(batch, qlen)` and returns deterministic pseudo-embeddings. Used by
//!   the paper-scale experiments (our testbed has no V100/Atlas — see
//!   DESIGN.md §2) and by tests that must not depend on built artifacts.

use std::path::PathBuf;
// Arc here is pure data sharing (`Arc<str>` text payloads), not part of a
// model-checked protocol, so it stays on std; the executor's RwLock +
// atomics come from the loom-switchable shim because the version/mirror
// handshake below is model-checked by tests/loom_admission.rs.
use std::sync::Arc;
use std::time::Duration;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{RwLock, RwLockReadGuard};

use anyhow::Result;

use super::profile::DeviceProfile;
use crate::runtime::{tokenizer, EmbeddingEngine};
use crate::util::rng::Pcg;
use crate::vecstore::{FlatIndex, Hit, Index, Quant, QuantizedFlatIndex};

/// A batch embedding executor owned by one worker instance.
pub trait Backend {
    /// Embed a batch; one vector per input text. Texts arrive as
    /// `Arc<str>` so the whole pipeline (HTTP parse → queue → batch)
    /// shares one allocation per payload.
    fn embed(&mut self, texts: &[Arc<str>]) -> Result<Vec<Vec<f32>>>;
    /// Human-readable backend description (for /stats and logs).
    fn describe(&self) -> String;
    /// Largest batch worth submitting at once (bucket cap for real
    /// engines; queue depth elsewhere).
    fn max_batch(&self) -> usize;
}

/// Real PJRT backend.
pub struct RealBackend {
    engine: EmbeddingEngine,
}

impl RealBackend {
    pub fn load(artifacts: &PathBuf, model: &str) -> Result<RealBackend> {
        let mut engine = EmbeddingEngine::load(artifacts, model)?;
        engine.warmup()?;
        Ok(RealBackend { engine })
    }
}

impl Backend for RealBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> Result<Vec<Vec<f32>>> {
        self.engine.embed(texts)
    }

    fn describe(&self) -> String {
        format!("pjrt:{}", self.engine.model_name())
    }

    fn max_batch(&self) -> usize {
        self.engine.max_batch()
    }
}

/// Default synthetic bucket cap: the drain limit the batcher sees from a
/// synthetic worker. Real engines cap batches at their largest exported
/// bucket; `usize::MAX` here (the old behavior) made the batcher drain
/// unboundedly, so batch-size-dependent admission tests never saw
/// realistic batch shapes.
pub const SYNTH_BUCKET_CAP: usize = 64;

/// Profile-driven synthetic backend: calibrated latency + deterministic
/// hash pseudo-embeddings (so routing/batching tests can assert payloads).
pub struct SyntheticBackend {
    pub profile: DeviceProfile,
    pub d_model: usize,
    /// Wall-clock scale: 1.0 replays paper-scale seconds, small values
    /// (e.g. 1e-3) keep tests fast while preserving ratios.
    pub time_scale: f64,
    /// Largest batch reported to the batcher ([`SYNTH_BUCKET_CAP`] by
    /// default; [`SyntheticBackend::with_max_batch`] overrides).
    bucket_cap: usize,
    rng: Pcg,
}

impl SyntheticBackend {
    pub fn new(profile: DeviceProfile, time_scale: f64, seed: u64) -> SyntheticBackend {
        SyntheticBackend {
            profile,
            d_model: 64,
            time_scale,
            bucket_cap: SYNTH_BUCKET_CAP,
            rng: Pcg::new(seed),
        }
    }

    /// Override the synthetic bucket cap (clamped to ≥ 1) so tests can
    /// exercise a specific drain shape.
    pub fn with_max_batch(mut self, cap: usize) -> SyntheticBackend {
        self.bucket_cap = cap.max(1);
        self
    }

    fn pseudo_embedding(&self, text: &str, d: usize) -> Vec<f32> {
        // Deterministic unit vector derived from the token stream.
        let mut state = tokenizer::fnv1a64(text.as_bytes());
        let mut v: Vec<f32> = (0..d)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect();
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= norm);
        v
    }
}

impl Backend for SyntheticBackend {
    fn embed(&mut self, texts: &[Arc<str>]) -> Result<Vec<Vec<f32>>> {
        let qlen = texts
            .iter()
            .map(|t| tokenizer::token_count(t))
            .max()
            .unwrap_or(1);
        let secs = self
            .profile
            .noisy_service_time(texts.len(), qlen, &mut self.rng)
            * self.time_scale;
        if secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
        Ok(texts
            .iter()
            .map(|t| self.pseudo_embedding(t, self.d_model))
            .collect())
    }

    fn describe(&self) -> String {
        format!("synthetic:{}", self.profile.name)
    }

    fn max_batch(&self) -> usize {
        self.bucket_cap
    }
}

/// CPU-side batch retrieval executor: owns the vector index the service
/// scans, behind a `RwLock` so concurrent front-end threads share read
/// scans while corpus writers take the lock exclusively.
///
/// This is where CPU-offloaded peak queries converge: the service's
/// retrieval path collects a panel of embedded queries (whether they were
/// embedded on the NPU queue or the CPU overflow queue) and drives one
/// [`Index::search_batch`] call, which shards the scan across host cores
/// on the SIMD kernels instead of paying one sequential scan per query.
pub struct RetrievalExecutor {
    /// The index's storage codec, cached at construction (a boxed index
    /// never changes codec) so hot-path callers don't take the lock.
    quant: Quant,
    index: RwLock<Box<dyn Index + Send + Sync>>,
    /// Bumped (inside the write guard) on every corpus mutation, so
    /// device-side mirrors ([`RetrievalExecutor::export_corpus`]) can
    /// check freshness without comparing arenas.
    version: AtomicU64,
    /// Times a read guard was recovered from a poisoned lock (surfaced
    /// via `/stats` as `retrieval_poisoned_recoveries`).
    poisoned_recoveries: AtomicU64,
}

impl RetrievalExecutor {
    pub fn new(index: Box<dyn Index + Send + Sync>) -> RetrievalExecutor {
        RetrievalExecutor {
            quant: index.quant(),
            index: RwLock::new(index),
            version: AtomicU64::new(0),
            poisoned_recoveries: AtomicU64::new(0),
        }
    }

    /// Read-side lock acquisition that survives poisoning. A writer that
    /// panics while holding the lock (the canonical case: `add` asserting
    /// on a dimension mismatch, which fires *before* any mutation)
    /// poisons it; `expect`ing the guard would then permanently kill
    /// every front-end retrieval thread for a corpus that is intact.
    /// Scans are read-only, so recovering the guard is safe; each
    /// recovery is counted for operators.
    fn read_index(&self) -> RwLockReadGuard<'_, Box<dyn Index + Send + Sync>> {
        self.recover_read(self.index.read())
    }

    /// The poisoned-recovery path itself, split out so the loom suite can
    /// drive it with a manufactured [`std::sync::PoisonError`] (a panic
    /// inside a loom model aborts the whole model, so poisoning cannot be
    /// induced naturally there).
    fn recover_read<'a>(
        &'a self,
        res: std::sync::LockResult<RwLockReadGuard<'a, Box<dyn Index + Send + Sync>>>,
    ) -> RwLockReadGuard<'a, Box<dyn Index + Send + Sync>> {
        res.unwrap_or_else(|e| {
            // ordering: Relaxed — monotonic stats counter; nothing orders
            // against its value (the guard itself carries the data).
            self.poisoned_recoveries.fetch_add(1, Ordering::Relaxed);
            e.into_inner()
        })
    }

    /// Test/loom-only probe: feed an already-poisoned `LockResult`
    /// through the recovery path and return the recovered corpus length.
    /// See [`RetrievalExecutor::recover_read`] for why loom needs this.
    #[cfg(any(test, loom))]
    #[doc(hidden)]
    pub fn poisoned_recovery_probe(&self) -> usize {
        let g = self
            .index
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        self.recover_read(Err(std::sync::PoisonError::new(g))).len()
    }

    /// Read guards recovered from a poisoned index lock so far.
    pub fn poisoned_recoveries(&self) -> u64 {
        // ordering: Relaxed — monotonic stats counter (see above).
        self.poisoned_recoveries.load(Ordering::Relaxed)
    }

    /// Monotone corpus version: bumps on every [`RetrievalExecutor::add`].
    ///
    /// ordering: Acquire, pairing with the Release bumps that happen
    /// inside the write guard — a caller that observes version v also
    /// observes every row mutation published before the bump to v. The
    /// loom suite proves the handshake: a mirror that saw version v and
    /// re-checks it can never scan rows from a later, unseen commit.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Convenience: an empty exact (flat) index of `dim`.
    pub fn flat(dim: usize) -> RetrievalExecutor {
        RetrievalExecutor::new(Box::new(FlatIndex::new(dim)))
    }

    /// Convenience: an empty exact index of `dim` whose rows are stored
    /// under `quant` — the compact arena CPU-offloaded peak queries scan
    /// (2-4× less bandwidth per concurrent scan than f32).
    pub fn flat_quant(dim: usize, quant: Quant) -> RetrievalExecutor {
        RetrievalExecutor::new(Box::new(QuantizedFlatIndex::new(dim, quant)))
    }

    /// Storage codec of the attached index's row arena (lock-free).
    pub fn quant(&self) -> Quant {
        self.quant
    }

    /// The arena codec as a span/metric label (lock-free) — what the
    /// tracing layer stamps on scan spans served by this executor.
    pub fn codec_label(&self) -> crate::metrics::trace::CodecLabel {
        quant_codec_label(self.quant)
    }

    /// Opt the attached index into NUMA-aware scan sharding (exclusive
    /// lock: the arena is rewritten through per-node pinned first-touch
    /// copies — see `vecstore::numa`). `None` reverts to plain sharding.
    /// Results are bit-identical either way; placement moves bytes,
    /// never scores. Returns `false` when the index does not support it
    /// (e.g. IVF). No version bump: contents are unchanged, so device
    /// mirrors stay valid.
    pub fn set_numa(&self, topo: Option<crate::devices::affinity::Topology>) -> bool {
        self.index.write().expect("index lock poisoned").set_numa(topo)
    }

    /// Add one corpus vector (exclusive lock; cheap relative to scans).
    /// The version bump happens inside the guard, so a reader holding the
    /// lock always sees a version consistent with the rows it can scan.
    pub fn add(&self, id: u64, vector: &[f32]) {
        let mut g = self.index.write().expect("index lock poisoned");
        g.add(id, vector);
        // ordering: Release — the bump publishes the row mutation above
        // it; version() loads Acquire to pair. Still inside the guard so
        // rows/version stay mutually consistent for guard holders.
        self.version.fetch_add(1, Ordering::Release);
    }

    /// Commit one ingest batch under a single exclusive lock: the write
    /// amortization the streaming pipeline relies on (one lock + one
    /// version window per batch instead of per document, so concurrent
    /// scans see at most one barrier per commit). The version advances by
    /// the row count, inside the guard, so device-side mirrors taken
    /// before the commit always read as stale. Dimension mismatches are
    /// the caller's job to filter — a mis-sized row would assert inside
    /// the guard and poison the lock for writers.
    pub fn add_batch(&self, rows: &[(u64, Vec<f32>)]) {
        if rows.is_empty() {
            return;
        }
        let mut g = self.index.write().expect("index lock poisoned");
        let items: Vec<(u64, &[f32])> =
            rows.iter().map(|(id, v)| (*id, v.as_slice())).collect();
        g.add_batch(&items);
        // ordering: Release — publishes the batch commit (see `add`).
        self.version.fetch_add(rows.len() as u64, Ordering::Release);
    }

    /// Commit one ingest batch with upsert semantics: per row, tombstone
    /// any live rows under the id, then append (same guard, one version
    /// window per batch — mirrors taken before the commit read as stale).
    /// Rows apply in order, so a batch carrying the same id twice keeps
    /// only the last — exactly what WAL replay re-applies after a crash.
    /// Returns the rows tombstoned (0 ⇒ the batch was pure inserts).
    pub fn upsert_batch(&self, rows: &[(u64, Vec<f32>)]) -> usize {
        if rows.is_empty() {
            return 0;
        }
        let mut g = self.index.write().expect("index lock poisoned");
        let mut replaced = 0;
        for (id, v) in rows {
            replaced += g.upsert(*id, v);
        }
        // ordering: Release — publishes the upsert commit (see `add`).
        self.version.fetch_add(rows.len() as u64, Ordering::Release);
        replaced
    }

    /// Tombstone every live row stored under `id`. A successful delete
    /// bumps the version inside the write guard, so device-side mirrors
    /// invalidate exactly as adds do — an NPU arena can never resurrect
    /// a deleted row. Returns rows killed (0 ⇒ id absent, no bump).
    pub fn remove(&self, id: u64) -> usize {
        let mut g = self.index.write().expect("index lock poisoned");
        let killed = g.remove(id);
        if killed > 0 {
            // ordering: Release — publishes the tombstones (see `add`).
            self.version.fetch_add(1, Ordering::Release);
        }
        killed
    }

    /// Rows currently tombstoned in the attached index (the compaction
    /// trigger statistic — see `durability`).
    pub fn tombstones(&self) -> usize {
        self.read_index().tombstones()
    }

    /// Rewrite the index arenas dropping tombstoned rows (exclusive
    /// lock). Survivor bytes are copied verbatim and live-row order is
    /// preserved, so post-compaction scans are bit-identical; the version
    /// bump (only when rows were actually reclaimed) re-seeds mirrors
    /// under the same seam as any other corpus mutation.
    pub fn compact(&self) -> usize {
        let mut g = self.index.write().expect("index lock poisoned");
        let reclaimed = g.compact();
        if reclaimed > 0 {
            // ordering: Release — publishes the rewrite (see `add`).
            self.version.fetch_add(1, Ordering::Release);
        }
        reclaimed
    }

    /// Serialize the attached index (live rows only) with the version it
    /// captures, under one read guard so bytes and version agree. `None`
    /// when the index has no snapshot codec.
    pub fn snapshot_bytes(&self) -> Option<(Vec<u8>, u64)> {
        let g = self.read_index();
        let bytes = g.snapshot_bytes()?;
        // ordering: Acquire — pairs with the in-guard Release bumps;
        // writers are blocked while `g` is held, so this is exactly the
        // version the serialized bytes were committed under.
        Some((bytes, self.version.load(Ordering::Acquire)))
    }

    pub fn len(&self) -> usize {
        self.read_index().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.read_index().dim()
    }

    /// Begin a scan session: ONE read guard under which the admission
    /// cost estimate and the scan itself both run. Estimating cost with
    /// one guard and scanning under another (the old shape) was a TOCTOU
    /// — corpus `add`s between the two undercharged the admitted slot
    /// cost relative to the bytes the scan then actually streamed.
    /// Writers block for the session's lifetime, so the estimate is exact
    /// for the rows scanned; keep the session short-lived.
    pub fn begin_scan(&self) -> ScanSession<'_> {
        ScanSession { quant: self.quant, guard: self.read_index() }
    }

    /// Bytes one batched scan streams from the attached arena: the
    /// index's scanned-rows estimate (full corpus for exhaustive scans,
    /// the nprobe/nlist share for IVF) × bytes_per_row of the active
    /// codec. This is the executor's per-scan cost report to admission —
    /// the scan is memory-bound, so bytes scanned is the honest proxy
    /// for how much of the calibrated CPU depth one scan consumes (see
    /// `coordinator::queue_manager`). Admission-coupled scans should use
    /// [`RetrievalExecutor::begin_scan`] so estimate and scan share one
    /// guard.
    pub fn scan_bytes_estimate(&self) -> usize {
        self.begin_scan().scan_bytes_estimate()
    }

    /// Admission slot cost of one batched scan, normalized to embed-query
    /// cost units of `unit_bytes` (≥ 1: even a tiny scan holds a slot
    /// while it runs).
    pub fn scan_cost(&self, unit_bytes: usize) -> usize {
        self.begin_scan().scan_cost(unit_bytes)
    }

    /// Single-query top-k (shared lock).
    pub fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        self.read_index().search(query, k)
    }

    /// Batched top-k over a query panel (shared lock, sharded scan).
    pub fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        self.read_index().search_batch(queries, k)
    }

    /// Snapshot the corpus for a device-side mirror (the NPU retrieval
    /// offload arena): `(ids, row-major f32 rows, version)` under one
    /// read guard, so rows and version are mutually consistent. `None`
    /// when the index cannot guarantee that scanning the exported rows
    /// with the f32 kernels is bit-identical to its own scan (quantized
    /// arenas, pruning indexes) — see [`Index::export_f32_rows`].
    pub fn export_corpus(&self) -> Option<(Vec<u64>, Vec<f32>, u64)> {
        let g = self.read_index();
        let (ids, rows) = g.export_f32_rows()?;
        // ordering: Acquire — pairs with the in-guard Release bumps, and
        // the read guard blocks writers, so the exported rows and the
        // version are one consistent cut (the mirror-freshness handshake
        // the loom suite checks).
        Some((ids, rows, self.version.load(Ordering::Acquire)))
    }
}

/// One scan's read session over the executor's index: cost estimation
/// and the scan itself under a single guard (see
/// [`RetrievalExecutor::begin_scan`]).
pub struct ScanSession<'a> {
    quant: Quant,
    guard: RwLockReadGuard<'a, Box<dyn Index + Send + Sync>>,
}

impl ScanSession<'_> {
    pub fn dim(&self) -> usize {
        self.guard.dim()
    }

    pub fn len(&self) -> usize {
        self.guard.len()
    }

    /// Bytes the scan will stream — exact for the session's lifetime
    /// (writers are blocked while the guard is held).
    pub fn scan_bytes_estimate(&self) -> usize {
        self.guard.scan_rows_estimate() * self.quant.bytes_per_row(self.guard.dim())
    }

    /// Admission slot cost (see [`RetrievalExecutor::scan_cost`]).
    pub fn scan_cost(&self, unit_bytes: usize) -> usize {
        crate::coordinator::queue_manager::retrieval_slot_cost(
            self.scan_bytes_estimate(),
            unit_bytes,
        )
    }

    /// The batched scan this session was opened for.
    pub fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        self.guard.search_batch(queries, k)
    }

    /// The codec label scan spans served under this session carry.
    pub fn codec_label(&self) -> crate::metrics::trace::CodecLabel {
        quant_codec_label(self.quant)
    }
}

/// Map an arena codec to its span/metric label (the `codec` axis of the
/// `trace.*` name schema).
pub fn quant_codec_label(quant: Quant) -> crate::metrics::trace::CodecLabel {
    use crate::metrics::trace::CodecLabel;
    match quant {
        Quant::F32 => CodecLabel::F32,
        Quant::F16 => CodecLabel::F16,
        Quant::Int8 => CodecLabel::Int8,
        Quant::Pq { bits: 4, .. } => CodecLabel::Pq4,
        Quant::Pq { .. } => CodecLabel::Pq8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_synth() -> SyntheticBackend {
        let mut p = DeviceProfile::v100_bge();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        SyntheticBackend::new(p, 1e-6, 1)
    }

    #[test]
    fn synthetic_returns_unit_vectors() {
        let mut b = fast_synth();
        let out = b.embed(&["hello world".into(), "other".into()]).unwrap();
        assert_eq!(out.len(), 2);
        for v in &out {
            let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn synthetic_is_deterministic_per_text() {
        let mut b = fast_synth();
        let a = b.embed(&["same text".into()]).unwrap();
        let c = b.embed(&["same text".into()]).unwrap();
        assert_eq!(a, c);
        let d = b.embed(&["different".into()]).unwrap();
        assert_ne!(a, d);
    }

    #[test]
    fn retrieval_executor_quantized_flat() {
        for quant in [Quant::F16, Quant::Int8, Quant::pq(4), Quant::pq(8)] {
            let ex = RetrievalExecutor::flat_quant(4, quant);
            // PQ placeholders (m = 0) resolve at construction.
            assert_eq!(ex.quant(), quant.resolved(4));
            for i in 0..16u64 {
                let a = (i as f32) * 0.3;
                ex.add(i, &[a.cos(), a.sin(), 0.0, 0.0]);
            }
            let q = [0.6f32.cos(), 0.6f32.sin(), 0.0, 0.0];
            let hits = ex.search(&q, 3);
            assert_eq!(hits[0].id, 2, "{quant:?}"); // 0.6 == 2 * 0.3
            let batch = ex.search_batch(&[&q[..]], 3);
            assert_eq!(batch[0], hits);
        }
        assert_eq!(RetrievalExecutor::flat(4).quant(), Quant::F32);
    }

    #[test]
    fn scan_cost_tracks_codec_bytes_per_row() {
        let dim = 16;
        // PQ at dim 16 packs m = 2 sub-spaces: 1 byte/row at 4 bits,
        // 2 at 8 — the admission model's reward for the codec ladder.
        for (quant, bpr) in [
            (Quant::F32, 64),
            (Quant::F16, 32),
            (Quant::Int8, 20),
            (Quant::pq(4), 1),
            (Quant::pq(8), 2),
        ] {
            let ex = RetrievalExecutor::flat_quant(dim, quant);
            assert_eq!(ex.scan_bytes_estimate(), 0);
            // An empty index still costs one slot per scan.
            assert_eq!(ex.scan_cost(1024), 1);
            for i in 0..64u64 {
                ex.add(i, &[0.5; 16]);
            }
            assert_eq!(quant.bytes_per_row(dim), bpr, "{quant:?}");
            assert_eq!(ex.scan_bytes_estimate(), 64 * bpr);
            // cost = ceil(bytes / unit), so the compact codecs cost
            // strictly less than f32 at the same unit.
            assert_eq!(ex.scan_cost(1024), (64 * bpr).div_ceil(1024));
            // A huge unit collapses every scan to the 1-slot floor.
            assert_eq!(ex.scan_cost(usize::MAX), 1);
        }
    }

    #[test]
    fn scan_cost_charges_ivf_only_for_probed_share() {
        use crate::vecstore::IvfIndex;
        let dim = 8;
        let mut ivf = IvfIndex::new(dim, 8, 2);
        for i in 0..64u64 {
            let a = (i as f32) * 0.1;
            ivf.add(i, &[a.cos(), a.sin(), 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        }
        // Unbuilt: scans everything.
        assert_eq!(ivf.scan_rows_estimate(), 64);
        ivf.build(7);
        // Built: nprobe/nlist share of the corpus, not the whole arena.
        assert_eq!(ivf.scan_rows_estimate(), 16); // 64 · 2 / 8
        let ex = RetrievalExecutor::new(Box::new(ivf));
        assert_eq!(ex.scan_bytes_estimate(), 16 * Quant::F32.bytes_per_row(dim));
    }

    #[test]
    fn retrieval_executor_batch_matches_single() {
        let ex = RetrievalExecutor::flat(4);
        assert!(ex.is_empty());
        for i in 0..32u64 {
            let a = (i as f32) * 0.1;
            let v = [a.cos(), a.sin(), 0.0, 0.0];
            ex.add(i, &v);
        }
        assert_eq!(ex.len(), 32);
        assert_eq!(ex.dim(), 4);
        let queries: Vec<[f32; 4]> = (0..5)
            .map(|i| {
                let a = (i as f32) * 0.7;
                [a.cos(), a.sin(), 0.0, 0.0]
            })
            .collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let batch = ex.search_batch(&qrefs, 3);
        assert_eq!(batch.len(), 5);
        for (q, got) in qrefs.iter().zip(&batch) {
            assert_eq!(got, &ex.search(q, 3));
        }
    }

    #[test]
    fn synthetic_max_batch_is_clamped_and_configurable() {
        // Regression (satellite): usize::MAX let the batcher drain
        // unboundedly; the synthetic bucket cap must be finite and
        // overridable so admission tests see realistic batch shapes.
        let b = fast_synth();
        assert_eq!(b.max_batch(), SYNTH_BUCKET_CAP);
        assert!(b.max_batch() < usize::MAX);
        let b = fast_synth().with_max_batch(8);
        assert_eq!(b.max_batch(), 8);
        // The clamp floor: a zero cap would wedge the drain loop.
        let b = fast_synth().with_max_batch(0);
        assert_eq!(b.max_batch(), 1);
    }

    #[test]
    fn corpus_version_bumps_on_every_add() {
        let ex = RetrievalExecutor::flat(4);
        assert_eq!(ex.version(), 0);
        ex.add(1, &[1.0, 0.0, 0.0, 0.0]);
        ex.add(2, &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(ex.version(), 2);
    }

    #[test]
    fn ingest_add_batch_commits_rows_and_versions_once() {
        let ex = RetrievalExecutor::flat(4);
        ex.add(0, &[1.0, 0.0, 0.0, 0.0]);
        let rows: Vec<(u64, Vec<f32>)> = (1..9u64)
            .map(|i| {
                let a = (i as f32) * 0.3;
                (i, vec![a.cos(), a.sin(), 0.0, 0.0])
            })
            .collect();
        ex.add_batch(&rows);
        assert_eq!(ex.len(), 9);
        // Version advanced by exactly the committed row count.
        assert_eq!(ex.version(), 9);
        // Every committed row is immediately retrievable.
        for (id, v) in &rows {
            assert_eq!(ex.search(v, 1)[0].id, *id);
        }
        // Empty commits are free: no version churn for mirrors.
        ex.add_batch(&[]);
        assert_eq!(ex.version(), 9);
    }

    #[test]
    fn remove_and_upsert_bump_versions_for_mirrors() {
        let ex = RetrievalExecutor::flat(4);
        ex.add(1, &[1.0, 0.0, 0.0, 0.0]);
        ex.add(2, &[0.0, 1.0, 0.0, 0.0]);
        let v0 = ex.version();
        // Delete: version bumps (mirror invalidates), row disappears.
        assert_eq!(ex.remove(1), 1);
        assert!(ex.version() > v0);
        assert_eq!(ex.len(), 1);
        assert_eq!(ex.tombstones(), 1);
        // Deleting an absent id is version-free: no mirror churn.
        let v1 = ex.version();
        assert_eq!(ex.remove(42), 0);
        assert_eq!(ex.version(), v1);
        // The mirror export excludes the tombstone.
        let (ids, _, _) = ex.export_corpus().unwrap();
        assert_eq!(ids, vec![2]);
        // Upsert replaces in place; duplicate ids in one batch keep the
        // last row, matching replay order.
        let replaced = ex.upsert_batch(&[
            (2, vec![1.0, 0.0, 0.0, 0.0]),
            (3, vec![0.0, 0.0, 1.0, 0.0]),
            (3, vec![0.0, 0.0, 0.0, 1.0]),
        ]);
        assert_eq!(replaced, 2); // old row 2 + first row 3 of the batch
        assert_eq!(ex.len(), 2);
        assert_eq!(ex.search(&[0.0, 0.0, 0.0, 1.0], 1)[0].id, 3);
        // Compaction reclaims, bumps once, and changes no results.
        let hits_before = ex.search(&[1.0, 0.0, 0.0, 0.0], 2);
        let v2 = ex.version();
        assert!(ex.tombstones() > 0);
        let reclaimed = ex.compact();
        assert_eq!(reclaimed, 3);
        assert_eq!(ex.version(), v2 + 1);
        assert_eq!(ex.tombstones(), 0);
        assert_eq!(ex.search(&[1.0, 0.0, 0.0, 0.0], 2), hits_before);
        // Compacting a clean index is version-free.
        assert_eq!(ex.compact(), 0);
        assert_eq!(ex.version(), v2 + 1);
    }

    /// Satellite regression (incremental encode): a corpus version bump
    /// must never re-encode rows it did not touch. Upserts tombstone +
    /// append and batch adds encode only the new rows, so every
    /// pre-existing row's stored bytes stay bit-identical — under int8
    /// (per-row scales) and under trained PQ (packed codes against the
    /// frozen codebook). A whole-arena re-encode would be O(n) work per
    /// ingest commit *and*, for PQ, a chance to retrain the codebook and
    /// silently shift every stored code.
    #[test]
    fn ingest_bump_keeps_untouched_row_bytes_bit_identical() {
        use crate::util::rng::Pcg;
        let dim = 16;
        for quant in [Quant::Int8, Quant::pq(4), Quant::pq(8)] {
            let mut rng = Pcg::new(57);
            let mut idx = QuantizedFlatIndex::new(dim, quant);
            // 300 rows: past the PQ staging threshold, so the arena is
            // trained and storing packed codes.
            let vs: Vec<Vec<f32>> = (0..300)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect();
            for (i, v) in vs.iter().enumerate() {
                idx.add(i as u64, v);
            }
            let before: Vec<Vec<u8>> =
                (0..300).map(|r| idx.arena.row_bytes(r, dim)).collect();
            // An upsert (the executor's `upsert_batch` per-row call):
            // tombstone + append, touching exactly one logical row.
            let fresh: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            idx.upsert(7, &fresh);
            // A batch append (the executor's `add_batch` under one guard).
            let late: Vec<(u64, Vec<f32>)> = (300..308u64)
                .map(|i| (i, (0..dim).map(|_| rng.normal() as f32).collect()))
                .collect();
            let refs: Vec<(u64, &[f32])> =
                late.iter().map(|(i, v)| (*i, v.as_slice())).collect();
            idx.add_batch(&refs);
            for (r, want) in before.iter().enumerate() {
                assert_eq!(
                    &idx.arena.row_bytes(r, dim),
                    want,
                    "{quant:?}: row {r} re-encoded by an ingest that never touched it"
                );
            }
        }
    }

    #[test]
    fn snapshot_bytes_roundtrips_through_decode() {
        let ex = RetrievalExecutor::flat(4);
        ex.add(1, &[1.0, 0.0, 0.0, 0.0]);
        ex.add(2, &[0.0, 1.0, 0.0, 0.0]);
        ex.remove(1);
        let (bytes, version) = ex.snapshot_bytes().expect("flat has a codec");
        assert_eq!(version, ex.version());
        let restored = crate::vecstore::persist::decode_index(&bytes).unwrap();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored.search(&[0.0, 1.0, 0.0, 0.0], 1)[0].id, 2);
    }

    #[test]
    fn export_corpus_snapshots_flat_f32_only() {
        let ex = RetrievalExecutor::flat(4);
        ex.add(7, &[1.0, 0.0, 0.0, 0.0]);
        ex.add(9, &[0.0, 1.0, 0.0, 0.0]);
        let (ids, rows, version) = ex.export_corpus().expect("flat f32 exports");
        assert_eq!(ids, vec![7, 9]);
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0], 1.0);
        assert_eq!(version, ex.version());
        // Quantized arenas cannot guarantee a bit-identical f32 mirror.
        let qx = RetrievalExecutor::flat_quant(4, Quant::Int8);
        qx.add(1, &[0.5, 0.5, 0.0, 0.0]);
        assert!(qx.export_corpus().is_none());
    }

    /// Satellite regression: one panicking writer must not permanently
    /// kill front-end retrieval. The canonical poisoner is `add` with a
    /// mis-sized vector — the dimension assert fires while the write
    /// guard is held (and before any mutation, so the corpus is intact).
    #[test]
    fn poisoned_lock_recovers_reads_and_counts() {
        let ex = std::sync::Arc::new(RetrievalExecutor::flat(4));
        for i in 0..8u64 {
            let a = (i as f32) * 0.4;
            ex.add(i, &[a.cos(), a.sin(), 0.0, 0.0]);
        }
        // Poison: a writer thread panics while holding the write lock.
        let poisoner = std::sync::Arc::clone(&ex);
        let joined = std::thread::spawn(move || poisoner.add(99, &[1.0, 2.0])).join();
        assert!(joined.is_err(), "mis-sized add must panic");
        assert!(ex.index.is_poisoned(), "lock must actually be poisoned");
        // Every read-side accessor recovers and serves intact data.
        assert_eq!(ex.len(), 8);
        assert_eq!(ex.dim(), 4);
        let q = [0.8f32.cos(), 0.8f32.sin(), 0.0, 0.0];
        let hits = ex.search(&q, 3);
        assert_eq!(hits[0].id, 2); // 0.8 == 2 · 0.4
        assert_eq!(ex.search_batch(&[&q[..]], 3)[0], hits);
        let session = ex.begin_scan();
        assert_eq!(session.len(), 8);
        drop(session);
        assert!(ex.poisoned_recoveries() >= 4);
    }

    /// Satellite regression (admission-cost TOCTOU): with estimate and
    /// scan under one read guard, concurrent adds can never make the
    /// admitted cost lag the bytes the scan actually streams — writers
    /// block until the session drops, so the lag is exactly zero (well
    /// under the one-batch tolerance the invariant allows).
    #[test]
    fn scan_session_pins_cost_to_scanned_bytes_under_concurrent_adds() {
        let dim = 8;
        let ex = std::sync::Arc::new(RetrievalExecutor::flat(dim));
        for i in 0..32u64 {
            let a = (i as f32) * 0.2;
            let mut v = vec![0.0f32; dim];
            v[0] = a.cos();
            v[1] = a.sin();
            ex.add(i, &v);
        }
        let session = ex.begin_scan();
        let admitted_bytes = session.scan_bytes_estimate();
        // A writer racing the admitted scan: must block on the session.
        let writer = {
            let ex = std::sync::Arc::clone(&ex);
            std::thread::spawn(move || {
                for i in 32..48u64 {
                    let mut v = vec![0.0f32; dim];
                    v[0] = 1.0;
                    ex.add(i, &v);
                }
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        // The corpus this session can scan is byte-for-byte what was
        // costed — the racing adds have not landed.
        assert_eq!(session.len(), 32);
        assert_eq!(session.scan_bytes_estimate(), admitted_bytes);
        let q = vec![1.0f32; dim];
        let hits = session.search_batch(&[&q[..]], 5);
        assert_eq!(session.len() * Quant::F32.bytes_per_row(dim), admitted_bytes);
        assert_eq!(hits[0].len(), 5);
        drop(session);
        writer.join().unwrap();
        assert_eq!(ex.len(), 48);
    }

    #[test]
    fn codec_labels_track_arena_quant() {
        use crate::metrics::trace::CodecLabel;
        for (quant, label) in [
            (Quant::F32, CodecLabel::F32),
            (Quant::F16, CodecLabel::F16),
            (Quant::Int8, CodecLabel::Int8),
            (Quant::pq(4), CodecLabel::Pq4),
            (Quant::pq(8), CodecLabel::Pq8),
        ] {
            let ex = RetrievalExecutor::flat_quant(8, quant);
            assert_eq!(ex.codec_label(), label, "{quant:?}");
            assert_eq!(ex.begin_scan().codec_label(), label, "{quant:?}");
        }
    }

    #[test]
    fn synthetic_sleeps_scaled_time() {
        let mut p = DeviceProfile::v100_bge();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        let mut b = SyntheticBackend::new(p.clone(), 1e-3, 1); // ms instead of s
        let t0 = std::time::Instant::now();
        b.embed(&vec![Arc::<str>::from("q"); 10]).unwrap();
        let el = t0.elapsed().as_secs_f64();
        let want = p.service_time(10, 2) * 1e-3;
        assert!(el >= want * 0.8, "slept {el}, want >= {want}");
    }
}
