//! Batch execution backends for worker instances.
//!
//! [`Backend`] is what a `coordinator::instance::Worker` drives: give it a
//! batch of texts, get embeddings back. Two implementations:
//!
//! * **Real** — wraps [`crate::runtime::EmbeddingEngine`]: PJRT-compiled
//!   AOT artifacts on the CPU PJRT client (the production path). Because
//!   PJRT handles are not `Send`, workers construct this backend on their
//!   own thread via the factory passed to the service.
//! * **[`SyntheticBackend`]** — profile-driven: sleeps for the calibrated
//!   `t(batch, qlen)` and returns deterministic pseudo-embeddings. Used by
//!   the paper-scale experiments (our testbed has no V100/Atlas — see
//!   DESIGN.md §2) and by tests that must not depend on built artifacts.

use std::path::PathBuf;
use std::sync::RwLock;
use std::time::Duration;

use anyhow::Result;

use super::profile::DeviceProfile;
use crate::runtime::{tokenizer, EmbeddingEngine};
use crate::util::rng::Pcg;
use crate::vecstore::{FlatIndex, Hit, Index, Quant, QuantizedFlatIndex};

/// A batch embedding executor owned by one worker instance.
pub trait Backend {
    /// Embed a batch; one vector per input text.
    fn embed(&mut self, texts: &[String]) -> Result<Vec<Vec<f32>>>;
    /// Human-readable backend description (for /stats and logs).
    fn describe(&self) -> String;
    /// Largest batch worth submitting at once (bucket cap for real
    /// engines; queue depth elsewhere).
    fn max_batch(&self) -> usize;
}

/// Real PJRT backend.
pub struct RealBackend {
    engine: EmbeddingEngine,
}

impl RealBackend {
    pub fn load(artifacts: &PathBuf, model: &str) -> Result<RealBackend> {
        let mut engine = EmbeddingEngine::load(artifacts, model)?;
        engine.warmup()?;
        Ok(RealBackend { engine })
    }
}

impl Backend for RealBackend {
    fn embed(&mut self, texts: &[String]) -> Result<Vec<Vec<f32>>> {
        self.engine.embed(texts)
    }

    fn describe(&self) -> String {
        format!("pjrt:{}", self.engine.model_name())
    }

    fn max_batch(&self) -> usize {
        self.engine.max_batch()
    }
}

/// Profile-driven synthetic backend: calibrated latency + deterministic
/// hash pseudo-embeddings (so routing/batching tests can assert payloads).
pub struct SyntheticBackend {
    pub profile: DeviceProfile,
    pub d_model: usize,
    /// Wall-clock scale: 1.0 replays paper-scale seconds, small values
    /// (e.g. 1e-3) keep tests fast while preserving ratios.
    pub time_scale: f64,
    rng: Pcg,
}

impl SyntheticBackend {
    pub fn new(profile: DeviceProfile, time_scale: f64, seed: u64) -> SyntheticBackend {
        SyntheticBackend { profile, d_model: 64, time_scale, rng: Pcg::new(seed) }
    }

    fn pseudo_embedding(&self, text: &str, d: usize) -> Vec<f32> {
        // Deterministic unit vector derived from the token stream.
        let mut state = tokenizer::fnv1a64(text.as_bytes());
        let mut v: Vec<f32> = (0..d)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect();
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        v.iter_mut().for_each(|x| *x /= norm);
        v
    }
}

impl Backend for SyntheticBackend {
    fn embed(&mut self, texts: &[String]) -> Result<Vec<Vec<f32>>> {
        let qlen = texts
            .iter()
            .map(|t| tokenizer::token_count(t))
            .max()
            .unwrap_or(1);
        let secs = self
            .profile
            .noisy_service_time(texts.len(), qlen, &mut self.rng)
            * self.time_scale;
        if secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
        Ok(texts
            .iter()
            .map(|t| self.pseudo_embedding(t, self.d_model))
            .collect())
    }

    fn describe(&self) -> String {
        format!("synthetic:{}", self.profile.name)
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }
}

/// CPU-side batch retrieval executor: owns the vector index the service
/// scans, behind a `RwLock` so concurrent front-end threads share read
/// scans while corpus writers take the lock exclusively.
///
/// This is where CPU-offloaded peak queries converge: the service's
/// retrieval path collects a panel of embedded queries (whether they were
/// embedded on the NPU queue or the CPU overflow queue) and drives one
/// [`Index::search_batch`] call, which shards the scan across host cores
/// on the SIMD kernels instead of paying one sequential scan per query.
pub struct RetrievalExecutor {
    /// The index's storage codec, cached at construction (a boxed index
    /// never changes codec) so hot-path callers don't take the lock.
    quant: Quant,
    index: RwLock<Box<dyn Index + Send + Sync>>,
}

impl RetrievalExecutor {
    pub fn new(index: Box<dyn Index + Send + Sync>) -> RetrievalExecutor {
        RetrievalExecutor { quant: index.quant(), index: RwLock::new(index) }
    }

    /// Convenience: an empty exact (flat) index of `dim`.
    pub fn flat(dim: usize) -> RetrievalExecutor {
        RetrievalExecutor::new(Box::new(FlatIndex::new(dim)))
    }

    /// Convenience: an empty exact index of `dim` whose rows are stored
    /// under `quant` — the compact arena CPU-offloaded peak queries scan
    /// (2-4× less bandwidth per concurrent scan than f32).
    pub fn flat_quant(dim: usize, quant: Quant) -> RetrievalExecutor {
        RetrievalExecutor::new(Box::new(QuantizedFlatIndex::new(dim, quant)))
    }

    /// Storage codec of the attached index's row arena (lock-free).
    pub fn quant(&self) -> Quant {
        self.quant
    }

    /// Add one corpus vector (exclusive lock; cheap relative to scans).
    pub fn add(&self, id: u64, vector: &[f32]) {
        self.index.write().expect("index lock poisoned").add(id, vector);
    }

    pub fn len(&self) -> usize {
        self.index.read().expect("index lock poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.index.read().expect("index lock poisoned").dim()
    }

    /// Bytes one batched scan streams from the attached arena: the
    /// index's scanned-rows estimate (full corpus for exhaustive scans,
    /// the nprobe/nlist share for IVF) × bytes_per_row of the active
    /// codec. This is the executor's per-scan cost report to admission —
    /// the scan is memory-bound, so bytes scanned is the honest proxy
    /// for how much of the calibrated CPU depth one scan consumes (see
    /// `coordinator::queue_manager`).
    pub fn scan_bytes_estimate(&self) -> usize {
        let g = self.index.read().expect("index lock poisoned");
        g.scan_rows_estimate() * self.quant.bytes_per_row(g.dim())
    }

    /// Admission slot cost of one batched scan, normalized to embed-query
    /// cost units of `unit_bytes` (≥ 1: even a tiny scan holds a slot
    /// while it runs).
    pub fn scan_cost(&self, unit_bytes: usize) -> usize {
        crate::coordinator::queue_manager::retrieval_slot_cost(
            self.scan_bytes_estimate(),
            unit_bytes,
        )
    }

    /// Single-query top-k (shared lock).
    pub fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        self.index.read().expect("index lock poisoned").search(query, k)
    }

    /// Batched top-k over a query panel (shared lock, sharded scan).
    pub fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        self.index
            .read()
            .expect("index lock poisoned")
            .search_batch(queries, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_synth() -> SyntheticBackend {
        let mut p = DeviceProfile::v100_bge();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        SyntheticBackend::new(p, 1e-6, 1)
    }

    #[test]
    fn synthetic_returns_unit_vectors() {
        let mut b = fast_synth();
        let out = b.embed(&["hello world".into(), "other".into()]).unwrap();
        assert_eq!(out.len(), 2);
        for v in &out {
            let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn synthetic_is_deterministic_per_text() {
        let mut b = fast_synth();
        let a = b.embed(&["same text".into()]).unwrap();
        let c = b.embed(&["same text".into()]).unwrap();
        assert_eq!(a, c);
        let d = b.embed(&["different".into()]).unwrap();
        assert_ne!(a, d);
    }

    #[test]
    fn retrieval_executor_quantized_flat() {
        for quant in [Quant::F16, Quant::Int8] {
            let ex = RetrievalExecutor::flat_quant(4, quant);
            assert_eq!(ex.quant(), quant);
            for i in 0..16u64 {
                let a = (i as f32) * 0.3;
                ex.add(i, &[a.cos(), a.sin(), 0.0, 0.0]);
            }
            let q = [0.6f32.cos(), 0.6f32.sin(), 0.0, 0.0];
            let hits = ex.search(&q, 3);
            assert_eq!(hits[0].id, 2, "{quant:?}"); // 0.6 == 2 * 0.3
            let batch = ex.search_batch(&[&q[..]], 3);
            assert_eq!(batch[0], hits);
        }
        assert_eq!(RetrievalExecutor::flat(4).quant(), Quant::F32);
    }

    #[test]
    fn scan_cost_tracks_codec_bytes_per_row() {
        let dim = 16;
        for (quant, bpr) in [(Quant::F32, 64), (Quant::F16, 32), (Quant::Int8, 20)] {
            let ex = RetrievalExecutor::flat_quant(dim, quant);
            assert_eq!(ex.scan_bytes_estimate(), 0);
            // An empty index still costs one slot per scan.
            assert_eq!(ex.scan_cost(1024), 1);
            for i in 0..64u64 {
                ex.add(i, &[0.5; 16]);
            }
            assert_eq!(quant.bytes_per_row(dim), bpr, "{quant:?}");
            assert_eq!(ex.scan_bytes_estimate(), 64 * bpr);
            // cost = ceil(bytes / unit), so the compact codecs cost
            // strictly less than f32 at the same unit.
            assert_eq!(ex.scan_cost(1024), (64 * bpr).div_ceil(1024));
            // A huge unit collapses every scan to the 1-slot floor.
            assert_eq!(ex.scan_cost(usize::MAX), 1);
        }
    }

    #[test]
    fn scan_cost_charges_ivf_only_for_probed_share() {
        use crate::vecstore::IvfIndex;
        let dim = 8;
        let mut ivf = IvfIndex::new(dim, 8, 2);
        for i in 0..64u64 {
            let a = (i as f32) * 0.1;
            ivf.add(i, &[a.cos(), a.sin(), 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        }
        // Unbuilt: scans everything.
        assert_eq!(ivf.scan_rows_estimate(), 64);
        ivf.build(7);
        // Built: nprobe/nlist share of the corpus, not the whole arena.
        assert_eq!(ivf.scan_rows_estimate(), 16); // 64 · 2 / 8
        let ex = RetrievalExecutor::new(Box::new(ivf));
        assert_eq!(ex.scan_bytes_estimate(), 16 * Quant::F32.bytes_per_row(dim));
    }

    #[test]
    fn retrieval_executor_batch_matches_single() {
        let ex = RetrievalExecutor::flat(4);
        assert!(ex.is_empty());
        for i in 0..32u64 {
            let a = (i as f32) * 0.1;
            let v = [a.cos(), a.sin(), 0.0, 0.0];
            ex.add(i, &v);
        }
        assert_eq!(ex.len(), 32);
        assert_eq!(ex.dim(), 4);
        let queries: Vec<[f32; 4]> = (0..5)
            .map(|i| {
                let a = (i as f32) * 0.7;
                [a.cos(), a.sin(), 0.0, 0.0]
            })
            .collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let batch = ex.search_batch(&qrefs, 3);
        assert_eq!(batch.len(), 5);
        for (q, got) in qrefs.iter().zip(&batch) {
            assert_eq!(got, &ex.search(q, 3));
        }
    }

    #[test]
    fn synthetic_sleeps_scaled_time() {
        let mut p = DeviceProfile::v100_bge();
        p.noise_sigma = 0.0;
        p.outlier_prob = 0.0;
        let mut b = SyntheticBackend::new(p.clone(), 1e-3, 1); // ms instead of s
        let t0 = std::time::Instant::now();
        b.embed(&vec!["q".to_string(); 10]).unwrap();
        let el = t0.elapsed().as_secs_f64();
        let want = p.service_time(10, 2) * 1e-3;
        assert!(el >= want * 0.8, "slept {el}, want >= {want}");
    }
}
