//! Device layer: calibrated performance profiles of the paper's testbed
//! devices, the executor abstraction (real PJRT vs profile-driven
//! synthetic), and CPU affinity/NUMA placement (paper §4.4).

pub mod affinity;
pub mod executor;
pub mod profile;

pub use executor::{Backend, SyntheticBackend};
pub use profile::{DeviceKind, DeviceProfile};
