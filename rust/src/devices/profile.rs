//! Calibrated device performance profiles.
//!
//! The paper reduces each device to the linear latency model of Eq. 12,
//! `t_proc = α·C + β` (C = concurrency = batch size under the paper's
//! batch-synchronous closed-loop measurement, §5.1.3). We carry a
//! **piecewise-linear** true curve anchored on the paper's *fine-tuned*
//! queue depths at the 1 s and 2 s SLOs, so that the paper's own
//! phenomena re-emerge from our estimator code rather than being wired
//! in: the linear fit over low-concurrency probes slightly over-predicts
//! capacity under the looser SLO (convexity), stress tests quantise to
//! their step, and noisy devices (Kunpeng, §5.3) scatter the fit.
//!
//! Calibration sources (see DESIGN.md §5 for the derivations):
//! * β from the paper's Figure 4 fits: V100 0.27, Xeon 0.32,
//!   Atlas 0.24, Kunpeng 0.85.
//! * anchors from Tables 1-3 fine-tuned depths (bge: V100 44/96,
//!   Xeon 8/22, Atlas 84/172, Kunpeng 2/8; jina: Table 2).
//! * noise/outliers: Kunpeng's elevated outlier rate reproduces the
//!   Table 3 estimator-vs-stress discrepancy the paper reports.

use crate::util::rng::Pcg;

/// SLO comparison with an absolute epsilon: calibrated anchor points land
/// exactly on the SLO and must not fail to float rounding.
pub fn slo_met(t: f64, slo: f64) -> bool {
    t <= slo + 1e-9
}

/// Device class, per the paper's NPU/GPU-vs-CPU split.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Accelerator (NPU or GPU — the paper treats them interchangeably).
    Npu,
    /// Host CPU sockets.
    Cpu,
}

impl std::fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeviceKind::Npu => write!(f, "NPU"),
            DeviceKind::Cpu => write!(f, "CPU"),
        }
    }
}

/// Calibrated latency model for one device (one embedding instance).
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    pub kind: DeviceKind,
    /// Intercept β (seconds): model-load/launch overhead, Eq. 12/13.
    pub beta: f64,
    /// Slope α₁ (s/query) below the first anchor.
    pub alpha1: f64,
    /// Slope α₂ (s/query) above the first anchor (convexity; = α₁ for a
    /// perfectly linear device).
    pub alpha2: f64,
    /// Concurrency at which the slope changes (the 1 s-SLO anchor).
    pub knee: usize,
    /// Query length (tokens) at which α/β were calibrated (paper: 75).
    pub ref_len: usize,
    /// Exponent of the compute-term length scaling: α ∝ (len/ref_len)^e.
    pub len_alpha_exp: f64,
    /// Exponent of the intercept length scaling (IO grows slower).
    pub len_beta_exp: f64,
    /// Relative gaussian noise on each measured latency.
    pub noise_sigma: f64,
    /// Probability a measurement is an outlier (late by `outlier_scale`x).
    pub outlier_prob: f64,
    pub outlier_scale: f64,
    /// CPU-only: cores available / cores the calibration used.
    pub cores: usize,
    pub ref_cores: usize,
}

impl DeviceProfile {
    /// Noise-free service time (seconds) for a batch of `batch` queries of
    /// `qlen` tokens. This is the paper's t_proc for concurrency C=batch.
    pub fn service_time(&self, batch: usize, qlen: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let lf = qlen as f64 / self.ref_len as f64;
        let alpha_scale = lf.powf(self.len_alpha_exp) * self.core_slowdown();
        let beta_scale = lf.powf(self.len_beta_exp) * self.core_slowdown();
        let b = batch as f64;
        let knee = self.knee as f64;
        let lin = if b <= knee || self.alpha1 == self.alpha2 {
            self.alpha1 * b
        } else {
            self.alpha1 * knee + self.alpha2 * (b - knee)
        };
        lin * alpha_scale + self.beta * beta_scale
    }

    /// Service time with measurement noise/outliers (stress tests and the
    /// simulator sample this; the estimator sees these values).
    pub fn noisy_service_time(&self, batch: usize, qlen: usize, rng: &mut Pcg) -> f64 {
        let t = self.service_time(batch, qlen);
        let mut v = t * (1.0 + self.noise_sigma * rng.normal());
        if rng.chance(self.outlier_prob) {
            v = t * self.outlier_scale * (1.0 + 0.5 * rng.f64());
        }
        v.max(t * 0.5)
    }

    /// Core-count slowdown for CPU devices (Fig. 6 calibration).
    ///
    /// `s(c) = 1 + k·((ref/c)^e − 1)` with (k, e) tuned so the CPU stops
    /// helping below 44 cores at the 1 s SLO and below 36 cores at 2 s
    /// (the crossovers the paper reports). NPUs return 1.0.
    pub fn core_slowdown(&self) -> f64 {
        if self.kind != DeviceKind::Cpu || self.cores >= self.ref_cores {
            return 1.0;
        }
        const K: f64 = 0.035;
        const E: f64 = 4.8;
        let r = self.ref_cores as f64 / self.cores.max(1) as f64;
        1.0 + K * (r.powf(E) - 1.0)
    }

    /// Largest noise-free concurrency meeting `slo` seconds at `qlen`
    /// tokens (ground truth the estimators are judged against).
    pub fn true_max_concurrency(&self, slo: f64, qlen: usize) -> usize {
        if !slo_met(self.service_time(1, qlen), slo) {
            return 0; // paper Eq. 11: device unusable at this SLO
        }
        let mut c = 1usize;
        // Exponential then binary search; curve is monotone in batch.
        while slo_met(self.service_time(c * 2, qlen), slo) && c < 1 << 20 {
            c *= 2;
        }
        let (mut lo, mut hi) = (c, c * 2);
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if slo_met(self.service_time(mid, qlen), slo) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    pub fn with_cores(&self, cores: usize) -> DeviceProfile {
        let mut p = self.clone();
        p.cores = cores;
        p
    }

    // ----- the paper's testbed, bge-large-zh-v1.5 calibration -----

    /// Tesla V100 GPU (bge): fine-tuned anchors 44 @ 1 s, 96 @ 2 s.
    pub fn v100_bge() -> DeviceProfile {
        DeviceProfile::anchored("tesla_v100", DeviceKind::Npu, 0.27, 44, 96, 0.015, 0.002, 3.0)
    }

    /// 2x Intel Xeon E5-2690 (bge): anchors 8 @ 1 s, 22 @ 2 s.
    pub fn xeon_e5_2690_bge() -> DeviceProfile {
        DeviceProfile::anchored("xeon_e5_2690", DeviceKind::Cpu, 0.32, 8, 22, 0.02, 0.005, 3.0)
    }

    /// Atlas 300I DUO NPU (bge): anchors 84 @ 1 s, 172 @ 2 s.
    pub fn atlas_300i_duo_bge() -> DeviceProfile {
        DeviceProfile::anchored("atlas_300i_duo", DeviceKind::Npu, 0.24, 84, 172, 0.02, 0.01, 4.0)
    }

    /// 2x Kunpeng 920 (bge): anchors 2 @ 1 s, 8 @ 2 s. Elevated outlier
    /// rate per the paper's §5.3 observation.
    pub fn kunpeng_920_bge() -> DeviceProfile {
        DeviceProfile::anchored("kunpeng_920", DeviceKind::Cpu, 0.85, 2, 8, 0.05, 0.06, 2.5)
    }

    // ----- jina calibration (Table 2) -----

    /// Tesla V100 (jina): anchors 48 @ 1 s, 112 @ 2 s.
    pub fn v100_jina() -> DeviceProfile {
        DeviceProfile::anchored("tesla_v100_jina", DeviceKind::Npu, 0.25, 48, 112, 0.015, 0.002, 3.0)
    }

    /// 2x Xeon E5-2690 (jina): anchors 11 @ 1 s, 30 @ 2 s.
    pub fn xeon_e5_2690_jina() -> DeviceProfile {
        DeviceProfile::anchored("xeon_e5_2690_jina", DeviceKind::Cpu, 0.35, 11, 30, 0.02, 0.005, 3.0)
    }

    /// Atlas 300I DUO (jina): anchors 128 @ 1 s, 256 @ 2 s.
    pub fn atlas_300i_duo_jina() -> DeviceProfile {
        DeviceProfile::anchored("atlas_300i_duo_jina", DeviceKind::Npu, 0.2, 128, 256, 0.02, 0.01, 4.0)
    }

    /// 2x Kunpeng 920 (jina): anchors 6 @ 1 s, 20 @ 2 s.
    pub fn kunpeng_920_jina() -> DeviceProfile {
        DeviceProfile::anchored("kunpeng_920_jina", DeviceKind::Cpu, 0.55, 6, 20, 0.05, 0.06, 2.5)
    }

    /// Registry lookup by name (CLI/config use).
    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        Some(match name {
            "v100_bge" | "v100" | "tesla_v100" => Self::v100_bge(),
            "xeon_bge" | "xeon" | "xeon_e5_2690" => Self::xeon_e5_2690_bge(),
            "atlas_bge" | "atlas" | "atlas_300i_duo" => Self::atlas_300i_duo_bge(),
            "kunpeng_bge" | "kunpeng" | "kunpeng_920" => Self::kunpeng_920_bge(),
            "v100_jina" | "tesla_v100_jina" => Self::v100_jina(),
            "xeon_jina" | "xeon_e5_2690_jina" => Self::xeon_e5_2690_jina(),
            "atlas_jina" | "atlas_300i_duo_jina" => Self::atlas_300i_duo_jina(),
            "kunpeng_jina" | "kunpeng_920_jina" => Self::kunpeng_920_jina(),
            _ => return None,
        })
    }

    /// Build a profile from SLO anchor points: latency hits 1.0 s at
    /// `c_1s` concurrent queries and 2.0 s at `c_2s` (paper fine-tuned
    /// depths), with intercept `beta` from the Figure 4 fit.
    fn anchored(
        name: &str,
        kind: DeviceKind,
        beta: f64,
        c_1s: usize,
        c_2s: usize,
        noise_sigma: f64,
        outlier_prob: f64,
        outlier_scale: f64,
    ) -> DeviceProfile {
        let alpha1 = (1.0 - beta) / c_1s as f64;
        let alpha2 = 1.0 / (c_2s - c_1s) as f64;
        let cores = if kind == DeviceKind::Cpu { 96 } else { 0 };
        DeviceProfile {
            name: name.to_string(),
            kind,
            beta,
            alpha1,
            alpha2,
            knee: c_1s,
            ref_len: 75,
            len_alpha_exp: 1.0,
            len_beta_exp: 0.3,
            noise_sigma,
            outlier_prob,
            outlier_scale,
            cores,
            ref_cores: if kind == DeviceKind::Cpu { 96 } else { 0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_hit_paper_fine_tuned_depths() {
        // bge, Table 1 / Table 3 fine-tuned values.
        assert_eq!(DeviceProfile::v100_bge().true_max_concurrency(1.0, 75), 44);
        assert_eq!(DeviceProfile::v100_bge().true_max_concurrency(2.0, 75), 96);
        assert_eq!(DeviceProfile::xeon_e5_2690_bge().true_max_concurrency(1.0, 75), 8);
        assert_eq!(DeviceProfile::xeon_e5_2690_bge().true_max_concurrency(2.0, 75), 22);
        assert_eq!(DeviceProfile::atlas_300i_duo_bge().true_max_concurrency(1.0, 75), 84);
        assert_eq!(DeviceProfile::atlas_300i_duo_bge().true_max_concurrency(2.0, 75), 172);
        assert_eq!(DeviceProfile::kunpeng_920_bge().true_max_concurrency(1.0, 75), 2);
        assert_eq!(DeviceProfile::kunpeng_920_bge().true_max_concurrency(2.0, 75), 8);
    }

    #[test]
    fn jina_anchors_match_table2() {
        assert_eq!(DeviceProfile::v100_jina().true_max_concurrency(1.0, 75), 48);
        assert_eq!(DeviceProfile::v100_jina().true_max_concurrency(2.0, 75), 112);
        assert_eq!(DeviceProfile::xeon_e5_2690_jina().true_max_concurrency(1.0, 75), 11);
        assert_eq!(DeviceProfile::xeon_e5_2690_jina().true_max_concurrency(2.0, 75), 30);
        assert_eq!(DeviceProfile::atlas_300i_duo_jina().true_max_concurrency(1.0, 75), 128);
        assert_eq!(DeviceProfile::atlas_300i_duo_jina().true_max_concurrency(2.0, 75), 256);
        assert_eq!(DeviceProfile::kunpeng_920_jina().true_max_concurrency(1.0, 75), 6);
        assert_eq!(DeviceProfile::kunpeng_920_jina().true_max_concurrency(2.0, 75), 20);
    }

    #[test]
    fn beta_cpu_exceeds_beta_npu() {
        // Paper inequality (15): β_CPU > β_NPU for each pairing.
        assert!(DeviceProfile::xeon_e5_2690_bge().beta > DeviceProfile::v100_bge().beta);
        assert!(DeviceProfile::kunpeng_920_bge().beta > DeviceProfile::atlas_300i_duo_bge().beta);
    }

    #[test]
    fn alpha_cpu_exceeds_alpha_npu() {
        // Paper inequality (14): α_CPU > α_NPU.
        assert!(
            DeviceProfile::xeon_e5_2690_bge().alpha1 > DeviceProfile::v100_bge().alpha1
        );
        assert!(
            DeviceProfile::kunpeng_920_bge().alpha1 > DeviceProfile::atlas_300i_duo_bge().alpha1
        );
    }

    #[test]
    fn alpha_ratio_matches_paper_fig4() {
        // Paper: α_NPU/α_CPU ≈ 0.21 (V100/Xeon) and ≈ 0.12 (Atlas/Kunpeng).
        let r1 = DeviceProfile::v100_bge().alpha1 / DeviceProfile::xeon_e5_2690_bge().alpha1;
        let r2 =
            DeviceProfile::atlas_300i_duo_bge().alpha1 / DeviceProfile::kunpeng_920_bge().alpha1;
        assert!((r1 - 0.21).abs() < 0.03, "V100/Xeon α ratio {r1}");
        assert!((r2 - 0.12).abs() < 0.03, "Atlas/Kunpeng α ratio {r2}");
    }

    #[test]
    fn service_time_monotone_in_batch_and_length() {
        let p = DeviceProfile::v100_bge();
        let mut prev = 0.0;
        for b in 1..200 {
            let t = p.service_time(b, 75);
            assert!(t > prev);
            prev = t;
        }
        assert!(p.service_time(10, 500) > p.service_time(10, 75));
    }

    #[test]
    fn core_scaling_crossovers_match_fig6() {
        // CPU benefit vanishes below ~44 cores at 1 s and ~36 at 2 s.
        let p = DeviceProfile::xeon_e5_2690_bge();
        assert!(p.with_cores(96).true_max_concurrency(1.0, 75) >= 8);
        assert!(p.with_cores(48).true_max_concurrency(1.0, 75) >= 1);
        assert_eq!(p.with_cores(40).true_max_concurrency(1.0, 75), 0);
        assert!(p.with_cores(40).true_max_concurrency(2.0, 75) >= 1);
        assert_eq!(p.with_cores(32).true_max_concurrency(2.0, 75), 0);
    }

    #[test]
    fn npu_ignores_core_scaling() {
        let p = DeviceProfile::v100_bge();
        assert_eq!(p.core_slowdown(), 1.0);
    }

    #[test]
    fn fig5_length_scaling_kills_cpu_at_500_tokens_1s() {
        // Paper Fig. 5: CPU additional concurrency → 0 at 500 tokens / 1 s,
        // but still ≈2 at 500 tokens / 2 s.
        let cpu = DeviceProfile::xeon_e5_2690_bge();
        assert_eq!(cpu.true_max_concurrency(1.0, 500), 0);
        let at2s = cpu.true_max_concurrency(2.0, 500);
        assert!((1..=4).contains(&at2s), "CPU @500tok/2s: {at2s}");
        // NPU retains some capacity at 500 tokens.
        assert!(DeviceProfile::v100_bge().true_max_concurrency(2.0, 500) >= 5);
    }

    #[test]
    fn noisy_service_time_is_reproducible_and_positive() {
        let p = DeviceProfile::kunpeng_920_bge();
        let mut a = Pcg::new(3);
        let mut b = Pcg::new(3);
        for batch in 1..20 {
            let x = p.noisy_service_time(batch, 75, &mut a);
            let y = p.noisy_service_time(batch, 75, &mut b);
            assert_eq!(x, y);
            assert!(x > 0.0);
        }
    }

    #[test]
    fn kunpeng_is_noisier_than_xeon() {
        let k = DeviceProfile::kunpeng_920_bge();
        let x = DeviceProfile::xeon_e5_2690_bge();
        assert!(k.outlier_prob > x.outlier_prob);
    }

    #[test]
    fn registry_lookup() {
        assert!(DeviceProfile::by_name("v100").is_some());
        assert!(DeviceProfile::by_name("kunpeng_jina").is_some());
        assert!(DeviceProfile::by_name("h100").is_none());
    }

    #[test]
    fn single_query_timeout_case_eq11() {
        // A hypothetical very slow CPU: even one query misses the SLO →
        // the offloading opportunity disappears (paper Eq. 11).
        let mut p = DeviceProfile::kunpeng_920_bge();
        p.beta = 1.2;
        assert_eq!(p.true_max_concurrency(1.0, 75), 0);
    }
}
