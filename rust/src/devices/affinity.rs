//! CPU affinity + NUMA placement (paper §4.4).
//!
//! The paper's empirical guidance for ARM hosts: pin embedding workers to
//! cores **in reversed index order** (the service framework and OS settle
//! on low-index cores) and **never cross a NUMA node** within one worker.
//! This module implements that plan: a topology model, the reversed
//! non-crossing core picker, and the actual `sched_setaffinity` call
//! (via the in-repo FFI shim `util::sys` — the vendor set has no `libc`
//! crate).

use anyhow::{bail, Result};

/// Host CPU topology: total cores grouped into equal NUMA nodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    pub cores: usize,
    pub numa_nodes: usize,
}

impl Topology {
    pub fn new(cores: usize, numa_nodes: usize) -> Topology {
        assert!(numa_nodes > 0 && cores >= numa_nodes);
        Topology { cores, numa_nodes }
    }

    /// Detect the running host (cores from the OS; NUMA from sysfs,
    /// defaulting to 1 when unavailable).
    pub fn detect() -> Topology {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let numa_nodes = std::fs::read_dir("/sys/devices/system/node")
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| {
                        e.file_name()
                            .to_string_lossy()
                            .strip_prefix("node")
                            .map(|s| s.chars().all(|c| c.is_ascii_digit()))
                            .unwrap_or(false)
                    })
                    .count()
                    .max(1)
            })
            .unwrap_or(1);
        Topology { cores, numa_nodes }
    }

    pub fn cores_per_node(&self) -> usize {
        self.cores / self.numa_nodes
    }

    /// NUMA node of a core index.
    pub fn node_of(&self, core: usize) -> usize {
        (core / self.cores_per_node()).min(self.numa_nodes - 1)
    }

    /// All core indices belonging to `node` (remainder cores fold into
    /// the last node, mirroring [`Topology::node_of`]).
    pub fn cores_of_node(&self, node: usize) -> Vec<usize> {
        assert!(node < self.numa_nodes);
        (0..self.cores).filter(|&c| self.node_of(c) == node).collect()
    }

    /// Pick `n` cores for one worker per the paper's §4.4 heuristic:
    /// highest indices first, truncated so the set never crosses a NUMA
    /// boundary. Returns an error if `n` exceeds one node's cores (the
    /// paper recommends one CPU instance per machine sized within a node
    /// group; callers wanting more spawn multiple workers).
    pub fn pick_cores_reversed(&self, n: usize, already_taken: usize) -> Result<Vec<usize>> {
        if n == 0 {
            bail!("cannot pin to zero cores");
        }
        if n > self.cores_per_node() * self.numa_nodes {
            bail!("requested {n} cores > {} available", self.cores);
        }
        // Walk from the top core downward, skipping cores already handed
        // out, and cut the allocation at a NUMA boundary.
        let mut picked = Vec::with_capacity(n);
        let start = self
            .cores
            .checked_sub(already_taken)
            .ok_or_else(|| anyhow::anyhow!("cores exhausted"))?;
        if start == 0 {
            bail!("cores exhausted");
        }
        let first = start - 1;
        let node = self.node_of(first);
        for core in (0..=first).rev() {
            if self.node_of(core) != node {
                break; // §4.4: no NUMA crossing
            }
            picked.push(core);
            if picked.len() == n {
                return Ok(picked);
            }
        }
        bail!(
            "cannot allocate {n} cores within NUMA node {node} (got {})",
            picked.len()
        )
    }
}

/// Pin the calling thread to the given cores (Linux `sched_setaffinity`).
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cores: &[usize]) -> Result<()> {
    if cores.is_empty() {
        bail!("empty core set");
    }
    crate::util::sys::set_thread_affinity(cores)
        .map_err(|e| anyhow::anyhow!("sched_setaffinity failed: {e}"))
}

#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cores: &[usize]) -> Result<()> {
    Ok(()) // no-op off Linux
}

/// Current thread's allowed cores (for tests).
#[cfg(target_os = "linux")]
pub fn current_affinity() -> Result<Vec<usize>> {
    crate::util::sys::get_thread_affinity()
        .map_err(|e| anyhow::anyhow!("sched_getaffinity failed: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kunpeng_like_topology() {
        // 128 cores, 4 numas (the paper's Atlas 800 host).
        let t = Topology::new(128, 4);
        assert_eq!(t.cores_per_node(), 32);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(127), 3);
        assert_eq!(t.node_of(95), 2);
    }

    #[test]
    fn cores_of_node_partitions_all_cores() {
        let t = Topology::new(10, 3); // uneven: remainder folds into node 2
        let mut seen = Vec::new();
        for node in 0..t.numa_nodes {
            let cores = t.cores_of_node(node);
            assert!(!cores.is_empty());
            for &c in &cores {
                assert_eq!(t.node_of(c), node);
            }
            seen.extend(cores);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn reversed_pick_starts_at_top_core() {
        let t = Topology::new(128, 4);
        let cores = t.pick_cores_reversed(8, 0).unwrap();
        assert_eq!(cores, vec![127, 126, 125, 124, 123, 122, 121, 120]);
    }

    #[test]
    fn pick_never_crosses_numa() {
        let t = Topology::new(128, 4);
        // From offset 30 taken, the walk starts at core 97 (node 3) and may
        // only descend to core 96 before hitting node 2 → only 2 available.
        let err = t.pick_cores_reversed(8, 30).unwrap_err();
        assert!(err.to_string().contains("NUMA"), "{err}");
        let ok = t.pick_cores_reversed(2, 30).unwrap();
        assert_eq!(ok, vec![97, 96]);
        for w in ok.windows(2) {
            assert_eq!(t.node_of(w[0]), t.node_of(w[1]));
        }
    }

    #[test]
    fn single_numa_topology_behaves() {
        let t = Topology::new(8, 1);
        assert_eq!(t.pick_cores_reversed(8, 0).unwrap().len(), 8);
        assert!(t.pick_cores_reversed(9, 0).is_err());
        assert!(t.pick_cores_reversed(0, 0).is_err());
    }

    #[test]
    fn exhausted_cores_error() {
        let t = Topology::new(8, 1);
        assert!(t.pick_cores_reversed(1, 8).is_err());
    }

    #[test]
    fn detect_reports_positive_counts() {
        let t = Topology::detect();
        assert!(t.cores >= 1);
        assert!(t.numa_nodes >= 1);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn pin_and_read_back() {
        let all = current_affinity().unwrap();
        if all.len() < 2 {
            return; // single-core CI box: nothing to assert
        }
        let target = vec![all[0]];
        pin_current_thread(&target).unwrap();
        let now = current_affinity().unwrap();
        assert_eq!(now, target);
        // restore
        pin_current_thread(&all).unwrap();
    }
}
