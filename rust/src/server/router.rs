//! Typed request routing for the v1 API.
//!
//! The route table is declarative: method + path pattern → [`Endpoint`],
//! with `{id}`-style params typed at the table (only `u64` today) and
//! parsed exactly once. Matching yields one of four outcomes the front
//! ends map straight to responses:
//!
//! * [`RouteOutcome::Match`] — handler + parsed params (+ whether the
//!   path is a deprecated alias, so the response can carry a
//!   `Deprecation` header).
//! * [`RouteOutcome::BadParam`] — the shape and method matched but a
//!   typed param didn't parse → **400** with code `invalid_id` (fixes
//!   the old inconsistency where `DELETE /v1/corpus/3junk` sometimes
//!   404'd and sometimes 400'd depending on the junk).
//! * [`RouteOutcome::MethodNotAllowed`] — the path exists under another
//!   method → automatic **405** with an `Allow` header listing every
//!   method the path serves.
//! * [`RouteOutcome::NotFound`] — **404**.
//!
//! `/healthz`, `/metrics` and `/stats` are deprecated aliases of their
//! `/v1/` homes: they keep serving identical bodies but are flagged so
//! responses emit `Deprecation: true` (see `docs/API.md`).

/// What a matched route dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    /// `GET /v1/healthz` (alias `/healthz`) — liveness.
    Healthz,
    /// `GET /v1/metrics` (alias `/metrics`) — metrics snapshot.
    Metrics,
    /// `GET /v1/stats` (alias `/stats`) — queue/route/durability stats.
    Stats,
    /// `GET /v1/ingest/status` — ingest counters.
    IngestStatus,
    /// `GET /v1/trace` — recent request spans + the slow-query log.
    Trace,
    /// `POST /v1/embed` — embed a batch of texts.
    Embed,
    /// `POST /v1/search` — embed a panel of queries and answer them with
    /// one batched top-k scan (the traced retrieval path).
    Search,
    /// `POST /v1/corpus` — streaming NDJSON ingest (body never
    /// materialized; both server modes special-case it).
    CorpusIngest,
    /// `POST /v1/corpus/snapshot` — durable checkpoint.
    CorpusSnapshot,
    /// `DELETE /v1/corpus/{id}` — tombstone one document.
    CorpusDelete,
}

/// One path segment pattern.
#[derive(Debug, Clone, Copy)]
enum Seg {
    Lit(&'static str),
    /// A `{id}`-style typed parameter: matches any segment shape-wise;
    /// must parse as decimal `u64` to produce a [`RouteOutcome::Match`].
    U64,
}

struct Route {
    method: &'static str,
    segs: &'static [Seg],
    endpoint: Endpoint,
    deprecated: bool,
}

/// Declarative route table. Order matters only for tie-breaks between
/// patterns that match the same concrete path (`/v1/corpus/snapshot`
/// before `/v1/corpus/{id}`: the literal wins).
static ROUTES: &[Route] = &[
    Route {
        method: "GET",
        segs: &[Seg::Lit("v1"), Seg::Lit("healthz")],
        endpoint: Endpoint::Healthz,
        deprecated: false,
    },
    Route {
        method: "GET",
        segs: &[Seg::Lit("healthz")],
        endpoint: Endpoint::Healthz,
        deprecated: true,
    },
    Route {
        method: "GET",
        segs: &[Seg::Lit("v1"), Seg::Lit("metrics")],
        endpoint: Endpoint::Metrics,
        deprecated: false,
    },
    Route {
        method: "GET",
        segs: &[Seg::Lit("metrics")],
        endpoint: Endpoint::Metrics,
        deprecated: true,
    },
    Route {
        method: "GET",
        segs: &[Seg::Lit("v1"), Seg::Lit("stats")],
        endpoint: Endpoint::Stats,
        deprecated: false,
    },
    Route {
        method: "GET",
        segs: &[Seg::Lit("stats")],
        endpoint: Endpoint::Stats,
        deprecated: true,
    },
    Route {
        method: "GET",
        segs: &[Seg::Lit("v1"), Seg::Lit("ingest"), Seg::Lit("status")],
        endpoint: Endpoint::IngestStatus,
        deprecated: false,
    },
    Route {
        method: "GET",
        segs: &[Seg::Lit("v1"), Seg::Lit("trace")],
        endpoint: Endpoint::Trace,
        deprecated: false,
    },
    Route {
        method: "POST",
        segs: &[Seg::Lit("v1"), Seg::Lit("embed")],
        endpoint: Endpoint::Embed,
        deprecated: false,
    },
    Route {
        method: "POST",
        segs: &[Seg::Lit("v1"), Seg::Lit("search")],
        endpoint: Endpoint::Search,
        deprecated: false,
    },
    Route {
        method: "POST",
        segs: &[Seg::Lit("v1"), Seg::Lit("corpus")],
        endpoint: Endpoint::CorpusIngest,
        deprecated: false,
    },
    Route {
        method: "POST",
        segs: &[Seg::Lit("v1"), Seg::Lit("corpus"), Seg::Lit("snapshot")],
        endpoint: Endpoint::CorpusSnapshot,
        deprecated: false,
    },
    Route {
        method: "DELETE",
        segs: &[Seg::Lit("v1"), Seg::Lit("corpus"), Seg::U64],
        endpoint: Endpoint::CorpusDelete,
        deprecated: false,
    },
];

/// A successful route: the endpoint plus params parsed once.
#[derive(Debug, Clone)]
pub struct RouteMatch {
    pub endpoint: Endpoint,
    /// The `{id}` param when the pattern has one.
    pub id: Option<u64>,
    /// True when matched via a deprecated alias path.
    pub deprecated: bool,
}

/// Result of routing one request line.
#[derive(Debug, Clone)]
pub enum RouteOutcome {
    Match(RouteMatch),
    /// Method + shape matched, but a typed param didn't parse.
    BadParam { message: String },
    /// Path exists under other methods; `allow` is the `Allow` value.
    MethodNotAllowed { allow: String },
    NotFound,
}

/// The router — stateless over the static table.
pub struct Router;

impl Router {
    pub fn route(method: &str, path: &str) -> RouteOutcome {
        let segs = match segments(path) {
            Some(s) => s,
            None => return RouteOutcome::NotFound,
        };
        let mut allow: Vec<&'static str> = Vec::new();
        let mut bad_param: Option<String> = None;
        for r in ROUTES {
            if r.segs.len() != segs.len() {
                continue;
            }
            let shape_ok = r.segs.iter().zip(segs.iter()).all(|(pat, got)| match *pat {
                Seg::Lit(l) => l == *got,
                Seg::U64 => true,
            });
            if !shape_ok {
                continue;
            }
            if r.method != method {
                if !allow.contains(&r.method) {
                    allow.push(r.method);
                }
                continue;
            }
            let mut id = None;
            let mut param_err = None;
            for (pat, got) in r.segs.iter().zip(segs.iter()) {
                if matches!(pat, Seg::U64) {
                    match got.parse::<u64>() {
                        Ok(v) => id = Some(v),
                        Err(_) => {
                            param_err =
                                Some(format!("document id must be a decimal u64, got {got:?}"))
                        }
                    }
                }
            }
            if let Some(msg) = param_err {
                bad_param = Some(msg);
                continue;
            }
            return RouteOutcome::Match(RouteMatch {
                endpoint: r.endpoint,
                id,
                deprecated: r.deprecated,
            });
        }
        if let Some(message) = bad_param {
            return RouteOutcome::BadParam { message };
        }
        if !allow.is_empty() {
            return RouteOutcome::MethodNotAllowed { allow: allow.join(", ") };
        }
        RouteOutcome::NotFound
    }
}

/// Split a path into segments. `None` rejects shapes routing never
/// serves (no leading `/`, empty segments from `//` or a trailing `/`)
/// — those stay 404, matching the pre-router behavior.
fn segments(path: &str) -> Option<Vec<&str>> {
    let p = path.strip_prefix('/')?;
    if p.is_empty() {
        return Some(Vec::new());
    }
    let segs: Vec<&str> = p.split('/').collect();
    if segs.iter().any(|s| s.is_empty()) {
        return None;
    }
    Some(segs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn must_match(method: &str, path: &str) -> RouteMatch {
        match Router::route(method, path) {
            RouteOutcome::Match(m) => m,
            other => panic!("{method} {path} → {other:?}"),
        }
    }

    #[test]
    fn canonical_v1_paths_route() {
        assert_eq!(must_match("GET", "/v1/healthz").endpoint, Endpoint::Healthz);
        assert_eq!(must_match("GET", "/v1/metrics").endpoint, Endpoint::Metrics);
        assert_eq!(must_match("GET", "/v1/stats").endpoint, Endpoint::Stats);
        assert_eq!(must_match("GET", "/v1/ingest/status").endpoint, Endpoint::IngestStatus);
        assert_eq!(must_match("GET", "/v1/trace").endpoint, Endpoint::Trace);
        assert_eq!(must_match("POST", "/v1/embed").endpoint, Endpoint::Embed);
        assert_eq!(must_match("POST", "/v1/search").endpoint, Endpoint::Search);
        assert_eq!(must_match("POST", "/v1/corpus").endpoint, Endpoint::CorpusIngest);
        assert_eq!(
            must_match("POST", "/v1/corpus/snapshot").endpoint,
            Endpoint::CorpusSnapshot
        );
        for path in ["/v1/healthz", "/v1/metrics", "/v1/stats"] {
            assert!(!must_match("GET", path).deprecated, "{path}");
        }
    }

    #[test]
    fn deprecated_aliases_route_with_the_flag() {
        for (path, ep) in [
            ("/healthz", Endpoint::Healthz),
            ("/metrics", Endpoint::Metrics),
            ("/stats", Endpoint::Stats),
        ] {
            let m = must_match("GET", path);
            assert_eq!(m.endpoint, ep, "{path}");
            assert!(m.deprecated, "{path} must be flagged deprecated");
        }
    }

    #[test]
    fn typed_param_parses_once() {
        let m = must_match("DELETE", "/v1/corpus/42");
        assert_eq!(m.endpoint, Endpoint::CorpusDelete);
        assert_eq!(m.id, Some(42));
        assert_eq!(must_match("DELETE", "/v1/corpus/0").id, Some(0));
        assert_eq!(
            must_match("DELETE", &format!("/v1/corpus/{}", u64::MAX)).id,
            Some(u64::MAX)
        );
    }

    /// The bugfix satellite: trailing junk on the id is a typed-param
    /// failure (400 `invalid_id`), consistently — never a 404.
    #[test]
    fn bad_ids_are_bad_param_not_not_found() {
        for path in ["/v1/corpus/3junk", "/v1/corpus/not-a-number", "/v1/corpus/-1"] {
            match Router::route("DELETE", path) {
                RouteOutcome::BadParam { message } => {
                    assert!(message.contains("u64"), "{message}")
                }
                other => panic!("DELETE {path} → {other:?}"),
            }
        }
    }

    #[test]
    fn wrong_method_is_405_with_allow_union() {
        match Router::route("POST", "/v1/healthz") {
            RouteOutcome::MethodNotAllowed { allow } => assert_eq!(allow, "GET"),
            other => panic!("{other:?}"),
        }
        match Router::route("GET", "/v1/corpus/7") {
            RouteOutcome::MethodNotAllowed { allow } => assert_eq!(allow, "DELETE"),
            other => panic!("{other:?}"),
        }
        // /v1/corpus/snapshot shape-matches both the literal POST route
        // and DELETE /v1/corpus/{id}: Allow lists both methods.
        match Router::route("PUT", "/v1/corpus/snapshot") {
            RouteOutcome::MethodNotAllowed { allow } => {
                assert!(allow.contains("POST") && allow.contains("DELETE"), "{allow}")
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn literal_beats_param_on_ties() {
        // POST /v1/corpus/snapshot must hit the snapshot endpoint, not
        // be a bad {id}.
        assert_eq!(
            must_match("POST", "/v1/corpus/snapshot").endpoint,
            Endpoint::CorpusSnapshot
        );
    }

    #[test]
    fn unroutable_shapes_are_not_found() {
        for (method, path) in [
            ("GET", "/nope"),
            ("GET", "/"),
            ("GET", ""),
            ("GET", "/v1/healthz/"),
            ("GET", "//v1/healthz"),
            ("DELETE", "/v1/corpus/3/junk"),
            ("GET", "/v1"),
        ] {
            assert!(
                matches!(Router::route(method, path), RouteOutcome::NotFound),
                "{method} {path}"
            );
        }
    }
}
