//! The event-driven readiness loop (unix only): one reactor thread owns
//! every connection; a bounded worker pool runs handlers only.
//!
//! # Shape
//!
//! ```text
//!            epoll / poll(2)                 ThreadPool (handlers)
//!   ┌─────────────────────────────┐       ┌──────────────────────┐
//!   │ listener ── accept          │  job  │ dispatch_outcome(..) │
//!   │ conns ──── read → parse ────┼──────▶│ corpus_endpoint(..)  │
//!   │ timers ─── 408 / idle close │◀──────┤ (blocking, detached) │
//!   │ wake ───── worker messages  │  Msg  └──────────────────────┘
//!   └─────────────────────────────┘
//! ```
//!
//! Sockets are non-blocking; each connection's [`Conn`] incremental
//! state machine (`try_parse_head` + `decode_step`) is advanced on
//! readable events, so an open keep-alive connection costs one fd and
//! ~one buffer — never a thread. When a request's body completes, the
//! handler runs on the pool and posts its [`Response`] back over an
//! mpsc channel (plus one byte down the wake socketpair to interrupt
//! the poll); the reactor serializes and flushes it, buffering
//! partially-written responses behind writable-interest.
//!
//! **Timers** live in the [`TimerWheel`]: an idle timeout for
//! connections with no request in flight (silent close) and a
//! per-request wall-clock deadline armed at a request's first byte and
//! cleared when its body finishes decoding (408 + close — the
//! slow-loris guard, same semantics as the threaded fallback).
//! Cancellation is generation-based and lazy.
//!
//! **Streaming ingest detaches.** `POST /v1/corpus` must feed chunks
//! into the admission-controlled ingest pipeline with backpressure,
//! which is inherently blocking. After its head parses, the connection
//! is deregistered and handed (stream + buffered bytes, via
//! `Conn::into_parts`) to a pool worker that flips the socket back to
//! blocking, drives the proven blocking `corpus_endpoint` path, writes
//! the response itself, and re-attaches the connection for keep-alive
//! via [`Msg::Reattach`]. Everything else stays on the reactor.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::service::WindVE;
use crate::util::threadpool::ThreadPool;

use super::http::{self, BodyStep, Conn, Framing, Head, Response};
use super::router::{Endpoint, RouteOutcome, Router};
use super::timer::{Fired, TimerWheel};
use super::{ServerOptions, MAX_REQUESTS_PER_CONN};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Interest bits for the poller facade.
const READ: u8 = 1;
const WRITE: u8 = 2;

/// Cap on one poll wait, so the stop flag is observed even without a
/// wake byte and beyond-horizon timers keep cascading.
const MAX_POLL_WAIT: Duration = Duration::from_millis(500);

/// One readiness event, normalized across epoll and poll(2).
#[derive(Clone, Copy)]
struct PollEvent {
    token: u64,
    readable: bool,
    writable: bool,
    hangup: bool,
}

// ---------------------------------------------------------------------------
// Poller facade: epoll on Linux, poll(2) elsewhere.
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod poller {
    use super::PollEvent;
    use crate::util::sys;
    use std::io;

    pub(super) struct Poller {
        ep: sys::Epoll,
        buf: Vec<sys::EpollEvent>,
    }

    impl Poller {
        pub(super) fn new() -> io::Result<Poller> {
            Ok(Poller {
                ep: sys::Epoll::new()?,
                buf: vec![sys::EpollEvent { events: 0, data: 0 }; 256],
            })
        }

        fn mask(interest: u8) -> u32 {
            let mut m = 0;
            if interest & super::READ != 0 {
                m |= sys::EPOLLIN;
            }
            if interest & super::WRITE != 0 {
                m |= sys::EPOLLOUT;
            }
            m
        }

        pub(super) fn register(&mut self, fd: i32, token: u64, interest: u8) -> io::Result<()> {
            self.ep.ctl(sys::EPOLL_CTL_ADD, fd, Self::mask(interest), token)
        }

        pub(super) fn reregister(&mut self, fd: i32, token: u64, interest: u8) -> io::Result<()> {
            self.ep.ctl(sys::EPOLL_CTL_MOD, fd, Self::mask(interest), token)
        }

        pub(super) fn deregister(&mut self, fd: i32) {
            let _ = self.ep.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0);
        }

        pub(super) fn wait(&mut self, timeout_ms: i32, out: &mut Vec<PollEvent>) -> io::Result<()> {
            out.clear();
            let n = self.ep.wait(&mut self.buf, timeout_ms)?;
            for ev in &self.buf[..n] {
                // Braced copies: EpollEvent is packed on x86.
                let events = { ev.events };
                out.push(PollEvent {
                    token: { ev.data },
                    readable: events & sys::EPOLLIN != 0,
                    writable: events & sys::EPOLLOUT != 0,
                    hangup: events & (sys::EPOLLHUP | sys::EPOLLERR) != 0,
                });
            }
            Ok(())
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod poller {
    use super::PollEvent;
    use crate::util::sys;
    use std::collections::HashMap;
    use std::io;

    /// Portable fallback: the fd set is rebuilt for every `poll(2)`
    /// call. O(conns) per wait, which is fine at fallback scale.
    pub(super) struct Poller {
        fds: HashMap<u64, (i32, u8)>,
    }

    impl Poller {
        pub(super) fn new() -> io::Result<Poller> {
            Ok(Poller { fds: HashMap::new() })
        }

        pub(super) fn register(&mut self, fd: i32, token: u64, interest: u8) -> io::Result<()> {
            self.fds.insert(token, (fd, interest));
            Ok(())
        }

        pub(super) fn reregister(&mut self, fd: i32, token: u64, interest: u8) -> io::Result<()> {
            self.fds.insert(token, (fd, interest));
            Ok(())
        }

        pub(super) fn deregister(&mut self, fd: i32) {
            self.fds.retain(|_, (f, _)| *f != fd);
        }

        pub(super) fn wait(&mut self, timeout_ms: i32, out: &mut Vec<PollEvent>) -> io::Result<()> {
            out.clear();
            let mut tokens = Vec::with_capacity(self.fds.len());
            let mut pfds = Vec::with_capacity(self.fds.len());
            for (&token, &(fd, interest)) in &self.fds {
                let mut events = 0i16;
                if interest & super::READ != 0 {
                    events |= sys::POLLIN;
                }
                if interest & super::WRITE != 0 {
                    events |= sys::POLLOUT;
                }
                tokens.push(token);
                pfds.push(sys::PollFd { fd, events, revents: 0 });
            }
            sys::poll_fds(&mut pfds, timeout_ms)?;
            for (i, pfd) in pfds.iter().enumerate() {
                if pfd.revents == 0 {
                    continue;
                }
                out.push(PollEvent {
                    token: tokens[i],
                    readable: pfd.revents & sys::POLLIN != 0,
                    writable: pfd.revents & sys::POLLOUT != 0,
                    hangup: pfd.revents & (sys::POLLHUP | sys::POLLERR) != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Connection state.
// ---------------------------------------------------------------------------

/// Where a connection is in its request/response cycle.
enum Phase {
    /// Parsing (or waiting for) a request head.
    Head,
    /// Decoding the request body.
    Body { head: Head, outcome: RouteOutcome, framing: Framing, collected: Vec<u8> },
    /// Handler running on the pool; no socket interest.
    Await,
    /// Serialized response buffered in `out`, flushing.
    Flush,
}

struct ConnState {
    conn: Conn<TcpStream>,
    fd: i32,
    phase: Phase,
    /// Requests already completed on this connection.
    served: usize,
    /// Pending response bytes (write-side buffering) and flush cursor.
    out: Vec<u8>,
    out_pos: usize,
    close_after_flush: bool,
    /// Timer generation: bumping it lazily cancels any armed timer.
    gen: u64,
    /// Current poller interest (dedupes reregister syscalls).
    interest: u8,
    /// Armed request deadline (None while idle). Doubles as the timer
    /// kind discriminant when an entry fires: Some → 408, None → idle
    /// close.
    deadline_at: Option<Instant>,
    /// Respond-stage span in flight: (trace id, flush start). Set when a
    /// traced response enters the write buffer, recorded when the last
    /// byte flushes — so the span covers real socket time, not just
    /// serialization.
    pending_respond: Option<(u64, Instant)>,
}

/// Worker → reactor messages (paired with a wake byte).
enum Msg {
    /// A handler finished: serialize + flush on the owning connection.
    Response { token: u64, resp: Response, keep: bool, trace: u64 },
    /// A detached streaming-ingest connection coming back for
    /// keep-alive.
    Reattach { token: u64, conn: Conn<TcpStream>, served: usize, gen: u64 },
}

/// Handle returned by [`spawn`]: join on stop, wake to interrupt the
/// poll wait.
pub(super) struct ReactorHandle {
    pub(super) join: JoinHandle<()>,
    pub(super) wake_tx: Arc<TcpStream>,
}

/// Write one byte down the wake channel (best-effort: a full buffer
/// means wakes are already pending).
pub(super) fn wake(tx: &TcpStream) {
    let mut w = tx;
    let _ = w.write(&[1u8]);
}

/// A non-blocking loopback socketpair standing in for a pipe (no
/// `pipe2` FFI needed): `(rx, tx)`.
fn wake_pair() -> Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind("127.0.0.1:0").context("wake channel bind")?;
    let tx = TcpStream::connect(l.local_addr()?).context("wake channel connect")?;
    let (rx, _) = l.accept().context("wake channel accept")?;
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    let _ = tx.set_nodelay(true);
    Ok((rx, tx))
}

fn drain_wake(rx: &TcpStream) {
    let mut buf = [0u8; 256];
    let mut r = rx;
    loop {
        match r.read(&mut buf) {
            Ok(0) => return,
            Ok(_) => continue,
            Err(_) => return, // WouldBlock: drained
        }
    }
}

/// Bind the reactor onto `listener` and run it on its own thread.
pub(super) fn spawn(
    listener: TcpListener,
    svc: Arc<WindVE>,
    opts: ServerOptions,
    stop: Arc<AtomicBool>,
) -> Result<ReactorHandle> {
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let (wake_rx, wake_tx) = wake_pair()?;
    let wake_tx = Arc::new(wake_tx);
    let mut poller = poller::Poller::new().context("create poller")?;
    poller
        .register(listener.as_raw_fd(), TOKEN_LISTENER, READ)
        .context("register listener")?;
    poller
        .register(wake_rx.as_raw_fd(), TOKEN_WAKE, READ)
        .context("register wake channel")?;
    let (msg_tx, msg_rx) = mpsc::channel();
    let wake_for_loop = Arc::clone(&wake_tx);
    let join = std::thread::Builder::new()
        .name("windve-reactor".into())
        .spawn(move || {
            let mut r = Reactor {
                poller,
                conns: HashMap::new(),
                wheel: TimerWheel::new(Instant::now()),
                svc,
                slo: opts.slo,
                request_deadline: opts.request_deadline,
                idle_timeout: opts.idle_timeout,
                pool: ThreadPool::new(opts.handler_workers.max(1)),
                msg_tx,
                msg_rx,
                wake_tx: wake_for_loop,
                next_token: FIRST_CONN_TOKEN,
            };
            r.run(&listener, &wake_rx, &stop);
        })
        .context("spawn reactor thread")?;
    Ok(ReactorHandle { join, wake_tx })
}

struct Reactor {
    poller: poller::Poller,
    conns: HashMap<u64, ConnState>,
    wheel: TimerWheel,
    svc: Arc<WindVE>,
    slo: Duration,
    request_deadline: Duration,
    idle_timeout: Duration,
    pool: ThreadPool,
    msg_tx: mpsc::Sender<Msg>,
    msg_rx: mpsc::Receiver<Msg>,
    wake_tx: Arc<TcpStream>,
    next_token: u64,
}

impl Reactor {
    fn run(&mut self, listener: &TcpListener, wake_rx: &TcpStream, stop: &AtomicBool) {
        let mut events: Vec<PollEvent> = Vec::with_capacity(256);
        loop {
            if stop.load(Ordering::Acquire) {
                return;
            }
            for f in self.wheel.expire(Instant::now()) {
                self.on_timer(f);
            }
            while let Ok(m) = self.msg_rx.try_recv() {
                self.on_msg(m);
            }
            if stop.load(Ordering::Acquire) {
                return;
            }
            let timeout = self
                .wheel
                .next_deadline()
                .map(|at| at.saturating_duration_since(Instant::now()))
                .unwrap_or(MAX_POLL_WAIT)
                .min(MAX_POLL_WAIT);
            // +1ms rounds sub-millisecond remainders up instead of
            // busy-spinning a 0ms poll until the deadline lands.
            let ms = timeout.as_millis() as i32 + 1;
            if self.poller.wait(ms, &mut events).is_err() {
                continue;
            }
            while let Some(ev) = events.pop() {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(listener),
                    TOKEN_WAKE => drain_wake(wake_rx),
                    _ => self.conn_event(ev),
                }
            }
            while let Ok(m) = self.msg_rx.try_recv() {
                self.on_msg(m);
            }
        }
    }

    // -- accept ------------------------------------------------------------

    fn accept_ready(&mut self, listener: &TcpListener) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let fd = stream.as_raw_fd();
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.register(fd, token, READ).is_err() {
                        continue; // dropping the stream closes it
                    }
                    self.conns.insert(
                        token,
                        ConnState {
                            conn: Conn::new(stream),
                            fd,
                            phase: Phase::Head,
                            served: 0,
                            out: Vec::new(),
                            out_pos: 0,
                            close_after_flush: false,
                            gen: 0,
                            interest: READ,
                            deadline_at: None,
                            pending_respond: None,
                        },
                    );
                    self.arm_idle(token);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("accept error: {e}");
                    return;
                }
            }
        }
    }

    // -- timers ------------------------------------------------------------

    fn arm_request(&mut self, token: u64) {
        let at = Instant::now() + self.request_deadline;
        if let Some(st) = self.conns.get_mut(&token) {
            st.gen += 1;
            st.deadline_at = Some(at);
            let gen = st.gen;
            self.wheel.insert(at, token, gen);
        }
    }

    fn arm_idle(&mut self, token: u64) {
        let at = Instant::now() + self.idle_timeout;
        if let Some(st) = self.conns.get_mut(&token) {
            st.gen += 1;
            st.deadline_at = None;
            let gen = st.gen;
            self.wheel.insert(at, token, gen);
        }
    }

    fn on_timer(&mut self, f: Fired) {
        let is_request = match self.conns.get(&f.token) {
            Some(st)
                if st.gen == f.gen && matches!(st.phase, Phase::Head | Phase::Body { .. }) =>
            {
                st.deadline_at.is_some()
            }
            _ => return, // stale generation or phase: lazily cancelled
        };
        if is_request {
            // Slow-loris trip: same 408-and-close as the threaded path.
            self.respond_close(f.token, Response::request_timeout());
        } else {
            self.close(f.token); // idle keep-alive: silent close
        }
    }

    // -- socket events -----------------------------------------------------

    fn conn_event(&mut self, ev: PollEvent) {
        if ev.hangup {
            self.close(ev.token);
            return;
        }
        if ev.writable {
            self.flush(ev.token);
        }
        if ev.readable {
            self.readable(ev.token);
        }
    }

    fn readable(&mut self, token: u64) {
        loop {
            let st = match self.conns.get_mut(&token) {
                Some(s) => s,
                None => return,
            };
            if !matches!(st.phase, Phase::Head | Phase::Body { .. }) {
                return; // Await/Flush: nothing to read into
            }
            match st.conn.fill_once() {
                Ok(0) => {
                    self.on_eof(token);
                    return;
                }
                Ok(_) => {
                    // First byte of a request moves idle → on-the-clock.
                    let armed = self
                        .conns
                        .get(&token)
                        .is_some_and(|s| s.deadline_at.is_some());
                    if !armed {
                        self.arm_request(token);
                    }
                    self.advance(token);
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    return
                }
                Err(_) => {
                    self.close(token);
                    return;
                }
            }
        }
    }

    fn on_eof(&mut self, token: u64) {
        let clean = match self.conns.get(&token) {
            Some(st) => matches!(st.phase, Phase::Head) && st.conn.buffered() == 0,
            None => return,
        };
        if clean {
            self.close(token); // peer closed an idle keep-alive conn
        } else {
            self.respond_close(token, Response::bad_request("connection closed mid-request"));
        }
    }

    /// Drive the parse as far as buffered bytes allow, transitioning
    /// Head → Body → dispatch.
    fn advance(&mut self, token: u64) {
        loop {
            let st = match self.conns.get_mut(&token) {
                Some(s) => s,
                None => return,
            };
            if matches!(st.phase, Phase::Head) {
                match st.conn.try_parse_head() {
                    Err(e) => {
                        let msg = format!("{e:#}");
                        self.respond_close(token, Response::bad_request(&msg));
                        return;
                    }
                    Ok(None) => return,
                    Ok(Some(head)) => {
                        let outcome = Router::route(&head.method, &head.path);
                        if matches!(&outcome, RouteOutcome::Match(m) if m.endpoint == Endpoint::CorpusIngest)
                        {
                            self.detach_for_ingest(token, head);
                            return;
                        }
                        let framing = match Framing::for_head(&head) {
                            Ok(f) => f,
                            Err(e) => {
                                let msg = format!("{e:#}");
                                self.respond_close(token, Response::bad_request(&msg));
                                return;
                            }
                        };
                        // Pre-announced oversize body: 413 without
                        // reading it (mirrors read_body_string).
                        if let Ok(Some(n)) = head.content_length() {
                            if !head.chunked() && n > http::MAX_BODY {
                                self.respond_close(
                                    token,
                                    Response::payload_too_large(&format!(
                                        "body too large ({n} bytes)"
                                    )),
                                );
                                return;
                            }
                        }
                        // Re-borrow after the framing checks; the entry
                        // can only have vanished if an error path above
                        // already closed the connection, in which case
                        // there is nothing left to advance.
                        let Some(st) = self.conns.get_mut(&token) else {
                            return;
                        };
                        st.phase =
                            Phase::Body { head, outcome, framing, collected: Vec::new() };
                        continue;
                    }
                }
            }

            enum Step {
                NeedMore,
                Done,
                Failed(String),
                TooLarge(usize),
            }
            let step = {
                let st = match self.conns.get_mut(&token) {
                    Some(s) => s,
                    None => return,
                };
                match &mut st.phase {
                    Phase::Body { framing, collected, .. } => loop {
                        match st.conn.decode_step(framing) {
                            Err(e) => break Step::Failed(format!("{e:#}")),
                            Ok(BodyStep::NeedMore) => break Step::NeedMore,
                            Ok(BodyStep::Done) => break Step::Done,
                            Ok(BodyStep::Chunk(c)) => {
                                collected.extend_from_slice(&c);
                                if collected.len() > http::MAX_BODY {
                                    break Step::TooLarge(collected.len());
                                }
                            }
                        }
                    },
                    _ => return,
                }
            };
            match step {
                Step::NeedMore => return,
                Step::Failed(msg) => {
                    self.respond_close(token, Response::bad_request(&msg));
                    return;
                }
                Step::TooLarge(n) => {
                    self.respond_close(
                        token,
                        Response::payload_too_large(&format!("body too large ({n} bytes)")),
                    );
                    return;
                }
                Step::Done => {
                    self.dispatch(token);
                    return;
                }
            }
        }
    }

    // -- handler dispatch --------------------------------------------------

    /// Body fully decoded: hand the request to the worker pool and park
    /// the connection (no socket interest) until the response message.
    fn dispatch(&mut self, token: u64) {
        let st = match self.conns.get_mut(&token) {
            Some(s) => s,
            None => return,
        };
        let phase = std::mem::replace(&mut st.phase, Phase::Await);
        let (head, outcome, body) = match phase {
            Phase::Body { head, outcome, collected, .. } => (head, outcome, collected),
            other => {
                st.phase = other;
                return;
            }
        };
        // The request deadline covers head + body, not handler latency
        // (handlers bound their own waits) — matches the threaded path.
        st.gen += 1;
        st.deadline_at = None;
        let fd = st.fd;
        let served = st.served;
        st.interest = 0;
        let _ = self.poller.reregister(fd, token, 0);
        let keep = head.wants_keep_alive() && served + 1 < MAX_REQUESTS_PER_CONN;
        // Mint the trace on the reactor thread (accept-side), same as the
        // threaded server: queue_wait measured by workers starts from a
        // request that already owns its ID.
        let ctx = super::ReqCtx::new(&self.svc, &head);
        let svc = Arc::clone(&self.svc);
        let slo = self.slo;
        let tx = self.msg_tx.clone();
        let wk = Arc::clone(&self.wake_tx);
        self.pool.execute(move || {
            let resp = match String::from_utf8(body) {
                Ok(s) => super::dispatch_outcome(&outcome, &s, &svc, slo, &ctx),
                Err(e) => Response::bad_request(&e.to_string()),
            };
            let _ = tx.send(Msg::Response { token, resp, keep, trace: ctx.trace });
            wake(&wk);
        });
    }

    /// `POST /v1/corpus`: deregister and hand the connection to a
    /// blocking worker (see module docs).
    fn detach_for_ingest(&mut self, token: u64, head: Head) {
        let mut st = match self.conns.remove(&token) {
            Some(s) => s,
            None => return,
        };
        self.poller.deregister(st.fd);
        st.gen += 1; // lazily cancel the armed request timer
        let gen = st.gen;
        let served = st.served;
        let deadline_at =
            st.deadline_at.unwrap_or_else(|| Instant::now() + self.request_deadline);
        let (stream, buf) = st.conn.into_parts();
        let svc = Arc::clone(&self.svc);
        let tx = self.msg_tx.clone();
        let wk = Arc::clone(&self.wake_tx);
        let read_timeout = Duration::from_secs(10).min(self.request_deadline);
        self.pool.execute(move || {
            if stream.set_nonblocking(false).is_err() {
                return; // conn drops → closed
            }
            let _ = stream.set_read_timeout(Some(read_timeout));
            let mut conn = Conn::from_parts(stream, buf);
            // Carry over whatever budget the request has already spent.
            conn.arm_deadline_at(deadline_at);
            let (resp, body_ok) = super::corpus_endpoint(&mut conn, &head, &svc);
            let resp =
                if conn.deadline_exceeded() { Response::request_timeout() } else { resp };
            let keep = head.wants_keep_alive()
                && served + 1 < MAX_REQUESTS_PER_CONN
                && body_ok
                && !conn.deadline_exceeded();
            if conn.stream_mut().write_all(resp.serialize_with(keep).as_bytes()).is_err() {
                return;
            }
            if !keep {
                return;
            }
            conn.finish_request();
            if conn.stream_mut().set_nonblocking(true).is_err() {
                return;
            }
            let _ = tx.send(Msg::Reattach { token, conn, served: served + 1, gen });
            wake(&wk);
        });
    }

    // -- worker messages ---------------------------------------------------

    fn on_msg(&mut self, msg: Msg) {
        match msg {
            Msg::Response { token, resp, keep, trace } => {
                let st = match self.conns.get_mut(&token) {
                    Some(s) => s,
                    None => return, // conn died while the handler ran
                };
                if !matches!(st.phase, Phase::Await) {
                    return;
                }
                st.out = resp.serialize_with(keep).into_bytes();
                st.out_pos = 0;
                st.close_after_flush = !keep;
                st.phase = Phase::Flush;
                st.pending_respond = (trace != 0).then(|| (trace, Instant::now()));
                self.flush(token);
            }
            Msg::Reattach { token, conn, served, gen } => {
                self.reattach(token, conn, served, gen)
            }
        }
    }

    fn reattach(&mut self, token: u64, mut conn: Conn<TcpStream>, served: usize, gen: u64) {
        let fd = conn.stream_mut().as_raw_fd();
        if self.poller.register(fd, token, READ).is_err() {
            return; // dropping the conn closes it
        }
        let pipelined = conn.buffered() > 0;
        self.conns.insert(
            token,
            ConnState {
                conn,
                fd,
                phase: Phase::Head,
                served,
                out: Vec::new(),
                out_pos: 0,
                close_after_flush: false,
                // Continue the pre-detach generation: stale wheel
                // entries from before the detach must not match.
                gen,
                interest: READ,
                deadline_at: None,
                pending_respond: None,
            },
        );
        if pipelined {
            self.arm_request(token);
            self.advance(token);
        } else {
            self.arm_idle(token);
        }
    }

    // -- responses ---------------------------------------------------------

    /// Buffer an error response and close once it flushes.
    fn respond_close(&mut self, token: u64, resp: Response) {
        let st = match self.conns.get_mut(&token) {
            Some(s) => s,
            None => return,
        };
        st.gen += 1;
        st.deadline_at = None;
        st.out = resp.serialize_with(false).into_bytes();
        st.out_pos = 0;
        st.close_after_flush = true;
        st.phase = Phase::Flush;
        self.flush(token);
    }

    fn flush(&mut self, token: u64) {
        enum FlushResult {
            Done,
            Blocked,
            Gone,
        }
        let res = {
            let st = match self.conns.get_mut(&token) {
                Some(s) => s,
                None => return,
            };
            if !matches!(st.phase, Phase::Flush) {
                return;
            }
            loop {
                if st.out_pos >= st.out.len() {
                    break FlushResult::Done;
                }
                match st.conn.stream_mut().write(&st.out[st.out_pos..]) {
                    Ok(0) => break FlushResult::Gone,
                    Ok(n) => st.out_pos += n,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        break FlushResult::Blocked
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break FlushResult::Gone,
                }
            }
        };
        match res {
            FlushResult::Gone => self.close(token),
            FlushResult::Blocked => {
                if let Some(st) = self.conns.get_mut(&token) {
                    if st.interest != WRITE {
                        st.interest = WRITE;
                        let fd = st.fd;
                        let _ = self.poller.reregister(fd, token, WRITE);
                    }
                }
            }
            FlushResult::Done => self.finish_response(token),
        }
    }

    /// A response fully flushed: close, or rotate back to Head and
    /// immediately drive any pipelined request already buffered.
    fn finish_response(&mut self, token: u64) {
        let (close, pending) = match self.conns.get_mut(&token) {
            Some(st) => {
                st.out = Vec::new();
                st.out_pos = 0;
                (st.close_after_flush, st.pending_respond.take())
            }
            None => return,
        };
        if let Some((trace, t0)) = pending {
            super::record_respond(&self.svc, trace, t0);
        }
        if close {
            self.close(token);
            return;
        }
        // Re-borrow after the flush bookkeeping above; a vanished entry
        // means the connection was closed concurrently — nothing to arm.
        let Some(st) = self.conns.get_mut(&token) else {
            return;
        };
        st.served += 1;
        st.phase = Phase::Head;
        st.conn.finish_request();
        let fd = st.fd;
        let pipelined = st.conn.buffered() > 0;
        if st.interest != READ {
            st.interest = READ;
            let _ = self.poller.reregister(fd, token, READ);
        }
        if pipelined {
            self.arm_request(token);
            self.advance(token);
        } else {
            self.arm_idle(token);
        }
    }

    fn close(&mut self, token: u64) {
        if let Some(st) = self.conns.remove(&token) {
            self.poller.deregister(st.fd);
            // st.conn drops here → close(2)
        }
    }
}
